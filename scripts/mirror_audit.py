#!/usr/bin/env python3
"""Development mirror of the `vla-char audit` static-analysis pass.

This is a line-for-line Python port of `rust/src/analysis/` (the scan
primitives and rules A1-A6), kept so the audit's verdict can be
cross-checked without a Rust toolchain — e.g. from a docs-only environment
or while prototyping a new rule. The Rust implementation is the source of
truth and the CI gate; if the two disagree, fix the mirror.

Usage: mirror_audit.py [REPO_ROOT]     exit 0 when clean, 1 with
                                       diagnostics listed on stdout
"""

import sys
from pathlib import Path

# ---------------------------------------------------------------- scan


def is_word_byte(c):
    return c.isalnum() and c.isascii() or c == "_"


def is_key_byte(c):
    return (c.islower() or c.isdigit()) and c.isascii() or c in "_-"


def strip_comment(line):
    i, n, in_str = 0, len(line), False
    while i < n:
        c = line[i]
        if in_str:
            if c == "\\":
                i += 2
            else:
                in_str = c != '"'
                i += 1
            continue
        if c == '"':
            in_str = True
            i += 1
        elif c == "'" and i + 3 < n and line[i + 1] == "\\" and line[i + 3] == "'":
            i += 4
        elif c == "'" and i + 2 < n and line[i + 2] == "'":
            i += 3
        elif c == "/" and i + 1 < n and line[i + 1] == "/":
            return line[:i]
        else:
            i += 1
    return line


def blank_strings(line):
    stripped = strip_comment(line)
    out, in_str, i, n = [], False, 0, len(stripped)
    while i < n:
        c = stripped[i]
        if in_str:
            if c == "\\":
                out.append(" ")
                if i + 1 < n:
                    out.append(" ")
                i += 2
                continue
            if c == '"':
                in_str = False
                out.append(c)
            else:
                out.append(" ")
        else:
            if c == '"':
                in_str = True
            out.append(c)
        i += 1
    return "".join(out)


def rust_lines(text):
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    return lines


def code_view(text):
    return "".join(strip_comment(line) + "\n" for line in rust_lines(text))


def find_word_from(text, word, start):
    if not word or start > len(text):
        return None
    while True:
        pos = text.find(word, start)
        if pos < 0:
            return None
        end = pos + len(word)
        left_ok = pos == 0 or not is_word_byte(text[pos - 1])
        right_ok = end == len(text) or not is_word_byte(text[end])
        if left_ok and right_ok:
            return pos
        start = pos + 1


def contains_word(text, word):
    return find_word_from(text, word, 0) is not None


def contains_field_access(body, field):
    at = find_word_from(body, field, 0)
    while at is not None:
        if at > 0 and body[at - 1] == ".":
            return True
        at = find_word_from(body, field, at + 1)
    return False


def line_of_offset(text, at):
    return text.count("\n", 0, min(at, len(text))) + 1


def string_literals(text):
    out = []
    for i, raw in enumerate(rust_lines(text)):
        line = strip_comment(raw)
        j, n = 0, len(line)
        while j < n:
            if line[j] == '"':
                lit = []
                j += 1
                while j < n and line[j] != '"':
                    if line[j] == "\\" and j + 1 < n:
                        j += 1
                    lit.append(line[j])
                    j += 1
                out.append((i + 1, "".join(lit)))
            j += 1
    return out


def block_at(code, start, open_c, close_c):
    i, depth, inner_start, in_str, n = start, 0, 0, False, len(code)
    while i < n:
        c = code[i]
        if in_str:
            if c == "\\":
                i += 2
                continue
            in_str = c != '"'
            i += 1
            continue
        if c == '"':
            in_str = True
        elif c == open_c:
            depth += 1
            if depth == 1:
                inner_start = i + 1
        elif c == close_c:
            if depth == 0:
                return None
            depth -= 1
            if depth == 0:
                return (line_of_offset(code, inner_start), code[inner_start:i])
        i += 1
    return None


def delim_block(text, anchor, open_c, close_c):
    code = code_view(text)
    at = find_word_from(code, anchor, 0)
    if at is None:
        return None
    blk = block_at(code, at, open_c, close_c)
    if blk is None:
        return None
    return (line_of_offset(code, at), blk[1])


def delim_blocks(text, anchor, open_c, close_c):
    code = code_view(text)
    out, frm = [], 0
    while True:
        at = find_word_from(code, anchor, frm)
        if at is None:
            return out
        blk = block_at(code, at, open_c, close_c)
        if blk is not None:
            out.append((line_of_offset(code, at), blk[1]))
        frm = at + 1


def field_name(line):
    for p in ("pub(crate) ", "pub(super) ", "pub "):
        if line.startswith(p):
            line = line[len(p):]
            break
    colon = line.find(":")
    if colon < 0:
        return None
    ident = line[:colon].strip()
    if ident and all(is_word_byte(c) for c in ident) and not ident[0].isdigit():
        return ident
    return None


def struct_fields(text, name):
    blk = delim_block(text, f"struct {name}", "{", "}")
    if blk is None:
        return None
    anchor_line, inner = blk
    fields, depth = [], 0
    for k, raw in enumerate(inner.split("\n")):
        line = raw.strip()
        if depth == 0 and not line.startswith("#["):
            f = field_name(line)
            if f is not None:
                fields.append((f, anchor_line + k))
        depth = max(0, depth + sum(raw.count(c) for c in "{(") - sum(raw.count(c) for c in "})"))
    return (anchor_line, fields)


def paren_keys(text):
    code = code_view(text)
    out, i, in_str, n = [], 0, False, len(code)
    while i < n:
        c = code[i]
        if in_str:
            if c == "\\":
                i += 2
                continue
            in_str = c != '"'
            i += 1
            continue
        if c == '"':
            in_str = True
            i += 1
            continue
        if c != "(":
            i += 1
            continue
        line = line_of_offset(code, i)
        j = i + 1
        while j < n and code[j].isspace():
            j += 1
        if j >= n or code[j] != '"':
            i += 1
            continue
        lit_start = j + 1
        k = lit_start
        while k < n and code[k] not in '"\\\n':
            k += 1
        if k >= n or code[k] != '"':
            i += 1
            continue
        key = code[lit_start:k]
        m = k + 1
        while m < n and code[m].isspace():
            m += 1
        if m < n and code[m] == "," and key and all(is_key_byte(c) for c in key):
            out.append((line, key))
        i = k + 1
    return out


def backticked(line):
    parts = line.split("`")
    return parts[1::2]


def int_after(text, anchor):
    code = code_view(text)
    at = code.find(anchor)
    if at < 0:
        return None
    rest = code[at + len(anchor):]
    skipped = 0
    for c in rest:
        if c.isdigit():
            break
        skipped += 1
    digits = []
    for c in rest[skipped:]:
        if c.isdigit():
            digits.append(c)
        elif c != "_":
            break
    if not digits or skipped > 80:
        return None
    return (line_of_offset(code, at), int("".join(digits)))


# ---------------------------------------------------------------- tree


EXTRAS = [
    "README.md",
    "docs/ARCHITECTURE.md",
    "docs/TELEMETRY.md",
    "docs/ANALYSIS.md",
    "scripts/check_bench.py",
    "scripts/check_events.py",
    "scripts/ci.sh",
    ".github/workflows/ci.yml",
    "BENCH_sim.json",
    "BENCH_fleet.json",
]


def load_tree(root):
    tree = {}
    for rel in ("rust/src", "rust/tests", "rust/benches", "examples"):
        base = root / rel
        if base.is_dir():
            for p in sorted(base.rglob("*.rs")):
                tree[p.relative_to(root).as_posix()] = p.read_text()
    for extra in EXTRAS:
        p = root / extra
        if p.is_file():
            tree[extra] = p.read_text()
    return tree


def files_under(tree, prefix):
    return [(p, tree[p]) for p in sorted(tree) if p.startswith(prefix)]


def rust_src(tree):
    return [(p, c) for p, c in files_under(tree, "rust/src/") if p.endswith(".rs")]


def diag(out, rule, file, line, message):
    out.append((rule, file, line, message))


def missing_file(out, rule, file):
    diag(out, rule, file, 1, f"required file `{file}` is missing from the tree")


# ---------------------------------------------------------------- A1


A1_CACHE = "rust/src/sim/scenario/cache.rs"
A1_TARGETS = [
    ("SimOptions", "rust/src/sim/simulator.rs"),
    ("VlaConfig", "rust/src/model/vla.rs"),
    ("DecoderConfig", "rust/src/model/vla.rs"),
    ("WorkloadShape", "rust/src/model/vla.rs"),
]


def run_a1(tree):
    out = []
    cache = tree.get(A1_CACHE)
    if cache is None:
        missing_file(out, "A1", A1_CACHE)
        return out
    for name, def_file in A1_TARGETS:
        text = tree.get(def_file)
        if text is None:
            missing_file(out, "A1", def_file)
            continue
        sf = struct_fields(text, name)
        if sf is None:
            diag(out, "A1", def_file, 1,
                 f"struct `{name}` not found (fingerprint target of {A1_CACHE})")
            continue
        fields = sf[1]
        blocks = delim_blocks(cache, name, "{", "}")
        if not blocks:
            diag(out, "A1", A1_CACHE, 1,
                 f"no `{name} {{ .. }}` destructuring in the lowering cache")
            continue
        best = min(
            ((line, [f for f in fields if not contains_word(inner, f[0])])
             for line, inner in blocks),
            key=lambda x: len(x[1]),
        )
        for fname, fline in best[1]:
            diag(out, "A1", A1_CACHE, best[0],
                 f"field `{name}.{fname}` ({def_file}:{fline}) is not covered by the "
                 f"`{name}` destructuring — the cache could alias two configs that "
                 "differ in it")
    return out


# ---------------------------------------------------------------- A2


A2_COMPARISONS = [
    ("ScenarioResult", "rust/src/sim/scenario/eval.rs",
     "rust/tests/scenario_tests.rs", "result_bits"),
    ("FleetReport", "rust/src/sim/fleet/sim.rs", "rust/tests/fleet_tests.rs", "fingerprint"),
    ("FleetReport", "rust/src/sim/fleet/sim.rs", "rust/src/telemetry/replay.rs",
     "report_mismatch"),
]
A2_TELEMETRY_TESTS = "rust/tests/telemetry_tests.rs"


def run_a2(tree):
    out = []
    for name, def_file, cmp_file, cmp_fn in A2_COMPARISONS:
        d, c = tree.get(def_file), tree.get(cmp_file)
        if d is None:
            missing_file(out, "A2", def_file)
            continue
        if c is None:
            missing_file(out, "A2", cmp_file)
            continue
        sf = struct_fields(d, name)
        if sf is None:
            diag(out, "A2", def_file, 1,
                 f"struct `{name}` not found (compared by {cmp_file}::{cmp_fn})")
            continue
        blk = delim_block(c, f"fn {cmp_fn}", "{", "}")
        if blk is None:
            diag(out, "A2", cmp_file, 1,
                 f"comparison fn `{cmp_fn}` not found (must reduce `{name}` bit-exactly)")
            continue
        line, body = blk
        for fname, fline in sf[1]:
            if not contains_field_access(body, fname):
                diag(out, "A2", cmp_file, line,
                     f"`{name}.{fname}` ({def_file}:{fline}) is not read by `{cmp_fn}` "
                     "— the bitwise pin would not notice it diverging")
    tt = tree.get(A2_TELEMETRY_TESTS)
    if tt is None:
        missing_file(out, "A2", A2_TELEMETRY_TESTS)
    elif not contains_word(tt, "report_mismatch"):
        diag(out, "A2", A2_TELEMETRY_TESTS, 1,
             "telemetry tests must compare reports through `report_mismatch` (the "
             "field-complete comparator), not an ad-hoc tuple")
    return out


# ---------------------------------------------------------------- A3


A3_MOD = "rust/src/experiment/mod.rs"
A3_CLI = "rust/src/cli/mod.rs"
A3_TESTS = "rust/tests/experiment_tests.rs"
A3_README = "README.md"
A3_ARCH = "docs/ARCHITECTURE.md"


def a3_registry_idents(tree, out):
    mod_rs = tree.get(A3_MOD)
    if mod_rs is None:
        missing_file(out, "A3", A3_MOD)
        return None
    code = code_view(mod_rs)
    at = find_word_from(code, "static REGISTRY", 0)
    blk = None
    if at is not None:
        eq = code.find("=", at)
        if eq >= 0:
            blk = block_at(code, eq, "[", "]")
    if blk is None:
        diag(out, "A3", A3_MOD, 1, "no `static REGISTRY` list found")
        return None
    line, inner = blk
    idents = []
    for k, raw in enumerate(inner.split("\n")):
        rest = raw.strip()
        while "&" in rest:
            rest = rest[rest.index("&") + 1:]
            ident = ""
            for ch in rest:
                if ch.isascii() and ch.isalnum() or ch == "_":
                    ident += ch
                else:
                    break
            if ident:
                idents.append((ident, line + k))
    if not idents:
        diag(out, "A3", A3_MOD, line, "REGISTRY list parsed empty")
        return None
    return idents


def a3_experiment_impls(tree):
    impls = {}
    for path, text in files_under(tree, "rust/src/experiment/"):
        if not path.endswith(".rs"):
            continue
        for line, body in delim_blocks(text, "impl Experiment for", "{", "}"):
            raw = text.split("\n")[line - 1]
            code = strip_comment(raw)
            after = code.split("impl Experiment for", 1)
            if len(after) < 2:
                continue
            rest = after[1].lstrip()
            ident = ""
            for ch in rest:
                if ch.isascii() and ch.isalnum() or ch == "_":
                    ident += ch
                else:
                    break
            if not ident:
                continue

            def first_lit(anchor):
                blk = delim_block(body, anchor, "{", "}")
                if blk is None:
                    return ""
                lits = string_literals(blk[1])
                return lits[0][1] if lits else ""

            impls[ident] = (first_lit("fn name"), first_lit("fn description"), path, line)
    return impls


def a3_cli_extras(tree, out):
    cli = tree.get(A3_CLI)
    if cli is None:
        missing_file(out, "A3", A3_CLI)
        return set()
    code = code_view(cli)
    at = find_word_from(code, "EXTRA_SUBCOMMANDS", 0)
    blk = None
    if at is not None:
        eq = code.find("=", at)
        if eq >= 0:
            blk = block_at(code, eq, "[", "]")
    if blk is None:
        diag(out, "A3", A3_CLI, 1, "no EXTRA_SUBCOMMANDS table found")
        return set()
    return {k for _, k in paren_keys(blk[1])}


def run_a3(tree):
    out = []
    idents = a3_registry_idents(tree, out)
    if idents is None:
        return out
    impls = a3_experiment_impls(tree)
    names = []
    for ident, line in idents:
        imp = impls.get(ident)
        if imp is None:
            diag(out, "A3", A3_MOD, line,
                 f"registry entry `&{ident}` has no `impl Experiment` with a parsed name")
            continue
        name, desc, file, iline = imp
        if not name:
            diag(out, "A3", file, iline, f"experiment `{ident}` has an empty name()")
        if not desc:
            diag(out, "A3", file, iline, f"experiment `{ident}` has an empty description()")
        names.append(name)
    seen = set()
    for n in names:
        if n in seen:
            diag(out, "A3", A3_MOD, 1, f"duplicate experiment name `{n}` in the registry")
        seen.add(n)
    extras = a3_cli_extras(tree, out)

    readme = tree.get(A3_README)
    if readme is None:
        missing_file(out, "A3", A3_README)
    else:
        rows, table_line = {}, 1
        for i, line in enumerate(readme.split("\n")):
            if line.startswith("| Subcommand"):
                table_line = i + 1
            if not line.startswith("| `"):
                continue
            cells = line.split("|")
            if len(cells) < 2:
                continue
            for tok in backticked(cells[1]):
                rows.setdefault(tok, i + 1)
        for name in names:
            if name not in rows:
                diag(out, "A3", A3_README, table_line,
                     f"experiment `{name}` is missing from the README subcommand table")
        for tok, line in sorted(rows.items()):
            if tok not in names and tok not in extras:
                diag(out, "A3", A3_README, line,
                     f"`{tok}` in the README subcommand table is not a CLI subcommand")

    tests = tree.get(A3_TESTS)
    if tests is None:
        missing_file(out, "A3", A3_TESTS)
    else:
        blk = delim_block(tests, "fn registry_covers_every_subcommand", "{", "}")
        if blk is None:
            diag(out, "A3", A3_TESTS, 1, "no registry completeness test found")
        else:
            line, body = blk
            wants = {
                s for _, s in string_literals(body)
                if s and all(c.isascii() and (c.islower() or c.isdigit()) or c == "-" for c in s)
            }
            for name in names:
                if name not in wants:
                    diag(out, "A3", A3_TESTS, line,
                         f"`{name}` is missing from the registry completeness want-list")
            cnt = int_after(tests, "names.len(),")
            if cnt is None:
                diag(out, "A3", A3_TESTS, line,
                     "no `names.len()` count assertion in the completeness test")
            elif cnt[1] != len(names):
                diag(out, "A3", A3_TESTS, cnt[0],
                     f"registry count assertion says {cnt[1]} but the registry has "
                     f"{len(names)}")

    arch = tree.get(A3_ARCH)
    if arch is None:
        missing_file(out, "A3", A3_ARCH)
        return out
    entries, map_line = {}, 1
    for i, line in enumerate(arch.split("\n")):
        at = line.find("── ")
        if at < 0:
            continue
        if not entries:
            map_line = i + 1
        tok = ""
        for ch in line[at + 3:]:
            if ch.isspace():
                break
            tok += ch
        entries.setdefault(tok, i + 1)
    top_dirs = set()
    for p, _ in files_under(tree, "rust/src/"):
        rest = p[len("rust/src/"):]
        if "/" in rest:
            first, remainder = rest.split("/", 1)
            if remainder:
                top_dirs.add(first)
    for d in sorted(top_dirs):
        if f"{d}/" not in entries:
            diag(out, "A3", A3_ARCH, map_line,
                 f"module `rust/src/{d}/` is missing from the module map")
    for tok, line in sorted(entries.items()):
        if tok.endswith("/"):
            dirname = tok[:-1]
            exists = any(
                p.startswith("rust/src/") and dirname in p.split("/") and not p.endswith(dirname)
                for p in tree
            )
            if not exists:
                diag(out, "A3", A3_ARCH, line,
                     f"`{tok}` in the module map does not exist under rust/src/")
        elif tok.endswith(".rs"):
            suffix = f"/{tok}"
            if not any(p.startswith("rust/src/") and p.endswith(suffix) for p in tree):
                diag(out, "A3", A3_ARCH, line,
                     f"`{tok}` in the module map does not exist under rust/src/")
    return out


# ---------------------------------------------------------------- A4


A4_TEL = "rust/src/telemetry/mod.rs"
A4_DOCS = "docs/TELEMETRY.md"
A4_PY = "scripts/check_events.py"


def a4_literal_set(py, anchor, out, what):
    blk = delim_block(py, anchor, "{", "}")
    if blk is None:
        diag(out, "A4", A4_PY, 1, f"no `{what}` set in check_events.py")
        return []
    line, body = blk
    return [(line + l - 1, s) for l, s in string_literals(body)]


def run_a4(tree):
    out = []
    tel, docs, py = tree.get(A4_TEL), tree.get(A4_DOCS), tree.get(A4_PY)
    if tel is None or docs is None or py is None:
        for path, got in ((A4_TEL, tel), (A4_DOCS, docs), (A4_PY, py)):
            if got is None:
                missing_file(out, "A4", path)
        return out
    blk = delim_block(tel, "pub fn kind", "{", "}")
    if blk is None:
        diag(out, "A4", A4_TEL, 1, "no `pub fn kind` match found")
        return out
    kind_line, kind_body = blk
    kinds_rs = [(kind_line + l - 1, s) for l, s in string_literals(kind_body)]
    if not kinds_rs:
        diag(out, "A4", A4_TEL, kind_line, "`kind()` yields no kind strings")
        return out
    kinds_py = a4_literal_set(py, "KINDS =", out, "KINDS")
    preamble_py = a4_literal_set(py, "PREAMBLE_KINDS =", out, "PREAMBLE_KINDS")
    rs_set = {s for _, s in kinds_rs}
    py_set = {s for _, s in kinds_py}
    for line, kind in kinds_rs:
        if kind not in py_set:
            diag(out, "A4", A4_TEL, line,
                 f"wire kind `{kind}` is missing from check_events.py KINDS")
        if not contains_word(docs, kind):
            diag(out, "A4", A4_TEL, line, f"wire kind `{kind}` is not documented in {A4_DOCS}")
    for line, kind in kinds_py:
        if kind not in rs_set:
            diag(out, "A4", A4_PY, line,
                 f"KINDS entry `{kind}` is not a wire kind emitted by `kind()`")
    for line, kind in preamble_py:
        if kind not in py_set:
            diag(out, "A4", A4_PY, line, f"PREAMBLE_KINDS entry `{kind}` is not in KINDS")
    rs_v = int_after(tel, "SCHEMA_VERSION: u64 =")
    py_v = int_after(py, "SCHEMA_VERSION = ")
    if rs_v is None:
        diag(out, "A4", A4_TEL, 1, "no SCHEMA_VERSION const")
    elif py_v is None:
        diag(out, "A4", A4_PY, 1, "no SCHEMA_VERSION const")
    elif rs_v[1] != py_v[1]:
        diag(out, "A4", A4_TEL, rs_v[0],
             f"SCHEMA_VERSION {rs_v[1]} != check_events.py SCHEMA_VERSION {py_v[1]}")
    blk = delim_block(tel, "pub fn to_json", "{", "}")
    if blk is None:
        diag(out, "A4", A4_TEL, 1, "no `pub fn to_json` emitter found")
        return out
    json_line, json_body = blk
    seen = set()
    for l, key in paren_keys(json_body):
        if key in seen:
            continue
        seen.add(key)
        if not contains_word(docs, key):
            diag(out, "A4", A4_TEL, json_line + l - 1,
                 f"wire key `{key}` emitted by to_json() is not documented in {A4_DOCS}")
    return out


# ---------------------------------------------------------------- A5


A5_UNIT_RULES = [
    ("_gbps", None, [["8", "BITS_PER_BYTE"], ["1e9", "1_000_000_000"]],
     "Gbit/s arithmetic needs an explicit x8 bits-per-byte and a 1e9 factor"),
    ("_ms", None, [["1e3", "1e-3", "1000", "0.001"]],
     "millisecond arithmetic needs an explicit 1e3 factor"),
    ("_us", None, [["1e6", "1e-6", "1_000_000"]],
     "microsecond arithmetic needs an explicit 1e6 factor"),
    ("_gb", "_bytes", [["1e9", "GB"]],
     "bytes-to-GB arithmetic needs an explicit 1e9 (or GB const) factor"),
]

A5_APPROVED = [
    "_s", "_ms", "_us", "_hz", "_j", "_w", "_watts", "_gb", "_gbps", "_bytes", "_byte",
    "_frac", "_share", "_util", "_pct", "_x", "_b",
]

A5_GRANDFATHERED = {
    "action", "actions", "actions_sum", "arrival", "base_total", "bytes", "capacity",
    "clock", "decode", "decode_time", "decode_tps", "dispatch_overhead", "draft_step",
    "eff_bw", "eff_gflops", "efficiency", "embeds_sum", "energy", "flops", "flops_bf16",
    "flops_f32", "host_dispatch", "hz", "internal_bw", "kernel_launch_overhead", "l2_bw",
    "link_utilization", "max", "mean", "min", "p50", "p90", "p99", "peak_bw", "prefill",
    "prefill_logits_l2", "reduction_bw_penalty", "speedup_vs_baseline", "std",
    "step_latency", "stream_efficiency", "t_compute", "t_compute_bound", "t_mem_other",
    "t_mem_weights", "t_memory", "t_memory_bound", "t_overhead", "t_overhead_bound",
    "t_parallel", "t_serial", "throughput", "time", "time_serial", "total_latency",
    "vision", "weight_scale",
}


def a5_suffixed_chains(code, suffix):
    out, i, n = [], 0, len(code)

    def is_chain(c):
        return c.isascii() and c.isalnum() or c in "_."

    while i < n:
        if not is_chain(code[i]):
            i += 1
            continue
        start = i
        while i < n and is_chain(code[i]):
            i += 1
        chain = code[start:i].strip(".")
        if chain.endswith(suffix) and len(chain) > len(suffix):
            out.append((start, i, chain))
    return out


def a5_arith_adjacent(code, start, end):
    n = len(code)
    r = end
    while r < n and code[r] == " ":
        r += 1
    if r < n and code[r] in "*/":
        return True
    left = start
    while left > 0 and code[left - 1] == " ":
        left -= 1
    if left == 0:
        return False
    c = code[left - 1]
    if c == "/":
        return True
    if c == "*":
        m = left - 1
        while m > 0 and code[m - 1] == " ":
            m -= 1
        if m == 0:
            return False
        p = code[m - 1]
        return p.isascii() and p.isalnum() or p in '_)"'
    return False


def a5_f64_field(code):
    t = code.strip()
    if not t.startswith("pub "):
        return None
    rest = t[4:]
    if ":" not in rest:
        return None
    name, ty = rest.split(":", 1)
    name = name.strip()
    ty = ty.strip().rstrip(",").strip()
    if ty != "f64":
        return None
    ok = name and all(
        (c.islower() or c.isdigit()) and c.isascii() or c == "_" for c in name
    ) and not name[0].isdigit()
    return name if ok else None


def run_a5(tree):
    out = []
    for path, text in rust_src(tree):
        for i, raw in enumerate(text.split("\n")):
            code = blank_strings(raw)
            for suffix, only_if, factors, why in A5_UNIT_RULES:
                if only_if is not None and only_if not in code:
                    continue
                for start, end, chain in a5_suffixed_chains(code, suffix):
                    if not a5_arith_adjacent(code, start, end):
                        continue
                    ok = all(any(contains_word(code, tok) for tok in grp) for grp in factors)
                    if not ok:
                        diag(out, "A5", path, i + 1,
                             f"`{chain}` is scaled without its unit conversion — {why}")
                    break
            name = a5_f64_field(code)
            if name is not None:
                named = ("_per_" in name or any(name.endswith(s) for s in A5_APPROVED)
                         or name in A5_GRANDFATHERED)
                if not named:
                    suffixes = ", ".join(A5_APPROVED[:4])
                    diag(out, "A5", path, i + 1,
                         f"public f64 field `{name}` does not name its unit — add a "
                         f"suffix ({suffixes}, ...) or `_per_`")
    return out


# ---------------------------------------------------------------- A6


A6_BASELINES = [
    ("BENCH_sim.json", "rust/benches/bench_sim_perf.rs"),
    ("BENCH_fleet.json", "rust/benches/bench_fleet.rs"),
]
A6_CI = ["scripts/ci.sh", ".github/workflows/ci.yml"]


def a6_bench_name(base):
    for i, raw in enumerate(base.split("\n")):
        if not raw.lstrip().startswith('"bench"'):
            continue
        lits = [s for _, s in string_literals(raw)]
        if lits[:1] == ["bench"] and len(lits) > 1:
            return (i + 1, lits[1])
    return None


def a6_object_keys(inner, base_line):
    out = []
    for k, raw in enumerate(inner.split("\n")):
        t = raw.strip()
        if not t.startswith('"'):
            continue
        endq = t.find('"', 1)
        if endq < 0:
            continue
        if t[endq + 1:].lstrip().startswith(":"):
            out.append((base_line + k, t[1:endq]))
    return out


def run_a6(tree):
    out = []
    for baseline, bench_src in A6_BASELINES:
        base, src = tree.get(baseline), tree.get(bench_src)
        if base is None:
            missing_file(out, "A6", baseline)
            continue
        if src is None:
            missing_file(out, "A6", bench_src)
            continue
        src_lits = {s for _, s in string_literals(src)}
        bn = a6_bench_name(base)
        if bn is None:
            diag(out, "A6", baseline, 1, 'baseline has no `"bench": "<name>"` entry')
        elif bn[1] not in src_lits:
            diag(out, "A6", baseline, bn[0],
                 f"bench name `{bn[1]}` is not emitted by {bench_src}")
        for section in ('"exact"', '"metrics"'):
            blk = delim_block(base, section, "{", "}")
            if blk is None:
                diag(out, "A6", baseline, 1, f"baseline has no {section} object")
                continue
            for line, key in a6_object_keys(blk[1], blk[0]):
                if key not in src_lits:
                    diag(out, "A6", baseline, line,
                         f"baseline key `{key}` is not emitted by {bench_src} — the "
                         "gate would fail on every run (or the key was renamed on one "
                         "side only)")
    for path, text in files_under(tree, "rust/benches/"):
        if path.endswith(".rs") and not contains_word(text, "json_path_from_args"):
            diag(out, "A6", path, 1,
                 "bench binary does not call `json_path_from_args` — it cannot be gated")
    for ci in A6_CI:
        text = tree.get(ci)
        if text is None:
            missing_file(out, "A6", ci)
            continue
        for baseline, _ in A6_BASELINES:
            gated = any(
                "check_bench.py" in l and baseline in l for l in text.split("\n")
            )
            if not gated:
                diag(out, "A6", ci, 1, f"{ci} never runs check_bench.py against {baseline}")
    return out


# ---------------------------------------------------------------- driver


RULES = [("A1", run_a1), ("A2", run_a2), ("A3", run_a3), ("A4", run_a4),
         ("A5", run_a5), ("A6", run_a6)]


def is_suppressed(tree, d):
    rule, file, line, _ = d
    text = tree.get(file)
    if text is None:
        return False
    marker = f"audit:allow({rule})"
    lines = text.split("\n")

    def has(n):
        return 1 <= n <= len(lines) and marker in lines[n - 1]

    return has(line) or (line >= 2 and has(line - 1))


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    tree = load_tree(root)
    print(f"mirror audit over {len(tree)} files from {root}")
    total = 0
    for rule_id, run in RULES:
        diags = [d for d in run(tree) if not is_suppressed(tree, d)]
        status = "ok" if not diags else f"{len(diags)} diagnostic(s)"
        print(f"  {rule_id}: {status}")
        for r, f, l, m in diags:
            print(f"    {r} {f}:{l}: {m}")
        total += len(diags)
    if total:
        print(f"mirror audit FAILED ({total} diagnostic(s))")
        return 1
    print("mirror audit clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
