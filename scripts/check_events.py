#!/usr/bin/env python3
"""Validate a vla-char telemetry NDJSON event stream from outside Rust.

Usage: check_events.py [PATH]         (reads stdin when PATH is omitted
                                       or `-`; pipe `fleet --daemon` in)

Checks, from the stream alone — no access to the live FleetReport:

  schema    every line is a JSON object carrying `v` == 1, a known `ev`
            kind, and a finite numeric `t`.

  framing   exactly one `run_start` and one `run_end`; only `cache` /
            `phase` preamble events before `run_start`; nothing after
            `run_end`.

  monotone  timestamps are non-decreasing *within the run frame*
            (`run_start` .. `run_end`). Preamble `phase` spans are
            step-relative by design (docs/TELEMETRY.md) and are NOT
            held to the run clock.

  conserve  arrivals == dispatches + drops + rejects counted from the
            individual events, and those counts match the `run_end`
            summary's arrived/served/dropped/rejected.

Summary-only streams (a `run_start` + `run_end` frame with no body
events, e.g. the single-lane batcher delegation) cannot be certified
from their events; they are skipped with a warning, exit code 0.

Exit code 0 on pass, 1 on any violation (all violations are listed).
"""

import json
import sys

KINDS = {
    "run_start", "arrival", "admit", "reject", "dispatch", "completion",
    "drop", "scale", "failure", "cache", "phase", "run_end",
}
PREAMBLE_KINDS = {"cache", "phase"}
SCHEMA_VERSION = 1


def check(lines):
    violations = []
    counts = {}
    in_frame = False
    ended = False
    prev_t = None
    end_summary = None
    n_events = 0

    for lineno, raw in enumerate(lines, 1):
        raw = raw.strip()
        if not raw:
            continue
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            violations.append(f"line {lineno}: not JSON ({e})")
            continue
        if not isinstance(obj, dict):
            violations.append(f"line {lineno}: not a JSON object")
            continue

        v, ev, t = obj.get("v"), obj.get("ev"), obj.get("t")
        if v != SCHEMA_VERSION:
            violations.append(f"line {lineno}: schema version {v!r} (want {SCHEMA_VERSION})")
            continue
        if ev not in KINDS:
            violations.append(f"line {lineno}: unknown event kind {ev!r}")
            continue
        if not isinstance(t, (int, float)) or t != t or t in (float("inf"), float("-inf")):
            violations.append(f"line {lineno}: bad timestamp {t!r}")
            continue

        n_events += 1
        counts[ev] = counts.get(ev, 0) + 1

        if ended:
            violations.append(f"line {lineno}: {ev} after run_end")
            continue

        if ev == "run_start":
            if in_frame:
                violations.append(f"line {lineno}: second run_start")
            in_frame = True
            prev_t = t
            continue

        if not in_frame:
            if ev not in PREAMBLE_KINDS:
                violations.append(f"line {lineno}: {ev} before run_start")
            continue

        # inside the run frame: the clock only moves forward
        if t < prev_t:
            violations.append(
                f"line {lineno}: timestamp regression {t} < {prev_t} at {ev}")
        prev_t = max(prev_t, t)

        if ev == "run_end":
            ended = True
            end_summary = obj

    if not in_frame:
        violations.append("no run_start in stream")
    if not ended:
        violations.append("no run_end in stream (truncated stream?)")

    arrivals = counts.get("arrival", 0)
    dispatches = counts.get("dispatch", 0)
    drops = counts.get("drop", 0)
    rejects = counts.get("reject", 0)

    if not violations and end_summary is not None and arrivals == 0 \
            and end_summary.get("arrived", 0) > 0:
        print(
            "WARNING: summary-only stream (run_end reports "
            f"{end_summary.get('arrived')} arrived but the stream carries no "
            "body events); cannot certify from events alone — skipping",
            file=sys.stderr)
        return 0

    if end_summary is not None:
        if arrivals != dispatches + drops + rejects:
            violations.append(
                f"conservation: {arrivals} arrivals != {dispatches} dispatches "
                f"+ {drops} drops + {rejects} rejects")
        for key, got in (("arrived", arrivals), ("served", dispatches),
                         ("dropped", drops), ("rejected", rejects)):
            want = end_summary.get(key)
            if want != got:
                violations.append(
                    f"run_end.{key} = {want!r} but the stream carries {got}")

    if violations:
        for m in violations:
            print(f"FAIL: {m}", file=sys.stderr)
        print(f"\nevent stream FAILED ({len(violations)} violation(s))",
              file=sys.stderr)
        return 1

    kinds = ", ".join(f"{k}={n}" for k, n in sorted(counts.items()))
    print(f"event stream OK: {n_events} events ({kinds})")
    return 0


def main():
    args = sys.argv[1:]
    if len(args) > 1:
        print(__doc__, file=sys.stderr)
        return 2
    if not args or args[0] == "-":
        return check(sys.stdin)
    with open(args[0]) as f:
        return check(f)


if __name__ == "__main__":
    sys.exit(main())
