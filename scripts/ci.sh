#!/usr/bin/env bash
# Local mirror of the CI gate. Run from anywhere inside the repo:
#
#   scripts/ci.sh           # tier-1 verify + lint gates + bench compile
#   scripts/ci.sh --tier1   # only the tier-1 verify (build + test)
#
# The tier-1 verify is exactly what the project ROADMAP specifies:
#   cargo build --release && cargo test -q
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

if [[ "${1:-}" == "--tier1" ]]; then
    echo "tier-1 verify PASSED"
    exit 0
fi

echo "==> cargo fmt --check"
cargo fmt --all --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --workspace -- -D warnings

echo "==> cargo bench --no-run (compile-only smoke)"
cargo bench --no-run

echo "==> bench baseline gate (bench_sim_perf --json vs BENCH_sim.json)"
mkdir -p reports
cargo bench --bench bench_sim_perf -- --json reports/BENCH_sim.json
python3 scripts/check_bench.py BENCH_sim.json reports/BENCH_sim.json

echo "==> bench baseline gate (bench_fleet --json vs BENCH_fleet.json)"
cargo bench --bench bench_fleet -- --json reports/BENCH_fleet.json
python3 scripts/check_bench.py BENCH_fleet.json reports/BENCH_fleet.json

echo "==> vla-char pim smoke (ranked scenario matrix, top 10)"
mkdir -p reports
cargo run --release -- pim --top 10 | tee reports/pim_top10.txt

echo "==> vla-char pim pareto smoke (energy-aware Pareto front, top 10)"
cargo run --release -- pim --pareto --top 10 | tee reports/pim_pareto_top10.txt
grep -E "Pareto front \(per-stream\): [1-9]" reports/pim_pareto_top10.txt >/dev/null \
    || { echo "ERROR: empty Pareto front in pim report"; exit 1; }

echo "==> vla-char offload smoke (edge-to-cloud placement matrix, link presets)"
cargo run --release -- offload --top 10 | tee reports/offload_top10.txt
grep -E "placement matrix" reports/offload_top10.txt >/dev/null \
    || { echo "ERROR: no ranked placement matrix in offload report"; exit 1; }
grep -E "5g/wifi6/wired" reports/offload_top10.txt >/dev/null \
    || { echo "ERROR: link presets missing from the placement matrix title"; exit 1; }
grep -E "Pareto front \(Hz vs J/action vs [\$]/action\): [1-9]" reports/offload_top10.txt >/dev/null \
    || { echo "ERROR: empty 3-objective Pareto front in offload report"; exit 1; }

echo "==> vla-char serve smoke (simulator-backed shard serving, both topologies)"
cargo run --release -- serve --shards 1,2,4 --deadline-ms 200 --top 0 \
    | tee reports/serve_shards.txt
grep -E "ranked by aggregate actions/s" reports/serve_shards.txt >/dev/null \
    || { echo "ERROR: no ranked shard table in serve report"; exit 1; }
grep -E "replicate-[0-9]" reports/serve_shards.txt >/dev/null \
    || { echo "ERROR: no replicate rows in serve report"; exit 1; }
grep -E "pipeline-[0-9]" reports/serve_shards.txt >/dev/null \
    || { echo "ERROR: no pipeline rows in serve report"; exit 1; }

echo "==> vla-char fleet smoke (10k-stream heterogeneous fleet, full policy grid)"
cargo run --release -- fleet --fleet-streams 10000 --rate 0.05 --duration 20 \
    --deadline-ms 500 | tee reports/fleet_10k.txt
grep -E "Fleet policy matrix" reports/fleet_10k.txt >/dev/null \
    || { echo "ERROR: no policy matrix in fleet report"; exit 1; }
grep -E "earliest-free|round-robin|least-loaded|edf" reports/fleet_10k.txt >/dev/null \
    || { echo "ERROR: empty policy table in fleet report"; exit 1; }

echo "==> vla-char telemetry daemon smoke (NDJSON stream -> check_events.py)"
cargo run --release -- fleet --daemon --fleet-streams 50 --rate 1 \
    --duration 5 --deadline-ms 400 | tee reports/fleet_daemon.ndjson \
    | python3 scripts/check_events.py
cargo run --release -- fleet --events reports/fleet_events.ndjson \
    --fleet-streams 50 --rate 1 --duration 5 --deadline-ms 400 \
    | tee reports/fleet_events.txt
grep -E "FL5-events-replay" reports/fleet_events.txt >/dev/null \
    || { echo "ERROR: no FL5 replay check in fleet --events report"; exit 1; }
python3 scripts/check_events.py reports/fleet_events.ndjson

echo "==> vla-char telemetry experiment smoke (TL1-TL4)"
cargo run --release -- telemetry | tee reports/telemetry.txt
grep -E "TL1-replay-bitwise" reports/telemetry.txt >/dev/null \
    || { echo "ERROR: no TL1 check in telemetry report"; exit 1; }

echo "==> vla-char audit (static self-analysis A1-A6, hard gate)"
cargo run --release -- audit | tee reports/audit.txt

if command -v pytest >/dev/null 2>&1 || python3 -c 'import pytest' >/dev/null 2>&1; then
    echo "==> python -m pytest python/tests -q (soft gate until L1/L2 artifacts land)"
    python3 -m pytest python/tests -q || echo "WARNING: python tests failed (soft gate)"
else
    echo "==> skipping python tests (pytest not installed)"
fi

echo "CI gate PASSED"
