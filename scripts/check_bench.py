#!/usr/bin/env python3
"""Gate a fresh BENCH_*.json against its checked-in baseline.

Usage: check_bench.py BASELINE CURRENT [--tolerance FRAC]

Two gate classes, keyed off the BASELINE document (extra keys in the
current document are informational and ignored):

  exact    every key must match the baseline numerically, zero tolerance.
           These are machine-independent counts (matrix sizes, simulation
           ledgers) — any drift means the grid or the cache changed shape
           and the baseline must be re-pinned deliberately.

  metrics  higher-is-better throughputs. The current value must be at
           least baseline * (1 - tolerance); default tolerance 0.25. The
           baseline stores conservative floors, so a pass means "no worse
           than 25% under the floor", catching real regressions while
           riding out runner noise.

Exit code 0 on pass, 1 on any violation (all violations are listed).
"""

import argparse
import json
import sys


def fail(msgs):
    for m in msgs:
        print(f"FAIL: {m}", file=sys.stderr)
    print(f"\nbench gate FAILED ({len(msgs)} violation(s))", file=sys.stderr)
    return 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative shortfall on metrics (default 0.25)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)

    violations = []

    if base.get("bench") != cur.get("bench"):
        violations.append(
            f"bench name mismatch: baseline {base.get('bench')!r} vs current {cur.get('bench')!r}")
    if base.get("schema") != cur.get("schema"):
        violations.append(
            f"schema mismatch: baseline {base.get('schema')!r} vs current {cur.get('schema')!r}")

    cur_exact = cur.get("exact", {})
    for key, want in base.get("exact", {}).items():
        got = cur_exact.get(key)
        if got is None:
            violations.append(f"exact.{key}: missing from current document")
        elif got != want:
            violations.append(f"exact.{key}: expected {want}, got {got}")

    cur_metrics = cur.get("metrics", {})
    for key, floor in base.get("metrics", {}).items():
        got = cur_metrics.get(key)
        bound = floor * (1.0 - args.tolerance)
        if got is None:
            violations.append(f"metrics.{key}: missing from current document")
        elif got < bound:
            violations.append(
                f"metrics.{key}: {got:.4g} is below {bound:.4g} "
                f"(baseline floor {floor:.4g}, tolerance {args.tolerance:.0%})")
        else:
            print(f"ok: metrics.{key} = {got:.4g} (floor {floor:.4g})")

    if violations:
        return fail(violations)
    print("bench gate PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
