#!/usr/bin/env python3
"""Numeric mirror of rust/src/sim/fleet (authoring-time cross-check).

The authoring container has no Rust toolchain, so this mirror re-implements
the fleet simulator's arithmetic — the xoshiro256++ PRNG, the SplitMix
sub-stream derivation, Poisson arrival building, the single-lane legacy
mirror, and the general typed-event loop with every admission/scheduling
policy — to validate the behavioral assertions the Rust unit tests pin
(EDF vs FIFO miss rates, autoscaler reactions, failure flush conservation,
token-bucket metering) before they ever reach CI.

Float caveat: Python's math.log may differ from Rust's f64::ln by 1 ulp,
so *counts* here are expected-equal-but-not-guaranteed; every assertion
this script checks has a behavioral margin, not a bitwise one.

Usage: python3 scripts/mirror_fleet.py        # run all checks, exit 0/1
"""

import heapq
import math
import sys

M64 = (1 << 64) - 1


def splitmix_next(sm):
    sm = (sm + 0x9E3779B97F4A7C15) & M64
    z = sm
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return sm, z ^ (z >> 31)


def stream_seed(seed, stream):
    z = seed ^ (((stream + 1) & M64) * 0x9E3779B97F4A7C15 & M64)
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return z ^ (z >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Prng:
    def __init__(self, seed):
        s, sm = [], seed
        for _ in range(4):
            sm, v = splitmix_next(sm)
            s.append(v)
        self.s = s

    @classmethod
    def for_stream(cls, seed, stream):
        return cls(stream_seed(seed, stream))

    def next_u64(self):
        s = self.s
        result = (rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def exponential(self, rate):
        return -math.log(max(self.next_f64(), 1e-300)) / rate


def build_arrivals(streams, rate_hz, duration_s, seed):
    arrivals = []
    per_stream = [0] * streams
    for s in range(streams):
        rng = Prng.for_stream(seed, s)
        t, step = 0.0, 0
        while True:
            t += rng.exponential(rate_hz)
            if t > duration_s:
                break
            arrivals.append((t, s, step))
            per_stream[s] += 1
            step += 1
    arrivals.sort(key=lambda r: r[0])
    return arrivals, per_stream


def quantize_step(step_s):
    # Duration::from_secs_f64 (round to nearest ns, ties even) -> as_secs_f64
    ns = round(step_s * 1e9)
    return (ns // 10**9) + (ns % 10**9) / 1e9


FAIL_SALT = 0xFA1157A70BADC0DE


def p99(xs):
    if not xs:
        return 0.0
    ys = sorted(xs)
    # util::stats::percentile_sorted: rank = q * (n - 1), linear interp
    rank = 0.99 * (len(ys) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(ys) - 1)
    frac = rank - lo
    return ys[lo] * (1.0 - frac) + ys[hi] * frac


class Report:
    pass


def run_fleet(
    streams,
    rate_hz,
    duration_s,
    seed,
    shards,  # list of (lanes, step_s, actions_per_step, j_per_action)
    deadline_s=None,
    admission=("drop",),  # ("drop",) | ("token", rate, burst) | ("slo", depth)
    scheduling="earliest",  # earliest | rr | least | edf
    mults=(1.0,),
    autoscaler=None,  # (interval, q_up, q_down, p99_up|None, warmup, min_e, max_e)
    failure_rate_hz=0.0,
):
    arrivals, per_stream_arrived = build_arrivals(streams, rate_hz, duration_s, seed)
    arrived = len(arrivals)
    mults = list(mults) or [1.0]
    nclass = len(mults)

    engines = []  # [spec_idx, step, free, busy, alive, fail_at, dynamic]
    uid = [0]

    def spawn(spec_idx, at, dynamic):
        if failure_rate_hz > 0.0:
            fail_at = at + Prng.for_stream(seed ^ FAIL_SALT, uid[0]).exponential(failure_rate_hz)
        else:
            fail_at = math.inf
        uid[0] += 1
        e = [spec_idx, quantize_step(shards[spec_idx][1]), at, 0.0, True, fail_at, dynamic]
        eid = len(engines)
        engines.append(e)
        if math.isfinite(fail_at):
            push_event(fail_at, ("fail", eid))
        if dynamic:
            push_event(at, ("done", eid))

    evq, evseq = [], [0]

    def push_event(t, ev):
        heapq.heappush(evq, (t, evseq[0], ev))
        evseq[0] += 1

    for i, sp in enumerate(shards):
        for _ in range(sp[0]):
            spawn(i, 0.0, False)

    # ready queue
    heap_mode = scheduling != "rr"
    ready_heap, ready_seq = [], [0]
    rr_queues = [[] for _ in range(streams)]
    rr_next = [0]
    queued = [0]

    def deadline_of(s):
        return None if deadline_s is None else deadline_s * mults[s % nclass]

    def ready_push(s, arr):
        if heap_mode:
            key = arr + (deadline_of(s) or 0.0) if scheduling == "edf" else arr
            heapq.heappush(ready_heap, (key, ready_seq[0], s, arr))
            ready_seq[0] += 1
        else:
            rr_queues[s].append(arr)
        queued[0] += 1

    def ready_pop():
        if heap_mode:
            if not ready_heap:
                return None
            _, _, s, arr = heapq.heappop(ready_heap)
            return (s, arr)
        for off in range(streams):
            s = (rr_next[0] + off) % streams
            if rr_queues[s]:
                arr = rr_queues[s].pop(0)
                rr_next[0] = (s + 1) % streams
                return (s, arr)
        return None

    bucket = None
    if admission[0] == "token":
        bucket = [admission[2] * 1.0, 0.0]  # tokens, last_t

    window = []
    r = Report()
    r.delays, r.services = [], []
    r.per_stream_served = [0] * streams
    r.per_stream_dropped = [0] * streams
    r.per_stream_rejected = [0] * streams
    r.actions = 0.0
    r.energy = 0.0
    r.makespan = 0.0
    r.failures = 0
    r.scale_ups = 0
    r.scale_downs = 0
    r.peak = sum(1 for e in engines if e[4])
    completed = [0]
    last_stream = [-1]
    burst = [0]
    r.max_burst = 0

    cursor = [0]
    if arrivals:
        push_event(arrivals[0][0], ("arrive", arrivals[0][1]))
    if autoscaler:
        push_event(autoscaler[0], ("scale",))

    def alive():
        return sum(1 for e in engines if e[4])

    def pick_engine(now):
        best = None
        for i, e in enumerate(engines):
            if not e[4] or e[2] > now:
                continue
            if best is None:
                best = i
            else:
                eb = engines[best]
                if scheduling == "least":
                    if e[3] < eb[3]:
                        best = i
                elif e[2] < eb[2]:
                    best = i
        return best

    def dispatch_all(now):
        while True:
            e = pick_engine(now)
            if e is None:
                return
            nxt = ready_pop()
            if nxt is None:
                return
            s, arr = nxt
            queued[0] -= 1
            delay = now - arr
            if autoscaler:
                window.append(delay)
            d = deadline_of(s)
            if d is not None and delay > d:
                r.per_stream_dropped[s] += 1
                completed[0] += 1
                continue
            if s == last_stream[0]:
                burst[0] += 1
            else:
                burst[0] = 1
                last_stream[0] = s
            r.max_burst = max(r.max_burst, burst[0])
            eng = engines[e]
            service = eng[1]
            eng[2] = now + service
            eng[3] += service
            spec = shards[eng[0]]
            r.actions += spec[2]
            r.energy += spec[3] * spec[2]
            r.makespan = max(r.makespan, eng[2])
            r.delays.append(delay)
            r.services.append(service)
            r.per_stream_served[s] += 1
            completed[0] += 1
            push_event(eng[2], ("done", e))

    def flush():
        while True:
            nxt = ready_pop()
            if nxt is None:
                break
            s, _ = nxt
            r.per_stream_dropped[s] += 1
            completed[0] += 1
        queued[0] = 0
        while cursor[0] < len(arrivals):
            _, s, _ = arrivals[cursor[0]]
            r.per_stream_dropped[s] += 1
            completed[0] += 1
            cursor[0] += 1

    while completed[0] < arrived:
        if not evq:
            flush()
            break
        now, _, ev = heapq.heappop(evq)
        kind = ev[0]
        if kind == "arrive":
            s = ev[1]
            cursor[0] += 1
            if cursor[0] < len(arrivals):
                nxt = arrivals[cursor[0]]
                push_event(nxt[0], ("arrive", nxt[1]))
            if admission[0] == "drop":
                admit = True
            elif admission[0] == "token":
                tokens, last_t = bucket
                tokens = min(tokens + (now - last_t) * admission[1], admission[2] * 1.0)
                admit = tokens >= 1.0
                if admit:
                    tokens -= 1.0
                bucket[0], bucket[1] = tokens, now
            else:  # slo
                admit = not (nclass > 1 and s % nclass == nclass - 1 and queued[0] >= admission[1])
            if not admit:
                r.per_stream_rejected[s] += 1
                completed[0] += 1
            else:
                ready_push(s, now)
                dispatch_all(now)
        elif kind == "done":
            dispatch_all(now)
        elif kind == "scale":
            interval, q_up, q_down, p99_up, warmup, min_e, max_e = autoscaler
            a = alive()
            w99 = p99(window)
            window.clear()
            if a < min_e:
                decision = "up"
            elif (queued[0] > q_up or (p99_up is not None and w99 > p99_up)) and a < max_e:
                decision = "up"
            elif queued[0] < q_down and a > min_e:
                decision = "down"
            else:
                decision = "hold"
            if decision == "up":
                spawn(0, now + warmup, True)
                r.scale_ups += 1
                r.peak = max(r.peak, alive())
            elif decision == "down":
                for i in range(len(engines) - 1, -1, -1):
                    e = engines[i]
                    if e[4] and e[6] and e[2] <= now:
                        e[4] = False
                        r.scale_downs += 1
                        break
            if completed[0] < arrived:
                push_event(now + interval, ("scale",))
        elif kind == "fail":
            e = engines[ev[1]]
            if e[4]:
                e[4] = False
                r.failures += 1
            if autoscaler is None and all(not e[4] for e in engines):
                flush()

    r.arrived = arrived
    r.served = len(r.services)
    r.dropped = sum(r.per_stream_dropped)
    r.rejected = sum(r.per_stream_rejected)
    r.per_stream_arrived = per_stream_arrived
    total = max(r.makespan, 1e-12)
    r.throughput = r.served / total
    r.p99 = p99(r.delays)
    r.miss = r.dropped / arrived if arrived else 0.0
    return r


def run_single_lane(streams, rate_hz, duration_s, seed, step_s, deadline_s=None, rr=False):
    """Mirror of FleetSim::run_single_lane == engine::batcher::run_batcher."""
    arrivals, per_stream_arrived = build_arrivals(streams, rate_hz, duration_s, seed)
    arrived = len(arrivals)
    service = quantize_step(step_s)
    queues = [[] for _ in range(streams)]
    pending = list(arrivals)
    pi = 0
    clock = 0.0
    delays, per_stream, per_stream_dropped = [], [0] * streams, [0] * streams
    rr_next = 0
    last_stream, burst, max_burst = -1, 0, 0
    while True:
        while pi < len(pending) and pending[pi][0] <= clock:
            t, s, st = pending[pi]
            queues[s].append((t, s, st))
            pi += 1
        pick = None
        if rr:
            for off in range(streams):
                s = (rr_next + off) % streams
                if queues[s]:
                    pick = s
                    break
        else:
            best = None
            for i, q in enumerate(queues):
                if q and (best is None or q[0][0] < queues[best][0][0]):
                    best = i
            pick = best
        if pick is None:
            if pi < len(pending):
                t, s, st = pending[pi]
                pi += 1
                clock = t
                queues[s].append((t, s, st))
                continue
            break
        req = queues[pick].pop(0)
        rr_next = (pick + 1) % streams
        start = max(clock, req[0])
        delay = start - req[0]
        if deadline_s is not None and delay > deadline_s:
            per_stream_dropped[pick] += 1
            continue
        if pick == last_stream:
            burst += 1
        else:
            burst = 1
            last_stream = pick
        max_burst = max(max_burst, burst)
        delays.append(delay)
        per_stream[pick] += 1
        clock = start + service
    r = Report()
    r.arrived = arrived
    r.served = len(delays)
    r.dropped = sum(per_stream_dropped)
    r.per_stream_served = per_stream
    r.per_stream_arrived = per_stream_arrived
    r.per_stream_dropped = per_stream_dropped
    r.max_burst = max_burst
    r.throughput = r.served / max(clock, 1e-12)
    r.delays = delays
    r.p99 = p99(delays)
    return r


CHECKS = []


def check(name, cond, detail=""):
    CHECKS.append((name, bool(cond), detail))
    print(f"  [{'ok' if cond else 'FAIL'}] {name}{(' — ' + detail) if detail else ''}")


def main():
    print("fleet mirror checks:")

    # --- degenerate: event loop == single-lane mirror (counts) ---
    for rr in (False, True):
        sched = "rr" if rr else "earliest"
        a = run_single_lane(3, 2.0, 10.0, 11, 0.4, deadline_s=0.3, rr=rr)
        b = run_fleet(3, 2.0, 10.0, 11, [(1, 0.4, 1.0, 0.0)], deadline_s=0.3, scheduling=sched)
        check(
            f"degenerate {sched}: mirror == event loop",
            a.served == b.served
            and a.dropped == b.dropped
            and a.per_stream_served == b.per_stream_served
            and a.max_burst == b.max_burst
            and abs(a.throughput - b.throughput) < 1e-12,
            f"served {a.served}/{b.served} dropped {a.dropped}/{b.dropped}",
        )

    # --- conservation under every admission policy ---
    for adm in (("drop",), ("token", 2.0, 2), ("slo", 2)):
        r = run_fleet(
            4, 2.0, 10.0, 11, [(2, 0.25, 1.0, 0.0)], deadline_s=0.2,
            admission=adm, mults=(1.0, 2.0),
        )
        check(
            f"conservation under {adm[0]}",
            r.arrived == r.served + r.dropped + r.rejected and r.served > 0,
            f"arrived {r.arrived} = {r.served}+{r.dropped}+{r.rejected}",
        )

    # --- token bucket metering ---
    r = run_fleet(4, 2.0, 10.0, 11, [(1, 0.05, 1.0, 0.0)], admission=("token", 1.0, 2))
    check(
        "token bucket sheds load",
        r.rejected > 0 and r.served <= 13,
        f"arrived {r.arrived} served {r.served} rejected {r.rejected}",
    )

    # --- more lanes drain ---
    one = run_fleet(4, 2.0, 10.0, 11, [(1, 0.5, 1.0, 0.0)])
    four = run_fleet(4, 2.0, 10.0, 11, [(4, 0.5, 1.0, 0.0)])
    check(
        "4 lanes beat 1 on p99 and throughput",
        four.p99 < one.p99 and four.throughput > one.throughput,
        f"p99 {one.p99:.2f}->{four.p99:.3f} thr {one.throughput:.2f}->{four.throughput:.2f}",
    )

    # --- autoscaler reacts under overload ---
    fixed = run_fleet(6, 2.0, 10.0, 17, [(1, 0.5, 1.0, 0.0)])
    scaled = run_fleet(
        6, 2.0, 10.0, 17, [(1, 0.5, 1.0, 0.0)],
        autoscaler=(0.25, 4, 1, None, 0.25, 1, 6),
    )
    check(
        "autoscaler scales up and cuts the tail",
        scaled.scale_ups > 0 and 1 < scaled.peak <= 6 and scaled.p99 < fixed.p99,
        f"ups {scaled.scale_ups} peak {scaled.peak} p99 {fixed.p99:.2f}->{scaled.p99:.2f}",
    )
    check(
        "autoscaler conserves",
        scaled.arrived == scaled.served + scaled.dropped + scaled.rejected
        and scaled.arrived == fixed.arrived,
    )

    # --- failure injection ---
    r = run_fleet(2, 2.0, 10.0, 23, [(3, 0.1, 1.0, 0.0)], failure_rate_hz=0.2)
    check(
        "failures conserve (3 engines, mean fail 5 s)",
        r.arrived == r.served + r.dropped + r.rejected and r.served > 0,
        f"failures {r.failures} served {r.served}/{r.arrived}",
    )
    dead = run_fleet(2, 2.0, 10.0, 29, [(1, 0.1, 1.0, 0.0)], failure_rate_hz=50.0)
    check(
        "collapsed fleet flushes and conserves",
        dead.arrived == dead.served + dead.dropped + dead.rejected
        and dead.failures >= 1
        and dead.dropped > 0,
        f"failures {dead.failures} dropped {dead.dropped}/{dead.arrived}",
    )

    # --- EDF vs FIFO at saturation ---
    kw = dict(
        deadline_s=0.12, mults=(0.25, 1.0, 4.0),
    )
    fifo = run_fleet(8, 1.5, 10.0, 71, [(1, 0.1, 1.0, 0.0)], scheduling="earliest", **kw)
    edf = run_fleet(8, 1.5, 10.0, 71, [(1, 0.1, 1.0, 0.0)], scheduling="edf", **kw)
    check(
        "EDF never worse than FIFO on miss% at saturation",
        fifo.dropped > 0 and edf.miss <= fifo.miss + 1e-12,
        f"miss fifo {fifo.miss:.3f} edf {edf.miss:.3f} "
        f"(drops {fifo.dropped} vs {edf.dropped})",
    )

    # --- SLO priority sheds only best-effort ---
    r = run_fleet(
        4, 2.0, 10.0, 11, [(1, 0.05, 1.0, 0.0)],
        admission=("slo", 0), mults=(1.0, 1.0),
    )
    check(
        "slo(depth 0) rejects exactly the best-effort class",
        all(r.per_stream_rejected[s] == r.per_stream_arrived[s] for s in (1, 3))
        and all(r.per_stream_rejected[s] == 0 for s in (0, 2)),
        f"rejected {r.per_stream_rejected}",
    )

    # --- 10k-stream heterogeneous smoke (bench shape) ---
    big = run_fleet(
        10_000, 0.05, 20.0, 7,
        [(2, 0.08, 1.0, 0.0), (1, 0.05, 1.0, 0.0), (1, 0.12, 1.0, 0.0)],
        deadline_s=0.5, scheduling="edf", mults=(0.5, 1.0, 2.0),
    )
    check(
        "10k-stream heterogeneous fleet conserves",
        big.arrived == big.served + big.dropped + big.rejected and big.arrived > 5000,
        f"arrived {big.arrived} served {big.served} dropped {big.dropped}",
    )
    print(f"  10k-fleet: arrived={big.arrived} served={big.served} dropped={big.dropped} "
          f"rejected={big.rejected} thr={big.throughput:.1f}/s p99={big.p99*1e3:.1f}ms")

    failed = [c for c in CHECKS if not c[1]]
    print(f"{len(CHECKS) - len(failed)}/{len(CHECKS)} checks passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
