"""Pallas fused SwiGLU feed-forward kernel (L1).

During decode the FFN is a pair of GEMVs whose weights dominate DRAM traffic.
Fusing gate/up/activation/down into one kernel removes the intermediate
[rows, ffn] round-trips — on TPU this is the difference between three
HBM-resident intermediates and a single VMEM-resident accumulation. The grid
tiles the ffn dimension so each step's (w_gate, w_up) slabs stream through
VMEM once while the `down` product accumulates into the output block.

interpret=True for CPU-PJRT execution; numerics validated against
`ref.fused_ffn_ref` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ffn-dimension tile: each grid step streams [hidden, FFN_BLOCK] slabs of the
# gate/up weights and a [FFN_BLOCK, hidden] slab of the down weights.
FFN_BLOCK = 256


def _fused_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    j = pl.program_id(0)
    x = x_ref[...]  # [rows, hidden]
    g = x @ wg_ref[...]  # [rows, FFN_BLOCK]
    u = x @ wu_ref[...]
    act = g * jax.lax.logistic(g) * u  # silu(g) * u
    partial = act @ wd_ref[...]  # [rows, hidden]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=())
def fused_ffn(x, w_gate, w_up, w_down):
    """Fused SwiGLU FFN (see `ref.fused_ffn_ref`).

    Args:
      x: [rows, hidden] float32.
      w_gate, w_up: [hidden, ffn] float32, ffn a multiple of FFN_BLOCK or
        smaller than it.
      w_down: [ffn, hidden] float32.

    Returns:
      [rows, hidden] float32.
    """
    rows, hidden = x.shape
    ffn = w_gate.shape[1]
    block = min(FFN_BLOCK, ffn)
    if ffn % block != 0:
        raise ValueError(f"ffn {ffn} must be a multiple of {block}")
    n_blocks = ffn // block
    return pl.pallas_call(
        _fused_ffn_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rows, hidden), lambda j: (0, 0)),
            pl.BlockSpec((hidden, block), lambda j: (0, j)),
            pl.BlockSpec((hidden, block), lambda j: (0, j)),
            pl.BlockSpec((block, hidden), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((rows, hidden), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)
