"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package has an exact counterpart here written in
straightforward jax.numpy. pytest (with hypothesis shape/value sweeps)
asserts allclose between kernel and oracle; the AOT pipeline refuses to emit
artifacts if the check fails.
"""

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Masked single-token decode attention over a padded KV cache.

    Args:
      q: [kv_heads, q_per_kv, head_dim] query for ONE new token (GQA layout:
         each of the kv_heads serves q_per_kv query heads).
      k_cache: [kv_heads, max_seq, head_dim] keys, valid in [0, pos].
      v_cache: [kv_heads, max_seq, head_dim] values, valid in [0, pos].
      pos: scalar int32 — index of the CURRENT token (attends to <= pos).

    Returns:
      [kv_heads, q_per_kv, head_dim] attention output.
    """
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, q.dtype))
    # [kv_heads, q_per_kv, max_seq]
    scores = jnp.einsum("hqd,hsd->hqs", q, k_cache) * scale
    idx = jnp.arange(k_cache.shape[1])
    mask = idx[None, None, :] <= pos
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask  # zero out masked lanes exactly
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqs,hsd->hqd", probs, v_cache)


def fused_ffn_ref(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: (silu(x @ w_gate) * (x @ w_up)) @ w_down.

    Args:
      x: [rows, hidden]
      w_gate, w_up: [hidden, ffn]
      w_down: [ffn, hidden]

    Returns:
      [rows, hidden]
    """
    g = x @ w_gate
    u = x @ w_up
    act = g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u  # silu(g) * u
    return act @ w_down
