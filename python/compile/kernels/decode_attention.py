"""Pallas decode-attention kernel — the action-generation hot spot (L1).

This is the operator the paper identifies as the bottleneck's core: one new
query token attending over the whole KV cache, arithmetic intensity ~1
FLOP/byte, bounded by how fast K/V (and, at model scale, weights) stream from
HBM.

TPU adaptation of the GPU kernel the paper profiles (DESIGN.md
§Hardware-Adaptation): instead of a threadblock per head with shared-memory
staging, we give each KV head a grid step (BlockSpec schedules its K/V slab
HBM->VMEM) and stream the cache in `CHUNK`-sized blocks with an online-
softmax accumulator inside the kernel — the same one-pass structure
flash-decoding uses, shaped for VMEM residency rather than warp shuffles.

Lowered with `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO with identical numerics
(validated against `ref.decode_attention_ref` by pytest).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV positions processed per online-softmax iteration. 32 divides every
# max_seq we emit (128) and keeps the live block small enough that the same
# kernel tiles into ~16 KiB VMEM working sets at real model scale.
CHUNK = 32


def _decode_attention_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref):
    """One grid step = one KV head: online softmax over KV chunks."""
    q = q_ref[0]  # [q_per_kv, head_dim]
    pos = pos_ref[0]
    seq = k_ref.shape[1]
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, q.dtype))
    q_per_kv = q.shape[0]

    neg_big = jnp.finfo(q.dtype).min

    def body(i, carry):
        m, l, acc = carry
        start = i * CHUNK
        k_blk = k_ref[0, pl.dslice(start, CHUNK), :]  # [CHUNK, head_dim]
        v_blk = v_ref[0, pl.dslice(start, CHUNK), :]
        s = (q @ k_blk.T) * scale  # [q_per_kv, CHUNK]
        idx = start + jax.lax.broadcasted_iota(jnp.int32, (1, CHUNK), 1)
        valid = idx <= pos
        s = jnp.where(valid, s, neg_big)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        # exp of masked lanes must be exactly zero so padding never leaks
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((q_per_kv, 1), neg_big, dtype=q.dtype)
    l0 = jnp.zeros((q_per_kv, 1), dtype=q.dtype)
    acc0 = jnp.zeros((q_per_kv, head_dim), dtype=q.dtype)
    n_chunks = seq // CHUNK
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = acc / l


@functools.partial(jax.jit, static_argnames=())
def decode_attention(q, k_cache, v_cache, pos):
    """Flash-decode attention for one token (see `ref.decode_attention_ref`).

    Args:
      q: [kv_heads, q_per_kv, head_dim] float32.
      k_cache / v_cache: [kv_heads, max_seq, head_dim] float32, max_seq a
        multiple of CHUNK (=32).
      pos: scalar int32, current token index (attends to positions <= pos).

    Returns:
      [kv_heads, q_per_kv, head_dim] float32.
    """
    kv_heads, max_seq, head_dim = k_cache.shape
    q_per_kv = q.shape[1]
    if max_seq % CHUNK != 0:
        raise ValueError(f"max_seq {max_seq} must be a multiple of {CHUNK}")
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1,))
    return pl.pallas_call(
        _decode_attention_kernel,
        grid=(kv_heads,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),  # pos (broadcast)
            pl.BlockSpec((1, q_per_kv, head_dim), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, max_seq, head_dim), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, max_seq, head_dim), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_per_kv, head_dim), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kv_heads, q_per_kv, head_dim), q.dtype),
        interpret=True,
    )(pos_arr, q, k_cache, v_cache)
