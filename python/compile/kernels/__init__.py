"""L1 Pallas kernels (build-time only) + pure-jnp oracles."""

from .decode_attention import decode_attention
from .fused_ffn import fused_ffn
from .ref import decode_attention_ref, fused_ffn_ref

__all__ = ["decode_attention", "fused_ffn", "decode_attention_ref", "fused_ffn_ref"]
