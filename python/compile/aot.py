"""AOT pipeline: lower the tiny VLA to HLO-text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
  vision.hlo.txt   vision_encode(params, patches)
  prefill.hlo.txt  prefill(params, embeds, token_ids)
  decode.hlo.txt   decode_step(params, token, pos, k_cache, v_cache)
  action.hlo.txt   action_head(params, cond)
  params.f32.bin   flat little-endian float32 parameter vector
  manifest.json    shapes/dims the rust side needs + golden checksums

Before writing anything, the kernels are re-validated against their jnp
oracles and the decode path is checked for prefill/decode consistency —
artifacts are only emitted from a numerically-verified build.
"""

import argparse
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import TINY
from .kernels import (decode_attention, decode_attention_ref, fused_ffn,
                      fused_ffn_ref)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def validate_kernels() -> None:
    """Refuse to emit artifacts unless L1 kernels match their oracles."""
    rng = np.random.default_rng(7)
    d = TINY.decoder
    q = jnp.asarray(rng.standard_normal(
        (d.kv_heads, d.heads // d.kv_heads, d.head_dim)), jnp.float32)
    k = jnp.asarray(rng.standard_normal(
        (d.kv_heads, d.max_seq, d.head_dim)), jnp.float32)
    v = jnp.asarray(rng.standard_normal(
        (d.kv_heads, d.max_seq, d.head_dim)), jnp.float32)
    for pos in (0, 31, 32, d.max_seq - 1):
        got = decode_attention(q, k, v, jnp.int32(pos))
        want = decode_attention_ref(q, k, v, jnp.int32(pos))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    x = jnp.asarray(rng.standard_normal((1, d.hidden)), jnp.float32)
    wg = jnp.asarray(0.05 * rng.standard_normal((d.hidden, d.ffn)), jnp.float32)
    wu = jnp.asarray(0.05 * rng.standard_normal((d.hidden, d.ffn)), jnp.float32)
    wd = jnp.asarray(0.05 * rng.standard_normal((d.ffn, d.hidden)), jnp.float32)
    np.testing.assert_allclose(
        fused_ffn(x, wg, wu, wd), fused_ffn_ref(x, wg, wu, wd),
        rtol=2e-5, atol=2e-5)


def golden_trace(params, out_dir):
    """Run one full control step in python; rust integration tests replay it
    through the artifacts and must match these numbers. The exact inputs are
    dumped alongside (numpy's PRNG is not reproducible from rust)."""
    cfg = TINY
    rng = np.random.default_rng(42)
    patches_np = rng.standard_normal(
        (cfg.vision.patches, cfg.vision.patch_dim)).astype(np.float32)
    token_ids_np = rng.integers(
        0, cfg.decoder.vocab, cfg.prompt_tokens).astype(np.int32)
    patches_np.astype("<f4").tofile(
        os.path.join(out_dir, "golden_patches.f32.bin"))
    patches = jnp.asarray(patches_np)
    token_ids = jnp.asarray(token_ids_np)
    embeds = model.vision_encode(params, patches)
    logits, kc, vc = model.prefill(params, embeds, token_ids)
    generated = []
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = cfg.prefill_len
    for _ in range(4):
        generated.append(int(tok))
        logits, kc, vc = model.decode_step(
            params, tok, jnp.int32(pos), kc, vc)
        tok = jnp.argmax(logits).astype(jnp.int32)
        pos += 1
    actions = model.action_head(params, embeds[-1])
    return {
        "patch_seed": 42,
        "prompt_token_ids": [int(t) for t in token_ids_np],
        "prefill_logits_l2": float(jnp.linalg.norm(logits)),
        "first_tokens": generated,
        "next_token": int(tok),
        "embeds_sum": float(embeds.sum()),
        "actions_sum": float(actions.sum()),
        "actions_first_row": [float(a) for a in np.asarray(actions[0])],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="(legacy) path of the primary artifact; its dirname "
                         "becomes --out-dir")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()
    out_dir = args.out_dir or (os.path.dirname(args.out) if args.out else None)
    out_dir = out_dir or os.path.join(os.path.dirname(__file__), "..", "..",
                                      "artifacts")
    os.makedirs(out_dir, exist_ok=True)

    print("[aot] validating L1 kernels against oracles ...")
    validate_kernels()

    cfg = TINY
    v, d, a = cfg.vision, cfg.decoder, cfg.action
    params_np = model.init_params()
    params = jnp.asarray(params_np)
    n_params = int(params_np.size)

    cache_shape = (d.layers, d.kv_heads, d.max_seq, d.head_dim)
    lowerings = {
        "vision": jax.jit(model.vision_encode).lower(
            _spec((n_params,)), _spec((v.patches, v.patch_dim))),
        "prefill": jax.jit(model.prefill).lower(
            _spec((n_params,)), _spec((cfg.image_tokens, d.hidden)),
            _spec((cfg.prompt_tokens,), jnp.int32)),
        "decode": jax.jit(model.decode_step).lower(
            _spec((n_params,)), _spec((), jnp.int32), _spec((), jnp.int32),
            _spec(cache_shape), _spec(cache_shape)),
        "action": jax.jit(model.action_head).lower(
            _spec((n_params,)), _spec((d.hidden,))),
    }
    for name, lowered in lowerings.items():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote {path} ({len(text)} chars)")

    params_path = os.path.join(out_dir, "params.f32.bin")
    params_np.astype("<f4").tofile(params_path)
    print(f"[aot] wrote {params_path} ({params_np.nbytes} bytes)")

    print("[aot] computing golden trace ...")
    golden = golden_trace(params, out_dir)

    manifest = {
        "version": 1,
        "n_params": n_params,
        "params_sha256": hashlib.sha256(params_np.tobytes()).hexdigest(),
        "vision": {"patches": v.patches, "patch_dim": v.patch_dim,
                   "layers": v.layers, "hidden": v.hidden},
        "decoder": {"layers": d.layers, "hidden": d.hidden, "heads": d.heads,
                    "kv_heads": d.kv_heads, "head_dim": d.head_dim,
                    "ffn": d.ffn, "vocab": d.vocab, "max_seq": d.max_seq},
        "action": {"horizon": a.horizon, "action_dim": a.action_dim,
                   "diffusion_steps": a.diffusion_steps},
        "workload": {"image_tokens": cfg.image_tokens,
                     "prompt_tokens": cfg.prompt_tokens,
                     "decode_tokens": cfg.decode_tokens,
                     "prefill_len": cfg.prefill_len},
        "artifacts": {n: f"{n}.hlo.txt" for n in lowerings},
        "golden": golden,
    }
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {manifest_path}")
    print("[aot] done")


if __name__ == "__main__":
    main()
