"""L2: the tiny VLA in JAX (build-time only; never imported at runtime).

Four entry points, each AOT-lowered to an HLO-text artifact by `aot.py`:

  vision_encode(params, patches)              -> visual embeddings
  prefill(params, embeds, token_ids)          -> (logits, k_cache, v_cache)
  decode_step(params, token, pos, k, v)       -> (logits, k_cache, v_cache)
  action_head(params, cond)                   -> action chunk [horizon, dim]

All weights live in ONE flat float32 vector so the rust runtime passes a
single `params.f32.bin` literal; slices are static (offsets resolved at trace
time from the manifest built by `ParamBook`). The decode path calls the L1
Pallas kernels (`decode_attention`, `fused_ffn`), so they lower into the same
HLO the rust coordinator executes.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .configs import TINY, TinyVlaCfg
from .kernels import decode_attention, fused_ffn


class ParamBook:
    """Assigns every weight tensor a slice of one flat parameter vector.

    Build-time bookkeeping: `alloc` is called in a fixed order during model
    construction; the same order produces the same offsets in `init_params`
    and inside the traced model functions.
    """

    def __init__(self):
        self.entries = []  # (name, shape, offset, size)
        self.total = 0

    def alloc(self, name: str, shape: tuple) -> tuple:
        size = int(np.prod(shape))
        self.entries.append((name, tuple(shape), self.total, size))
        self.total += size
        return self.entries[-1]

    def manifest(self) -> dict:
        return {
            "total": self.total,
            "entries": [
                {"name": n, "shape": list(s), "offset": o, "size": z}
                for (n, s, o, z) in self.entries
            ],
        }


def build_book(cfg: TinyVlaCfg = TINY) -> ParamBook:
    """Declare every parameter in deterministic order."""
    book = ParamBook()
    v, d, a = cfg.vision, cfg.decoder, cfg.action

    book.alloc("vis.patch_embed", (v.patch_dim, v.hidden))
    book.alloc("vis.pos_embed", (v.patches, v.hidden))
    for l in range(v.layers):
        _alloc_block(book, f"vis.b{l}", v.hidden, v.heads * v.head_dim,
                     v.heads * v.head_dim, v.ffn, swiglu=False)
    book.alloc("vis.ln_f", (v.hidden,))
    book.alloc("proj.fc1", (v.hidden, 2 * v.hidden))
    book.alloc("proj.fc2", (2 * v.hidden, d.hidden))

    book.alloc("dec.embed", (d.vocab, d.hidden))
    for l in range(d.layers):
        _alloc_block(book, f"dec.b{l}", d.hidden, d.q_dim, d.kv_dim, d.ffn,
                     swiglu=True)
    book.alloc("dec.ln_f", (d.hidden,))
    book.alloc("dec.lm_head", (d.hidden, d.vocab))

    book.alloc("act.cond_proj", (d.hidden, a.hidden))
    book.alloc("act.time_embed", (a.diffusion_steps, a.hidden))
    book.alloc("act.in_proj", (a.action_dim, a.hidden))
    for l in range(a.layers):
        _alloc_block(book, f"act.b{l}", a.hidden, a.heads * a.head_dim,
                     a.heads * a.head_dim, a.ffn, swiglu=False)
    book.alloc("act.ln_f", (a.hidden,))
    book.alloc("act.out_proj", (a.hidden, a.action_dim))
    return book


def _alloc_block(book, prefix, hidden, q_dim, kv_dim, ffn, swiglu):
    book.alloc(f"{prefix}.ln1", (hidden,))
    book.alloc(f"{prefix}.wq", (hidden, q_dim))
    book.alloc(f"{prefix}.wk", (hidden, kv_dim))
    book.alloc(f"{prefix}.wv", (hidden, kv_dim))
    book.alloc(f"{prefix}.wo", (q_dim, hidden))
    book.alloc(f"{prefix}.ln2", (hidden,))
    if swiglu:
        book.alloc(f"{prefix}.w_gate", (hidden, ffn))
        book.alloc(f"{prefix}.w_up", (hidden, ffn))
        book.alloc(f"{prefix}.w_down", (ffn, hidden))
    else:
        book.alloc(f"{prefix}.fc1", (hidden, ffn))
        book.alloc(f"{prefix}.fc2", (ffn, hidden))


def init_params(cfg: TinyVlaCfg = TINY) -> np.ndarray:
    """Deterministic parameter vector (scaled-normal init, norms at 1)."""
    book = build_book(cfg)
    rng = np.random.default_rng(cfg.seed)
    flat = np.empty(book.total, dtype=np.float32)
    for name, shape, offset, size in book.entries:
        if name.endswith((".ln1", ".ln2", ".ln_f")):
            w = np.ones(size, dtype=np.float32)
        elif name.endswith(".pos_embed") or name.endswith(".time_embed"):
            w = 0.02 * rng.standard_normal(size).astype(np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else size
            std = 1.0 / np.sqrt(fan_in)
            w = (std * rng.standard_normal(size)).astype(np.float32)
        flat[offset:offset + size] = w
    return flat


class Slicer:
    """Trace-time view of the flat parameter vector."""

    def __init__(self, flat, book: ParamBook):
        self.flat = flat
        self.index = {n: (s, o, z) for (n, s, o, z) in book.entries}

    def __call__(self, name: str):
        shape, offset, size = self.index[name]
        return jax.lax.dynamic_slice(self.flat, (offset,), (size,)).reshape(shape)


# ---------------------------------------------------------------------------
# shared blocks
# ---------------------------------------------------------------------------


def _rms_norm(x, w):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * w


def _mha(q, k, v, heads, head_dim, causal):
    """Full-sequence multi-head attention (prefill/vision/action path)."""
    seq = q.shape[0]
    qh = q.reshape(seq, heads, head_dim).transpose(1, 0, 2)
    kh = k.reshape(k.shape[0], -1, head_dim).transpose(1, 0, 2)
    vh = v.reshape(v.shape[0], -1, head_dim).transpose(1, 0, 2)
    kv_heads = kh.shape[0]
    if kv_heads != heads:  # GQA: repeat KV heads
        rep = heads // kv_heads
        kh = jnp.repeat(kh, rep, axis=0)
        vh = jnp.repeat(vh, rep, axis=0)
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) / np.sqrt(head_dim)
    if causal:
        idx = jnp.arange(seq)
        mask = idx[None, :, None] >= idx[None, None, :]
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, vh)
    return out.transpose(1, 0, 2).reshape(seq, heads * head_dim)


def _encoder_block(p, prefix, x, heads, head_dim, causal=False):
    """Pre-LN block with GELU MLP (vision & action towers)."""
    h = _rms_norm(x, p(f"{prefix}.ln1"))
    q, k, v = h @ p(f"{prefix}.wq"), h @ p(f"{prefix}.wk"), h @ p(f"{prefix}.wv")
    x = x + _mha(q, k, v, heads, head_dim, causal) @ p(f"{prefix}.wo")
    h = _rms_norm(x, p(f"{prefix}.ln2"))
    x = x + jax.nn.gelu(h @ p(f"{prefix}.fc1")) @ p(f"{prefix}.fc2")
    return x


def _decoder_block_prefill(p, prefix, x, cfg):
    d = cfg.decoder
    h = _rms_norm(x, p(f"{prefix}.ln1"))
    q, k, v = h @ p(f"{prefix}.wq"), h @ p(f"{prefix}.wk"), h @ p(f"{prefix}.wv")
    x = x + _mha(q, k, v, d.heads, d.head_dim, causal=True) @ p(f"{prefix}.wo")
    h = _rms_norm(x, p(f"{prefix}.ln2"))
    x = x + fused_ffn(h, p(f"{prefix}.w_gate"), p(f"{prefix}.w_up"),
                      p(f"{prefix}.w_down"))
    return x, k, v


# ---------------------------------------------------------------------------
# entry points (AOT-lowered)
# ---------------------------------------------------------------------------


def vision_encode(params, patches, cfg: TinyVlaCfg = TINY):
    """Vision tower + projector: [patches, patch_dim] -> [patches, dec.hidden]."""
    v = cfg.vision
    p = Slicer(params, build_book(cfg))
    x = patches @ p("vis.patch_embed") + p("vis.pos_embed")
    for l in range(v.layers):
        x = _encoder_block(p, f"vis.b{l}", x, v.heads, v.head_dim)
    x = _rms_norm(x, p("vis.ln_f"))
    x = jax.nn.gelu(x @ p("proj.fc1")) @ p("proj.fc2")
    return x


def prefill(params, embeds, token_ids, cfg: TinyVlaCfg = TINY):
    """Prefill over [image_tokens] embeds + [prompt_tokens] token ids.

    Returns (logits[vocab], k_cache, v_cache) with caches
    [layers, kv_heads, max_seq, head_dim], positions [0, prefill_len) filled.
    """
    d = cfg.decoder
    p = Slicer(params, build_book(cfg))
    tok = p("dec.embed")[token_ids]
    x = jnp.concatenate([embeds, tok], axis=0)
    seq = x.shape[0]
    k_cache = jnp.zeros((d.layers, d.kv_heads, d.max_seq, d.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    for l in range(d.layers):
        x, k, v = _decoder_block_prefill(p, f"dec.b{l}", x, cfg)
        kh = k.reshape(seq, d.kv_heads, d.head_dim).transpose(1, 0, 2)
        vh = v.reshape(seq, d.kv_heads, d.head_dim).transpose(1, 0, 2)
        k_cache = k_cache.at[l, :, :seq, :].set(kh)
        v_cache = v_cache.at[l, :, :seq, :].set(vh)
    x = _rms_norm(x, p("dec.ln_f"))
    logits = x[-1] @ p("dec.lm_head")
    return logits, k_cache, v_cache


def decode_step(params, token, pos, k_cache, v_cache, cfg: TinyVlaCfg = TINY):
    """One autoregressive step at position `pos` (0-based; the index the new
    token occupies). Uses the L1 Pallas kernels for attention and FFN.

    Returns (logits[vocab], k_cache, v_cache) with position `pos` filled.
    """
    d = cfg.decoder
    p = Slicer(params, build_book(cfg))
    x = p("dec.embed")[token]  # [hidden]
    x = x[None, :]  # [1, hidden]
    q_per_kv = d.heads // d.kv_heads
    for l in range(d.layers):
        prefix = f"dec.b{l}"
        h = _rms_norm(x, p(f"{prefix}.ln1"))
        q = (h @ p(f"{prefix}.wq")).reshape(d.heads, d.head_dim)
        k = (h @ p(f"{prefix}.wk")).reshape(d.kv_heads, d.head_dim)
        v = (h @ p(f"{prefix}.wv")).reshape(d.kv_heads, d.head_dim)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None, :, None, :], (l, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None, :, None, :], (l, 0, pos, 0))
        # GQA layout for the kernel: [kv_heads, q_per_kv, head_dim]
        qg = q.reshape(d.kv_heads, q_per_kv, d.head_dim)
        attn = decode_attention(qg, k_cache[l], v_cache[l], pos)
        attn = attn.reshape(1, d.q_dim)
        x = x + attn @ p(f"{prefix}.wo")
        h = _rms_norm(x, p(f"{prefix}.ln2"))
        x = x + fused_ffn(h, p(f"{prefix}.w_gate"), p(f"{prefix}.w_up"),
                          p(f"{prefix}.w_down"))
    x = _rms_norm(x, p("dec.ln_f"))
    logits = (x @ p("dec.lm_head"))[0]
    return logits, k_cache, v_cache


def action_head(params, cond, cfg: TinyVlaCfg = TINY):
    """DiT-style action decoder: iterative denoising of an action chunk
    conditioned on the final decoder state.

    Deterministic DDIM-like schedule (the initial chunk derives from the
    conditioning vector, so the artifact needs no RNG input). Returns
    [horizon, action_dim] in [-1, 1].
    """
    a = cfg.action
    p = Slicer(params, build_book(cfg))
    c = cond @ p("act.cond_proj")  # [act.hidden]
    # deterministic pseudo-noise seeded by the conditioning vector
    base = jnp.sin(c)[None, : a.action_dim]
    x = 0.1 * jnp.tile(base, (a.horizon, 1))
    x = x + 0.01 * jnp.cos(jnp.arange(a.horizon, dtype=jnp.float32))[:, None]
    for step in range(a.diffusion_steps):
        t_emb = p("act.time_embed")[step]
        h = x @ p("act.in_proj") + c[None, :] + t_emb[None, :]
        for l in range(a.layers):
            h = _encoder_block(p, f"act.b{l}", h, a.heads, a.head_dim)
        h = _rms_norm(h, p("act.ln_f"))
        eps = h @ p("act.out_proj")  # predicted residual
        x = x - (1.0 / a.diffusion_steps) * eps
    return jnp.tanh(x)
