"""Model configuration for the runnable tiny VLA (build-time only).

The tiny VLA mirrors MolmoAct's three-subsystem architecture (vision towers ->
projector -> decoder-only reasoning engine with KV cache -> action head) at a
scale the CPU PJRT backend executes in milliseconds, so the rust engine can
measure the same phase decomposition the paper measures on Jetson.

Dimensions intentionally match `rust/src/model/vla.rs::tiny_test_config` so
the simulator's `cpu-host` predictions can be calibrated against real
measurements of the same workload (EXPERIMENTS.md E-C6).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class VisionCfg:
    layers: int = 2
    hidden: int = 128
    heads: int = 4
    head_dim: int = 32
    ffn: int = 512
    patches: int = 64       # 8x8 grid
    patch_dim: int = 147    # 3 * 7 * 7 pixels per patch


@dataclass(frozen=True)
class DecoderCfg:
    layers: int = 4
    hidden: int = 256
    heads: int = 8
    kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 1024
    vocab: int = 2048
    max_seq: int = 128

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim


@dataclass(frozen=True)
class ActionCfg:
    layers: int = 2
    hidden: int = 128
    heads: int = 4
    head_dim: int = 32
    ffn: int = 512
    horizon: int = 8
    action_dim: int = 7
    diffusion_steps: int = 4


@dataclass(frozen=True)
class TinyVlaCfg:
    vision: VisionCfg = field(default_factory=VisionCfg)
    decoder: DecoderCfg = field(default_factory=DecoderCfg)
    action: ActionCfg = field(default_factory=ActionCfg)
    prompt_tokens: int = 16
    decode_tokens: int = 24
    seed: int = 20260710

    @property
    def image_tokens(self) -> int:
        return self.vision.patches

    @property
    def prefill_len(self) -> int:
        return self.image_tokens + self.prompt_tokens


TINY = TinyVlaCfg()
