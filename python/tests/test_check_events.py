"""Unit tests for scripts/check_events.py (the NDJSON telemetry validator).

Each test pipes a small hand-built event stream through the script the way
CI does (stdin or a file argument) and asserts the exit code plus the
violation text: valid streams, every violation class (schema, framing,
monotonicity, conservation, malformed lines), and the summary-only
warn-and-skip path.
"""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[2] / "scripts" / "check_events.py"


def run_check(stream, *args):
    return subprocess.run(
        [sys.executable, str(SCRIPT), *map(str, args)],
        input=stream,
        capture_output=True,
        text=True,
    )


def ev(kind, t, **extra):
    return json.dumps({"v": 1, "ev": kind, "t": t, **extra})


def valid_stream():
    return "\n".join(
        [
            ev("cache", 0.0),
            ev("run_start", 0.0),
            ev("arrival", 0.5),
            ev("dispatch", 0.6),
            ev("completion", 0.9),
            ev("run_end", 1.0, arrived=1, served=1, dropped=0, rejected=0),
        ]
    )


def test_valid_stream_passes():
    r = run_check(valid_stream())
    assert r.returncode == 0, r.stderr
    assert "event stream OK" in r.stdout


def test_file_argument_matches_stdin(tmp_path):
    path = tmp_path / "events.ndjson"
    path.write_text(valid_stream() + "\n")
    assert run_check("", path).returncode == 0


def test_unknown_kind_fails():
    stream = valid_stream().replace('"ev": "completion"', '"ev": "warp"')
    r = run_check(stream)
    assert r.returncode == 1
    assert "unknown event kind 'warp'" in r.stderr


def test_wrong_schema_version_fails():
    stream = "\n".join([ev("run_start", 0.0), '{"v": 2, "ev": "run_end", "t": 1.0}'])
    r = run_check(stream)
    assert r.returncode == 1
    assert "schema version 2" in r.stderr


def test_timestamp_regression_fails():
    stream = "\n".join(
        [
            ev("run_start", 0.0),
            ev("arrival", 0.5),
            ev("dispatch", 0.4),  # clock moved backwards inside the frame
            ev("run_end", 1.0, arrived=1, served=1, dropped=0, rejected=0),
        ]
    )
    r = run_check(stream)
    assert r.returncode == 1
    assert "timestamp regression" in r.stderr


def test_conservation_against_summary_fails():
    stream = "\n".join(
        [
            ev("run_start", 0.0),
            ev("arrival", 0.5),
            ev("dispatch", 0.6),
            ev("run_end", 1.0, arrived=2, served=1, dropped=0, rejected=0),
        ]
    )
    r = run_check(stream)
    assert r.returncode == 1
    assert "run_end.arrived = 2 but the stream carries 1" in r.stderr


def test_unbalanced_arrivals_fail():
    stream = "\n".join(
        [
            ev("run_start", 0.0),
            ev("arrival", 0.5),
            ev("arrival", 0.6),
            ev("dispatch", 0.7),
            ev("run_end", 1.0, arrived=2, served=1, dropped=0, rejected=0),
        ]
    )
    r = run_check(stream)
    assert r.returncode == 1
    assert "conservation" in r.stderr


def test_malformed_line_fails():
    stream = valid_stream() + "\nnot json at all"
    r = run_check(stream)
    assert r.returncode == 1
    assert "not JSON" in r.stderr


def test_missing_run_end_fails():
    stream = "\n".join([ev("run_start", 0.0), ev("arrival", 0.5)])
    r = run_check(stream)
    assert r.returncode == 1
    assert "no run_end" in r.stderr


def test_body_event_before_run_start_fails():
    stream = "\n".join(
        [
            ev("arrival", 0.0),  # only cache/phase may precede run_start
            ev("run_start", 0.1),
            ev("run_end", 1.0, arrived=0, served=0, dropped=0, rejected=0),
        ]
    )
    r = run_check(stream)
    assert r.returncode == 1
    assert "arrival before run_start" in r.stderr


def test_summary_only_stream_warns_and_passes():
    stream = "\n".join(
        [
            ev("run_start", 0.0),
            ev("run_end", 1.0, arrived=5, served=5, dropped=0, rejected=0),
        ]
    )
    r = run_check(stream)
    assert r.returncode == 0
    assert "summary-only" in r.stderr


def test_usage_error_with_two_arguments(tmp_path):
    path = tmp_path / "events.ndjson"
    path.write_text(valid_stream())
    assert run_check("", path, path).returncode == 2
