"""Golden-trace stability: the manifest's recorded outputs must match a
fresh recomputation — guards against nondeterminism in the AOT pipeline
(which would silently break the rust golden-replay contract)."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import TINY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_golden_patches_file_matches_seed(manifest):
    raw = np.fromfile(os.path.join(ART, "golden_patches.f32.bin"), dtype="<f4")
    rng = np.random.default_rng(manifest["golden"]["patch_seed"])
    expect = rng.standard_normal(
        (TINY.vision.patches, TINY.vision.patch_dim)).astype(np.float32)
    np.testing.assert_array_equal(raw.reshape(expect.shape), expect)


def test_golden_trace_recomputes_identically(manifest):
    g = manifest["golden"]
    params = jnp.asarray(model.init_params())
    raw = np.fromfile(os.path.join(ART, "golden_patches.f32.bin"), dtype="<f4")
    patches = jnp.asarray(raw.reshape(TINY.vision.patches, TINY.vision.patch_dim))
    token_ids = jnp.asarray(np.array(g["prompt_token_ids"], dtype=np.int32))

    embeds = model.vision_encode(params, patches)
    assert abs(float(embeds.sum()) - g["embeds_sum"]) < 1e-2

    logits, kc, vc = model.prefill(params, embeds, token_ids)
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = TINY.prefill_len
    out = []
    for _ in range(len(g["first_tokens"])):
        out.append(int(tok))
        logits, kc, vc = model.decode_step(params, tok, jnp.int32(pos), kc, vc)
        tok = jnp.argmax(logits).astype(jnp.int32)
        pos += 1
    assert out == g["first_tokens"]
    assert int(tok) == g["next_token"]

    actions = model.action_head(params, embeds[-1])
    assert abs(float(actions.sum()) - g["actions_sum"]) < 1e-3
    np.testing.assert_allclose(
        np.asarray(actions[0]), np.array(g["actions_first_row"]), atol=1e-5)


def test_params_file_matches_init(manifest):
    raw = np.fromfile(os.path.join(ART, "params.f32.bin"), dtype="<f4")
    np.testing.assert_array_equal(raw, model.init_params())
