"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (decode_attention, decode_attention_ref,
                             fused_ffn, fused_ffn_ref)
from compile.kernels.decode_attention import CHUNK


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(scale * rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    kv_heads=st.sampled_from([1, 2, 4]),
    q_per_kv=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([8, 16, 32]),
    seq_chunks=st.integers(min_value=1, max_value=4),
    data=st.data(),
)
def test_decode_attention_matches_ref(kv_heads, q_per_kv, head_dim,
                                      seq_chunks, data):
    seq = seq_chunks * CHUNK
    pos = data.draw(st.integers(min_value=0, max_value=seq - 1))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = _rand(rng, (kv_heads, q_per_kv, head_dim))
    k = _rand(rng, (kv_heads, seq, head_dim))
    v = _rand(rng, (kv_heads, seq, head_dim))
    got = decode_attention(q, k, v, jnp.int32(pos))
    want = decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_decode_attention_pos_zero_returns_v0():
    """With pos=0 only the first KV position is visible: out == v[:, 0]."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 3, 16))
    k = _rand(rng, (2, CHUNK, 16))
    v = _rand(rng, (2, CHUNK, 16))
    got = decode_attention(q, k, v, jnp.int32(0))
    want = jnp.broadcast_to(v[:, None, 0, :], got.shape)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_padding():
    """Garbage beyond pos must not affect the result."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 2, 16))
    k = _rand(rng, (2, 2 * CHUNK, 16))
    v = _rand(rng, (2, 2 * CHUNK, 16))
    pos = CHUNK - 1
    out1 = decode_attention(q, k, v, jnp.int32(pos))
    k2 = k.at[:, pos + 1:, :].set(1e6)
    v2 = v.at[:, pos + 1:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, jnp.int32(pos))
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_probabilities_convex():
    """Output must lie in the convex hull of visible values (softmax mix)."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 1, 8))
    k = _rand(rng, (1, CHUNK, 8))
    v = _rand(rng, (1, CHUNK, 8))
    pos = 10
    out = np.asarray(decode_attention(q, k, v, jnp.int32(pos)))[0, 0]
    vis = np.asarray(v)[0, : pos + 1]
    assert (out <= vis.max(axis=0) + 1e-5).all()
    assert (out >= vis.min(axis=0) - 1e-5).all()


def test_decode_attention_rejects_bad_seq():
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 1, 8))
    k = _rand(rng, (1, CHUNK + 1, 8))
    v = _rand(rng, (1, CHUNK + 1, 8))
    with pytest.raises(ValueError, match="multiple"):
        decode_attention(q, k, v, jnp.int32(0))


def test_decode_attention_extreme_scores_stable():
    """Online softmax must not overflow with large score magnitudes."""
    rng = np.random.default_rng(4)
    q = _rand(rng, (1, 1, 8), scale=30.0)
    k = _rand(rng, (1, CHUNK, 8), scale=30.0)
    v = _rand(rng, (1, CHUNK, 8))
    out = decode_attention(q, k, v, jnp.int32(CHUNK - 1))
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# fused_ffn
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 8]),
    hidden=st.sampled_from([16, 64, 256]),
    ffn_mult=st.sampled_from([1, 2, 4]),
    data=st.data(),
)
def test_fused_ffn_matches_ref(rows, hidden, ffn_mult, data):
    ffn = 256 * ffn_mult
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    x = _rand(rng, (rows, hidden))
    wg = _rand(rng, (hidden, ffn), scale=0.05)
    wu = _rand(rng, (hidden, ffn), scale=0.05)
    wd = _rand(rng, (ffn, hidden), scale=0.05)
    got = fused_ffn(x, wg, wu, wd)
    want = fused_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_fused_ffn_small_ffn_single_block():
    """ffn smaller than the block size runs as a single grid step."""
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 32))
    wg = _rand(rng, (32, 64), scale=0.1)
    wu = _rand(rng, (32, 64), scale=0.1)
    wd = _rand(rng, (64, 32), scale=0.1)
    np.testing.assert_allclose(
        fused_ffn(x, wg, wu, wd), fused_ffn_ref(x, wg, wu, wd),
        rtol=3e-5, atol=3e-5)


def test_fused_ffn_zero_input_gives_zero():
    x = jnp.zeros((1, 16), jnp.float32)
    wg = jnp.ones((16, 256), jnp.float32)
    wu = jnp.ones((16, 256), jnp.float32)
    wd = jnp.ones((256, 16), jnp.float32)
    out = fused_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(out, np.zeros((1, 16)), atol=1e-7)


def test_fused_ffn_rejects_ragged_ffn():
    rng = np.random.default_rng(6)
    x = _rand(rng, (1, 16))
    with pytest.raises(ValueError, match="multiple"):
        fused_ffn(x, _rand(rng, (16, 300)), _rand(rng, (16, 300)),
                  _rand(rng, (300, 16)))
