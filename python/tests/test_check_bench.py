"""Unit tests for scripts/check_bench.py (the perf-baseline CI gate).

The gate is exercised the way CI uses it — as a subprocess — over small
baseline/current JSON pairs written to tmp_path: passing runs, each
violation class (exact drift, missing metric, below-floor metric, bench
name mismatch), tolerance behaviour, and malformed input.
"""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[2] / "scripts" / "check_bench.py"


def run_gate(*args):
    return subprocess.run(
        [sys.executable, str(SCRIPT), *map(str, args)],
        capture_output=True,
        text=True,
    )


def write(path, doc):
    path.write_text(json.dumps(doc))
    return path


def baseline_doc():
    return {
        "bench": "sim_perf",
        "exact": {"scenarios": 690},
        "metrics": {"scenarios_per_s": 100.0},
    }


def matching_current():
    return {
        "bench": "sim_perf",
        "exact": {"scenarios": 690},
        "metrics": {"scenarios_per_s": 120.0},
    }


def test_matching_documents_pass(tmp_path):
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", matching_current())
    r = run_gate(base, cur)
    assert r.returncode == 0, r.stderr
    assert "bench gate PASSED" in r.stdout


def test_extra_current_keys_are_ignored(tmp_path):
    cur_doc = matching_current()
    cur_doc["exact"]["new_counter"] = 7
    cur_doc["metrics"]["new_rate"] = 1.0
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    assert run_gate(base, cur).returncode == 0


def test_exact_drift_fails(tmp_path):
    cur_doc = matching_current()
    cur_doc["exact"]["scenarios"] = 691
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    r = run_gate(base, cur)
    assert r.returncode == 1
    assert "exact.scenarios: expected 690, got 691" in r.stderr


def test_missing_metric_fails(tmp_path):
    cur_doc = matching_current()
    del cur_doc["metrics"]["scenarios_per_s"]
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    r = run_gate(base, cur)
    assert r.returncode == 1
    assert "metrics.scenarios_per_s: missing" in r.stderr


def test_metric_below_floor_fails(tmp_path):
    cur_doc = matching_current()
    cur_doc["metrics"]["scenarios_per_s"] = 60.0  # floor 100, bound 75
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    r = run_gate(base, cur)
    assert r.returncode == 1
    assert "below" in r.stderr


def test_metric_within_tolerance_passes(tmp_path):
    cur_doc = matching_current()
    cur_doc["metrics"]["scenarios_per_s"] = 80.0  # >= 100 * (1 - 0.25)
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    assert run_gate(base, cur).returncode == 0


def test_tolerance_flag_tightens_the_bound(tmp_path):
    cur_doc = matching_current()
    cur_doc["metrics"]["scenarios_per_s"] = 95.0
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    assert run_gate(base, cur, "--tolerance", "0.1").returncode == 0
    assert run_gate(base, cur, "--tolerance", "0.01").returncode == 1


def test_bench_name_mismatch_fails(tmp_path):
    cur_doc = matching_current()
    cur_doc["bench"] = "fleet"
    base = write(tmp_path / "base.json", baseline_doc())
    cur = write(tmp_path / "cur.json", cur_doc)
    r = run_gate(base, cur)
    assert r.returncode == 1
    assert "bench name mismatch" in r.stderr


def test_malformed_current_is_an_error(tmp_path):
    base = write(tmp_path / "base.json", baseline_doc())
    cur = tmp_path / "cur.json"
    cur.write_text("{not json")
    assert run_gate(base, cur).returncode != 0


def test_usage_error_without_arguments():
    assert run_gate().returncode == 2


def test_checked_in_baselines_are_wellformed():
    # the real baselines must stay loadable with the sections the gate reads
    repo = SCRIPT.parents[1]
    for name in ("BENCH_sim.json", "BENCH_fleet.json"):
        doc = json.loads((repo / name).read_text())
        assert isinstance(doc.get("bench"), str), name
        assert doc.get("exact"), name
        assert doc.get("metrics"), name
