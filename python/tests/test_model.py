"""L2 model correctness: shapes, cache semantics, prefill/decode consistency."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import TINY


@pytest.fixture(scope="module")
def params():
    return jnp.asarray(model.init_params())


@pytest.fixture(scope="module")
def patches():
    rng = np.random.default_rng(9)
    return jnp.asarray(
        rng.standard_normal((TINY.vision.patches, TINY.vision.patch_dim)),
        jnp.float32)


def test_param_book_is_contiguous():
    book = model.build_book()
    expect = 0
    for _, _, offset, size in book.entries:
        assert offset == expect
        expect += size
    assert book.total == expect
    names = [e[0] for e in book.entries]
    assert len(names) == len(set(names)), "duplicate parameter names"


def test_init_params_deterministic():
    a = model.init_params()
    b = model.init_params()
    np.testing.assert_array_equal(a, b)


def test_vision_shapes(params, patches):
    out = model.vision_encode(params, patches)
    assert out.shape == (TINY.image_tokens, TINY.decoder.hidden)
    assert np.isfinite(np.asarray(out)).all()


def test_prefill_fills_cache_prefix(params, patches):
    d = TINY.decoder
    emb = model.vision_encode(params, patches)
    toks = jnp.arange(TINY.prompt_tokens, dtype=jnp.int32)
    logits, kc, vc = model.prefill(params, emb, toks)
    assert logits.shape == (d.vocab,)
    assert kc.shape == (d.layers, d.kv_heads, d.max_seq, d.head_dim)
    n = TINY.prefill_len
    # prefix filled, suffix zero
    assert float(jnp.abs(kc[:, :, :n]).sum()) > 0
    assert float(jnp.abs(kc[:, :, n:]).sum()) == 0.0
    assert float(jnp.abs(vc[:, :, n:]).sum()) == 0.0


def test_decode_writes_one_position(params, patches):
    emb = model.vision_encode(params, patches)
    toks = jnp.arange(TINY.prompt_tokens, dtype=jnp.int32)
    _, kc, vc = model.prefill(params, emb, toks)
    pos = TINY.prefill_len
    _, kc2, vc2 = model.decode_step(params, jnp.int32(7), jnp.int32(pos), kc, vc)
    diff = jnp.abs(kc2 - kc).sum(axis=(0, 1, 3))
    changed = np.nonzero(np.asarray(diff) > 0)[0]
    np.testing.assert_array_equal(changed, [pos])


def test_prefill_decode_consistency(params, patches):
    """Decoding token t at position p must reproduce the logits of a prefill
    that already contains t — same network, two execution paths."""
    d = TINY.decoder
    emb = model.vision_encode(params, patches)
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, d.vocab, TINY.prompt_tokens),
        jnp.int32)
    # path A: prefill of [emb; toks] then decode one generated token
    logits_a, kc, vc = model.prefill(params, emb, toks)
    tok = jnp.argmax(logits_a).astype(jnp.int32)
    logits_dec, _, _ = model.decode_step(
        params, tok, jnp.int32(TINY.prefill_len), kc, vc)
    # path B: prefill of [emb; toks; tok] directly
    toks_b = jnp.concatenate([toks, tok[None]])
    logits_b, _, _ = model.prefill(
        params, emb, toks_b)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_b), rtol=2e-4, atol=2e-4)


def test_greedy_decode_deterministic(params, patches):
    emb = model.vision_encode(params, patches)
    toks = jnp.arange(TINY.prompt_tokens, dtype=jnp.int32)

    def run():
        logits, kc, vc = model.prefill(params, emb, toks)
        out = []
        tok = jnp.argmax(logits).astype(jnp.int32)
        for i in range(5):
            out.append(int(tok))
            logits, kc, vc = model.decode_step(
                params, tok, jnp.int32(TINY.prefill_len + i), kc, vc)
            tok = jnp.argmax(logits).astype(jnp.int32)
        return out

    assert run() == run()


def test_action_head_bounded_and_deterministic(params):
    cond = jnp.linspace(-1, 1, TINY.decoder.hidden, dtype=jnp.float32)
    a1 = model.action_head(params, cond)
    a2 = model.action_head(params, cond)
    assert a1.shape == (TINY.action.horizon, TINY.action.action_dim)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert float(jnp.abs(a1).max()) <= 1.0, "tanh-bounded actions"


def test_action_head_sensitive_to_condition(params):
    c1 = jnp.zeros((TINY.decoder.hidden,), jnp.float32)
    c2 = jnp.ones((TINY.decoder.hidden,), jnp.float32)
    a1 = model.action_head(params, c1)
    a2 = model.action_head(params, c2)
    assert float(jnp.abs(a1 - a2).max()) > 1e-4
