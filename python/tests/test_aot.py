"""AOT pipeline: artifacts are emitted, well-formed, and self-consistent."""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
EXPECTED = ["vision.hlo.txt", "prefill.hlo.txt", "decode.hlo.txt",
            "action.hlo.txt", "params.f32.bin", "manifest.json"]


@pytest.fixture(scope="module")
def artifacts():
    """Build artifacts if missing (mirrors `make artifacts`)."""
    if not all(os.path.exists(os.path.join(ART, f)) for f in EXPECTED):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True)
    return ART


def test_all_artifacts_exist(artifacts):
    for f in EXPECTED:
        path = os.path.join(artifacts, f)
        assert os.path.exists(path), f
        assert os.path.getsize(path) > 0, f


def test_hlo_text_wellformed(artifacts):
    for name in ["vision", "prefill", "decode", "action"]:
        with open(os.path.join(artifacts, f"{name}.hlo.txt")) as f:
            text = f.read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # tuple return convention the rust loader unwraps
        assert "ROOT" in text, name


def test_manifest_matches_params(artifacts):
    import hashlib
    with open(os.path.join(artifacts, "manifest.json")) as f:
        m = json.load(f)
    raw = open(os.path.join(artifacts, "params.f32.bin"), "rb").read()
    assert len(raw) == 4 * m["n_params"]
    assert hashlib.sha256(raw).hexdigest() == m["params_sha256"]


def test_manifest_dims_match_config(artifacts):
    from compile.configs import TINY
    with open(os.path.join(artifacts, "manifest.json")) as f:
        m = json.load(f)
    assert m["decoder"]["layers"] == TINY.decoder.layers
    assert m["decoder"]["vocab"] == TINY.decoder.vocab
    assert m["decoder"]["max_seq"] == TINY.decoder.max_seq
    assert m["workload"]["prefill_len"] == TINY.prefill_len
    assert m["action"]["horizon"] == TINY.action.horizon
    assert set(m["artifacts"]) == {"vision", "prefill", "decode", "action"}


def test_golden_trace_present(artifacts):
    with open(os.path.join(artifacts, "manifest.json")) as f:
        m = json.load(f)
    g = m["golden"]
    assert len(g["first_tokens"]) == 4
    assert len(g["actions_first_row"]) == 7
    assert abs(g["actions_sum"]) < 8 * 7  # tanh-bounded


def test_decode_hlo_embeds_pallas_lowering(artifacts):
    """The decode artifact must contain the interpret-lowered kernel loop
    structure (while/fori from the online-softmax), i.e. the L1 kernel really
    lowered into the same HLO the rust runtime executes."""
    with open(os.path.join(artifacts, "decode.hlo.txt")) as f:
        text = f.read()
    assert "while" in text, "online-softmax fori_loop should lower to while"
