//! Fixture tests for the `vla-char audit` static-analysis rules.
//!
//! Each rule gets a minimal synthetic [`SourceTree`] in two variants: a
//! clean one that must produce zero diagnostics, and one with a single
//! seeded violation that must produce exactly the expected diagnostic
//! (rule ID, file, line, and message substance). The final test is the
//! golden pin: the audit must run clean over the real checked-in tree, so
//! any drift a future PR introduces fails `cargo test` with the same
//! file/line-anchored message CI prints.

use std::path::Path;

use vla_char::analysis::{self, Diagnostic, SourceTree};

/// Run one rule by ID, with suppression filtering, as the audit does.
fn run(id: &str, tree: &SourceTree) -> Vec<Diagnostic> {
    analysis::run_rule(analysis::rule(id).expect("registered rule"), tree)
}

fn assert_clean(id: &str, tree: &SourceTree) {
    let diags = run(id, tree);
    assert!(diags.is_empty(), "{id} fixture expected clean, got: {diags:?}");
}

/// Assert exactly one diagnostic with the expected anchor and content.
fn assert_one(diags: &[Diagnostic], rule: &str, file: &str, line: usize, needle: &str) {
    assert_eq!(diags.len(), 1, "expected one {rule} diagnostic, got: {diags:?}");
    let d = &diags[0];
    assert_eq!(d.rule, rule);
    assert_eq!(d.file, file);
    assert_eq!(d.line, line, "wrong line anchor in: {d}");
    assert!(d.message.contains(needle), "message should mention `{needle}`: {d}");
}

// ---------------------------------------------------------------- A1

const A1_CACHE: &str = "rust/src/sim/scenario/cache.rs";

const A1_SIM_DEFS: &str = concat!(
    "pub struct SimOptions {\n",
    "    pub prefetch: bool,\n",
    "    pub pim_new_knob: bool,\n",
    "}\n",
);

const A1_CONFIG_DEFS: &str = concat!(
    "pub struct VlaConfig {\n",
    "    pub decoder: DecoderConfig,\n",
    "}\n",
    "pub struct DecoderConfig {\n",
    "    pub dims: u64,\n",
    "}\n",
    "pub struct WorkloadShape {\n",
    "    pub decode_tokens: u64,\n",
    "}\n",
);

const A1_CACHE_PREFIX: &str = concat!(
    "fn fp(c, o, shape) {\n",
    "    let VlaConfig { decoder } = c;\n",
    "    let DecoderConfig { dims } = decoder;\n",
    "    let WorkloadShape { decode_tokens } = shape;\n",
);

fn a1_tree(sim_options_line: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert("rust/src/sim/simulator.rs", A1_SIM_DEFS);
    t.insert("rust/src/model/vla.rs", A1_CONFIG_DEFS);
    t.insert(A1_CACHE, &format!("{A1_CACHE_PREFIX}{sim_options_line}}}\n"));
    t
}

#[test]
fn a1_clean_fixture_passes() {
    assert_clean("A1", &a1_tree("    let SimOptions { prefetch, pim_new_knob } = o;\n"));
}

#[test]
fn a1_catches_uncovered_fingerprint_field() {
    // `pim_new_knob` exists on SimOptions but the cache destructuring
    // (line 5) does not name it — the cache could alias two configs
    let tree = a1_tree("    let SimOptions { prefetch } = o;\n");
    assert_one(&run("A1", &tree), "A1", A1_CACHE, 5, "SimOptions.pim_new_knob");
}

// ---------------------------------------------------------------- A2

const A2_SCEN_TESTS: &str = "rust/tests/scenario_tests.rs";

fn a2_tree(result_bits: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert(
        "rust/src/sim/scenario/eval.rs",
        "pub struct ScenarioResult {\n    pub time: f64,\n    pub link_s: f64,\n}\n",
    );
    t.insert(A2_SCEN_TESTS, result_bits);
    t.insert("rust/src/sim/fleet/sim.rs", "pub struct FleetReport {\n    pub served: usize,\n}\n");
    t.insert("rust/tests/fleet_tests.rs", "fn fingerprint(r) {\n    (r.served,)\n}\n");
    t.insert(
        "rust/src/telemetry/replay.rs",
        "fn report_mismatch(a, b) {\n    a.served != b.served\n}\n",
    );
    t.insert("rust/tests/telemetry_tests.rs", "use replay::report_mismatch;\n");
    t
}

#[test]
fn a2_clean_fixture_passes() {
    let tree = a2_tree("fn result_bits(r) {\n    (r.time.to_bits(), r.link_s.to_bits())\n}\n");
    assert_clean("A2", &tree);
}

#[test]
fn a2_catches_field_missing_from_bitwise_tuple() {
    // ScenarioResult.link_s is never read by result_bits (fn opens line 1)
    let tree = a2_tree("fn result_bits(r) {\n    (r.time.to_bits(),)\n}\n");
    assert_one(&run("A2", &tree), "A2", A2_SCEN_TESTS, 1, "link_s");
}

// ---------------------------------------------------------------- A3

const A3_README: &str = "README.md";

const A3_MOD_RS: &str = concat!(
    "pub static REGISTRY: &[&dyn Experiment] = &[\n",
    "    &Alpha,\n",
    "    &Beta,\n",
    "];\n",
    "\n",
    "impl Experiment for Alpha {\n",
    "    fn name(&self) -> &'static str {\n",
    "        \"alpha\"\n",
    "    }\n",
    "    fn description(&self) -> &'static str {\n",
    "        \"first\"\n",
    "    }\n",
    "}\n",
    "\n",
    "impl Experiment for Beta {\n",
    "    fn name(&self) -> &'static str {\n",
    "        \"beta\"\n",
    "    }\n",
    "    fn description(&self) -> &'static str {\n",
    "        \"second\"\n",
    "    }\n",
    "}\n",
);

const A3_CLI_RS: &str = concat!(
    "const EXTRA_SUBCOMMANDS: &[(&str, &str)] = &[\n",
    "    (\"report\", \"registry loop\"),\n",
    "];\n",
);

const A3_TESTS_RS: &str = concat!(
    "#[test]\n",
    "fn registry_covers_every_subcommand() {\n",
    "    let want = [\"alpha\", \"beta\"];\n",
    "    assert_eq!(names.len(), 2);\n",
    "}\n",
);

const A3_ARCH_MD: &str = "rust/src/\n├── cli/\n└── experiment/\n";

const A3_README_OK: &str = concat!(
    "| Subcommand | What |\n",
    "|---|---|\n",
    "| `alpha` | first |\n",
    "| `beta` | second |\n",
    "| `report` | registry loop |\n",
);

fn a3_tree(readme: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert("rust/src/experiment/mod.rs", A3_MOD_RS);
    t.insert("rust/src/cli/mod.rs", A3_CLI_RS);
    t.insert("rust/tests/experiment_tests.rs", A3_TESTS_RS);
    t.insert("docs/ARCHITECTURE.md", A3_ARCH_MD);
    t.insert(A3_README, readme);
    t
}

#[test]
fn a3_clean_fixture_passes() {
    assert_clean("A3", &a3_tree(A3_README_OK));
}

#[test]
fn a3_catches_readme_table_drift() {
    // drop the `beta` row: the registered experiment must be flagged
    // against the table header (line 1)
    let readme = concat!(
        "| Subcommand | What |\n",
        "|---|---|\n",
        "| `alpha` | first |\n",
        "| `report` | registry loop |\n",
    );
    assert_one(&run("A3", &a3_tree(readme)), "A3", A3_README, 1, "`beta` is missing");
}

// ---------------------------------------------------------------- A4

const A4_TEL: &str = "rust/src/telemetry/mod.rs";

const A4_TEL_RS: &str = concat!(
    "pub const SCHEMA_VERSION: u64 = 1;\n",
    "\n",
    "impl Event {\n",
    "    pub fn kind(&self) -> &'static str {\n",
    "        match self {\n",
    "            Event::Arrival { .. } => \"arrival\",\n",
    "            Event::Scale { .. } => \"scale\",\n",
    "        }\n",
    "    }\n",
    "\n",
    "    pub fn to_json(&self) -> String {\n",
    "        let pairs = [(\"t\", a), (\"n\", b)];\n",
    "        render(pairs)\n",
    "    }\n",
    "}\n",
);

const A4_DOCS_MD: &str = "Wire kinds: `arrival`, `scale`. Keys: `t`, `n`.\n";

fn a4_tree(py_kinds: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert(A4_TEL, A4_TEL_RS);
    t.insert("docs/TELEMETRY.md", A4_DOCS_MD);
    let mut py = format!("KINDS = {{{py_kinds}}}\n");
    py.push_str("PREAMBLE_KINDS = {\"arrival\"}\nSCHEMA_VERSION = 1\n");
    t.insert("scripts/check_events.py", &py);
    t
}

#[test]
fn a4_clean_fixture_passes() {
    assert_clean("A4", &a4_tree("\"arrival\", \"scale\""));
}

#[test]
fn a4_catches_kind_missing_from_validator() {
    // kind() emits "scale" on line 7 but the validator's KINDS lacks it
    let tree = a4_tree("\"arrival\"");
    assert_one(&run("A4", &tree), "A4", A4_TEL, 7, "`scale` is missing from check_events.py");
}

// ---------------------------------------------------------------- A5

const A5_NET: &str = "rust/src/sim/net.rs";

const A5_LINK_OK: &str = concat!(
    "pub struct Link {\n",
    "    pub bw_gbps: f64,\n",
    "}\n",
    "\n",
    "fn t(bytes: f64, l: &Link) -> f64 {\n",
    "    bytes * 8.0 / (l.bw_gbps * 1e9)\n",
    "}\n",
);

const A5_LINK_BAD: &str = concat!(
    "pub struct Link {\n",
    "    pub bw_gbps: f64,\n",
    "}\n",
    "\n",
    "fn t(bytes: f64, l: &Link) -> f64 {\n",
    "    bytes / (l.bw_gbps * 1e9)\n",
    "}\n",
);

fn a5_tree(src: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert(A5_NET, src);
    t
}

#[test]
fn a5_clean_fixture_passes() {
    assert_clean("A5", &a5_tree(A5_LINK_OK));
}

#[test]
fn a5_catches_missing_unit_conversion() {
    // the PR 9 bug shape: payload bytes divided by a Gbit/s bandwidth
    // without the x8 bits-per-byte factor (line 6)
    assert_one(&run("A5", &a5_tree(A5_LINK_BAD)), "A5", A5_NET, 6, "l.bw_gbps");
}

#[test]
fn a5_catches_unitless_public_field() {
    let tree = a5_tree("pub struct Link {\n    pub speed: f64,\n}\n");
    assert_one(&run("A5", &tree), "A5", A5_NET, 2, "`speed` does not name its unit");
}

#[test]
fn a5_suppression_marker_silences_the_line() {
    let src = concat!(
        "fn t(bytes: f64, bw_gbps: f64) -> f64 {\n",
        "    // audit:allow(A5) the factor lives one call up\n",
        "    bytes / (bw_gbps * 1e9)\n",
        "}\n",
    );
    assert_clean("A5", &a5_tree(src));
}

// ---------------------------------------------------------------- A6

const A6_BASE: &str = "BENCH_sim.json";

const A6_SIM_JSON: &str = concat!(
    "{\n",
    "  \"bench\": \"sim_perf\",\n",
    "  \"exact\": {\n",
    "    \"scenarios\": 690\n",
    "  },\n",
    "  \"metrics\": {\n",
    "    \"rate\": 1.5\n",
    "  }\n",
    "}\n",
);

const A6_FLEET_JSON: &str = concat!(
    "{\n",
    "  \"bench\": \"fleet\",\n",
    "  \"exact\": {\n",
    "    \"streams\": 2\n",
    "  },\n",
    "  \"metrics\": {\n",
    "    \"x\": 1.0\n",
    "  }\n",
    "}\n",
);

const A6_SIM_BENCH_OK: &str = concat!(
    "fn main() {\n",
    "    let p = json_path_from_args();\n",
    "    emit(\"sim_perf\");\n",
    "    emit(\"scenarios\");\n",
    "    emit(\"rate\");\n",
    "}\n",
);

const A6_SIM_BENCH_BAD: &str = concat!(
    "fn main() {\n",
    "    let p = json_path_from_args();\n",
    "    emit(\"sim_perf\");\n",
    "    emit(\"scenarios\");\n",
    "}\n",
);

const A6_FLEET_BENCH: &str = concat!(
    "fn main() {\n",
    "    let p = json_path_from_args();\n",
    "    emit(\"fleet\");\n",
    "    emit(\"streams\");\n",
    "    emit(\"x\");\n",
    "}\n",
);

const A6_CI_SH: &str = concat!(
    "python3 scripts/check_bench.py BENCH_sim.json reports/sim.json\n",
    "python3 scripts/check_bench.py BENCH_fleet.json reports/fleet.json\n",
);

const A6_CI_YML: &str = concat!(
    "      - run: python3 scripts/check_bench.py BENCH_sim.json r/sim.json\n",
    "      - run: python3 scripts/check_bench.py BENCH_fleet.json r/fleet.json\n",
);

fn a6_tree(sim_bench: &str) -> SourceTree {
    let mut t = SourceTree::default();
    t.insert(A6_BASE, A6_SIM_JSON);
    t.insert("BENCH_fleet.json", A6_FLEET_JSON);
    t.insert("rust/benches/bench_sim_perf.rs", sim_bench);
    t.insert("rust/benches/bench_fleet.rs", A6_FLEET_BENCH);
    t.insert("scripts/ci.sh", A6_CI_SH);
    t.insert(".github/workflows/ci.yml", A6_CI_YML);
    t
}

#[test]
fn a6_clean_fixture_passes() {
    assert_clean("A6", &a6_tree(A6_SIM_BENCH_OK));
}

#[test]
fn a6_catches_baseline_key_the_bench_never_emits() {
    // BENCH_sim.json pins `rate` (line 7) but the bench source never
    // emits that literal
    assert_one(&run("A6", &a6_tree(A6_SIM_BENCH_BAD)), "A6", A6_BASE, 7, "baseline key `rate`");
}

// ---------------------------------------------------------------- golden

/// The audit must be clean on the real checked-in tree — the same gate
/// `vla-char audit` enforces in CI, pinned here so `cargo test` fails with
/// the full diagnostic list if any invariant drifts.
#[test]
fn audit_is_clean_on_the_real_tree() {
    let root = analysis::repo_root_from(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("repo root above the rust/ crate");
    let tree = SourceTree::load(&root).expect("load the audited file set");
    assert!(tree.len() > 50, "expected the real tree, found only {} files", tree.len());
    let diags = analysis::run_all(&tree);
    let listing: Vec<String> = diags.iter().map(ToString::to_string).collect();
    assert!(
        diags.is_empty(),
        "audit must run clean on the checked-in tree:\n{}",
        listing.join("\n")
    );
}
