//! Artifact → PJRT round-trip: every compiled module loads and executes with
//! the manifest's shapes; numerics match the python-recorded golden trace.
//!
//! Needs `make artifacts` output and a working PJRT runtime. Without either
//! (e.g. the offline `xla` stub build), each test logs a skip and passes
//! vacuously; the artifact-parsing logic itself is unit-tested in
//! `runtime::artifacts` which runs everywhere.

use std::sync::Mutex;
use vla_char::engine::VlaModel;
use vla_char::runtime::{artifacts_dir, load_manifest, load_params, Runtime};

// PJRT client creation is serialized across tests.
static LOCK: Mutex<()> = Mutex::new(());

fn artifacts() -> Option<std::path::PathBuf> {
    match artifacts_dir() {
        Ok(dir) => Some(dir),
        Err(e) => {
            eprintln!("skipping artifact test (run `make artifacts`): {e}");
            None
        }
    }
}

fn model() -> Option<(Runtime, VlaModel)> {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT round-trip test: {e}");
            return None;
        }
    };
    // With a live client, only missing artifacts may skip; broken ones fail.
    let dir = artifacts()?;
    let model = VlaModel::load_from(&rt, &dir).expect("artifacts exist but failed to load");
    Some((rt, model))
}

#[test]
fn manifest_matches_params_file() {
    let Some(dir) = artifacts() else { return };
    let m = load_manifest(&dir).unwrap();
    let params = load_params(&dir, m.n_params).unwrap();
    assert_eq!(params.len(), m.n_params);
    // params are finite and not all zero
    assert!(params.iter().all(|x| x.is_finite()));
    assert!(params.iter().any(|x| *x != 0.0));
}

#[test]
fn all_modules_compile_and_run() {
    let _g = LOCK.lock().unwrap();
    let Some((_rt, model)) = model() else { return };
    let m = model.manifest.clone();

    // vision
    let patches = vec![0.1f32; m.vision.patches * m.vision.patch_dim];
    let (embeds, host, _) = model.encode_vision(&patches).unwrap();
    assert_eq!(host.len(), m.workload.image_tokens * m.decoder.hidden);

    // prefill
    let prompt: Vec<i32> = (0..m.workload.prompt_tokens as i32).collect();
    let (logits, cache, _) = model.run_prefill(&embeds, &prompt).unwrap();
    assert_eq!(logits.len(), m.decoder.vocab);
    assert_eq!(cache.len, m.workload.prefill_len);

    // decode
    let (logits2, cache2, _) = model.run_decode_step(3, cache).unwrap();
    assert_eq!(logits2.len(), m.decoder.vocab);
    assert_eq!(cache2.len, m.workload.prefill_len + 1);

    // action
    let cond = vec![0.5f32; m.decoder.hidden];
    let (actions, _) = model.run_action(&cond).unwrap();
    assert_eq!(actions.len(), m.action.horizon * m.action.action_dim);
    assert!(actions.iter().all(|a| a.abs() <= 1.0), "tanh-bounded");
}

#[test]
fn bad_inputs_rejected() {
    let _g = LOCK.lock().unwrap();
    let Some((_rt, model)) = model() else { return };
    assert!(model.encode_vision(&[0.0; 3]).is_err(), "wrong patch buffer");
    assert!(model.run_action(&[0.0; 3]).is_err(), "wrong cond width");
}

#[test]
fn decode_rejects_full_cache() {
    let _g = LOCK.lock().unwrap();
    let Some((_rt, model)) = model() else { return };
    let m = model.manifest.clone();
    let patches = vec![0.0f32; m.vision.patches * m.vision.patch_dim];
    let (embeds, _, _) = model.encode_vision(&patches).unwrap();
    let prompt: Vec<i32> = vec![0; m.workload.prompt_tokens];
    let (_, mut cache, _) = model.run_prefill(&embeds, &prompt).unwrap();
    // fill to the brim
    while cache.len < m.decoder.max_seq {
        let (_, c, _) = model.run_decode_step(1, cache).unwrap();
        cache = c;
    }
    assert!(model.run_decode_step(1, cache).is_err(), "cache overflow must error");
}

#[test]
fn golden_trace_replays_exactly() {
    let _g = LOCK.lock().unwrap();
    let Some(dir) = artifacts() else { return };
    let Some((_rt, model)) = model() else { return };
    let m = model.manifest.clone();
    let g = &m.golden;

    // the exact inputs python used
    let raw = std::fs::read(dir.join("golden_patches.f32.bin")).unwrap();
    let patches: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    assert_eq!(patches.len(), m.vision.patches * m.vision.patch_dim);

    let (embeds, host, _) = model.encode_vision(&patches).unwrap();
    let embeds_sum: f64 = host.iter().map(|x| *x as f64).sum();
    let rel = (embeds_sum - g.embeds_sum).abs() / g.embeds_sum.abs().max(1e-9);
    assert!(rel < 1e-3, "embeds_sum {embeds_sum} vs golden {}", g.embeds_sum);

    let (logits, mut cache, _) = model.run_prefill(&embeds, &g.prompt_token_ids).unwrap();
    let mut tok = model.greedy(&logits);
    let mut generated = Vec::new();
    for _ in 0..g.first_tokens.len() {
        generated.push(tok as i64);
        let (l, c, _) = model.run_decode_step(tok, cache).unwrap();
        cache = c;
        tok = model.greedy(&l);
    }
    assert_eq!(generated, g.first_tokens, "greedy decode must replay python exactly");
    assert_eq!(tok as i64, g.next_token);

    let hidden = m.decoder.hidden;
    let cond = &host[host.len() - hidden..];
    let (actions, _) = model.run_action(cond).unwrap();
    let sum: f64 = actions.iter().map(|x| *x as f64).sum();
    assert!(
        (sum - g.actions_sum).abs() < 1e-3,
        "actions_sum {sum} vs golden {}",
        g.actions_sum
    );
    for (i, want) in g.actions_first_row.iter().enumerate() {
        assert!(
            (actions[i] as f64 - want).abs() < 1e-4,
            "action[0][{i}] {} vs {}",
            actions[i],
            want
        );
    }
}
