//! Scenario-matrix acceptance suite: validity rules, the closed-form
//! matrix size (legacy fixed point AND parameterized lever grids),
//! parallel==serial determinism over scenario evaluation (energy and
//! capacity fields included), capacity-validity reporting, the speedup
//! sanity bound, Pareto-front laws over the real matrix, and the
//! PIM-vs-SoC counterpart dominance the paper's co-design thesis predicts.

use vla_char::engine::ShardMode;
use vla_char::hw::platform;
use vla_char::model::molmoact::molmoact_7b;
use vla_char::model::scaling::scaled_vla;
use vla_char::sim::scenario::{
    matrix_size, matrix_size_grid, pareto_front, scenario_matrix, scenario_matrix_grid, EvalCache,
    Evaluator, Lever, LeverGrid, LeverGroup, NetLink, OffloadMode, Scenario, ScenarioResult,
    SPEC_ALPHA, SPEC_GAMMA,
};
use vla_char::sim::{sweep, Bound, SimOptions};

/// Scenario-engine options: ambient PIM off — exploiting PIM is a lever.
fn opts() -> SimOptions {
    SimOptions { decode_stride: 32, pim: false, ..Default::default() }
}

fn evaluator(p: &vla_char::hw::Platform) -> Evaluator {
    Evaluator::new(p, &opts(), &molmoact_7b(), &scaled_vla(2.0))
}

#[test]
fn matrix_size_matches_documented_closed_form() {
    for p in platform::sweep_platforms() {
        let m = scenario_matrix(&p);
        assert_eq!(m.len(), matrix_size(&p), "{}: closed form diverged", p.name);
        let expect = if p.mem.pim.is_some() { 72 } else { 24 };
        assert_eq!(m.len(), expect, "{}", p.name);
        for s in &m {
            assert!(s.validate(&p).is_ok(), "{}: `{}` invalid", p.name, s.name);
        }
    }
    // the acceptance floor: >= 24 valid scenarios on >= 3 PIM-capable platforms
    let pim_capable = platform::pim_platforms();
    assert!(pim_capable.len() >= 3);
    for p in &pim_capable {
        assert!(scenario_matrix(p).len() >= 24, "{}", p.name);
    }
}

/// ACCEPTANCE: the grid closed form (weights x kv x T x (1+G+B) plus the
/// PIM-draft branch) equals the full enumeration on every sweep platform,
/// for the legacy fixed point, the phase-2 default, and an expanded grid.
#[test]
fn grid_closed_form_pinned_against_enumeration() {
    let expanded = LeverGrid {
        spec_gammas: vec![2, 4, 8],
        spec_alphas: vec![0.5, 0.7, 0.9],
        trace_factors: vec![0.25, 0.5],
        batch_streams: vec![4, 16],
        shard_engines: Vec::new(),
        offload_modes: Vec::new(),
        offload_links: Vec::new(),
    };
    let sharded = LeverGrid { shard_engines: vec![2, 4], ..LeverGrid::default_phase2() };
    for grid in [LeverGrid::legacy(), LeverGrid::default_phase2(), expanded, sharded] {
        for p in platform::sweep_platforms() {
            let m = scenario_matrix_grid(&p, &grid);
            assert_eq!(m.len(), matrix_size_grid(&p, &grid), "{}: closed form diverged", p.name);
            for s in &m {
                assert!(s.validate(&p).is_ok(), "{}: `{}` invalid", p.name, s.name);
            }
        }
    }
    // pinned counts: legacy 72/24, phase-2 default (b8 axis) 102/36
    assert_eq!(matrix_size_grid(&platform::orin_pim(), &LeverGrid::legacy()), 72);
    assert_eq!(matrix_size_grid(&platform::orin(), &LeverGrid::legacy()), 24);
    assert_eq!(matrix_size_grid(&platform::orin_pim(), &LeverGrid::default_phase2()), 102);
    assert_eq!(matrix_size_grid(&platform::orin(), &LeverGrid::default_phase2()), 36);
    // the serving axis multiplies the count: |shards| = 2 -> S = 5
    let sharded = LeverGrid { shard_engines: vec![2, 4], ..LeverGrid::default_phase2() };
    assert_eq!(matrix_size_grid(&platform::orin_pim(), &sharded), 102 * 5);
    assert_eq!(matrix_size_grid(&platform::orin(), &sharded), 36 * 5);
}

#[test]
fn validity_rules_reject_impossible_combos() {
    let orin = platform::orin();
    // PIM levers on a non-PIM platform
    for lever in [
        Lever::PimWeightStream { bits: 8 },
        Lever::PimKvAttention,
        Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA },
    ] {
        let sc = Scenario::of(vec![lever]);
        assert!(sc.validate(&orin).is_err(), "{} must need PIM", sc.name);
        assert!(evaluator(&orin).eval(&sc).is_err());
    }
    // ...and the generated matrix never contains them
    assert!(scenario_matrix(&orin).iter().all(|s| !s.requires_pim()));
    // two levers of one group
    let dup = Scenario::of(vec![
        Lever::QuantizeWeights { bits: 8 },
        Lever::QuantizeWeights { bits: 4 },
    ]);
    assert!(dup.validate(&orin).is_err());
    // a PIM-resident draft claims the PIM units exclusively
    let contended = Scenario::of(vec![
        Lever::PimKvAttention,
        Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA },
    ]);
    assert!(contended.validate(&platform::orin_pim()).is_err());
}

/// ACCEPTANCE: the scenario sweep must be a pure reordering of the serial
/// path — bitwise, over every cell of the EXPANDED (grid) matrix of a PIM
/// platform, energy, capacity, AND placement (link/$) outputs included.
#[test]
fn parallel_scenario_sweep_matches_serial_bitwise() {
    let p = platform::orin_pim();
    let ev = evaluator(&p);
    let grid = LeverGrid {
        spec_gammas: vec![2, 4],
        spec_alphas: vec![0.5],
        trace_factors: vec![0.5],
        batch_streams: vec![8],
        shard_engines: vec![2],
        offload_modes: OffloadMode::all(),
        offload_links: vec![NetLink::five_g()],
    };
    let matrix = scenario_matrix_grid(&p, &grid);
    assert!(matrix.len() > 72, "the grid must EXPAND the legacy matrix");
    // compare through the field-complete reducer — an ad-hoc tuple here
    // silently missed decode_time/avg_watts/capacity_gb/bound for two PRs
    let eval = |sc: &Scenario| result_bits(&ev.eval(sc).unwrap());
    let serial = sweep::parallel_map_with(&matrix, 1, eval);
    let parallel = sweep::parallel_map_with(&matrix, 8, eval);
    assert_eq!(serial, parallel, "scenario evaluation must be deterministic under the pool");
}

/// ACCEPTANCE: a real platform/scale pair exercises the capacity rule —
/// a bf16 30B-class model overflows the 36 GB HBM4-PIM stack; the matrix
/// still evaluates and REPORTS those rows (flag false), never drops them.
#[test]
fn capacity_invalid_scenarios_reported_not_dropped() {
    let p = platform::thor_hbm4_pim();
    let ev = Evaluator::new(&p, &opts(), &scaled_vla(30.0), &scaled_vla(2.0));
    let grid = LeverGrid::default_phase2();
    let matrix = scenario_matrix_grid(&p, &grid);
    let results: Vec<_> = matrix.iter().map(|sc| ev.eval(sc).unwrap()).collect();
    // every enumerated cell produced a row — nothing silently dropped
    assert_eq!(results.len(), matrix_size_grid(&p, &grid));
    let invalid = results.iter().filter(|r| !r.fits_capacity).count();
    let valid = results.len() - invalid;
    assert!(invalid > 0, "bf16 30B rows must overflow a 36 GB stack");
    assert!(valid > 0, "quantized/residency rows must fit a 36 GB stack");
    // the baseline is among the invalid rows, with a meaningful excess
    let base = results.iter().find(|r| r.scenario == "baseline").unwrap();
    assert!(!base.fits_capacity);
    assert!(base.footprint_gb > base.capacity_gb * 1.2, "{} GB", base.footprint_gb);
    // invalid rows still carry full projections
    assert!(base.step_latency > 0.0 && base.total_j > 0.0);
    // and capacity is monotone along the quantization ladder: W4@PIM fits
    let w4 = results.iter().find(|r| r.scenario == "W4@PIM").unwrap();
    assert!(w4.fits_capacity, "W4 30B must fit: {} GB", w4.footprint_gb);
}

/// Pareto-front laws over the REAL evaluated matrix (Hz up, J/action
/// down): front members are mutually non-dominated and every non-front
/// row is dominated by some front member.
#[test]
fn pareto_front_laws_hold_on_the_real_matrix() {
    let p = platform::thor_hbm4_pim();
    let ev = evaluator(&p);
    let results: Vec<_> = scenario_matrix_grid(&p, &LeverGrid::default_phase2())
        .iter()
        .map(|sc| ev.eval(sc).unwrap())
        .collect();
    let pts: Vec<(f64, f64)> = results.iter().map(|r| (r.control_hz, r.j_per_action)).collect();
    let front = pareto_front(&pts);
    assert!(!front.is_empty());
    let dom = |a: (f64, f64), b: (f64, f64)| -> bool {
        a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
    };
    for &i in &front {
        for &j in &front {
            assert!(i == j || !dom(pts[j], pts[i]), "front members must not dominate each other");
        }
    }
    for k in 0..pts.len() {
        if !front.contains(&k) {
            assert!(
                front.iter().any(|&i| dom(pts[i], pts[k])),
                "non-front row {} ({}) must be dominated by a front member",
                k,
                results[k].scenario
            );
        }
    }
}

/// No scenario may slow a step beyond its modeled lever overhead:
/// speedup >= 1 / modeled_overhead() for every cell of the matrix.
#[test]
fn every_scenario_within_sanity_bound() {
    for p in [platform::orin(), platform::thor_hbm4(), platform::orin_pim()] {
        let ev = evaluator(&p);
        for sc in scenario_matrix_grid(&p, &LeverGrid::default_phase2()) {
            let r = ev.eval(&sc).unwrap();
            let floor = 1.0 / sc.modeled_overhead();
            assert!(
                r.speedup_vs_baseline >= floor,
                "{} on {}: speedup {} < floor {}",
                sc.name,
                p.name,
                r.speedup_vs_baseline,
                floor
            );
        }
    }
}

/// The paper's co-design thesis, as dominance checks: on the LPDDR6X-PIM
/// platforms (and the HBM4-PIM ceiling) each PIM lever must beat its SoC
/// counterpart. The KV pair is compared at the weights-on-PIM operating
/// point — with bf16 weights streaming off-chip, decode is weight-bound
/// and KV placement cannot show.
#[test]
fn pim_levers_beat_soc_counterparts_on_pim_platforms() {
    let spec = Lever::Speculate { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA };
    let pim_spec = Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA };
    for p in platform::pim_platforms() {
        let ev = evaluator(&p);
        let hz = |levers: Vec<Lever>| ev.eval(&Scenario::of(levers)).unwrap().control_hz;
        let pairs = [
            (
                "weight streaming",
                hz(vec![Lever::PimWeightStream { bits: 8 }]),
                hz(vec![Lever::QuantizeWeights { bits: 8 }]),
            ),
            (
                "kv residency",
                hz(vec![Lever::PimWeightStream { bits: 8 }, Lever::PimKvAttention]),
                hz(vec![Lever::PimWeightStream { bits: 8 }, Lever::QuantizeKv]),
            ),
            ("draft on pim", hz(vec![pim_spec.clone()]), hz(vec![spec.clone()])),
        ];
        for (tag, pim_hz, soc_hz) in pairs {
            assert!(pim_hz > soc_hz, "{}: {tag} PIM {pim_hz} Hz <= SoC {soc_hz} Hz", p.name);
        }
    }
}

/// W4 regression (the 4-bit arm used to silently equal bf16): through the
/// scenario engine, W4 must halve the decode weight stream vs W8 and rank
/// strictly ahead of it on a bandwidth-bound platform.
#[test]
fn w4_scenario_streams_half_of_w8() {
    let ev = evaluator(&platform::orin());
    let w8 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
    let w4 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 4 }])).unwrap();
    assert!(w4.decode_time < w8.decode_time);
    // decode is BW-bound on Orin: halving the stream lands near half the time
    let ratio = w4.decode_time / w8.decode_time;
    assert!((0.4..0.75).contains(&ratio), "W4/W8 decode ratio {ratio}");
}

/// ACCEPTANCE: the serving axis is a first-class matrix member. Every
/// shard row evaluates; against its shard-free counterpart (same stack,
/// serving lever removed), replication never improves per-stream latency
/// but multiplies aggregate throughput and footprint, while a pipelined
/// decoder cuts the decode phase on an unchanged device footprint.
#[test]
fn shard_rows_evaluate_against_their_counterparts() {
    let p = platform::orin();
    let ev = evaluator(&p);
    let grid = LeverGrid { shard_engines: vec![2], ..LeverGrid::legacy() };
    let matrix = scenario_matrix_grid(&p, &grid);
    assert_eq!(matrix.len(), 24 * 3, "legacy x (none + rep2 + pipe2)");
    let mut rep_rows = 0;
    let mut pipe_rows = 0;
    for sc in &matrix {
        let Some(Lever::Shard { mode, engines }) = sc.lever(LeverGroup::Serving).cloned() else {
            continue;
        };
        assert_eq!(engines, 2);
        let r = ev.eval(sc).unwrap();
        assert_eq!(r.engines, 2, "{}", sc.name);
        let counterpart = Scenario::of(
            sc.levers.iter().filter(|l| l.group() != LeverGroup::Serving).cloned().collect(),
        );
        let c = ev.eval(&counterpart).unwrap();
        match mode {
            ShardMode::Replicate => {
                rep_rows += 1;
                // replication never speeds the per-stream step (contention
                // can only slow it) and doubles aggregate AND footprint
                assert!(r.step_latency >= c.step_latency * (1.0 - 1e-12), "{}", sc.name);
                assert!(
                    (r.aggregate_hz - 2.0 * r.amortized_hz).abs() <= 1e-9 * r.aggregate_hz,
                    "{}",
                    sc.name
                );
                assert!((r.footprint_gb / c.footprint_gb - 2.0).abs() < 1e-9, "{}", sc.name);
            }
            ShardMode::PipelineDecoder => {
                pipe_rows += 1;
                assert!(r.decode_time < c.decode_time, "{}: pipelining must cut decode", sc.name);
                assert!(r.control_hz > c.control_hz, "{}", sc.name);
                assert_eq!(r.footprint_gb.to_bits(), c.footprint_gb.to_bits(), "{}", sc.name);
            }
        }
    }
    assert_eq!(rep_rows, 24);
    assert_eq!(pipe_rows, 24);
}

/// Every output field of a [`ScenarioResult`], bit-exact: floats via
/// `to_bits`, everything else via its own equality. The comparison key the
/// incremental-vs-fresh pinning below is stated in.
fn result_bits(r: &ScenarioResult) -> (Vec<String>, Vec<u64>, (Bound, u64, u64, bool)) {
    (
        vec![r.scenario.clone(), r.platform.clone(), r.model.clone()],
        vec![
            r.decode_time.to_bits(),
            r.step_latency.to_bits(),
            r.control_hz.to_bits(),
            r.amortized_hz.to_bits(),
            r.speedup_vs_baseline.to_bits(),
            r.pim_util.to_bits(),
            r.aggregate_hz.to_bits(),
            r.total_j.to_bits(),
            r.j_per_action.to_bits(),
            r.avg_watts.to_bits(),
            r.link_s.to_bits(),
            r.usd_per_action.to_bits(),
            r.footprint_gb.to_bits(),
            r.capacity_gb.to_bits(),
        ],
        (r.bound, r.streams, r.engines, r.fits_capacity),
    )
}

/// TENTPOLE ACCEPTANCE: incremental evaluation is bitwise the fresh
/// (pre-cache) path over the ENTIRE sharded default grid on every sweep
/// platform — every output field, energy, capacity and shard columns
/// included. One cache is shared across all ten platform contexts, so the
/// sweep also exercises cross-context isolation; each scenario is then
/// re-evaluated warm to pin that cache hits never change results.
#[test]
fn incremental_eval_bitwise_matches_fresh_over_full_sharded_grid() {
    let cache = EvalCache::shared();
    let grid = LeverGrid::default_phase2_sharded();
    let mut rows = 0u64;
    for p in platform::sweep_platforms() {
        let ev = Evaluator::with_cache(&p, &opts(), &molmoact_7b(), &scaled_vla(2.0), &cache);
        let matrix = scenario_matrix_grid(&p, &grid);
        assert_eq!(matrix.len(), matrix_size_grid(&p, &grid), "{}", p.name);
        for sc in &matrix {
            let fresh = ev.eval_fresh(sc).unwrap();
            let inc = ev.eval(sc).unwrap();
            let warm = ev.eval(sc).unwrap();
            assert_eq!(result_bits(&fresh), result_bits(&inc), "{}: `{}`", p.name, sc.name);
            assert_eq!(result_bits(&inc), result_bits(&warm), "{}: `{}` warm", p.name, sc.name);
            rows += 1;
        }
    }
    // 3 PIM platforms x 510 rows + 7 SoC platforms x 180 rows; the repeat
    // evals above must all have been served from the decode-cost cache
    let s = cache.stats();
    assert_eq!(rows, 2790, "3 x 510 + 7 x 180 sweep rows");
    assert!(s.decode_cost_hits >= rows, "hits {} < rows {}", s.decode_cost_hits, rows);
}

/// TENTPOLE ACCEPTANCE: the simulation ledger the CI bench gate pins, as a
/// test — on the PIM ceiling the 510-scenario sharded grid costs 690 full
/// roofline integrations fresh and 90 incrementally (>= 5x fewer).
#[test]
fn incremental_simulation_ledger_pinned_on_the_pim_ceiling() {
    let p = platform::thor_hbm4_pim();
    let grid = LeverGrid::default_phase2_sharded();
    let matrix = scenario_matrix_grid(&p, &grid);
    assert_eq!(matrix.len(), 510);

    let fresh_cache = EvalCache::shared();
    let ev = Evaluator::with_cache(&p, &opts(), &molmoact_7b(), &scaled_vla(2.0), &fresh_cache);
    for sc in &matrix {
        ev.eval_fresh(sc).unwrap();
    }
    assert_eq!(fresh_cache.stats().integrals_computed, 690);

    let inc_cache = EvalCache::shared();
    let ev = Evaluator::with_cache(&p, &opts(), &molmoact_7b(), &scaled_vla(2.0), &inc_cache);
    for sc in &matrix {
        ev.eval(sc).unwrap();
    }
    let s = inc_cache.stats();
    assert_eq!(s.evals, 510);
    assert_eq!(s.integrals_computed, 90);
    assert!(690.0 / s.integrals_computed as f64 >= 5.0);
}

/// TENTPOLE PROPERTY: random lever stacks in random order, on a cache
/// shared between a PIM and a SoC context — cached evaluation is bitwise
/// the fresh path, repeat evaluation is bitwise the first, and the two
/// paths agree on validity. Lever-stack ORDER is deliberately shuffled:
/// the canonical decode key must make order invisible to the cache.
#[test]
fn random_lever_stacks_cached_eval_is_bitwise_fresh() {
    use vla_char::util::prop::prop_check;
    let pim = platform::thor_hbm4_pim();
    let soc = platform::orin();
    let cache = EvalCache::shared();
    let ev_pim = Evaluator::with_cache(&pim, &opts(), &molmoact_7b(), &scaled_vla(2.0), &cache);
    let ev_soc = Evaluator::with_cache(&soc, &opts(), &molmoact_7b(), &scaled_vla(2.0), &cache);
    prop_check("cached eval == fresh eval", 300, |rng| {
        let gamma = *rng.choose(&[2u64, 4, 8]);
        let alpha = *rng.choose(&[0.5, 0.7, 0.9]);
        let candidates = vec![
            Lever::QuantizeWeights { bits: *rng.choose(&[4u32, 8]) },
            Lever::PimWeightStream { bits: *rng.choose(&[4u32, 8]) },
            Lever::QuantizeKv,
            Lever::PimKvAttention,
            Lever::CompressTrace { factor: *rng.choose(&[0.25, 0.5]) },
            Lever::Speculate { gamma, alpha },
            Lever::PimDraft { gamma, alpha },
            Lever::Batch { streams: *rng.choose(&[4u64, 8, 16]) },
            Lever::Shard {
                mode: *rng.choose(&[ShardMode::Replicate, ShardMode::PipelineDecoder]),
                engines: *rng.choose(&[2u64, 4]),
            },
            Lever::Offload {
                mode: *rng.choose(&[
                    OffloadMode::VisionPrefillRemote,
                    OffloadMode::DecodeRemote,
                ]),
                link: *rng.choose(&[NetLink::five_g(), NetLink::wifi6(), NetLink::wired()]),
            },
        ];
        let mut stack: Vec<Lever> =
            candidates.into_iter().filter(|_| rng.next_f64() < 0.4).collect();
        rng.shuffle(&mut stack);
        let sc = Scenario::of(stack);
        let ev = if rng.next_f64() < 0.5 { &ev_pim } else { &ev_soc };
        match (ev.eval(&sc), ev.eval_fresh(&sc)) {
            (Ok(inc), Ok(fresh)) => {
                if result_bits(&inc) != result_bits(&fresh) {
                    return Err(format!("`{}`: cached != fresh", sc.name));
                }
                let again = ev.eval(&sc).map_err(|e| format!("`{}`: warm err {e}", sc.name))?;
                if result_bits(&again) != result_bits(&inc) {
                    return Err(format!("`{}`: warm repeat changed the result", sc.name));
                }
                Ok(())
            }
            (Err(_), Err(_)) => Ok(()),
            (a, b) => Err(format!(
                "`{}`: paths disagree on validity (cached ok={}, fresh ok={})",
                sc.name,
                a.is_ok(),
                b.is_ok()
            )),
        }
    });
}

/// ACCEPTANCE: the placement axis multiplies the closed form like every
/// other axis — the full offload grid (both modes x three link presets,
/// O = 7) enumerates, validates, and pins at 3570/1260 rows on the
/// PIM/SoC archetypes; dropping EITHER offload vector collapses the grid
/// back to the pre-offload sharded matrix.
#[test]
fn offload_grid_closed_form_pinned_against_enumeration() {
    let grid = LeverGrid::default_phase2_offload();
    for p in platform::sweep_platforms() {
        let m = scenario_matrix_grid(&p, &grid);
        assert_eq!(m.len(), matrix_size_grid(&p, &grid), "{}: closed form diverged", p.name);
        for s in &m {
            assert!(s.validate(&p).is_ok(), "{}: `{}` invalid", p.name, s.name);
        }
    }
    assert_eq!(matrix_size_grid(&platform::orin_pim(), &grid), 3570, "510 x (1 + 2x3)");
    assert_eq!(matrix_size_grid(&platform::orin(), &grid), 1260, "180 x (1 + 2x3)");
    for dropped in [
        LeverGrid { offload_links: Vec::new(), ..grid.clone() },
        LeverGrid { offload_modes: Vec::new(), ..grid.clone() },
    ] {
        assert_eq!(
            matrix_size_grid(&platform::orin_pim(), &dropped),
            matrix_size_grid(&platform::orin_pim(), &LeverGrid::default_phase2_sharded()),
            "an empty offload vector must drop the placement axis"
        );
    }
}

/// TENTPOLE ACCEPTANCE: incremental evaluation stays bitwise the fresh
/// path once placement levers enter the grid — the full offload legacy
/// grid (72 x 7 = 504 rows) on the PIM ceiling, every output field
/// including the link/$ columns, with warm repeats pinned too. The remote
/// evaluator must register the cloud tier as its own cache context.
#[test]
fn incremental_eval_bitwise_matches_fresh_with_offload_levers() {
    let p = platform::thor_hbm4_pim();
    let cache = EvalCache::shared();
    let ev = Evaluator::with_cache(&p, &opts(), &molmoact_7b(), &scaled_vla(2.0), &cache);
    let grid = LeverGrid {
        offload_modes: OffloadMode::all(),
        offload_links: NetLink::presets(),
        ..LeverGrid::legacy()
    };
    let matrix = scenario_matrix_grid(&p, &grid);
    assert_eq!(matrix.len(), 72 * 7);
    for sc in &matrix {
        let fresh = ev.eval_fresh(sc).unwrap();
        let inc = ev.eval(sc).unwrap();
        let warm = ev.eval(sc).unwrap();
        assert_eq!(result_bits(&fresh), result_bits(&inc), "`{}`", sc.name);
        assert_eq!(result_bits(&inc), result_bits(&warm), "`{}` warm", sc.name);
    }
    // the edge context plus the cloud tier the remote phases lower on
    assert!(cache.stats().contexts >= 2, "cloud context missing: {:?}", cache.stats());
}

/// Every scenario of the matrix reports a sane classification and a
/// PIM utilization only when PIM levers are present.
#[test]
fn classification_and_pim_util_are_consistent() {
    let p = platform::thor_pim();
    let ev = evaluator(&p);
    for sc in scenario_matrix(&p) {
        let r = ev.eval(&sc).unwrap();
        assert!((0.0..=1.0).contains(&r.pim_util), "{}: pim_util {}", sc.name, r.pim_util);
        if !sc.requires_pim() {
            assert_eq!(r.pim_util, 0.0, "{}: SoC scenario cannot use PIM", sc.name);
        }
        assert!(r.step_latency > 0.0 && r.control_hz > 0.0);
    }
}
