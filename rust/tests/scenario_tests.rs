//! Scenario-matrix acceptance suite: validity rules, the closed-form
//! matrix size, parallel==serial determinism over scenario evaluation,
//! the speedup sanity bound, and the PIM-vs-SoC counterpart dominance the
//! paper's co-design thesis predicts.

use vla_char::hw::platform;
use vla_char::model::molmoact::molmoact_7b;
use vla_char::model::scaling::scaled_vla;
use vla_char::sim::scenario::{
    matrix_size, scenario_matrix, Evaluator, Lever, Scenario, SPEC_ALPHA, SPEC_GAMMA,
};
use vla_char::sim::{sweep, SimOptions};

/// Scenario-engine options: ambient PIM off — exploiting PIM is a lever.
fn opts() -> SimOptions {
    SimOptions { decode_stride: 32, pim: false, ..Default::default() }
}

fn evaluator(p: &vla_char::hw::Platform) -> Evaluator {
    Evaluator::new(p, &opts(), &molmoact_7b(), &scaled_vla(2.0))
}

#[test]
fn matrix_size_matches_documented_closed_form() {
    for p in platform::sweep_platforms() {
        let m = scenario_matrix(&p);
        assert_eq!(m.len(), matrix_size(&p), "{}: closed form diverged", p.name);
        let expect = if p.mem.pim.is_some() { 72 } else { 24 };
        assert_eq!(m.len(), expect, "{}", p.name);
        for s in &m {
            assert!(s.validate(&p).is_ok(), "{}: `{}` invalid", p.name, s.name);
        }
    }
    // the acceptance floor: >= 24 valid scenarios on >= 3 PIM-capable platforms
    let pim_capable = platform::pim_platforms();
    assert!(pim_capable.len() >= 3);
    for p in &pim_capable {
        assert!(scenario_matrix(p).len() >= 24, "{}", p.name);
    }
}

#[test]
fn validity_rules_reject_impossible_combos() {
    let orin = platform::orin();
    // PIM levers on a non-PIM platform
    for lever in [
        Lever::PimWeightStream { bits: 8 },
        Lever::PimKvAttention,
        Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA },
    ] {
        let sc = Scenario::of(vec![lever]);
        assert!(sc.validate(&orin).is_err(), "{} must need PIM", sc.name);
        assert!(evaluator(&orin).eval(&sc).is_err());
    }
    // ...and the generated matrix never contains them
    assert!(scenario_matrix(&orin).iter().all(|s| !s.requires_pim()));
    // two levers of one group
    let dup = Scenario::of(vec![
        Lever::QuantizeWeights { bits: 8 },
        Lever::QuantizeWeights { bits: 4 },
    ]);
    assert!(dup.validate(&orin).is_err());
    // a PIM-resident draft claims the PIM units exclusively
    let contended = Scenario::of(vec![
        Lever::PimKvAttention,
        Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA },
    ]);
    assert!(contended.validate(&platform::orin_pim()).is_err());
}

/// The scenario sweep must be a pure reordering of the serial path —
/// bitwise, over every (scenario, platform) cell of a PIM platform.
#[test]
fn parallel_scenario_sweep_matches_serial_bitwise() {
    let p = platform::orin_pim();
    let ev = evaluator(&p);
    let matrix = scenario_matrix(&p);
    let eval = |sc: &Scenario| {
        let r = ev.eval(sc).unwrap();
        (
            r.step_latency.to_bits(),
            r.control_hz.to_bits(),
            r.amortized_hz.to_bits(),
            r.speedup_vs_baseline.to_bits(),
            r.pim_util.to_bits(),
        )
    };
    let serial = sweep::parallel_map_with(&matrix, 1, eval);
    let parallel = sweep::parallel_map_with(&matrix, 8, eval);
    assert_eq!(serial, parallel, "scenario evaluation must be deterministic under the pool");
}

/// No scenario may slow a step beyond its modeled lever overhead:
/// speedup >= 1 / modeled_overhead() for every cell of the matrix.
#[test]
fn every_scenario_within_sanity_bound() {
    for p in [platform::orin(), platform::thor_hbm4(), platform::orin_pim()] {
        let ev = evaluator(&p);
        for sc in scenario_matrix(&p) {
            let r = ev.eval(&sc).unwrap();
            let floor = 1.0 / sc.modeled_overhead();
            assert!(
                r.speedup_vs_baseline >= floor,
                "{} on {}: speedup {} < floor {}",
                sc.name,
                p.name,
                r.speedup_vs_baseline,
                floor
            );
        }
    }
}

/// The paper's co-design thesis, as dominance checks: on the LPDDR6X-PIM
/// platforms (and the HBM4-PIM ceiling) each PIM lever must beat its SoC
/// counterpart. The KV pair is compared at the weights-on-PIM operating
/// point — with bf16 weights streaming off-chip, decode is weight-bound
/// and KV placement cannot show.
#[test]
fn pim_levers_beat_soc_counterparts_on_pim_platforms() {
    let spec = Lever::Speculate { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA };
    let pim_spec = Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA };
    for p in platform::pim_platforms() {
        let ev = evaluator(&p);
        let hz = |levers: Vec<Lever>| ev.eval(&Scenario::of(levers)).unwrap().control_hz;
        let pairs = [
            (
                "weight streaming",
                hz(vec![Lever::PimWeightStream { bits: 8 }]),
                hz(vec![Lever::QuantizeWeights { bits: 8 }]),
            ),
            (
                "kv residency",
                hz(vec![Lever::PimWeightStream { bits: 8 }, Lever::PimKvAttention]),
                hz(vec![Lever::PimWeightStream { bits: 8 }, Lever::QuantizeKv]),
            ),
            ("draft on pim", hz(vec![pim_spec.clone()]), hz(vec![spec.clone()])),
        ];
        for (tag, pim_hz, soc_hz) in pairs {
            assert!(pim_hz > soc_hz, "{}: {tag} PIM {pim_hz} Hz <= SoC {soc_hz} Hz", p.name);
        }
    }
}

/// W4 regression (the 4-bit arm used to silently equal bf16): through the
/// scenario engine, W4 must halve the decode weight stream vs W8 and rank
/// strictly ahead of it on a bandwidth-bound platform.
#[test]
fn w4_scenario_streams_half_of_w8() {
    let ev = evaluator(&platform::orin());
    let w8 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
    let w4 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 4 }])).unwrap();
    assert!(w4.decode_time < w8.decode_time);
    // decode is BW-bound on Orin: halving the stream lands near half the time
    let ratio = w4.decode_time / w8.decode_time;
    assert!((0.4..0.75).contains(&ratio), "W4/W8 decode ratio {ratio}");
}

/// Every scenario of the matrix reports a sane classification and a
/// PIM utilization only when PIM levers are present.
#[test]
fn classification_and_pim_util_are_consistent() {
    let p = platform::thor_pim();
    let ev = evaluator(&p);
    for sc in scenario_matrix(&p) {
        let r = ev.eval(&sc).unwrap();
        assert!((0.0..=1.0).contains(&r.pim_util), "{}: pim_util {}", sc.name, r.pim_util);
        if !sc.requires_pim() {
            assert_eq!(r.pim_util, 0.0, "{}: SoC scenario cannot use PIM", sc.name);
        }
        assert!(r.step_latency > 0.0 && r.control_hz > 0.0);
    }
}
