//! Experiment-API surface: registry completeness (every simulator-backed
//! subcommand is a registered experiment), report-sink round-trips, the
//! parallel-sweep determinism guarantee (parallel == serial, result for
//! result), the degenerate-LeverGrid extension of the codesign
//! bitwise-identity suite, and the golden-report pin of the `pim` ranked
//! table.

use vla_char::experiment::{self, DirSink, ExpContext, Report, ReportSink, StdoutSink};
use vla_char::hw::{platform, DType, Platform};
use vla_char::model::molmoact::molmoact_7b;
use vla_char::model::scaling::scaled_vla;
use vla_char::model::VlaConfig;
use vla_char::sim::scenario::{scenario_matrix, scenario_matrix_grid, Evaluator, LeverGrid};
use vla_char::sim::{codesign, sweep, SimOptions, Simulator};
use vla_char::util::table::Table;

/// Every subcommand of the CLI — simulator- AND engine-backed — must
/// resolve to a registered experiment (the CLI dispatches on
/// `experiment::by_name`).
#[test]
fn registry_covers_every_subcommand() {
    let names: Vec<&str> = experiment::registry().iter().map(|e| e.name()).collect();
    for want in [
        "table1",
        "characterize",
        "project",
        "ablate",
        "codesign",
        "pim",
        "offload",
        "energy",
        "batch",
        "step",
        "control-loop",
        "serve",
        "fleet",
        "telemetry",
        "validate",
        "audit",
    ] {
        assert!(names.contains(&want), "subcommand `{want}` has no registered experiment");
        assert!(experiment::by_name(want).is_some());
    }
    assert_eq!(names.len(), 16, "new experiments must be added to this completeness list");
}

/// Every registered experiment runs against one shared context, passes its
/// own checks, and renders through both sinks. Engine-backed experiments
/// without a PJRT runtime must still emit a (skipped) status table and a
/// passing check.
#[test]
fn every_experiment_runs_and_emits() {
    let ctx = ExpContext {
        options: SimOptions { decode_stride: 32, ..Default::default() },
        sizes: vec![7.0, 100.0],
        batches: vec![1, 8],
        pim_sizes: vec![7.0],
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("vla_char_experiment_suite");
    let _ = std::fs::remove_dir_all(&dir);
    let mut sink = DirSink::new(&dir).unwrap();
    for e in experiment::registry() {
        let rep = e.run(&ctx).unwrap();
        assert_eq!(rep.name, e.name());
        assert!(rep.passed(), "{}: checks failed", e.name());
        assert!(rep.tables().count() > 0, "{}: no tables", e.name());
        StdoutSink.emit(&rep).unwrap();
        sink.emit(&rep).unwrap();
    }
    let (_, ok) = sink.finish().unwrap();
    assert!(ok);
    let expect_files = [
        "table1.md",
        "fig2.csv",
        "fig3.md",
        "codesign_matrix.md",
        "energy.csv",
        "pim_matrix.csv",
        "offload_matrix.csv",
        "serve_matrix.csv",
        "serve_topology.md",
        "fleet_policies.csv",
        "fleet_composition.md",
    ];
    for f in expect_files {
        assert!(dir.join(f).exists(), "missing {f}");
    }
}

/// The `serve` experiment is simulator-backed: it must RUN without a PJRT
/// runtime (no "skipped" status table), emit the ranked shard matrix with
/// one row per sweep cell, and pass its SV1..SV4 shard-model checks.
#[test]
fn serve_experiment_runs_without_pjrt_and_checks_pass() {
    let ctx = ExpContext {
        options: SimOptions { decode_stride: 32, ..Default::default() },
        shards: vec![1, 2, 4],
        shard_mode: "both".to_string(),
        deadline_ms: 200.0,
        duration_s: 2.0,
        top: 0,
        ..Default::default()
    };
    let rep = experiment::by_name("serve").unwrap().run(&ctx).unwrap();
    assert!(rep.passed(), "serve checks must pass");
    let ids: Vec<&str> = rep.checks.iter().map(|c| c.id).collect();
    for want in [
        "SV1-replicate-monotone",
        "SV2-pipeline-weights",
        "SV3-single-shard-bitwise",
        "SV4-arrival-conservation",
    ] {
        assert!(ids.contains(&want), "missing check {want}");
    }
    // no skipped-status table anywhere — the serving path is alive
    for (slug, t) in rep.tables() {
        assert!(!slug.ends_with("_status"), "serve must not skip: {slug}");
        assert!(t.n_rows() > 0, "{slug} is empty");
    }
    // topologies: rep1/rep2/rep4 + pipe2/pipe4 (pipe1 collapses into rep1);
    // cells = topologies x 3 stream points x 3 rates, all in the matrix
    let (_, topo) = rep.tables().find(|(s, _)| *s == "serve_topology").unwrap();
    assert_eq!(topo.n_rows(), 5);
    let (_, matrix) = rep.tables().find(|(s, _)| *s == "serve_matrix").unwrap();
    assert_eq!(matrix.n_rows(), 5 * 3 * 3);
}

/// The `offload` experiment emits the ranked placement matrix (with the
/// Hz / J/action / $/action objective columns), covers every enumerated
/// placement, and passes its O1..O4 checks — including the bitwise
/// all-local-vs-baseline comparison and the link-cost floor.
#[test]
fn offload_experiment_emits_ranked_placement_matrix() {
    let ctx = ExpContext {
        options: SimOptions { decode_stride: 32, ..Default::default() },
        platforms: vec![platform::orin(), platform::orin_pim()],
        pim_sizes: vec![7.0],
        top: 0,
        ..Default::default()
    };
    let rep = experiment::by_name("offload").unwrap().run(&ctx).unwrap();
    assert!(rep.passed(), "offload checks must pass");
    let ids: Vec<&str> = rep.checks.iter().map(|c| c.id).collect();
    for want in
        ["O1-all-local-bitwise", "O2-link-cost-floor", "O3-no-silent-drops", "O4-pareto3-front"]
    {
        assert!(ids.contains(&want), "missing check {want}");
    }
    let (_, t) = rep.tables().find(|(s, _)| *s == "offload_matrix").unwrap();
    assert!(t.title.contains("placement matrix"), "title: {}", t.title);
    // default grid (102 PIM + 36 SoC rows) x the armed axis (1 + 2x3)
    assert_eq!(t.n_rows(), (102 + 36) * 7);
    for col in ["Hz", "J/action", "$/action", "link (ms)"] {
        assert!(t.headers().iter().any(|h| h.as_str() == col), "missing column {col}");
    }
}

/// The refactor of `sim::codesign` onto the scenario engine must reproduce
/// the pre-scenario (PR 2) numbers BITWISE: here the original pipeline is
/// spelled out with raw simulator calls and compared to `codesign_study`
/// bit for bit, on a plain platform and on a PIM platform (where the
/// ambient auto-offload baseline must also be preserved).
#[test]
fn codesign_refactor_reproduces_legacy_numbers_bitwise() {
    let target = molmoact_7b();
    let draft = scaled_vla(2.0);
    let opt = SimOptions { decode_stride: 16, ..Default::default() };
    for p in [platform::orin(), platform::thor_pim()] {
        let decode_time = |cfg: &VlaConfig| -> f64 {
            Simulator::with_options(p.clone(), opt.clone()).simulate_decode(cfg).time
        };
        let step_with = |decode: f64| -> f64 {
            let r = Simulator::with_options(p.clone(), opt.clone()).simulate_vla(&target);
            r.vision.time + r.prefill.time + decode + r.action.time
        };
        // the PR 2 codesign pipeline, inlined
        let base_total = step_with(decode_time(&target));
        let mut w8 = target.clone();
        w8.decoder.dims.dtype = DType::I8;
        let t_w8 = step_with(decode_time(&w8));
        let t_kv = {
            let full = decode_time(&target);
            let mut short = target.clone();
            short.shape.prompt_tokens /= 2;
            short.shape.image_tokens /= 2;
            step_with((full + decode_time(&short)) / 2.0)
        };
        let mut short_cot = target.clone();
        short_cot.shape.decode_tokens /= 2;
        let t_cot = step_with(decode_time(&short_cot));
        let t_spec =
            step_with(codesign::speculative_decode_time(&p, &opt, &target, &draft, 4, 0.7));
        let mut combo = w8.clone();
        combo.shape.decode_tokens /= 2;
        let t_combo =
            step_with(codesign::speculative_decode_time(&p, &opt, &combo, &draft, 4, 0.7));

        let results = codesign::codesign_study(&p, &opt, &target, &draft);
        let want = [base_total, t_w8, t_kv, t_cot, t_spec, t_combo];
        assert_eq!(results.len(), want.len());
        for (r, w) in results.iter().zip(want) {
            assert_eq!(
                r.step_latency.to_bits(),
                w.to_bits(),
                "{} on {}: {} vs {}",
                r.technique,
                p.name,
                r.step_latency,
                w
            );
            assert_eq!(r.speedup_vs_baseline.to_bits(), (base_total / w).to_bits());
        }
    }
}

/// Extension of the codesign bitwise-identity suite: the legacy PR 3
/// fixed-point matrix (γ=4, α=0.7, 0.5x trace, no grids) must be
/// reproducible as a degenerate `LeverGrid` — same scenarios in the same
/// order, and bitwise-identical evaluations (latency AND the phase-2
/// energy/capacity outputs), so the grid machinery provably costs the
/// legacy path nothing.
#[test]
fn degenerate_grid_reproduces_legacy_matrix_bitwise() {
    let opt = SimOptions { decode_stride: 32, pim: false, ..Default::default() };
    for p in [platform::orin(), platform::thor_hbm4_pim()] {
        let legacy = scenario_matrix(&p);
        let degen = scenario_matrix_grid(&p, &LeverGrid::legacy());
        assert_eq!(legacy, degen, "{}: degenerate grid must equal the legacy matrix", p.name);
        let ev = Evaluator::new(&p, &opt, &molmoact_7b(), &scaled_vla(2.0));
        for (a, b) in legacy.iter().zip(&degen) {
            let ra = ev.eval(a).unwrap();
            let rb = ev.eval(b).unwrap();
            assert_eq!(ra.step_latency.to_bits(), rb.step_latency.to_bits(), "{}", a.name);
            assert_eq!(ra.control_hz.to_bits(), rb.control_hz.to_bits(), "{}", a.name);
            assert_eq!(ra.decode_time.to_bits(), rb.decode_time.to_bits(), "{}", a.name);
            assert_eq!(ra.total_j.to_bits(), rb.total_j.to_bits(), "{}", a.name);
            assert_eq!(ra.footprint_gb.to_bits(), rb.footprint_gb.to_bits(), "{}", a.name);
            assert_eq!(ra.fits_capacity, rb.fits_capacity, "{}", a.name);
            // at 7B every legacy row fits its device, so the phase-2
            // valid-first ranking degenerates to the original pure-Hz sort
            assert!(ra.fits_capacity, "{} on {}", a.name, p.name);
        }
    }
}

/// GOLDEN-REPORT regression: the `pim` ranked table for Thor+HBM4-PIM @ 7B
/// — header and top-3 rows — pinned through the `Table::from_csv`
/// round-trip against independently re-derived rows, so any report-shape
/// drift (column set, order, formats, ranking) fails loudly.
#[test]
fn pim_ranked_table_golden_for_thor_hbm4_pim_7b() {
    let p = platform::thor_hbm4_pim();
    let ctx = ExpContext {
        options: SimOptions { decode_stride: 32, ..Default::default() },
        platforms: vec![p.clone()],
        pim_sizes: vec![7.0],
        top: 3,
        // a single-platform sweep cannot satisfy the matrix-shape checks;
        // the golden pins the TABLE, which is built identically either way
        custom_platforms: true,
        ..Default::default()
    };
    let rep = experiment::by_name("pim").unwrap().run(&ctx).unwrap();
    let (_, table) = rep.tables().find(|(slug, _)| *slug == "pim_matrix").unwrap();

    // golden header, pinned literally
    let want_headers = [
        "#",
        "Platform",
        "model",
        "scenario",
        "step (s)",
        "Hz",
        "actions/s",
        "agg act/s",
        "J/action",
        "avg W",
        "speedup",
        "bound",
        "PIM util",
        "mem GB",
        "fits",
    ];
    assert_eq!(table.headers(), &want_headers);
    assert_eq!(table.n_rows(), 3);

    // the CSV round-trip is lossless
    let back = Table::from_csv(&table.title, &table.to_csv()).unwrap();
    assert_eq!(back.headers(), table.headers());
    assert_eq!(back.rows(), table.rows());

    // re-derive the expected top-3 rows straight from the evaluator, with
    // the experiment's exact options, grid, ranking, and cell formats
    let mut options = ctx.options.clone();
    options.decode_stride = options.decode_stride.max(8);
    options.pim = false;
    let ev = Evaluator::new(&p, &options, &scaled_vla(7.0), &ctx.draft);
    let mut results: Vec<_> = scenario_matrix_grid(&p, &ctx.lever_grid())
        .iter()
        .map(|sc| ev.eval(sc).unwrap())
        .collect();
    results.sort_by(|a, b| {
        b.fits_capacity
            .cmp(&a.fits_capacity)
            .then(b.control_hz.partial_cmp(&a.control_hz).unwrap())
    });
    for (i, r) in results.iter().take(3).enumerate() {
        let want = vec![
            format!("{}", i + 1),
            "Thor+HBM4-PIM".to_string(),
            "MolmoAct-7B".to_string(),
            r.scenario.clone(),
            format!("{:.2}", r.step_latency),
            format!("{:.3}", r.control_hz),
            format!("{:.3}", r.amortized_hz),
            format!("{:.3}", r.aggregate_hz),
            format!("{:.2}", r.j_per_action),
            format!("{:.1}", r.avg_watts),
            format!("{:.2}x", r.speedup_vs_baseline),
            r.bound.label().to_string(),
            format!("{:.0}%", 100.0 * r.pim_util),
            format!("{:.1}", r.footprint_gb),
            "yes".to_string(), // 7B fits a 36 GB stack in every lowering
        ];
        assert_eq!(back.rows()[i], want, "golden row {} drifted", i + 1);
    }
    // the winner's scenario stacks a PIM residency lever — the paper's
    // co-design thesis, visible in the golden's top row
    assert!(back.cell(0, 3).contains("@PIM"), "top scenario: {}", back.cell(0, 3));
}

/// `combined_matrix` row formatting over the scenario-backed study matches
/// the same table built from the inlined legacy pipeline.
#[test]
fn combined_matrix_rows_match_legacy_format() {
    let target = molmoact_7b();
    let draft = scaled_vla(2.0);
    let opt = SimOptions { decode_stride: 16, ..Default::default() };
    let plats = [platform::orin(), platform::thor_pim()];
    let t = codesign::combined_matrix(&plats, &opt, &target, &draft);
    assert_eq!(t.n_rows(), plats.len());
    for (i, p) in plats.iter().enumerate() {
        let results = codesign::codesign_study(p, &opt, &target, &draft);
        let base = &results[0];
        let combo = results.last().unwrap();
        assert_eq!(t.cell(i, 0), p.name);
        assert_eq!(t.cell(i, 1), format!("{:.3}", base.amortized_hz));
        assert_eq!(t.cell(i, 2), format!("{:.3}", combo.amortized_hz));
        assert_eq!(t.cell(i, 3), format!("{:.2}x", combo.speedup_vs_baseline));
    }
}

/// The markdown/CSV directory sink round-trips a table losslessly
/// (including commas, quotes, and the header row).
#[test]
fn report_sink_round_trip() {
    let dir = std::env::temp_dir().join("vla_char_sink_round_trip");
    let _ = std::fs::remove_dir_all(&dir);
    let mut t = Table::new("Round trip", &["name", "value"]).left_first();
    t.row(vec!["a,b".into(), "1.5".into()]);
    t.row(vec!["he said \"hi\"".into(), "2".into()]);
    let mut rep = Report::new("rt");
    rep.push_table("rt_table", t.clone());
    rep.note("a console note".to_string());
    rep.metric("answer", 42.0);
    let mut sink = DirSink::new(&dir).unwrap();
    sink.emit(&rep).unwrap();
    let (text, ok) = sink.finish().unwrap();
    assert!(ok && text.is_empty(), "no checks -> empty check block");
    let md = std::fs::read_to_string(dir.join("rt_table.md")).unwrap();
    assert!(md.contains("### Round trip"));
    let csv = std::fs::read_to_string(dir.join("rt_table.csv")).unwrap();
    let back = Table::from_csv("Round trip", &csv).unwrap();
    assert_eq!(back.headers(), t.headers());
    assert_eq!(back.rows(), t.rows());
    let metrics = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    assert!(metrics.contains("rt,answer,42"));
}

/// The worker pool must be a pure reordering of the serial path: same
/// work, same results, same order — bitwise, over real simulator cells.
#[test]
fn parallel_sweep_matches_serial_result_for_result() {
    let platforms = platform::sweep_platforms();
    let mut grid: Vec<(f64, Platform)> = Vec::new();
    for &s in &[2.0, 7.0, 30.0] {
        for p in &platforms {
            grid.push((s, p.clone()));
        }
    }
    let opt = SimOptions { decode_stride: 16, ..Default::default() };
    let eval = |(s, p): &(f64, Platform)| {
        let r = Simulator::with_options(p.clone(), opt.clone()).simulate_vla(&scaled_vla(*s));
        (r.total(), r.control_frequency(), r.amortized_frequency(), r.generation_share())
    };
    let serial = sweep::parallel_map_with(&grid, 1, eval);
    let parallel = sweep::parallel_map_with(&grid, 8, eval);
    assert_eq!(serial.len(), grid.len());
    assert_eq!(serial, parallel, "parallel sweep must be bitwise-identical to serial");
}

/// `fig3::run` (which routes through the pool) must agree cell-for-cell
/// with an inline serial reference in the documented grid order.
#[test]
fn fig3_sweep_matches_serial_reference() {
    let opt = SimOptions { decode_stride: 16, ..Default::default() };
    let sizes = [7.0, 100.0];
    let f = vla_char::report::fig3::run(&opt, &sizes);
    let mut k = 0;
    for &s in &sizes {
        for p in platform::sweep_platforms() {
            let r = Simulator::with_options(p.clone(), opt.clone()).simulate_vla(&scaled_vla(s));
            let c = &f.cells[k];
            assert_eq!(c.platform, p.name);
            assert_eq!(c.size_b, s);
            assert_eq!(c.hz, r.control_frequency(), "{s}B on {}", p.name);
            assert_eq!(c.amortized_hz, r.amortized_frequency());
            assert_eq!(c.total_latency, r.total());
            k += 1;
        }
    }
    assert_eq!(k, f.cells.len());
}
