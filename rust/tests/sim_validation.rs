//! E-C6: the simulator, calibrated on real PJRT-CPU measurements of the tiny
//! VLA, must predict phase latencies within the paper's 70-90% accuracy band,
//! and must agree with reality about WHICH phase dominates.
//!
//! Needs a working PJRT runtime + artifacts; with the offline `xla` stub the
//! tests log a skip and pass vacuously (self-calibration coverage lives in
//! `sim::calibrate`'s unit tests, which run everywhere).

use std::sync::Mutex;
use vla_char::engine::{FrameSource, VlaEngine, VlaModel};
use vla_char::model::Phase;
use vla_char::profile::PhaseProfiler;
use vla_char::runtime::Runtime;
use vla_char::sim::calibrate::{
    cpu_sim_options, tiny_config_from_manifest, validate, MeasuredPhases,
};
use vla_char::sim::Simulator;

static LOCK: Mutex<()> = Mutex::new(());

fn measure(steps: u64) -> Option<(vla_char::runtime::Manifest, MeasuredPhases)> {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT validation test: {e}");
            return None;
        }
    };
    // With a live client, only missing artifacts may skip; broken ones fail.
    let Ok(dir) = vla_char::runtime::artifacts_dir() else {
        eprintln!("skipping PJRT validation test: no artifacts (run `make artifacts`)");
        return None;
    };
    let model = VlaModel::load_from(&rt, &dir).expect("artifacts exist but failed to load");
    let m = model.manifest.clone();
    let engine = VlaEngine::new(model);
    let mut frames = FrameSource::new(1, m.vision.patches, m.vision.patch_dim, 42);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut prof = PhaseProfiler::new();
    for s in 0..steps {
        let r = engine.step(&frames.next_frame(0, s), &prompt).unwrap();
        prof.record(&r.times);
    }
    Some((
        m,
        MeasuredPhases {
            vision: prof.summary(Phase::Vision).p50,
            prefill: prof.summary(Phase::Prefill).p50,
            decode: prof.summary(Phase::Decode).p50,
            action: prof.summary(Phase::Action).p50,
        },
    ))
}

#[test]
fn calibrated_simulator_meets_paper_accuracy_bar() {
    let _g = LOCK.lock().unwrap();
    let Some((manifest, measured)) = measure(5) else { return };
    let v = validate(&manifest, &measured);
    let acc = v.total_accuracy();
    assert!(
        acc >= 0.70,
        "total-latency accuracy {:.1}% below the paper's 70% floor\n{}",
        acc * 100.0,
        v.table().to_markdown()
    );
    // decode (the paper's focus) must individually clear the floor
    let decode_acc = v.per_phase_accuracy()[2].3;
    assert!(decode_acc >= 0.60, "decode accuracy {:.1}%", decode_acc * 100.0);
}

#[test]
fn simulator_and_reality_agree_on_dominant_phase() {
    let _g = LOCK.lock().unwrap();
    let Some((manifest, measured)) = measure(3) else { return };
    let cfg = tiny_config_from_manifest(&manifest);
    let v = validate(&manifest, &measured);
    let sim = Simulator::with_options(
        vla_char::hw::platform::cpu_host_with(v.eff_gflops, v.eff_bw),
        cpu_sim_options(),
    );
    let pred = sim.simulate_vla(&cfg);
    // both sides: decode is the largest phase
    assert!(pred.decode.time > pred.vision.time);
    assert!(pred.decode.time > pred.prefill.time);
    assert!(measured.decode > measured.vision);
    assert!(measured.decode > measured.prefill);
    // generation share agreement within 20 points
    let real_share = (measured.prefill + measured.decode) / measured.total();
    let sim_share = pred.generation_share();
    assert!(
        (real_share - sim_share).abs() < 0.2,
        "generation share: measured {real_share:.2} vs simulated {sim_share:.2}"
    );
}
