//! Cross-module integration: engine + control loop + batcher over the real
//! PJRT artifacts, checked against the paper's qualitative claims.
//!
//! These tests need a working PJRT runtime plus `make artifacts` output. In
//! environments without either (e.g. the offline `xla` stub build), each test
//! logs a skip and passes vacuously — the simulation suites still gate CI.

use std::sync::Mutex;
use vla_char::engine::{
    run_batcher, run_control_loop, BatcherConfig, ControlLoopConfig, FrameSource, Policy,
    StepServer, VlaEngine, VlaModel,
};
use vla_char::runtime::Runtime;

static LOCK: Mutex<()> = Mutex::new(());

fn engine(decode_tokens: usize) -> Option<VlaEngine> {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT integration test: {e}");
            return None;
        }
    };
    // A real PJRT client exists. Only missing artifacts are a legitimate
    // skip; present-but-broken artifacts must FAIL, not skip.
    let Ok(dir) = vla_char::runtime::artifacts_dir() else {
        eprintln!("skipping PJRT integration test: no artifacts (run `make artifacts`)");
        return None;
    };
    let model = VlaModel::load_from(&rt, &dir).expect("artifacts exist but failed to load");
    Some(VlaEngine::with_decode_tokens(model, decode_tokens))
}

#[test]
fn decode_dominates_real_step() {
    let _g = LOCK.lock().unwrap();
    let Some(e) = engine(24) else { return };
    let m = e.model.manifest.clone();
    let mut frames = FrameSource::new(1, m.vision.patches, m.vision.patch_dim, 1);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let r = e.step(&frames.next_frame(0, 0), &prompt).unwrap();
    assert_eq!(r.tokens.len(), 24);
    assert!(
        r.times.decode > r.times.vision + r.times.prefill + r.times.action,
        "decode must be the dominant phase: {:?}",
        r.times
    );
    assert!(r.times.generation_share() > 0.5);
}

#[test]
fn decode_time_scales_with_token_budget() {
    let _g = LOCK.lock().unwrap();
    let Some(e) = engine(8) else { return };
    let m = e.model.manifest.clone();
    let mut frames = FrameSource::new(1, m.vision.patches, m.vision.patch_dim, 2);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let frame = frames.next_frame(0, 0);
    let r8 = e.step(&frame, &prompt).unwrap();
    let Some(e32) = engine(32) else { return };
    let r32 = e32.step(&frame, &prompt).unwrap();
    let ratio = r32.times.decode.as_secs_f64() / r8.times.decode.as_secs_f64();
    assert!(
        (2.0..8.0).contains(&ratio),
        "4x tokens should cost ~4x decode time, got {ratio:.2}x"
    );
}

#[test]
fn control_loop_reports_misses_and_phases() {
    let _g = LOCK.lock().unwrap();
    let Some(e) = engine(16) else { return };
    let r = run_control_loop(
        &e,
        &ControlLoopConfig {
            target_hz: 10.0,
            steps: 4,
            seed: 3,
        },
    )
    .unwrap();
    assert_eq!(r.steps, 4);
    assert_eq!(r.deadline_misses, 4, "tiny VLA on CPU cannot hit 10 Hz");
    assert!(r.achieved_hz > 0.0 && r.achieved_hz < 10.0);
    assert!(r.amortized_hz > r.achieved_hz, "chunking amortizes");
    assert!(r.mean_phase.iter().all(|t| *t > 0.0));
    assert!(r.generation_share > 0.5);
    assert!(r.latency_vs_budget() > 1.0);
}

struct EngineServer<'a>(&'a VlaEngine);

impl StepServer for EngineServer<'_> {
    fn serve(
        &mut self,
        frame: &vla_char::engine::Frame,
        prompt: &[i32],
    ) -> anyhow::Result<std::time::Duration> {
        Ok(self.0.step(frame, prompt)?.times.total())
    }
}

#[test]
fn serving_real_engine_round_robin() {
    let _g = LOCK.lock().unwrap();
    let Some(e) = engine(8) else { return };
    let m = e.model.manifest.clone();
    let frames = FrameSource::new(1, m.vision.patches, m.vision.patch_dim, 5);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut server = EngineServer(&e);
    let r = run_batcher(
        &mut server,
        m.vision.patches,
        m.vision.patch_dim,
        &prompt,
        &BatcherConfig {
            streams: 2,
            rate_hz: 1.0,
            duration_s: 2.0,
            policy: Policy::RoundRobin,
            seed: 9,
            deadline_s: None,
        },
    )
    .unwrap();
    assert!(r.served >= 2);
    assert_eq!(r.per_stream_served, r.per_stream_arrived);
    assert!(r.service.mean > 0.0);
}

#[test]
fn steps_are_deterministic() {
    let _g = LOCK.lock().unwrap();
    let Some(e) = engine(8) else { return };
    let m = e.model.manifest.clone();
    let mut f1 = FrameSource::new(1, m.vision.patches, m.vision.patch_dim, 11);
    let prompt = f1.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let frame = f1.next_frame(0, 0);
    let a = e.step(&frame, &prompt).unwrap();
    let b = e.step(&frame, &prompt).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.actions, b.actions);
}
