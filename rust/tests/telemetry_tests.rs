//! Integration tests of the telemetry subsystem: the replay invariant over
//! randomized fleet configurations, the events-off pin (NullSink leaves the
//! whole PR-7 policy grid bitwise-identical), the NDJSON wire round-trip,
//! and the traced shard batcher.

use vla_char::engine::{run_shard_batcher_traced, BatcherConfig, Policy, ShardModel};
use vla_char::sim::fleet::{
    AdmissionPolicy, AutoscalerConfig, FleetConfig, FleetReport, FleetSim, SchedulingPolicy,
    ShardSpec,
};
use vla_char::telemetry::replay::{replay, replay_ndjson, report_mismatch};
use vla_char::telemetry::{Event, RunMeta, VecSink};
use vla_char::util::prop::{ensure, prop_check};

/// Trace a fleet run into a `VecSink` alongside the live report.
fn traced(cfg: FleetConfig, specs: Vec<ShardSpec>) -> (FleetReport, Vec<Event>) {
    let sim = FleetSim::new(cfg, specs).unwrap();
    let mut sink = VecSink::new();
    let live = sim.run_traced(&RunMeta::default(), &mut sink);
    (live, sink.events)
}

/// The replay invariant survives randomized admission, scheduling,
/// autoscaler, deadline, and failure configurations — the property the
/// `fleet --events` mode stands on.
#[test]
fn replay_reconstructs_live_reports_under_random_configs() {
    prop_check("replayed == live bitwise", 60, |rng| {
        let admission = match rng.uniform_u64(0, 2) {
            0 => AdmissionPolicy::DropOnDeadline,
            1 => AdmissionPolicy::TokenBucket {
                rate_hz: rng.uniform_f64(0.5, 6.0),
                burst: rng.uniform_u64(1, 5) as u32,
            },
            _ => AdmissionPolicy::SloPriority { depth_limit: rng.uniform_usize(0, 4) },
        };
        let scheduling = *rng.choose(&[
            SchedulingPolicy::EarliestFree,
            SchedulingPolicy::RoundRobin,
            SchedulingPolicy::LeastLoaded,
            SchedulingPolicy::Edf,
        ]);
        let autoscaler = if rng.next_f64() < 0.4 {
            Some(AutoscalerConfig {
                check_interval_s: rng.uniform_f64(0.1, 0.5),
                queue_up: rng.uniform_usize(2, 8),
                queue_down: rng.uniform_usize(0, 2),
                p99_up_s: None,
                warmup_s: rng.uniform_f64(0.0, 0.5),
                min_engines: 1,
                max_engines: rng.uniform_usize(2, 6),
            })
        } else {
            None
        };
        let cfg = FleetConfig {
            streams: rng.uniform_usize(1, 6),
            rate_hz: rng.uniform_f64(0.5, 6.0),
            duration_s: rng.uniform_f64(0.5, 6.0),
            seed: rng.next_u64(),
            deadline_s: if rng.next_f64() < 0.7 { Some(rng.uniform_f64(0.05, 0.6)) } else { None },
            admission,
            scheduling,
            slo_deadline_mults: vec![0.25, 1.0, 4.0],
            autoscaler,
            failure_rate_hz: if rng.next_f64() < 0.5 { rng.uniform_f64(0.05, 2.0) } else { 0.0 },
        };
        let lanes = rng.uniform_usize(1, 4);
        let specs = vec![ShardSpec::uniform("a", lanes, rng.uniform_f64(0.02, 0.4))];
        let (live, events) = traced(cfg, specs);
        let replayed = replay(&events).map_err(|e| e.to_string())?;
        ensure(
            report_mismatch(&live, &replayed).is_none(),
            format!("replay diverged: {:?}", report_mismatch(&live, &replayed)),
        )?;
        // the NDJSON wire adds nothing and loses nothing
        let text: String = events.iter().map(|e| e.to_ndjson_line() + "\n").collect();
        let rewired = replay_ndjson(&text).map_err(|e| e.to_string())?;
        ensure(
            report_mismatch(&live, &rewired).is_none(),
            "NDJSON round-trip changed the replayed report",
        )
    });
}

/// The PR-7 acceptance grid (3 admissions x 4 schedulings x 2 fleets),
/// re-run with telemetry attached: the traced report matches the untraced
/// `run()` bitwise on every cell (NullSink pin), and every cell's stream
/// replays into the same report.
#[test]
fn policy_grid_is_bitwise_unchanged_by_telemetry_and_every_cell_replays() {
    let admissions = [
        AdmissionPolicy::DropOnDeadline,
        AdmissionPolicy::TokenBucket { rate_hz: 4.0, burst: 3 },
        AdmissionPolicy::SloPriority { depth_limit: 2 },
    ];
    let schedulings = [
        SchedulingPolicy::EarliestFree,
        SchedulingPolicy::RoundRobin,
        SchedulingPolicy::LeastLoaded,
        SchedulingPolicy::Edf,
    ];
    let fleets: [Vec<ShardSpec>; 2] = [
        vec![ShardSpec::uniform("uniform", 2, 0.15)],
        vec![ShardSpec::uniform("fast", 1, 0.08), ShardSpec::uniform("slow", 2, 0.3)],
    ];
    let mut cells = 0;
    for &admission in &admissions {
        for &scheduling in &schedulings {
            for fleet in &fleets {
                let cfg = FleetConfig {
                    streams: 5,
                    rate_hz: 3.0,
                    duration_s: 8.0,
                    seed: 13,
                    deadline_s: Some(0.4),
                    admission,
                    scheduling,
                    slo_deadline_mults: vec![0.5, 1.0, 2.0],
                    autoscaler: None,
                    failure_rate_hz: 0.0,
                };
                let sim = FleetSim::new(cfg.clone(), fleet.clone()).unwrap();
                let untraced = sim.run();
                let (live, events) = traced(cfg, fleet.clone());
                let tag = format!("{admission:?} + {scheduling:?} on {} specs", fleet.len());
                assert!(live.arrived > 0 && live.served > 0, "{tag}: empty run proves nothing");
                assert_eq!(
                    report_mismatch(&untraced, &live),
                    None,
                    "{tag}: tracing changed the report"
                );
                let replayed = replay(&events).unwrap();
                assert_eq!(
                    report_mismatch(&live, &replayed),
                    None,
                    "{tag}: stream does not replay"
                );
                cells += 1;
            }
        }
    }
    assert_eq!(cells, 24);
}

/// Streams stay parseable and monotone through the wire format, and the
/// traced shard batcher's stream certifies the live `ServeReport`.
#[test]
fn shard_batcher_stream_replays_through_the_wire() {
    use std::time::Duration;
    use vla_char::engine::{Frame, StepServer};

    struct Fixed(Duration);
    impl StepServer for Fixed {
        fn serve(&mut self, _f: &Frame, _p: &[i32]) -> anyhow::Result<Duration> {
            Ok(self.0)
        }
    }

    let cfg = BatcherConfig {
        streams: 3,
        rate_hz: 30.0,
        duration_s: 2.0,
        policy: Policy::Fifo,
        seed: 17,
        deadline_s: Some(0.08),
    };
    let model = ShardModel { mode: vla_char::engine::ShardMode::Replicate, engines: 2 };
    let mut sink = VecSink::new();
    let mut server = Fixed(Duration::from_millis(40));
    let live = run_shard_batcher_traced(
        &mut server,
        2,
        2,
        &[1],
        &cfg,
        &model,
        &RunMeta::default(),
        &mut sink,
    )
    .unwrap();

    // monotone timestamps end to end
    let mut prev = f64::NEG_INFINITY;
    for e in &sink.events {
        assert!(e.t() >= prev, "timestamp regression at {}", e.kind());
        prev = e.t();
    }

    let text: String = sink.events.iter().map(|e| e.to_ndjson_line() + "\n").collect();
    let replayed = replay_ndjson(&text).unwrap();
    assert_eq!(replayed.arrived, live.arrived);
    assert_eq!(replayed.served, live.served);
    assert_eq!(replayed.dropped, live.dropped);
    assert_eq!(replayed.throughput.to_bits(), live.throughput.to_bits());
    assert_eq!(replayed.queue_delay.p99.to_bits(), live.queue_delay.p99.to_bits());
    assert_eq!(replayed.per_stream_served, live.per_stream_served);
    assert_eq!(replayed.max_burst, live.max_burst);
}
