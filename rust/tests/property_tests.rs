//! Property-based tests over the simulator's invariants, using the in-repo
//! mini framework (`util::prop`): randomized operators, platforms, and
//! workload shapes must satisfy physical/monotonicity laws.

use vla_char::engine::{
    run_batcher, run_shard_batcher, BatcherConfig, Frame, Policy, ShardMode, ShardModel,
    StepServer,
};
use vla_char::hw::{platform, DType};
use vla_char::model::layer::BlockDims;
use vla_char::model::molmoact::molmoact_7b;
use vla_char::model::scaling::{scaled_vla, ANCHOR_SIZES_B};
use vla_char::model::Operator;
use vla_char::sim::scenario::{
    matrix_size_grid, pareto_front, pareto_front3, scenario_matrix_grid, Lever, LeverGrid, NetLink,
    OffloadMode, Scenario,
};
use vla_char::sim::{cost_on_soc, cost_op, SimOptions, Simulator};
use vla_char::util::json::Json;
use vla_char::util::prng::Prng;
use vla_char::util::prop::{ensure, ensure_close, prop_check};

fn random_matmul(rng: &mut Prng) -> Operator {
    let batch = rng.uniform_u64(1, 8);
    let m = rng.uniform_u64(1, 2048);
    let n = rng.uniform_u64(16, 8192);
    let k = rng.uniform_u64(16, 8192);
    Operator::matmul_weight("op", batch, m, n, k, DType::BF16)
}

#[test]
fn op_time_at_least_every_bound() {
    prop_check("t >= max(compute, mem) individually", 300, |rng| {
        let op = random_matmul(rng);
        let c = cost_on_soc(&platform::orin(), &op);
        ensure(
            c.t_serial() + 1e-15 >= c.t_compute,
            format!("serial {} < compute {}", c.t_serial(), c.t_compute),
        )?;
        ensure(
            c.t_serial() + 1e-15 >= c.t_mem_weights + c.t_mem_other,
            "serial below memory time",
        )?;
        ensure(c.t_serial().is_finite() && c.t_serial() > 0.0, "non-finite time")
    });
}

#[test]
fn more_bandwidth_never_slower() {
    prop_check("op time monotone in DRAM bandwidth", 200, |rng| {
        let op = random_matmul(rng);
        let t_lo = cost_on_soc(&platform::orin(), &op).t_serial();
        let t_hi = cost_on_soc(&platform::orin_gddr7(), &op).t_serial();
        ensure(
            t_hi <= t_lo * 1.0001,
            format!("GDDR7 slower than LPDDR5: {t_hi} vs {t_lo}"),
        )
    });
}

#[test]
fn pim_option_never_hurts() {
    prop_check("engine choice is a min", 200, |rng| {
        let op = random_matmul(rng);
        let plat = platform::orin_pim();
        let with = cost_op(&plat, &op, true).t_serial();
        let without = cost_op(&plat, &op, false).t_serial();
        ensure(
            with <= without * 1.0001,
            format!("PIM offload made op slower: {with} vs {without}"),
        )
    });
}

#[test]
fn prefetch_never_hurts_stages() {
    prop_check("prefetch <= serial for random decode positions", 40, |rng| {
        let cfg = molmoact_7b();
        let pos = rng.uniform_u64(1, 2000);
        let stage = cfg.decode_stage_at(pos);
        let plat = rng.uniform_usize(0, 3);
        let plat = platform::table1_platforms()[plat].clone();
        let on =
            Simulator::with_options(plat.clone(), SimOptions::default()).simulate_stage(&stage);
        let off = Simulator::with_options(
            plat,
            SimOptions {
                prefetch: false,
                ..Default::default()
            },
        )
        .simulate_stage(&stage);
        ensure(
            on.time <= off.time * 1.0001,
            format!("prefetch hurt at pos {pos}: {} vs {}", on.time, off.time),
        )
    });
}

#[test]
fn decode_time_monotone_in_kv_length() {
    prop_check("longer cache never cheaper", 60, |rng| {
        let cfg = molmoact_7b();
        let a = rng.uniform_u64(1, 1500);
        let b = a + rng.uniform_u64(1, 500);
        let sim = Simulator::new(platform::thor());
        let ta = sim.simulate_stage(&cfg.decode_stage_at(a)).time;
        let tb = sim.simulate_stage(&cfg.decode_stage_at(b)).time;
        ensure(tb + 1e-12 >= ta, format!("kv {a}->{b}: {ta} -> {tb}"))
    });
}

#[test]
fn stage_flop_byte_accounting_consistent() {
    prop_check("stage totals equal op sums", 60, |rng| {
        let d = BlockDims {
            hidden: 64 * rng.uniform_u64(1, 8),
            heads: 8,
            kv_heads: 4,
            head_dim: 32,
            ffn: 128 * rng.uniform_u64(1, 8),
            dtype: DType::BF16,
        };
        let ops = vla_char::model::layer::decoder_block_decode("x", &d, rng.uniform_u64(1, 512));
        let stage = vla_char::model::Stage::new("s", vla_char::model::Phase::Decode, ops.clone());
        let flops: f64 = ops.iter().map(|o| o.flops).sum();
        let bytes: f64 = ops.iter().map(|o| o.total_bytes()).sum();
        ensure_close(stage.total_flops(), flops, 1e-12, "flops")?;
        ensure_close(stage.total_bytes(), bytes, 1e-12, "bytes")
    });
}

#[test]
fn vla_total_is_sum_of_phases() {
    prop_check("phase decomposition sums to total", 8, |rng| {
        let size = *rng.choose(&ANCHOR_SIZES_B);
        let cfg = scaled_vla(size);
        let sim = Simulator::with_options(
            platform::thor(),
            SimOptions {
                decode_stride: 32,
                ..Default::default()
            },
        );
        let r = sim.simulate_vla(&cfg);
        let sum: f64 = r.stages().iter().map(|s| s.time).sum();
        ensure_close(r.total(), sum, 1e-12, "total")?;
        ensure((0.0..=1.0).contains(&r.generation_share()), "share in [0,1]")
    });
}

#[test]
fn scaling_latency_superlinear_in_params() {
    // doubling params should at least not reduce step latency (usually ~2x)
    prop_check("bigger model never faster", 5, |rng| {
        let i = rng.uniform_usize(0, ANCHOR_SIZES_B.len() - 2);
        let small = scaled_vla(ANCHOR_SIZES_B[i]);
        let big = scaled_vla(ANCHOR_SIZES_B[i + 1]);
        let sim = Simulator::with_options(
            platform::orin(),
            SimOptions {
                decode_stride: 32,
                ..Default::default()
            },
        );
        let ts = sim.simulate_vla(&small).total();
        let tb = sim.simulate_vla(&big).total();
        let msg = format!("{}B {} vs {}B {}", ANCHOR_SIZES_B[i], ts, ANCHOR_SIZES_B[i + 1], tb);
        ensure(tb > ts, msg)
    });
}

#[test]
fn pareto_front_laws_on_random_point_clouds() {
    // the ranking's two laws: front members are mutually non-dominated,
    // and every non-front point is dominated by some front member
    prop_check("pareto front laws", 200, |rng| {
        let n = rng.uniform_usize(1, 60);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.uniform_f64(0.1, 10.0), rng.uniform_f64(0.1, 10.0)))
            .collect();
        let front = pareto_front(&pts);
        ensure(!front.is_empty(), "front of a non-empty set is non-empty")?;
        let dom = |a: (f64, f64), b: (f64, f64)| -> bool {
            a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
        };
        for &i in &front {
            for &j in &front {
                if i != j {
                    ensure(!dom(pts[j], pts[i]), format!("front member {j} dominates {i}"))?;
                }
            }
        }
        for k in 0..n {
            if !front.contains(&k) {
                ensure(
                    front.iter().any(|&i| dom(pts[i], pts[k])),
                    format!("non-front point {k} undominated"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn pareto_front3_laws_on_random_point_clouds() {
    // the three-objective ranking's laws: mutual non-domination, full
    // coverage of the dominated set, and 2-objective degeneracy when the
    // third axis carries no information (all-local rows share $/action 0)
    prop_check("3-objective pareto front laws", 200, |rng| {
        let n = rng.uniform_usize(1, 60);
        let pts: Vec<(f64, f64, f64)> = (0..n)
            .map(|_| {
                (
                    rng.uniform_f64(0.1, 10.0),
                    rng.uniform_f64(0.1, 10.0),
                    rng.uniform_f64(0.1, 10.0),
                )
            })
            .collect();
        let front = pareto_front3(&pts);
        ensure(!front.is_empty(), "front of a non-empty set is non-empty")?;
        let dom = |a: (f64, f64, f64), b: (f64, f64, f64)| -> bool {
            a.0 >= b.0 && a.1 <= b.1 && a.2 <= b.2 && (a.0 > b.0 || a.1 < b.1 || a.2 < b.2)
        };
        for &i in &front {
            for &j in &front {
                if i != j {
                    ensure(!dom(pts[j], pts[i]), format!("front member {j} dominates {i}"))?;
                }
            }
        }
        for k in 0..n {
            if !front.contains(&k) {
                ensure(
                    front.iter().any(|&i| dom(pts[i], pts[k])),
                    format!("non-front point {k} undominated"),
                )?;
            }
        }
        // a constant third objective must reduce to the 2-objective front,
        // index for index (both functions preserve input order)
        let flat: Vec<(f64, f64, f64)> = pts.iter().map(|p| (p.0, p.1, 1.0)).collect();
        let flat2: Vec<(f64, f64)> = pts.iter().map(|p| (p.0, p.1)).collect();
        ensure(
            pareto_front3(&flat) == pareto_front(&flat2),
            "constant $/action must degenerate to the 2-objective front",
        )
    });
}

/// Build a random structurally-valid lever stack from the SoC axes (no PIM
/// levers, so it validates on any platform); `shrink_scenario` derives a
/// counterpart whose footprint is no larger.
fn random_soc_scenario(rng: &mut Prng) -> Vec<Lever> {
    let mut levers = Vec::new();
    match rng.uniform_u64(0, 3) {
        1 => levers.push(Lever::QuantizeWeights { bits: 8 }),
        2 => levers.push(Lever::QuantizeWeights { bits: 4 }),
        _ => {}
    }
    if rng.next_f64() < 0.5 {
        levers.push(Lever::QuantizeKv);
    }
    if rng.next_f64() < 0.5 {
        levers.push(Lever::CompressTrace { factor: rng.uniform_f64(0.2, 0.9) });
    }
    // batch xor speculation (the validity rule)
    match rng.uniform_u64(0, 3) {
        1 => levers.push(Lever::Speculate {
            gamma: rng.uniform_u64(2, 9),
            alpha: rng.uniform_f64(0.3, 0.95),
        }),
        2 => levers.push(Lever::Batch { streams: rng.uniform_u64(2, 17) }),
        _ => {}
    }
    // optional serving topology
    match rng.uniform_u64(0, 3) {
        1 => levers
            .push(Lever::Shard { mode: ShardMode::Replicate, engines: rng.uniform_u64(2, 9) }),
        2 => levers
            .push(Lever::Shard { mode: ShardMode::PipelineDecoder, engines: rng.uniform_u64(2, 9) }),
        _ => {}
    }
    levers
}

/// Derive a counterpart whose footprint is <= the original's: step the
/// weight lever down the quantization ladder, drop the draft, or halve the
/// batch — each strictly shrinks one footprint term, none grows any.
fn shrink_scenario(rng: &mut Prng, levers: &[Lever]) -> Vec<Lever> {
    let mut out: Vec<Lever> = levers.to_vec();
    match rng.uniform_u64(0, 5) {
        0 => {
            // W- ladder: none -> W8 -> W4
            if let Some(w) = out.iter_mut().find(|l| matches!(l, Lever::QuantizeWeights { .. })) {
                *w = Lever::QuantizeWeights { bits: 4 };
            } else {
                out.insert(0, Lever::QuantizeWeights { bits: 8 });
            }
        }
        1 => out.retain(|l| !matches!(l, Lever::Speculate { .. })), // drop the draft
        2 => {
            for l in out.iter_mut() {
                if let Lever::Batch { streams } = l {
                    *streams = (*streams / 2).max(1);
                }
            }
        }
        3 => {
            // halve the replica count: footprint is linear in replicate
            // engines (a pipeline's device footprint is R-invariant)
            for l in out.iter_mut() {
                if let Lever::Shard { mode: ShardMode::Replicate, engines } = l {
                    *engines = (*engines / 2).max(1);
                }
            }
        }
        _ => {
            let have_kv = out.iter().any(|l| matches!(l, Lever::QuantizeKv));
            if !have_kv {
                out.push(Lever::QuantizeKv);
            }
        }
    }
    out
}

#[test]
fn capacity_validity_monotone_in_footprint() {
    // if a scenario fits a device, any counterpart with a smaller (or
    // equal) footprint fits it too — at a RANDOM capacity point, so the
    // boundary itself moves per case
    let target = molmoact_7b();
    let draft = scaled_vla(2.0);
    prop_check("capacity monotone", 150, |rng| {
        let levers = random_soc_scenario(rng);
        let bigger = Scenario::of(levers.clone());
        let smaller = Scenario::of(shrink_scenario(rng, &levers));
        let fp_big = bigger.memory_footprint(&target, &draft);
        let fp_small = smaller.memory_footprint(&target, &draft);
        ensure(
            fp_small <= fp_big,
            format!("`{}` ({fp_small:.3e} B) > `{}` ({fp_big:.3e} B)", smaller.name, bigger.name),
        )?;
        let mut p = platform::orin();
        p.mem.capacity = rng.uniform_f64(1e9, 80e9);
        if bigger.fits_capacity(&p, &target, &draft) {
            ensure(
                smaller.fits_capacity(&p, &target, &draft),
                format!(
                    "`{}` fits {:.1} GB but `{}` does not",
                    bigger.name,
                    p.mem.capacity_gb(),
                    smaller.name
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn replicate_aggregate_monotone_in_engine_count() {
    // aggregate throughput R / (other + decode * max(1, R*q)) is monotone
    // non-decreasing in R for ANY positive step split and link demand
    // ratio: below saturation it grows linearly, past it it approaches the
    // bandwidth-bound asymptote from below — never regresses
    prop_check("replicate aggregate monotone until saturation", 200, |rng| {
        let other = rng.uniform_f64(1e-3, 10.0);
        let decode = rng.uniform_f64(1e-3, 30.0);
        let link_bw = rng.uniform_f64(1e9, 2e12);
        // the demand ESTIMATE may exceed the link (it is an upper bound on
        // an engine's pull); the contention model clamps it to the link
        let demand = rng.uniform_f64(0.0, 2.0) * link_bw;
        let mut prev = 0.0f64;
        for engines in 1..=16u64 {
            let m = ShardModel { mode: ShardMode::Replicate, engines };
            let step = other + decode * m.contention(demand, link_bw);
            let agg = engines as f64 / step;
            ensure(
                agg >= prev * (1.0 - 1e-12),
                format!("aggregate regressed at R={engines}: {prev} -> {agg}"),
            )?;
            // contention (and hence per-stream slow-down) is bounded by R
            ensure(m.contention(demand, link_bw) <= engines as f64 + 1e-12, "contention > R")?;
            prev = agg;
        }
        Ok(())
    });
}

#[test]
fn pipeline_per_engine_footprint_monotone_decreasing() {
    prop_check("pipeline weights shard 1/R", 200, |rng| {
        let weights = rng.uniform_f64(1e9, 2e11);
        let mut prev = f64::INFINITY;
        for engines in 1..=12u64 {
            let m = ShardModel { mode: ShardMode::PipelineDecoder, engines };
            let per = m.per_engine_weight_bytes(weights);
            ensure(per < prev, format!("per-engine weights not decreasing at R={engines}"))?;
            ensure_close(per * engines as f64, weights, 1e-12, "1/R shard")?;
            // the device holds ONE partitioned copy regardless of R
            ensure_close(m.device_footprint_bytes(weights), weights, 0.0, "device copy")?;
            prev = per;
        }
        // replicate is the opposite deal: full copy per engine, R on device
        let rep = ShardModel { mode: ShardMode::Replicate, engines: 6 };
        ensure_close(rep.per_engine_weight_bytes(weights), weights, 0.0, "full copy")?;
        ensure_close(rep.device_footprint_bytes(weights), 6.0 * weights, 1e-12, "R copies")
    });
}

struct FixedServer(std::time::Duration);

impl StepServer for FixedServer {
    fn serve(&mut self, _f: &Frame, _p: &[i32]) -> anyhow::Result<std::time::Duration> {
        Ok(self.0)
    }
}

#[test]
fn single_shard_bitwise_equals_legacy_batcher() {
    // over random serving configs (streams, rate, policy, deadline,
    // service time), one shard — replicate-1 or pipeline-1 — must be
    // BITWISE the legacy run_batcher path
    prop_check("one shard == run_batcher, bit for bit", 40, |rng| {
        let cfg = BatcherConfig {
            streams: rng.uniform_usize(1, 5),
            rate_hz: rng.uniform_f64(0.5, 4.0),
            duration_s: rng.uniform_f64(1.0, 6.0),
            policy: if rng.next_f64() < 0.5 { Policy::Fifo } else { Policy::RoundRobin },
            seed: rng.next_u64(),
            deadline_s: if rng.next_f64() < 0.5 {
                Some(rng.uniform_f64(0.05, 1.0))
            } else {
                None
            },
        };
        let service = std::time::Duration::from_micros(rng.uniform_u64(1_000, 800_000));
        let legacy = run_batcher(&mut FixedServer(service), 2, 2, &[1], &cfg)
            .map_err(|e| e.to_string())?;
        for mode in [ShardMode::Replicate, ShardMode::PipelineDecoder] {
            let model = ShardModel { mode, engines: 1 };
            let sharded = run_shard_batcher(&mut FixedServer(service), 2, 2, &[1], &cfg, &model)
                .map_err(|e| e.to_string())?;
            ensure(sharded.arrived == legacy.arrived, "arrived differs")?;
            ensure(sharded.served == legacy.served, "served differs")?;
            ensure(sharded.dropped == legacy.dropped, "dropped differs")?;
            ensure(
                sharded.throughput.to_bits() == legacy.throughput.to_bits(),
                "throughput bits differ",
            )?;
            ensure(
                sharded.queue_delay.p50.to_bits() == legacy.queue_delay.p50.to_bits()
                    && sharded.queue_delay.p99.to_bits() == legacy.queue_delay.p99.to_bits(),
                "queue-delay bits differ",
            )?;
            ensure(sharded.per_stream_served == legacy.per_stream_served, "per-stream differs")?;
            ensure(sharded.max_burst == legacy.max_burst, "burst differs")?;
        }
        Ok(())
    });
}

#[test]
fn grid_closed_form_matches_enumeration_on_random_grids() {
    prop_check("matrix_size_grid == |scenario_matrix_grid|", 40, |rng| {
        let list_u64 = |rng: &mut Prng, max_len: usize, lo: u64, hi: u64| -> Vec<u64> {
            (0..rng.uniform_usize(0, max_len)).map(|_| rng.uniform_u64(lo, hi)).collect()
        };
        let n_alpha = rng.uniform_usize(1, 4);
        let n_trace = rng.uniform_usize(0, 3);
        let mut modes = Vec::new();
        if rng.next_f64() < 0.5 {
            modes.push(OffloadMode::VisionPrefillRemote);
        }
        if rng.next_f64() < 0.5 {
            modes.push(OffloadMode::DecodeRemote);
        }
        let links: Vec<NetLink> = (0..rng.uniform_usize(0, 3))
            .map(|_| *rng.choose(&[NetLink::five_g(), NetLink::wifi6(), NetLink::wired()]))
            .collect();
        let grid = LeverGrid {
            spec_gammas: list_u64(rng, 3, 1, 9),
            spec_alphas: (0..n_alpha).map(|_| rng.uniform_f64(0.1, 0.9)).collect(),
            trace_factors: (0..n_trace).map(|_| rng.uniform_f64(0.1, 0.9)).collect(),
            batch_streams: list_u64(rng, 2, 2, 33),
            shard_engines: list_u64(rng, 2, 1, 9),
            offload_modes: modes,
            offload_links: links,
        };
        for p in [platform::orin(), platform::orin_pim()] {
            let n = scenario_matrix_grid(&p, &grid).len();
            let want = matrix_size_grid(&p, &grid);
            ensure(n == want, format!("{}: {n} != {want} for {grid:?}", p.name))?;
        }
        Ok(())
    });
}

#[test]
fn json_roundtrips_random_documents() {
    fn random_json(rng: &mut Prng, depth: u32) -> Json {
        match if depth == 0 { rng.uniform_u64(0, 3) } else { rng.uniform_u64(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.uniform_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.uniform_u64(0, 999))),
            4 => {
                Json::Arr((0..rng.uniform_u64(0, 4)).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => Json::Obj(
                (0..rng.uniform_u64(0, 4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop_check("parse(serialize(x)) == x", 300, |rng| {
        let doc = random_json(rng, 3);
        let compact = Json::parse(&doc.to_string_compact()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&doc.to_string_pretty()).map_err(|e| e.to_string())?;
        ensure(compact == doc, "compact roundtrip")?;
        ensure(pretty == doc, "pretty roundtrip")
    });
}

#[test]
fn stats_percentiles_ordered() {
    prop_check("min <= p50 <= p90 <= p99 <= max", 200, |rng| {
        let n = rng.uniform_usize(1, 200);
        let samples: Vec<f64> = (0..n).map(|_| rng.normal_ms(10.0, 5.0)).collect();
        let s = vla_char::util::stats::Summary::of(&samples);
        ensure(
            s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max,
            format!("{s:?}"),
        )?;
        ensure(s.mean >= s.min && s.mean <= s.max, "mean within range")
    });
}

#[test]
fn decode_stride_interpolation_bounded_error() {
    prop_check("stride sampling error < 3%", 6, |rng| {
        let cfg = molmoact_7b();
        let stride = rng.uniform_u64(2, 32);
        let plat = platform::table1_platforms()[rng.uniform_usize(0, 6)].clone();
        let exact = Simulator::with_options(plat.clone(), SimOptions::default())
            .simulate_decode(&cfg)
            .time;
        let approx = Simulator::with_options(
            plat,
            SimOptions {
                decode_stride: stride,
                ..Default::default()
            },
        )
        .simulate_decode(&cfg)
        .time;
        ensure(
            (exact - approx).abs() / exact < 0.03,
            format!("stride {stride}: exact {exact} vs {approx}"),
        )
    });
}
