//! Property-based tests over the simulator's invariants, using the in-repo
//! mini framework (`util::prop`): randomized operators, platforms, and
//! workload shapes must satisfy physical/monotonicity laws.

use vla_char::hw::{platform, DType};
use vla_char::model::layer::BlockDims;
use vla_char::model::molmoact::molmoact_7b;
use vla_char::model::scaling::{scaled_vla, ANCHOR_SIZES_B};
use vla_char::model::Operator;
use vla_char::sim::{cost_on_soc, cost_op, SimOptions, Simulator};
use vla_char::util::json::Json;
use vla_char::util::prng::Prng;
use vla_char::util::prop::{ensure, ensure_close, prop_check};

fn random_matmul(rng: &mut Prng) -> Operator {
    let batch = rng.uniform_u64(1, 8);
    let m = rng.uniform_u64(1, 2048);
    let n = rng.uniform_u64(16, 8192);
    let k = rng.uniform_u64(16, 8192);
    Operator::matmul_weight("op", batch, m, n, k, DType::BF16)
}

#[test]
fn op_time_at_least_every_bound() {
    prop_check("t >= max(compute, mem) individually", 300, |rng| {
        let op = random_matmul(rng);
        let c = cost_on_soc(&platform::orin(), &op);
        ensure(
            c.t_serial() + 1e-15 >= c.t_compute,
            format!("serial {} < compute {}", c.t_serial(), c.t_compute),
        )?;
        ensure(
            c.t_serial() + 1e-15 >= c.t_mem_weights + c.t_mem_other,
            "serial below memory time",
        )?;
        ensure(c.t_serial().is_finite() && c.t_serial() > 0.0, "non-finite time")
    });
}

#[test]
fn more_bandwidth_never_slower() {
    prop_check("op time monotone in DRAM bandwidth", 200, |rng| {
        let op = random_matmul(rng);
        let t_lo = cost_on_soc(&platform::orin(), &op).t_serial();
        let t_hi = cost_on_soc(&platform::orin_gddr7(), &op).t_serial();
        ensure(
            t_hi <= t_lo * 1.0001,
            format!("GDDR7 slower than LPDDR5: {t_hi} vs {t_lo}"),
        )
    });
}

#[test]
fn pim_option_never_hurts() {
    prop_check("engine choice is a min", 200, |rng| {
        let op = random_matmul(rng);
        let plat = platform::orin_pim();
        let with = cost_op(&plat, &op, true).t_serial();
        let without = cost_op(&plat, &op, false).t_serial();
        ensure(
            with <= without * 1.0001,
            format!("PIM offload made op slower: {with} vs {without}"),
        )
    });
}

#[test]
fn prefetch_never_hurts_stages() {
    prop_check("prefetch <= serial for random decode positions", 40, |rng| {
        let cfg = molmoact_7b();
        let pos = rng.uniform_u64(1, 2000);
        let stage = cfg.decode_stage_at(pos);
        let plat = rng.uniform_usize(0, 3);
        let plat = platform::table1_platforms()[plat].clone();
        let on =
            Simulator::with_options(plat.clone(), SimOptions::default()).simulate_stage(&stage);
        let off = Simulator::with_options(
            plat,
            SimOptions {
                prefetch: false,
                ..Default::default()
            },
        )
        .simulate_stage(&stage);
        ensure(
            on.time <= off.time * 1.0001,
            format!("prefetch hurt at pos {pos}: {} vs {}", on.time, off.time),
        )
    });
}

#[test]
fn decode_time_monotone_in_kv_length() {
    prop_check("longer cache never cheaper", 60, |rng| {
        let cfg = molmoact_7b();
        let a = rng.uniform_u64(1, 1500);
        let b = a + rng.uniform_u64(1, 500);
        let sim = Simulator::new(platform::thor());
        let ta = sim.simulate_stage(&cfg.decode_stage_at(a)).time;
        let tb = sim.simulate_stage(&cfg.decode_stage_at(b)).time;
        ensure(tb + 1e-12 >= ta, format!("kv {a}->{b}: {ta} -> {tb}"))
    });
}

#[test]
fn stage_flop_byte_accounting_consistent() {
    prop_check("stage totals equal op sums", 60, |rng| {
        let d = BlockDims {
            hidden: 64 * rng.uniform_u64(1, 8),
            heads: 8,
            kv_heads: 4,
            head_dim: 32,
            ffn: 128 * rng.uniform_u64(1, 8),
            dtype: DType::BF16,
        };
        let ops = vla_char::model::layer::decoder_block_decode("x", &d, rng.uniform_u64(1, 512));
        let stage = vla_char::model::Stage::new("s", vla_char::model::Phase::Decode, ops.clone());
        let flops: f64 = ops.iter().map(|o| o.flops).sum();
        let bytes: f64 = ops.iter().map(|o| o.total_bytes()).sum();
        ensure_close(stage.total_flops(), flops, 1e-12, "flops")?;
        ensure_close(stage.total_bytes(), bytes, 1e-12, "bytes")
    });
}

#[test]
fn vla_total_is_sum_of_phases() {
    prop_check("phase decomposition sums to total", 8, |rng| {
        let size = *rng.choose(&ANCHOR_SIZES_B);
        let cfg = scaled_vla(size);
        let sim = Simulator::with_options(
            platform::thor(),
            SimOptions {
                decode_stride: 32,
                ..Default::default()
            },
        );
        let r = sim.simulate_vla(&cfg);
        let sum: f64 = r.stages().iter().map(|s| s.time).sum();
        ensure_close(r.total(), sum, 1e-12, "total")?;
        ensure((0.0..=1.0).contains(&r.generation_share()), "share in [0,1]")
    });
}

#[test]
fn scaling_latency_superlinear_in_params() {
    // doubling params should at least not reduce step latency (usually ~2x)
    prop_check("bigger model never faster", 5, |rng| {
        let i = rng.uniform_usize(0, ANCHOR_SIZES_B.len() - 2);
        let small = scaled_vla(ANCHOR_SIZES_B[i]);
        let big = scaled_vla(ANCHOR_SIZES_B[i + 1]);
        let sim = Simulator::with_options(
            platform::orin(),
            SimOptions {
                decode_stride: 32,
                ..Default::default()
            },
        );
        let ts = sim.simulate_vla(&small).total();
        let tb = sim.simulate_vla(&big).total();
        let msg = format!("{}B {} vs {}B {}", ANCHOR_SIZES_B[i], ts, ANCHOR_SIZES_B[i + 1], tb);
        ensure(tb > ts, msg)
    });
}

#[test]
fn json_roundtrips_random_documents() {
    fn random_json(rng: &mut Prng, depth: u32) -> Json {
        match if depth == 0 { rng.uniform_u64(0, 3) } else { rng.uniform_u64(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.uniform_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.uniform_u64(0, 999))),
            4 => {
                Json::Arr((0..rng.uniform_u64(0, 4)).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => Json::Obj(
                (0..rng.uniform_u64(0, 4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop_check("parse(serialize(x)) == x", 300, |rng| {
        let doc = random_json(rng, 3);
        let compact = Json::parse(&doc.to_string_compact()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&doc.to_string_pretty()).map_err(|e| e.to_string())?;
        ensure(compact == doc, "compact roundtrip")?;
        ensure(pretty == doc, "pretty roundtrip")
    });
}

#[test]
fn stats_percentiles_ordered() {
    prop_check("min <= p50 <= p90 <= p99 <= max", 200, |rng| {
        let n = rng.uniform_usize(1, 200);
        let samples: Vec<f64> = (0..n).map(|_| rng.normal_ms(10.0, 5.0)).collect();
        let s = vla_char::util::stats::Summary::of(&samples);
        ensure(
            s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max,
            format!("{s:?}"),
        )?;
        ensure(s.mean >= s.min && s.mean <= s.max, "mean within range")
    });
}

#[test]
fn decode_stride_interpolation_bounded_error() {
    prop_check("stride sampling error < 3%", 6, |rng| {
        let cfg = molmoact_7b();
        let stride = rng.uniform_u64(2, 32);
        let plat = platform::table1_platforms()[rng.uniform_usize(0, 6)].clone();
        let exact = Simulator::with_options(plat.clone(), SimOptions::default())
            .simulate_decode(&cfg)
            .time;
        let approx = Simulator::with_options(
            plat,
            SimOptions {
                decode_stride: stride,
                ..Default::default()
            },
        )
        .simulate_decode(&cfg)
        .time;
        ensure(
            (exact - approx).abs() / exact < 0.03,
            format!("stride {stride}: exact {exact} vs {approx}"),
        )
    });
}
