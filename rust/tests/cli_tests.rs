//! CLI surface: every subcommand parses, runs, and returns the right exit
//! code (simulation-only commands here; PJRT commands are covered by the
//! integration suite and examples).

use vla_char::cli;

fn run(args: &[&str]) -> anyhow::Result<i32> {
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    cli::run(&argv)
}

#[test]
fn help_exits_zero() {
    assert_eq!(run(&["--help"]).unwrap(), 0);
    assert_eq!(run(&[]).unwrap(), 0);
}

#[test]
fn unknown_subcommand_exits_two() {
    assert_eq!(run(&["frobnicate"]).unwrap(), 2);
}

#[test]
fn unknown_flag_is_error() {
    assert!(run(&["table1", "--bogus"]).is_err());
}

#[test]
fn table1_ok() {
    assert_eq!(run(&["table1"]).unwrap(), 0);
}

#[test]
fn characterize_passes_checks() {
    assert_eq!(run(&["characterize", "--stride", "8"]).unwrap(), 0);
}

#[test]
fn characterize_with_trace_and_platform() {
    assert_eq!(
        run(&["characterize", "--stride", "8", "--trace", "--platform", "thor+pim"]).unwrap(),
        0
    );
}

#[test]
fn project_passes_checks() {
    assert_eq!(
        run(&["project", "--stride", "16", "--sizes", "2,7,30,100", "--amortized"]).unwrap(),
        0
    );
}

#[test]
fn project_compiled_runtime_also_passes_shape() {
    // C5 claims hold for the idealized runtime too (physics, not framework)
    assert_eq!(
        run(&["project", "--stride", "16", "--sizes", "7,100", "--compiled"]).unwrap(),
        0
    );
}

#[test]
fn ablate_ok() {
    assert_eq!(run(&["ablate"]).unwrap(), 0);
}

#[test]
fn report_writes_files() {
    let out = std::env::temp_dir().join("vla_char_cli_report");
    let _ = std::fs::remove_dir_all(&out);
    let code = run(&["report", "--stride", "16", "--out", out.to_str().unwrap()]).unwrap();
    assert_eq!(code, 0);
    for f in [
        "table1.md",
        "table1.csv",
        "fig2.md",
        "fig3.md",
        "fig3_amortized.md",
        "ablation_prefetch.md",
        "ablation_cot.md",
        "ablation_horizon.md",
        "ablation_framework.md",
        "pim_matrix.md",
        "pim_matrix.csv",
        "pim_capacity.md",
        "pim_capacity.csv",
        "step_status.md",
        "control_loop_status.md",
        "serve_topology.md",
        "serve_matrix.md",
        "serve_matrix.csv",
        "validate_status.md",
        "checks.txt",
    ] {
        assert!(out.join(f).exists(), "missing report file {f}");
    }
    let checks = std::fs::read_to_string(out.join("checks.txt")).unwrap();
    assert!(checks.contains("[PASS]"));
    assert!(!checks.contains("[FAIL]"), "all checks must pass:\n{checks}");
}

#[test]
fn bad_platform_is_error() {
    assert!(run(&["characterize", "--trace", "--platform", "h100"]).is_err());
}

#[test]
fn codesign_energy_batch_ok() {
    assert_eq!(run(&["codesign", "--stride", "32"]).unwrap(), 0);
    assert_eq!(run(&["energy", "--stride", "32", "--size", "30"]).unwrap(), 0);
    assert_eq!(
        run(&["batch", "--stride", "32", "--platform", "thor", "--batches", "1,8"]).unwrap(),
        0
    );
}

#[test]
fn pim_scenario_matrix_ok() {
    // the full matrix at one scale, top-5 rows; checks gate the exit code
    assert_eq!(run(&["pim", "--stride", "32", "--pim-sizes", "7", "--top", "5"]).unwrap(), 0);
    // --top 0 prints every ranked row
    assert_eq!(run(&["pim", "--stride", "32", "--pim-sizes", "7", "--top", "0"]).unwrap(), 0);
}

#[test]
fn pim_grid_and_pareto_flags_ok() {
    // a custom γ/α grid expands the matrix; --pareto ranks front-first and
    // emits the front table; the S1..S5 checks gate the exit code
    let grid = [
        "pim", "--stride", "32", "--pim-sizes", "7", "--top", "5", "--pareto", "--spec-grid",
        "2,4x0.5,0.9",
    ];
    assert_eq!(run(&grid).unwrap(), 0);
    // dropping the batch axis degenerates back to the legacy matrix shape
    let legacy = [
        "pim", "--stride", "32", "--pim-sizes", "7", "--top", "3", "--pim-batches", "none",
    ];
    assert_eq!(run(&legacy).unwrap(), 0);
    // malformed spec grids are context-build errors
    assert!(run(&["pim", "--spec-grid", "4"]).is_err());
    assert!(run(&["pim", "--spec-grid", "4x1.5"]).is_err());
}

#[test]
fn engine_subcommands_skip_without_runtime_or_run() {
    // engine-backed experiments are registry members: without a PJRT
    // runtime they report "skipped" and exit 0; with one they run for real
    // (and `step` exits 0 on success too) — either way the exit code is 0.
    assert_eq!(run(&["step"]).unwrap(), 0);
}

#[test]
fn serve_runs_simulator_backed_without_pjrt() {
    // `serve` is simulator-backed since the shard model landed: it must RUN
    // (checks SV1..SV4 gate the exit code), never report "skipped"
    assert_eq!(run(&["serve", "--stride", "16", "--duration", "2"]).unwrap(), 0);
    // shard flags sweep both topologies with a deadline
    let sharded = [
        "serve", "--stride", "16", "--duration", "2", "--shards", "1,2,4", "--shard-mode",
        "pipeline", "--deadline-ms", "200",
    ];
    assert_eq!(run(&sharded).unwrap(), 0);
    // malformed shard flags are context-build errors
    assert!(run(&["serve", "--shard-mode", "mesh"]).is_err());
    assert!(run(&["serve", "--shards", "0"]).is_err());
}

#[test]
fn pim_shard_axis_from_cli() {
    // `--pim-shards` adds the serving axis to the scenario matrix; the
    // S1..S5 checks (closed form included) gate the exit code
    let args = [
        "pim", "--stride", "32", "--pim-sizes", "7", "--top", "3", "--pim-shards", "2",
    ];
    assert_eq!(run(&args).unwrap(), 0);
}

#[test]
fn trace_export_writes_valid_json() {
    let out = std::env::temp_dir().join("vla_char_cli_trace.json");
    let _ = std::fs::remove_file(&out);
    assert_eq!(
        run(&["trace-export", "--size", "2", "--trace-out", out.to_str().unwrap()]).unwrap(),
        0
    );
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(vla_char::util::json::Json::parse(&text).is_ok());
}

#[test]
fn hbm_platforms_reachable_from_cli() {
    assert_eq!(run(&["codesign", "--stride", "32", "--platform", "thor+hbm4"]).unwrap(), 0);
    assert_eq!(run(&["batch", "--stride", "32", "--platform", "orin+hbm3"]).unwrap(), 0);
}

#[test]
fn project_sweeps_platform_directory() {
    let dir = std::env::temp_dir().join("vla_char_cli_platform_dir");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    for (file, name, bw) in [("a.json", "EdgeA", 400), ("b.json", "EdgeB", 900)] {
        std::fs::write(
            dir.join(file),
            format!(
                r#"{{"name": "{name}",
                    "soc": {{"sms": 16, "clock_ghz": 1.3, "tflops_bf16": 100,
                            "tflops_f32": 10, "smem_kib": 192, "l2_mib": 4,
                            "l2_bw_gbs": 2000}},
                    "mem": {{"name": "HBM3", "bw_gbs": {bw}, "capacity_gb": 24}}}}"#
            ),
        )
        .unwrap();
    }
    // a directory of platform JSONs is swept by `project` (checks are
    // paper-shape statements about the default matrix, so they're skipped
    // and the run exits 0)
    let pf = dir.to_str().unwrap();
    let args = ["project", "--stride", "16", "--sizes", "7", "--platform-file", pf];
    assert_eq!(run(&args).unwrap(), 0);
}

#[test]
fn custom_platform_and_model_files() {
    let dir = std::env::temp_dir();
    let plat = dir.join("vla_char_custom_platform.json");
    std::fs::write(
        &plat,
        r#"{"name": "EdgeX",
            "soc": {"sms": 32, "clock_ghz": 1.5, "tflops_bf16": 250,
                    "tflops_f32": 15, "smem_kib": 192, "l2_mib": 8,
                    "l2_bw_gbs": 4000},
            "mem": {"name": "HBM3", "bw_gbs": 800, "capacity_gb": 48}}"#,
    )
    .unwrap();
    assert_eq!(
        run(&["batch", "--stride", "32", "--platform-file", plat.to_str().unwrap()]).unwrap(),
        0
    );
}
