//! Integration tests of the fleet serving simulator: the parallel policy x
//! fleet sweep is bitwise the serial one, conservation holds under
//! randomized admission/scheduling/failure configurations, and the
//! degenerate single-shard fleet bitwise reproduces `run_shard_batcher` on
//! a real lowered scenario — the acceptance pin of the `fleet` experiment.

use vla_char::engine::{
    run_shard_batcher, BatcherConfig, Policy, ShardModel, ShardService, SimStepServer,
};
use vla_char::hw::platform;
use vla_char::model::scaling::scaled_vla;
use vla_char::sim::fleet::{
    AdmissionPolicy, AutoscalerConfig, FleetConfig, FleetReport, FleetSim, SchedulingPolicy,
    ShardSpec,
};
use vla_char::sim::scenario::Scenario;
use vla_char::sim::{sweep, SimOptions};
use vla_char::util::prop::{ensure, prop_check};

/// A fleet report reduced to an exactly-comparable form: every count and
/// every float's bit pattern.
fn fingerprint(r: &FleetReport) -> (Vec<usize>, Vec<u64>) {
    let mut counts = vec![r.arrived, r.served, r.dropped, r.rejected, r.max_burst, r.peak_engines];
    counts.extend_from_slice(&[r.failures, r.scale_ups, r.scale_downs]);
    counts.extend_from_slice(&r.per_stream_arrived);
    counts.extend_from_slice(&r.per_stream_served);
    counts.extend_from_slice(&r.per_stream_dropped);
    counts.extend_from_slice(&r.per_stream_rejected);
    let bits = vec![
        r.throughput.to_bits(),
        r.queue_delay.p50.to_bits(),
        r.queue_delay.p99.to_bits(),
        r.service.p50.to_bits(),
        r.service.p99.to_bits(),
        r.actions.to_bits(),
        r.agg_actions_s.to_bits(),
        r.energy_j.to_bits(),
        r.j_per_action.to_bits(),
        r.makespan_s.to_bits(),
    ];
    (counts, bits)
}

#[test]
fn policy_fleet_grid_parallel_matches_serial_bitwise() {
    // the exact property that lets the `fleet` experiment sweep its policy
    // grid on the worker pool: every cell replays bit for bit
    let admissions = [
        AdmissionPolicy::DropOnDeadline,
        AdmissionPolicy::TokenBucket { rate_hz: 4.0, burst: 3 },
        AdmissionPolicy::SloPriority { depth_limit: 2 },
    ];
    let schedulings = [
        SchedulingPolicy::EarliestFree,
        SchedulingPolicy::RoundRobin,
        SchedulingPolicy::LeastLoaded,
        SchedulingPolicy::Edf,
    ];
    let fleets: [Vec<ShardSpec>; 2] = [
        vec![ShardSpec::uniform("uniform", 2, 0.15)],
        vec![ShardSpec::uniform("fast", 1, 0.08), ShardSpec::uniform("slow", 2, 0.3)],
    ];
    let mut cells = Vec::new();
    for &admission in &admissions {
        for &scheduling in &schedulings {
            for fleet in &fleets {
                cells.push((admission, scheduling, fleet.to_vec()));
            }
        }
    }
    let run = |cell: &(AdmissionPolicy, SchedulingPolicy, Vec<ShardSpec>)| {
        let cfg = FleetConfig {
            streams: 5,
            rate_hz: 3.0,
            duration_s: 8.0,
            seed: 13,
            deadline_s: Some(0.4),
            admission: cell.0,
            scheduling: cell.1,
            slo_deadline_mults: vec![0.5, 1.0, 2.0],
            autoscaler: None,
            failure_rate_hz: 0.0,
        };
        FleetSim::new(cfg, cell.2.clone()).unwrap().run()
    };
    let par = sweep::parallel_map(&cells, run);
    let ser = sweep::parallel_map_with(&cells, 1, run);
    assert_eq!(par.len(), cells.len());
    for ((p, s), cell) in par.iter().zip(&ser).zip(&cells) {
        let tag = format!("{:?} + {:?} on {} specs", cell.0, cell.1, cell.2.len());
        assert!(p.conserves(), "{tag}: {p:?}");
        assert!(p.arrived > 0 && p.served > 0, "{tag}: empty run proves nothing");
        assert_eq!(fingerprint(p), fingerprint(s), "{tag}");
    }
}

#[test]
fn conservation_holds_under_random_policies_and_failures() {
    prop_check("arrived == served + dropped + rejected", 80, |rng| {
        let admission = match rng.uniform_u64(0, 2) {
            0 => AdmissionPolicy::DropOnDeadline,
            1 => AdmissionPolicy::TokenBucket {
                rate_hz: rng.uniform_f64(0.5, 6.0),
                burst: rng.uniform_u64(1, 5) as u32,
            },
            _ => AdmissionPolicy::SloPriority { depth_limit: rng.uniform_usize(0, 4) },
        };
        let scheduling = *rng.choose(&[
            SchedulingPolicy::EarliestFree,
            SchedulingPolicy::RoundRobin,
            SchedulingPolicy::LeastLoaded,
            SchedulingPolicy::Edf,
        ]);
        let autoscaler = if rng.next_f64() < 0.4 {
            Some(AutoscalerConfig {
                check_interval_s: rng.uniform_f64(0.1, 0.5),
                queue_up: rng.uniform_usize(2, 8),
                queue_down: rng.uniform_usize(0, 2),
                p99_up_s: None,
                warmup_s: rng.uniform_f64(0.0, 0.5),
                min_engines: 1,
                max_engines: rng.uniform_usize(2, 6),
            })
        } else {
            None
        };
        let cfg = FleetConfig {
            streams: rng.uniform_usize(1, 6),
            rate_hz: rng.uniform_f64(0.5, 6.0),
            duration_s: rng.uniform_f64(0.5, 6.0),
            seed: rng.next_u64(),
            deadline_s: if rng.next_f64() < 0.7 { Some(rng.uniform_f64(0.05, 0.6)) } else { None },
            admission,
            scheduling,
            slo_deadline_mults: vec![0.25, 1.0, 4.0],
            autoscaler,
            failure_rate_hz: if rng.next_f64() < 0.5 { rng.uniform_f64(0.05, 2.0) } else { 0.0 },
        };
        let lanes = rng.uniform_usize(1, 4);
        let fleet = vec![ShardSpec::uniform("a", lanes, rng.uniform_f64(0.02, 0.4))];
        let r = FleetSim::new(cfg, fleet).map_err(|e| e.to_string())?.run();
        ensure(r.conserves(), format!("conservation violated: {r:?}"))?;
        ensure(r.arrived == r.per_stream_arrived.iter().sum::<usize>(), "per-stream arrivals")
    });
}

/// One real scenario lowering (replicate-1 on Orin), shared by the pin.
fn lowered_single() -> ShardService {
    let options = SimOptions { decode_stride: 16, ..Default::default() };
    ShardService::lower(
        &platform::orin(),
        &options,
        &scaled_vla(7.0),
        &scaled_vla(2.0),
        &Scenario::baseline(),
        ShardModel::single(),
    )
    .unwrap()
}

#[test]
fn degenerate_fleet_bitwise_reproduces_run_shard_batcher() {
    // the acceptance pin: a 1-shard, 1-lane fleet with drop-on-deadline
    // admission, a legacy scheduling order, and one unit SLO class must be
    // BITWISE the sharded batcher over the same lowered scenario, for both
    // legacy policies, with and without a deadline
    let single = lowered_single();
    for (policy, scheduling) in [
        (Policy::Fifo, SchedulingPolicy::EarliestFree),
        (Policy::RoundRobin, SchedulingPolicy::RoundRobin),
    ] {
        for deadline_s in [None, Some(0.25)] {
            let bcfg = BatcherConfig {
                streams: 4,
                rate_hz: 2.5,
                duration_s: 6.0,
                policy,
                seed: 19,
                deadline_s,
            };
            let mut server = SimStepServer::for_service(&single);
            let legacy =
                run_shard_batcher(&mut server, 2, 2, &[1, 2, 3], &bcfg, &single.model).unwrap();
            let cfg = FleetConfig {
                streams: 4,
                rate_hz: 2.5,
                duration_s: 6.0,
                seed: 19,
                deadline_s,
                admission: AdmissionPolicy::DropOnDeadline,
                scheduling,
                slo_deadline_mults: vec![1.0],
                autoscaler: None,
                failure_rate_hz: 0.0,
            };
            let degen = FleetSim::new(cfg, vec![single.fleet_spec()]).unwrap().run();
            let tag = format!("{policy:?}/{scheduling:?}/deadline {deadline_s:?}");
            assert!(degen.arrived > 0, "{tag}: empty trace proves nothing");
            assert_eq!(degen.arrived, legacy.arrived, "{tag}");
            assert_eq!(degen.served, legacy.served, "{tag}");
            assert_eq!(degen.dropped, legacy.dropped, "{tag}");
            assert_eq!(degen.rejected, 0, "{tag}");
            assert_eq!(degen.throughput.to_bits(), legacy.throughput.to_bits(), "{tag}");
            assert_eq!(degen.queue_delay.p50.to_bits(), legacy.queue_delay.p50.to_bits(), "{tag}");
            assert_eq!(degen.queue_delay.p99.to_bits(), legacy.queue_delay.p99.to_bits(), "{tag}");
            assert_eq!(degen.per_stream_served, legacy.per_stream_served, "{tag}");
            assert_eq!(degen.per_stream_dropped, legacy.per_stream_dropped, "{tag}");
            assert_eq!(degen.max_burst, legacy.max_burst, "{tag}");
        }
    }
}
