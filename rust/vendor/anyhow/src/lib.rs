//! Minimal, offline-compatible subset of the `anyhow` API.
//!
//! This container has no network access and no vendored crates.io sources,
//! so the workspace ships this in-tree shim providing exactly the surface
//! the crate uses: [`Error`], [`Result`], [`Error::msg`], and the
//! [`anyhow!`], [`bail!`], and [`ensure!`] macros. Like the real `anyhow`,
//! any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, and `Error` itself intentionally does NOT implement
//! `std::error::Error` so that blanket conversion stays coherent.

use std::fmt;

/// A type-erased error, printable with `{}`, `{:#}`, and `{:?}`.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    /// Construct from any displayable message (mirror of `anyhow::Error::msg`).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// The chain of sources, starting at this error (shallow in this shim).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        std::iter::successors(Some(&*self.0 as &(dyn std::error::Error + 'static)), |e| {
            e.source()
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` in real anyhow appends the cause chain; do the same.
        if f.alternate() {
            let mut first = true;
            for cause in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{cause}")?;
                first = false;
            }
            Ok(())
        } else {
            fmt::Display::fmt(&self.0, f)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut sources = self.chain().skip(1).peekable();
        if sources.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in sources {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn msg_and_macros() {
        let e = anyhow!("bad {} at {}", "thing", 3);
        assert_eq!(e.to_string(), "bad thing at 3");
        assert_eq!(format!("{e:#}"), "bad thing at 3");
        assert!(format!("{e:?}").contains("bad thing"));
        assert_eq!(fails(true).unwrap(), 7);
        assert!(fails(false).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> Result<()> {
            bail!("stop {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop 1");
    }
}
