//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! This environment has no XLA/PJRT native library, so the runtime layer is
//! gated: host-side [`Literal`] construction and manipulation are fully
//! functional (they are plain tensors), while anything that needs a PJRT
//! device — [`PjRtClient::cpu`], compilation, execution — returns a clear
//! [`Error`]. Types that only exist post-client ([`PjRtLoadedExecutable`],
//! [`PjRtBuffer`]) are uninhabited, so their methods are statically
//! unreachable yet fully type-checked. Swapping this path dependency for the
//! real `xla` crate re-enables the runtime without touching any caller.

use std::path::Path;

/// Error type mirroring `xla::Error` as used by the callers
/// (`Display + Debug + Send + Sync + 'static`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: this build uses the in-tree `xla` stub (no XLA native \
         library in this environment); simulation-only commands are unaffected"
            .to_string(),
    )
}

/// Uninhabited core for post-client types: constructing one is impossible,
/// so methods can diverge via an empty match while staying type-correct.
#[derive(Debug, Clone, Copy)]
enum Void {}

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Tuple(Vec<Literal>),
}

/// A host-side tensor (shape + typed buffer), mirroring `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Sealed-ish helper mapping rust scalars onto [`Data`] buffers.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data
    where
        Self: Sized;
    fn unwrap(data: &Data) -> Option<Vec<Self>>
    where
        Self: Sized;
}

macro_rules! native {
    ($ty:ty, $variant:ident) => {
        impl NativeType for $ty {
            fn wrap(data: Vec<Self>) -> Data {
                Data::$variant(data)
            }
            fn unwrap(data: &Data) -> Option<Vec<Self>> {
                match data {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(i64, I64);

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal {
            dims: Vec::new(),
            data: T::wrap(vec![value]),
        }
    }

    /// Number of elements in the buffer (1 for scalars; for tuples, the sum
    /// over parts — tuples have no dims of their own).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
            Data::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    /// Reinterpret the buffer under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() || matches!(self.data, Data::Tuple(_)) {
            return Err(Error(format!(
                "reshape: {} elements do not fit dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("to_vec: element type mismatch".to_string()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(Error("to_tuple: literal is not a tuple".to_string())),
        }
    }

    /// Build a tuple literal (host-side convenience, used by tests). Tuples
    /// are shapeless containers: `dims()` is empty, elements live in parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::Tuple(parts),
        }
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client handle. In this stub, [`PjRtClient::cpu`] always reports the
/// runtime as unavailable; every other method is therefore unreachable.
#[derive(Debug, Clone)]
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn device_count(&self) -> usize {
        match self.0 {}
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }
}

/// Parsed HLO module. Text parsing needs the native library, so loading
/// reports the runtime as unavailable.
#[derive(Debug, Clone)]
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

/// A compiled executable (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// A device buffer (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip_host_side() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalars_and_tuples() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i64, 2])]);
        assert_eq!(t.element_count(), 3);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
