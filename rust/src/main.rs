//! `vla-char` — leader binary: experiment reproduction CLI over the
//! simulator, the PJRT runtime, and the control-loop coordinator.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match vla_char::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
