//! Paper-shape acceptance checks: every qualitative claim of §4 is encoded
//! as a pass/fail predicate over our reproduced results. `vla-char validate`
//! and the integration suite run these.

use super::fig2::Fig2;
use super::fig3::Fig3;

/// One acceptance check.
#[derive(Debug, Clone)]
pub struct Check {
    pub id: &'static str,
    pub claim: &'static str,
    pub passed: bool,
    pub detail: String,
}

/// Evaluate every §4.1 claim against a Fig 2 run.
pub fn check_fig2(f: &Fig2) -> Vec<Check> {
    let mut out = Vec::new();
    let over_orin = f.orin.total() / 0.1;
    let over_thor = f.thor.total() / 0.1;
    out.push(Check {
        id: "C1-latency-gap",
        claim: "latencies ~200-300x higher than 10 Hz real-time",
        passed: (120.0..420.0).contains(&over_orin) && over_thor > 80.0,
        detail: format!("Orin {over_orin:.0}x, Thor {over_thor:.0}x over the 100 ms budget"),
    });
    let share_o = f.orin.generation_share();
    let share_t = f.thor.generation_share();
    out.push(Check {
        id: "C2-generation-dominates",
        claim: "generation phase ~75% of full-model step latency",
        passed: (0.60..0.97).contains(&share_o) && (0.60..0.97).contains(&share_t),
        detail: format!(
            "generation share: Orin {:.1}%, Thor {:.1}%",
            share_o * 100.0,
            share_t * 100.0
        ),
    });
    let speedup = f.orin.total() / f.thor.total();
    out.push(Check {
        id: "C3-memory-bound",
        claim: "Thor has 5x compute but E2E improves only ~1.4x (BW-bound)",
        passed: (1.15..2.2).contains(&speedup)
            && f.orin.decode.memory_bound()
            && f.thor.decode.memory_bound(),
        detail: format!(
            "E2E speedup {speedup:.2}x; decode memory-bound on both platforms"
        ),
    });
    out
}

/// Evaluate every §4.2 / Fig 3 claim against a sweep.
pub fn check_fig3(f: &Fig3) -> Vec<Check> {
    let mut out = Vec::new();

    // monotone down in model size on every platform
    let mut mono = true;
    for p in &f.platforms {
        let mut last = f64::INFINITY;
        for &s in &f.sizes {
            let hz = f.cell(s, p).unwrap().hz;
            if hz > last * 1.0001 {
                mono = false;
            }
            last = hz;
        }
    }
    out.push(Check {
        id: "C5a-scale-hurts",
        claim: "control frequency decreases with model scale",
        passed: mono,
        detail: format!("checked {} platforms x {} sizes", f.platforms.len(), f.sizes.len()),
    });

    // bandwidth ordering at every size (Orin family). PIM must strictly beat
    // GDDR7 once the workload is large enough to be bandwidth-dominated
    // (>= 7B); at 2B the step is overhead-dominated and PIM's slower
    // off-chip link lets GDDR7 tie — a real crossover, so we only require
    // near-parity there.
    let mut ordered = true;
    for &s in &f.sizes {
        let hz = |p: &str| f.cell(s, p).unwrap().hz;
        if !(hz("Orin") < hz("Orin+LPDDR5X") && hz("Orin+LPDDR5X") < hz("Orin+GDDR7")) {
            ordered = false;
        }
        if !(hz("Thor") < hz("Thor+GDDR7")) {
            ordered = false;
        }
        let pim_bar = if s >= 7.0 { 1.0 } else { 0.9 };
        if hz("Orin+PIM") < pim_bar * hz("Orin+GDDR7")
            || hz("Thor+PIM") < pim_bar * hz("Thor+GDDR7")
        {
            ordered = false;
        }
    }
    out.push(Check {
        id: "C5b-bandwidth-helps",
        claim: "GDDR7 and PIM memories substantially improve performance",
        passed: ordered,
        detail: "frequency ordered base < LPDDR5X < GDDR7 <= PIM (PIM strictly ahead at >=7B)"
            .into(),
    });

    // improvement magnitude: PIM >= 3x over base at 7B+
    let gain = f.cell(*f.sizes.last().unwrap(), "Orin+PIM").unwrap().hz
        / f.cell(*f.sizes.last().unwrap(), "Orin").unwrap().hz;
    out.push(Check {
        id: "C5c-pim-substantial",
        claim: "PIM improvement is substantial (not marginal)",
        passed: gain > 3.0,
        detail: format!("Orin+PIM / Orin frequency gain at largest size: {gain:.1}x"),
    });

    // but the 10 Hz target stays out of reach at large scale
    let misses = f
        .sizes
        .iter()
        .filter(|&&s| s >= 30.0)
        .all(|&s| f.platforms.iter().all(|p| f.cell(s, p).unwrap().amortized_hz < 10.0));
    out.push(Check {
        id: "C5d-target-unreached",
        claim: "10 Hz remains out of reach for 30B+ models on all configs",
        passed: misses,
        detail: "amortized frequency < 10 Hz for every platform at >=30B".into(),
    });
    out
}

/// Render checks as a console block; returns overall pass.
pub fn render(checks: &[Check]) -> (String, bool) {
    let mut all = true;
    let mut s = String::new();
    for c in checks {
        let mark = if c.passed { "PASS" } else { "FAIL" };
        all &= c.passed;
        s.push_str(&format!("[{mark}] {:<22} {}\n       {}\n", c.id, c.claim, c.detail));
    }
    (s, all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimOptions;

    #[test]
    fn all_fig2_checks_pass() {
        let f = super::super::fig2::run(&SimOptions::default());
        let checks = check_fig2(&f);
        let (report, ok) = render(&checks);
        assert!(ok, "fig2 checks failed:\n{report}");
        assert_eq!(checks.len(), 3);
    }

    #[test]
    fn all_fig3_checks_pass() {
        let opt = SimOptions {
            decode_stride: 16,
            ..Default::default()
        };
        let f = super::super::fig3::run(&opt, &[2.0, 7.0, 30.0, 100.0]);
        let checks = check_fig3(&f);
        let (report, ok) = render(&checks);
        assert!(ok, "fig3 checks failed:\n{report}");
        assert_eq!(checks.len(), 4);
    }
}
