//! Figure 2 reproduction: per-phase latency of MolmoAct-7B on the commercial
//! edge platforms (Orin, Thor), plus the derived claims of §4.1:
//! latency vs the 10 Hz budget, generation share, and Thor-vs-Orin speedup.

use crate::hw::platform;
use crate::model::molmoact::molmoact_7b;
use crate::sim::{SimOptions, Simulator, VlaSimResult};
use crate::util::table::{ascii_bars, Table};
use crate::util::units::{fmt_pct, fmt_ratio, fmt_time};

/// All data behind Fig 2.
#[derive(Debug, Clone)]
pub struct Fig2 {
    pub orin: VlaSimResult,
    pub thor: VlaSimResult,
}

/// Run the Fig 2 experiment (simulated Jetson platforms, PyTorch-runtime
/// overhead model — see DESIGN.md §2 for the substitution).
pub fn run(options: &SimOptions) -> Fig2 {
    let cfg = molmoact_7b();
    Fig2 {
        orin: Simulator::with_options(platform::orin(), options.clone()).simulate_vla(&cfg),
        thor: Simulator::with_options(platform::thor(), options.clone()).simulate_vla(&cfg),
    }
}

impl Fig2 {
    /// The paper's phase-latency table (one row per platform).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Figure 2: MolmoAct-7B latency on current edge platforms",
            &[
                "Platform",
                "vision (s)",
                "prefill (s)",
                "decode (s)",
                "action (s)",
                "total (s)",
                "gen share",
                "vs 10Hz budget",
            ],
        )
        .left_first();
        for r in [&self.orin, &self.thor] {
            t.row(vec![
                r.platform.clone(),
                format!("{:.2}", r.vision.time),
                format!("{:.2}", r.prefill.time),
                format!("{:.2}", r.decode.time),
                format!("{:.2}", r.action.time),
                format!("{:.2}", r.total()),
                fmt_pct(r.generation_share()),
                format!("{:.0}x", r.total() / 0.1),
            ]);
        }
        t
    }

    /// ASCII bar chart of the stacked phase decomposition.
    pub fn bars(&self) -> String {
        let mut items = Vec::new();
        for r in [&self.orin, &self.thor] {
            for s in r.stages() {
                items.push((format!("{} {}", r.platform, s.phase), s.time));
            }
        }
        ascii_bars("Fig 2: phase latency (s)", &items, "s", 48)
    }

    /// Headline numbers of §4.1.
    pub fn summary(&self) -> String {
        format!(
            "E2E: Orin {} ({}x over 10 Hz budget), Thor {} ({}x)\n\
             generation share: Orin {}, Thor {}\n\
             Thor speedup {} (compute ratio 5.0x -> memory-bound)\n\
             decode memory-bound: Orin {}, Thor {}",
            fmt_time(self.orin.total()),
            (self.orin.total() / 0.1).round(),
            fmt_time(self.thor.total()),
            (self.thor.total() / 0.1).round(),
            fmt_pct(self.orin.generation_share()),
            fmt_pct(self.thor.generation_share()),
            fmt_ratio(self.orin.total() / self.thor.total()),
            self.orin.decode.memory_bound(),
            self.thor.decode.memory_bound(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_runs_and_renders() {
        let f = run(&SimOptions::default());
        let t = f.table();
        assert_eq!(t.n_rows(), 2);
        assert!(f.bars().contains("Orin decode"));
        assert!(f.summary().contains("generation share"));
    }

    #[test]
    fn fig2_decode_is_largest_phase() {
        let f = run(&SimOptions::default());
        for r in [&f.orin, &f.thor] {
            assert!(r.decode.time > r.vision.time);
            assert!(r.decode.time > r.prefill.time);
            assert!(r.decode.time > r.action.time);
        }
    }
}
