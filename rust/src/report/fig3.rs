//! Figure 3 reproduction: control frequency for scaled VLA models
//! (2 B – 100 B) across the platform matrix (Table 1 plus the HBM pathway
//! variants), against the 10–20 Hz real-time band. The sizes × platforms
//! grid is embarrassingly parallel and runs on the `sim::sweep` worker
//! pool; every cell is a pure function of (size, platform, options), so the
//! parallel sweep is bitwise-identical to the serial path.

use crate::hw::platform::sweep_platforms;
use crate::hw::Platform;
use crate::model::scaling::{scaled_vla, ANCHOR_SIZES_B};
use crate::sim::{sweep, SimOptions, Simulator};
use crate::util::table::Table;

/// One (model size, platform) cell.
#[derive(Debug, Clone)]
pub struct Fig3Cell {
    pub size_b: f64,
    pub platform: String,
    /// One-step control frequency (Hz).
    pub hz: f64,
    /// Amortized over the action-chunk horizon (actions/s).
    pub amortized_hz: f64,
    pub total_latency: f64,
    pub generation_share: f64,
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct Fig3 {
    pub sizes: Vec<f64>,
    pub platforms: Vec<String>,
    pub cells: Vec<Fig3Cell>,
}

/// Run the Fig 3 sweep over the default platform matrix. `decode_stride` > 1
/// accelerates the decode-phase integration with negligible error (see sim
/// tests).
pub fn run(options: &SimOptions, sizes: &[f64]) -> Fig3 {
    run_on(options, sizes, &sweep_platforms())
}

/// Run the Fig 3 sweep over an explicit platform set (e.g. a directory of
/// `--platform-file` JSONs). Cells are evaluated on the parallel sweep
/// runner in size-major, platform-minor order.
pub fn run_on(options: &SimOptions, sizes: &[f64], platforms: &[Platform]) -> Fig3 {
    let mut grid: Vec<(f64, &Platform)> = Vec::with_capacity(sizes.len() * platforms.len());
    for &size in sizes {
        for p in platforms {
            grid.push((size, p));
        }
    }
    let cells = sweep::parallel_map(&grid, |&(size, p)| {
        let cfg = scaled_vla(size);
        let sim = Simulator::with_options(p.clone(), options.clone());
        let r = sim.simulate_vla(&cfg);
        Fig3Cell {
            size_b: size,
            platform: p.name.clone(),
            hz: r.control_frequency(),
            amortized_hz: r.amortized_frequency(),
            total_latency: r.total(),
            generation_share: r.generation_share(),
        }
    });
    Fig3 {
        sizes: sizes.to_vec(),
        platforms: platforms.iter().map(|p| p.name.clone()).collect(),
        cells,
    }
}

/// Default Fig 3 (all anchor sizes).
pub fn run_default(options: &SimOptions) -> Fig3 {
    run(options, &ANCHOR_SIZES_B)
}

impl Fig3 {
    pub fn cell(&self, size_b: f64, platform: &str) -> Option<&Fig3Cell> {
        self.cells
            .iter()
            .find(|c| (c.size_b - size_b).abs() < 1e-9 && c.platform == platform)
    }

    /// Control-frequency matrix: rows = platforms, cols = model sizes.
    pub fn table(&self, amortized: bool) -> Table {
        let mut headers: Vec<String> = vec!["Platform".into()];
        headers.extend(self.sizes.iter().map(|s| format!("{s:.0}B (Hz)")));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let title = if amortized {
            "Figure 3b: amortized control frequency (action chunks, Hz)"
        } else {
            "Figure 3: control frequency across edge system configurations (Hz)"
        };
        let mut t = Table::new(title, &hdr_refs).left_first();
        for p in &self.platforms {
            let mut row = vec![p.clone()];
            for &s in &self.sizes {
                let c = self.cell(s, p).unwrap();
                let hz = if amortized { c.amortized_hz } else { c.hz };
                row.push(format!("{hz:.3}"));
            }
            t.row(row);
        }
        t
    }

    /// Which cells reach the 10 Hz target (amortized)?
    pub fn reaching_target(&self, target_hz: f64) -> Vec<&Fig3Cell> {
        self.cells
            .iter()
            .filter(|c| c.amortized_hz >= target_hz)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_sweep() -> Fig3 {
        let opt = SimOptions {
            decode_stride: 16,
            ..Default::default()
        };
        run(&opt, &[7.0, 100.0])
    }

    #[test]
    fn sweep_covers_matrix() {
        let f = small_sweep();
        // Table 1's seven platforms plus the two HBM pathway variants
        assert_eq!(f.platforms.len(), 9);
        assert_eq!(f.cells.len(), 2 * 9);
        assert_eq!(f.table(false).n_rows(), 9);
    }

    #[test]
    fn hbm_variants_beat_their_base_socs() {
        let f = small_sweep();
        for &s in &[7.0, 100.0] {
            assert!(f.cell(s, "Orin+HBM3").unwrap().hz > f.cell(s, "Orin").unwrap().hz);
            assert!(f.cell(s, "Thor+HBM4").unwrap().hz > f.cell(s, "Thor").unwrap().hz);
        }
    }

    #[test]
    fn explicit_platform_set_is_respected() {
        let opt = SimOptions { decode_stride: 16, ..Default::default() };
        let plats = vec![crate::hw::platform::orin(), crate::hw::platform::thor()];
        let f = run_on(&opt, &[7.0], &plats);
        assert_eq!(f.platforms, vec!["Orin".to_string(), "Thor".to_string()]);
        assert_eq!(f.cells.len(), 2);
    }

    #[test]
    fn frequency_monotone_in_size() {
        let f = small_sweep();
        for p in &f.platforms {
            let hz7 = f.cell(7.0, p).unwrap().hz;
            let hz100 = f.cell(100.0, p).unwrap().hz;
            assert!(hz7 > hz100, "{p}: 7B {hz7} must beat 100B {hz100}");
        }
    }

    #[test]
    fn memory_upgrades_increase_frequency() {
        let f = small_sweep();
        for &s in &[7.0, 100.0] {
            let base = f.cell(s, "Orin").unwrap().hz;
            let l5x = f.cell(s, "Orin+LPDDR5X").unwrap().hz;
            let g7 = f.cell(s, "Orin+GDDR7").unwrap().hz;
            let pim = f.cell(s, "Orin+PIM").unwrap().hz;
            assert!(l5x > base && g7 > l5x && pim > g7, "{s}B: {base} {l5x} {g7} {pim}");
        }
    }

    #[test]
    fn hundred_b_misses_target_everywhere() {
        // Paper: "achieving the 10 Hz target ... at larger model sizes
        // requires new innovations"
        let f = small_sweep();
        for p in &f.platforms {
            let c = f.cell(100.0, p).unwrap();
            assert!(
                c.amortized_hz < 10.0,
                "{p} at 100B should miss 10 Hz: {}",
                c.amortized_hz
            );
        }
    }
}
