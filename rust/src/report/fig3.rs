//! Figure 3 reproduction: control frequency for scaled VLA models
//! (2 B – 100 B) across the Table 1 platform matrix, against the 10–20 Hz
//! real-time band.

use crate::hw::platform::table1_platforms;
use crate::model::scaling::{scaled_vla, ANCHOR_SIZES_B};
use crate::sim::{SimOptions, Simulator};
use crate::util::table::Table;

/// One (model size, platform) cell.
#[derive(Debug, Clone)]
pub struct Fig3Cell {
    pub size_b: f64,
    pub platform: String,
    /// One-step control frequency (Hz).
    pub hz: f64,
    /// Amortized over the action-chunk horizon (actions/s).
    pub amortized_hz: f64,
    pub total_latency: f64,
    pub generation_share: f64,
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct Fig3 {
    pub sizes: Vec<f64>,
    pub platforms: Vec<String>,
    pub cells: Vec<Fig3Cell>,
}

/// Run the Fig 3 sweep. `decode_stride` > 1 accelerates the decode-phase
/// integration with negligible error (see sim tests).
pub fn run(options: &SimOptions, sizes: &[f64]) -> Fig3 {
    let platforms = table1_platforms();
    let mut cells = Vec::new();
    for &size in sizes {
        let cfg = scaled_vla(size);
        for p in &platforms {
            let sim = Simulator::with_options(p.clone(), options.clone());
            let r = sim.simulate_vla(&cfg);
            cells.push(Fig3Cell {
                size_b: size,
                platform: p.name.clone(),
                hz: r.control_frequency(),
                amortized_hz: r.amortized_frequency(),
                total_latency: r.total(),
                generation_share: r.generation_share(),
            });
        }
    }
    Fig3 {
        sizes: sizes.to_vec(),
        platforms: platforms.iter().map(|p| p.name.clone()).collect(),
        cells,
    }
}

/// Default Fig 3 (all anchor sizes).
pub fn run_default(options: &SimOptions) -> Fig3 {
    run(options, &ANCHOR_SIZES_B)
}

impl Fig3 {
    pub fn cell(&self, size_b: f64, platform: &str) -> Option<&Fig3Cell> {
        self.cells
            .iter()
            .find(|c| (c.size_b - size_b).abs() < 1e-9 && c.platform == platform)
    }

    /// Control-frequency matrix: rows = platforms, cols = model sizes.
    pub fn table(&self, amortized: bool) -> Table {
        let mut headers: Vec<String> = vec!["Platform".into()];
        headers.extend(self.sizes.iter().map(|s| format!("{s:.0}B (Hz)")));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let title = if amortized {
            "Figure 3b: amortized control frequency (action chunks, Hz)"
        } else {
            "Figure 3: control frequency across edge system configurations (Hz)"
        };
        let mut t = Table::new(title, &hdr_refs).left_first();
        for p in &self.platforms {
            let mut row = vec![p.clone()];
            for &s in &self.sizes {
                let c = self.cell(s, p).unwrap();
                let hz = if amortized { c.amortized_hz } else { c.hz };
                row.push(format!("{hz:.3}"));
            }
            t.row(row);
        }
        t
    }

    /// Which cells reach the 10 Hz target (amortized)?
    pub fn reaching_target(&self, target_hz: f64) -> Vec<&Fig3Cell> {
        self.cells
            .iter()
            .filter(|c| c.amortized_hz >= target_hz)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_sweep() -> Fig3 {
        let opt = SimOptions {
            decode_stride: 16,
            ..Default::default()
        };
        run(&opt, &[7.0, 100.0])
    }

    #[test]
    fn sweep_covers_matrix() {
        let f = small_sweep();
        assert_eq!(f.cells.len(), 2 * 7);
        assert_eq!(f.table(false).n_rows(), 7);
    }

    #[test]
    fn frequency_monotone_in_size() {
        let f = small_sweep();
        for p in &f.platforms {
            let hz7 = f.cell(7.0, p).unwrap().hz;
            let hz100 = f.cell(100.0, p).unwrap().hz;
            assert!(hz7 > hz100, "{p}: 7B {hz7} must beat 100B {hz100}");
        }
    }

    #[test]
    fn memory_upgrades_increase_frequency() {
        let f = small_sweep();
        for &s in &[7.0, 100.0] {
            let base = f.cell(s, "Orin").unwrap().hz;
            let l5x = f.cell(s, "Orin+LPDDR5X").unwrap().hz;
            let g7 = f.cell(s, "Orin+GDDR7").unwrap().hz;
            let pim = f.cell(s, "Orin+PIM").unwrap().hz;
            assert!(l5x > base && g7 > l5x && pim > g7, "{s}B: {base} {l5x} {g7} {pim}");
        }
    }

    #[test]
    fn hundred_b_misses_target_everywhere() {
        // Paper: "achieving the 10 Hz target ... at larger model sizes
        // requires new innovations"
        let f = small_sweep();
        for p in &f.platforms {
            let c = f.cell(100.0, p).unwrap();
            assert!(
                c.amortized_hz < 10.0,
                "{p} at 100B should miss 10 Hz: {}",
                c.amortized_hz
            );
        }
    }
}
