//! Experiment reproduction harness: one module per paper artifact (Table 1,
//! Fig 2, Fig 3), ablations, and the paper-shape acceptance checks.

pub mod ablations;
pub mod checks;
pub mod fig2;
pub mod fig3;

pub use checks::{check_fig2, check_fig3, render, Check};
