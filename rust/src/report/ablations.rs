//! Ablation studies over the simulator's design switches (DESIGN.md E-A1..3):
//! cross-operator prefetch, PIM offload, batch/serving pressure, and
//! reasoning-trace (CoT) length.

use crate::hw::platform;
use crate::model::molmoact::molmoact_7b;
use crate::sim::{SimOptions, Simulator};
use crate::util::table::Table;

/// E-A1: cross-operator prefetch on/off, per phase (paper §3.2 calls this
/// "particularly critical for memory-bound operations").
pub fn prefetch_ablation() -> Table {
    let cfg = molmoact_7b();
    let mut t = Table::new(
        "Ablation E-A1: cross-operator prefetch (MolmoAct-7B)",
        &["Platform", "phase", "no prefetch (s)", "prefetch (s)", "gain"],
    )
    .left_first();
    for plat in [platform::orin(), platform::thor()] {
        let on = Simulator::with_options(
            plat.clone(),
            SimOptions {
                prefetch: true,
                decode_stride: 8,
                ..Default::default()
            },
        )
        .simulate_vla(&cfg);
        let off = Simulator::with_options(
            plat.clone(),
            SimOptions {
                prefetch: false,
                decode_stride: 8,
                ..Default::default()
            },
        )
        .simulate_vla(&cfg);
        for (a, b) in off.stages().iter().zip(on.stages().iter()) {
            t.row(vec![
                plat.name.clone(),
                a.phase.to_string(),
                format!("{:.3}", a.time),
                format!("{:.3}", b.time),
                format!("{:.2}x", a.time / b.time.max(1e-12)),
            ]);
        }
    }
    t
}

/// E-A3: how the generated-token budget (CoT / reasoning-trace length) moves
/// the generation share — why "thinking" models hit the decode wall.
pub fn cot_length_ablation(lengths: &[u64]) -> Table {
    let mut t = Table::new(
        "Ablation E-A3: reasoning-trace length vs generation share (Orin)",
        &["decode tokens", "decode (s)", "total (s)", "gen share", "Hz"],
    );
    for &len in lengths {
        let mut cfg = molmoact_7b();
        cfg.shape.decode_tokens = len;
        let r = Simulator::with_options(
            platform::orin(),
            SimOptions {
                decode_stride: 8,
                ..Default::default()
            },
        )
        .simulate_vla(&cfg);
        t.row(vec![
            format!("{len}"),
            format!("{:.2}", r.decode.time),
            format!("{:.2}", r.total()),
            format!("{:.1}%", r.generation_share() * 100.0),
            format!("{:.4}", r.control_frequency()),
        ]);
    }
    t
}

/// E-A2 variant at the simulator level: action-chunk horizon amortization —
/// executing longer chunks per step raises actions/s at the cost of
/// staleness (open-loop horizon).
pub fn horizon_ablation(horizons: &[u64]) -> Table {
    let mut t = Table::new(
        "Ablation E-A2: action-chunk horizon amortization (Orin+PIM, 7B)",
        &["horizon", "step latency (s)", "steps Hz", "actions Hz"],
    );
    for &h in horizons {
        let mut cfg = molmoact_7b();
        cfg.action.horizon = h;
        let r = Simulator::with_options(
            platform::orin_pim(),
            SimOptions {
                decode_stride: 8,
                ..Default::default()
            },
        )
        .simulate_vla(&cfg);
        t.row(vec![
            format!("{h}"),
            format!("{:.3}", r.total()),
            format!("{:.3}", r.control_frequency()),
            format!("{:.3}", r.amortized_frequency()),
        ]);
    }
    t
}

/// Framework ablation: measured PyTorch-eager configuration vs an idealized
/// compiled runtime — how much of Fig 2 is framework overhead vs physics.
pub fn framework_ablation() -> Table {
    let cfg = molmoact_7b();
    let mut t = Table::new(
        "Ablation: eager framework overhead vs compiled runtime (MolmoAct-7B)",
        &["Platform", "eager total (s)", "compiled total (s)", "gap", "compiled gen share"],
    )
    .left_first();
    for plat in [platform::orin(), platform::thor()] {
        let eager = Simulator::with_options(
            plat.clone(),
            SimOptions {
                decode_stride: 8,
                ..Default::default()
            },
        )
        .simulate_vla(&cfg);
        let compiled = Simulator::with_options(
            plat.clone(),
            SimOptions {
                decode_stride: 8,
                ..SimOptions::compiled()
            },
        )
        .simulate_vla(&cfg);
        t.row(vec![
            plat.name.clone(),
            format!("{:.2}", eager.total()),
            format!("{:.2}", compiled.total()),
            format!("{:.2}x", eager.total() / compiled.total()),
            format!("{:.1}%", compiled.generation_share() * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_table_shows_gains() {
        let t = prefetch_ablation();
        assert_eq!(t.n_rows(), 8);
        // every gain cell >= 1.00x
        for r in 0..t.n_rows() {
            let gain: f64 = t.cell(r, 4).trim_end_matches('x').parse().unwrap();
            assert!(gain >= 0.99, "prefetch should never hurt: row {r} gain {gain}");
        }
    }

    #[test]
    fn cot_share_grows_with_length() {
        let t = cot_length_ablation(&[32, 128, 512]);
        let share = |r: usize| -> f64 {
            t.cell(r, 3).trim_end_matches('%').parse().unwrap()
        };
        assert!(share(0) < share(1) && share(1) < share(2));
    }

    #[test]
    fn horizon_amortizes() {
        let t = horizon_ablation(&[1, 8, 32]);
        let actions_hz = |r: usize| -> f64 { t.cell(r, 3).parse().unwrap() };
        assert!(actions_hz(2) > actions_hz(0) * 8.0);
    }

    #[test]
    fn compiled_runtime_faster_but_still_bound() {
        let t = framework_ablation();
        for r in 0..t.n_rows() {
            let gap: f64 = t.cell(r, 3).trim_end_matches('x').parse().unwrap();
            assert!(gap >= 1.0);
            let share: f64 = t.cell(r, 4).trim_end_matches('%').parse().unwrap();
            assert!(share > 60.0, "decode dominates even compiled: {share}%");
        }
    }
}
