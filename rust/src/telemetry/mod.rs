//! Typed, versioned telemetry event stream for the serving simulators.
//!
//! The fleet simulator ([`crate::sim::fleet::FleetSim`]), the shard batcher
//! ([`crate::engine::shard::run_shard_batcher`]), the autoscaler, and the
//! scenario evaluator can all narrate their execution as a stream of typed
//! [`Event`]s through an [`EventSink`]. The wire format is newline-delimited
//! JSON (NDJSON) built on [`crate::util::json`] — zero external
//! dependencies — with a `v` schema-version field on every line.
//!
//! Three invariants make the stream useful rather than decorative:
//!
//! 1. **NullSink is free.** Every traced entry point has an untraced
//!    delegate (`run()` → `run_traced(&RunMeta::default(), &mut NullSink)`);
//!    the traced body performs *identical arithmetic* in the same order, and
//!    all sink-only bookkeeping is gated on [`EventSink::enabled`]. The
//!    existing bitwise pins (degenerate-fleet == batcher, parallel ==
//!    serial, incremental == fresh) therefore hold with tracing compiled in.
//! 2. **The stream is self-certifying.** [`replay`](crate::telemetry::replay)
//!    folds an event stream back into a [`FleetReport`] that is
//!    bitwise-equal to the live report — conservation counts, throughput,
//!    p50/p99 bits and all. A stream that replays is a faithful record.
//! 3. **Timestamps are monotone** between `run_start` and `run_end`
//!    (preamble `cache`/`phase` events may precede `run_start`).
//!    `scripts/check_events.py` enforces this from the stream alone.
//!
//! See `docs/TELEMETRY.md` for the full wire-format reference.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

pub mod replay;

use std::fs::File;
use std::io::{BufWriter, Write as IoWrite};
use std::path::Path;

use crate::model::Phase;
use crate::sim::fleet::{FleetReport, ScaleDecision, ScaleTrigger};
use crate::sim::scenario::CacheStats;
use crate::util::json::Json;

/// Wire schema version. Bump on any breaking change to the NDJSON format.
pub const SCHEMA_VERSION: u64 = 1;

/// Which serving loop produced a stream. Replay arithmetic branches on this
/// (the single-lane mirror computes `actions`/`J/action` from end-of-run
/// totals; the event loop and the multi-lane batcher accumulate per
/// dispatch), so it is part of the wire format, not a display hint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// `FleetSim::run_single_lane` — the degenerate bitwise mirror.
    SingleLane,
    /// `FleetSim` discrete event loop.
    EventLoop,
    /// `engine::shard::run_shard_batcher` multi-lane loop.
    Batcher,
}

impl RunMode {
    pub fn as_str(self) -> &'static str {
        match self {
            RunMode::SingleLane => "single-lane",
            RunMode::EventLoop => "event-loop",
            RunMode::Batcher => "batcher",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<RunMode> {
        match s {
            "single-lane" => Ok(RunMode::SingleLane),
            "event-loop" => Ok(RunMode::EventLoop),
            "batcher" => Ok(RunMode::Batcher),
            other => Err(anyhow::anyhow!("unknown run mode `{other}`")),
        }
    }
}

/// Why a request was rejected at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Token-bucket admission ran dry.
    TokenBucket,
    /// SLO-priority admission shed the best-effort class.
    SloShed,
}

impl RejectReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::TokenBucket => "token_bucket",
            RejectReason::SloShed => "slo_shed",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<RejectReason> {
        match s {
            "token_bucket" => Ok(RejectReason::TokenBucket),
            "slo_shed" => Ok(RejectReason::SloShed),
            other => Err(anyhow::anyhow!("unknown reject reason `{other}`")),
        }
    }
}

/// Why an admitted request was dropped before service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Queue delay exceeded the (class-scaled) deadline at dispatch.
    Stale,
    /// Fleet died or ran out of events; the remainder was flushed.
    Flush,
}

impl DropReason {
    pub fn as_str(self) -> &'static str {
        match self {
            DropReason::Stale => "stale",
            DropReason::Flush => "flush",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<DropReason> {
        match s {
            "stale" => Ok(DropReason::Stale),
            "flush" => Ok(DropReason::Flush),
            other => Err(anyhow::anyhow!("unknown drop reason `{other}`")),
        }
    }
}

/// Caller-supplied context echoed into `run_start` (the simulators do not
/// know which platform/scenario their shard specs were lowered from).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMeta {
    pub platform: String,
    pub scenario: String,
}

/// One shard spec echoed into `run_start` so replay can reconstruct
/// single-lane energy totals without the original `FleetConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardEcho {
    pub label: String,
    pub lanes: usize,
    pub step_s: f64,
    pub actions_per_step: f64,
    pub j_per_action: f64,
}

/// Everything `run_start` carries: enough config echo to replay the stream
/// and to fingerprint the run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunStartInfo {
    pub platform: String,
    pub scenario: String,
    pub mode: RunMode,
    /// FNV-1a fingerprint over the canonical config encoding (see
    /// [`RunStartInfo::fingerprint`]). Serialized as a 16-hex-digit string —
    /// `Json::Num` is an f64 and would corrupt u64s above 2^53.
    pub config_fp: u64,
    pub streams: usize,
    pub rate_hz: f64,
    pub duration_s: f64,
    /// Serialized as a decimal string for the same 2^53 reason.
    pub seed: u64,
    pub deadline_s: Option<f64>,
    pub admission: String,
    pub scheduling: String,
    pub slo_mults: Vec<f64>,
    pub autoscaler: bool,
    pub failure_rate_hz: f64,
    /// Engines alive at t=0 (static lanes).
    pub engines: usize,
    pub shards: Vec<ShardEcho>,
}

impl RunStartInfo {
    /// FNV-1a over a canonical byte encoding of every field except
    /// `config_fp` itself (floats by their IEEE bits, so the fingerprint is
    /// exactly as strict as the bitwise pins).
    pub fn fingerprint(&self) -> u64 {
        let mut s = String::new();
        use std::fmt::Write as _;
        let _ = write!(
            s,
            "{}|{}|{}|{}|{:x}|{:x}|{}|",
            self.platform,
            self.scenario,
            self.mode.as_str(),
            self.streams,
            self.rate_hz.to_bits(),
            self.duration_s.to_bits(),
            self.seed,
        );
        match self.deadline_s {
            Some(d) => {
                let _ = write!(s, "d{:x}|", d.to_bits());
            }
            None => s.push_str("d-|"),
        }
        let _ = write!(s, "{}|{}|", self.admission, self.scheduling);
        for m in &self.slo_mults {
            let _ = write!(s, "m{:x}|", m.to_bits());
        }
        let _ = write!(
            s,
            "{}|{:x}|{}|",
            self.autoscaler,
            self.failure_rate_hz.to_bits(),
            self.engines
        );
        for sh in &self.shards {
            let _ = write!(
                s,
                "s{}:{}:{:x}:{:x}:{:x}|",
                sh.label,
                sh.lanes,
                sh.step_s.to_bits(),
                sh.actions_per_step.to_bits(),
                sh.j_per_action.to_bits()
            );
        }
        fnv1a64(s.as_bytes())
    }
}

/// End-of-run summary — a flat mirror of [`FleetReport`]'s headline fields.
/// Replay cross-checks its folded counts against these before returning.
#[derive(Debug, Clone, PartialEq)]
pub struct RunEndInfo {
    pub arrived: usize,
    pub served: usize,
    pub dropped: usize,
    pub rejected: usize,
    pub throughput: f64,
    pub delay_p50_s: f64,
    pub delay_p99_s: f64,
    pub max_burst: usize,
    pub actions: f64,
    pub energy_j: f64,
    pub j_per_action: f64,
    pub peak_engines: usize,
    pub failures: usize,
    pub scale_ups: usize,
    pub scale_downs: usize,
    pub makespan_s: f64,
}

impl RunEndInfo {
    pub fn of(r: &FleetReport) -> RunEndInfo {
        RunEndInfo {
            arrived: r.arrived,
            served: r.served,
            dropped: r.dropped,
            rejected: r.rejected,
            throughput: r.throughput,
            delay_p50_s: r.queue_delay.p50,
            delay_p99_s: r.queue_delay.p99,
            max_burst: r.max_burst,
            actions: r.actions,
            energy_j: r.energy_j,
            j_per_action: r.j_per_action,
            peak_engines: r.peak_engines,
            failures: r.failures,
            scale_ups: r.scale_ups,
            scale_downs: r.scale_downs,
            makespan_s: r.makespan_s,
        }
    }
}

/// One telemetry event. Hot-path variants (`Arrival`..`Failure`) are
/// allocation-free; the boxed start/end summaries keep the enum small.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    RunStart {
        t: f64,
        info: Box<RunStartInfo>,
    },
    Arrival {
        t: f64,
        stream: u32,
        step: u64,
    },
    Admit {
        t: f64,
        stream: u32,
    },
    Reject {
        t: f64,
        stream: u32,
        reason: RejectReason,
    },
    Dispatch {
        t: f64,
        engine: u32,
        stream: u32,
        delay_s: f64,
        service_s: f64,
        actions_per_step: f64,
        j_per_action: f64,
    },
    Completion {
        t: f64,
        engine: u32,
        stream: u32,
        service_s: f64,
    },
    Drop {
        t: f64,
        stream: u32,
        reason: DropReason,
    },
    Scale {
        t: f64,
        decision: ScaleDecision,
        trigger: ScaleTrigger,
        queued: usize,
        alive_before: usize,
        alive_after: usize,
        applied: bool,
    },
    Failure {
        t: f64,
        engine: u32,
    },
    CacheSnapshot {
        t: f64,
        label: String,
        stats: CacheStats,
    },
    PhaseSpan {
        t: f64,
        phase: Phase,
        dur_s: f64,
    },
    RunEnd {
        t: f64,
        info: Box<RunEndInfo>,
    },
}

impl Event {
    /// The `ev` discriminator on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::RunStart { .. } => "run_start",
            Event::Arrival { .. } => "arrival",
            Event::Admit { .. } => "admit",
            Event::Reject { .. } => "reject",
            Event::Dispatch { .. } => "dispatch",
            Event::Completion { .. } => "completion",
            Event::Drop { .. } => "drop",
            Event::Scale { .. } => "scale",
            Event::Failure { .. } => "failure",
            Event::CacheSnapshot { .. } => "cache",
            Event::PhaseSpan { .. } => "phase",
            Event::RunEnd { .. } => "run_end",
        }
    }

    /// Virtual timestamp (seconds). For `PhaseSpan` this is relative to the
    /// start of one control step, not to the run clock.
    pub fn t(&self) -> f64 {
        match self {
            Event::RunStart { t, .. }
            | Event::Arrival { t, .. }
            | Event::Admit { t, .. }
            | Event::Reject { t, .. }
            | Event::Dispatch { t, .. }
            | Event::Completion { t, .. }
            | Event::Drop { t, .. }
            | Event::Scale { t, .. }
            | Event::Failure { t, .. }
            | Event::CacheSnapshot { t, .. }
            | Event::PhaseSpan { t, .. }
            | Event::RunEnd { t, .. } => *t,
        }
    }

    /// Build a `run_end` from a finished report. `t_floor` is the last
    /// event-loop timestamp (a trailing admission reject can land after the
    /// last dispatch completes); the stamp never precedes the makespan.
    pub fn run_end(report: &FleetReport, t_floor: f64) -> Event {
        Event::RunEnd {
            t: t_floor.max(report.makespan_s),
            info: Box::new(RunEndInfo::of(report)),
        }
    }

    /// Build a `cache` snapshot from live [`CacheStats`].
    pub fn cache(t: f64, label: &str, stats: CacheStats) -> Event {
        Event::CacheSnapshot {
            t,
            label: label.to_string(),
            stats,
        }
    }

    /// Serialize to a [`Json`] object (always carries `v` and `ev`).
    pub fn to_json(&self) -> Json {
        let head = |kind: &'static str, t: f64| {
            vec![
                ("v", Json::Num(SCHEMA_VERSION as f64)),
                ("ev", Json::Str(kind.to_string())),
                ("t", Json::Num(t)),
            ]
        };
        match self {
            Event::RunStart { t, info } => {
                let mut pairs = head("run_start", *t);
                pairs.extend([
                    ("platform", Json::Str(info.platform.clone())),
                    ("scenario", Json::Str(info.scenario.clone())),
                    ("mode", Json::Str(info.mode.as_str().to_string())),
                    ("fp", Json::Str(format!("{:016x}", info.config_fp))),
                    ("streams", Json::Num(info.streams as f64)),
                    ("rate_hz", Json::Num(info.rate_hz)),
                    ("duration_s", Json::Num(info.duration_s)),
                    ("seed", Json::Str(info.seed.to_string())),
                    (
                        "deadline_s",
                        info.deadline_s.map_or(Json::Null, Json::Num),
                    ),
                    ("admission", Json::Str(info.admission.clone())),
                    ("scheduling", Json::Str(info.scheduling.clone())),
                    (
                        "slo_mults",
                        Json::Arr(info.slo_mults.iter().map(|m| Json::Num(*m)).collect()),
                    ),
                    ("autoscaler", Json::Bool(info.autoscaler)),
                    ("failure_rate_hz", Json::Num(info.failure_rate_hz)),
                    ("engines", Json::Num(info.engines as f64)),
                    (
                        "shards",
                        Json::Arr(
                            info.shards
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("label", Json::Str(s.label.clone())),
                                        ("lanes", Json::Num(s.lanes as f64)),
                                        ("step_s", Json::Num(s.step_s)),
                                        ("actions_per_step", Json::Num(s.actions_per_step)),
                                        ("j_per_action", Json::Num(s.j_per_action)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]);
                Json::obj(pairs)
            }
            Event::Arrival { t, stream, step } => {
                let mut pairs = head("arrival", *t);
                pairs.extend([
                    ("stream", Json::Num(*stream as f64)),
                    ("step", Json::Num(*step as f64)),
                ]);
                Json::obj(pairs)
            }
            Event::Admit { t, stream } => {
                let mut pairs = head("admit", *t);
                pairs.push(("stream", Json::Num(*stream as f64)));
                Json::obj(pairs)
            }
            Event::Reject { t, stream, reason } => {
                let mut pairs = head("reject", *t);
                pairs.extend([
                    ("stream", Json::Num(*stream as f64)),
                    ("reason", Json::Str(reason.as_str().to_string())),
                ]);
                Json::obj(pairs)
            }
            Event::Dispatch {
                t,
                engine,
                stream,
                delay_s,
                service_s,
                actions_per_step,
                j_per_action,
            } => {
                let mut pairs = head("dispatch", *t);
                pairs.extend([
                    ("engine", Json::Num(*engine as f64)),
                    ("stream", Json::Num(*stream as f64)),
                    ("delay_s", Json::Num(*delay_s)),
                    ("service_s", Json::Num(*service_s)),
                    ("actions_per_step", Json::Num(*actions_per_step)),
                    ("j_per_action", Json::Num(*j_per_action)),
                ]);
                Json::obj(pairs)
            }
            Event::Completion {
                t,
                engine,
                stream,
                service_s,
            } => {
                let mut pairs = head("completion", *t);
                pairs.extend([
                    ("engine", Json::Num(*engine as f64)),
                    ("stream", Json::Num(*stream as f64)),
                    ("service_s", Json::Num(*service_s)),
                ]);
                Json::obj(pairs)
            }
            Event::Drop { t, stream, reason } => {
                let mut pairs = head("drop", *t);
                pairs.extend([
                    ("stream", Json::Num(*stream as f64)),
                    ("reason", Json::Str(reason.as_str().to_string())),
                ]);
                Json::obj(pairs)
            }
            Event::Scale {
                t,
                decision,
                trigger,
                queued,
                alive_before,
                alive_after,
                applied,
            } => {
                let mut pairs = head("scale", *t);
                pairs.extend([
                    ("decision", Json::Str(decision.label().to_string())),
                    ("trigger", Json::Str(trigger.label().to_string())),
                    ("queued", Json::Num(*queued as f64)),
                    ("alive_before", Json::Num(*alive_before as f64)),
                    ("alive_after", Json::Num(*alive_after as f64)),
                    ("applied", Json::Bool(*applied)),
                ]);
                Json::obj(pairs)
            }
            Event::Failure { t, engine } => {
                let mut pairs = head("failure", *t);
                pairs.push(("engine", Json::Num(*engine as f64)));
                Json::obj(pairs)
            }
            Event::CacheSnapshot { t, label, stats } => {
                let mut pairs = head("cache", *t);
                pairs.extend([
                    ("label", Json::Str(label.clone())),
                    ("evals", Json::Num(stats.evals as f64)),
                    (
                        "integrals_requested",
                        Json::Num(stats.integrals_requested as f64),
                    ),
                    (
                        "integrals_computed",
                        Json::Num(stats.integrals_computed as f64),
                    ),
                    ("decode_cost_hits", Json::Num(stats.decode_cost_hits as f64)),
                    (
                        "baselines_computed",
                        Json::Num(stats.baselines_computed as f64),
                    ),
                    ("contexts", Json::Num(stats.contexts as f64)),
                ]);
                Json::obj(pairs)
            }
            Event::PhaseSpan { t, phase, dur_s } => {
                let mut pairs = head("phase", *t);
                pairs.extend([
                    ("phase", Json::Str(phase.name().to_string())),
                    ("dur_s", Json::Num(*dur_s)),
                ]);
                Json::obj(pairs)
            }
            Event::RunEnd { t, info } => {
                let mut pairs = head("run_end", *t);
                pairs.extend([
                    ("arrived", Json::Num(info.arrived as f64)),
                    ("served", Json::Num(info.served as f64)),
                    ("dropped", Json::Num(info.dropped as f64)),
                    ("rejected", Json::Num(info.rejected as f64)),
                    ("throughput", Json::Num(info.throughput)),
                    ("delay_p50_s", Json::Num(info.delay_p50_s)),
                    ("delay_p99_s", Json::Num(info.delay_p99_s)),
                    ("max_burst", Json::Num(info.max_burst as f64)),
                    ("actions", Json::Num(info.actions)),
                    ("energy_j", Json::Num(info.energy_j)),
                    ("j_per_action", Json::Num(info.j_per_action)),
                    ("peak_engines", Json::Num(info.peak_engines as f64)),
                    ("failures", Json::Num(info.failures as f64)),
                    ("scale_ups", Json::Num(info.scale_ups as f64)),
                    ("scale_downs", Json::Num(info.scale_downs as f64)),
                    ("makespan_s", Json::Num(info.makespan_s)),
                ]);
                Json::obj(pairs)
            }
        }
    }

    /// Deserialize from a parsed [`Json`] object. Rejects unknown schema
    /// versions and unknown `ev` kinds.
    pub fn from_json(j: &Json) -> anyhow::Result<Event> {
        let v = j.req_u64("v")?;
        if v != SCHEMA_VERSION {
            anyhow::bail!("unsupported telemetry schema version {v} (expected {SCHEMA_VERSION})");
        }
        let kind = j.req_str("ev")?;
        let t = j.req_f64("t")?;
        let stream_of = |j: &Json| -> anyhow::Result<u32> { Ok(j.req_u64("stream")? as u32) };
        let engine_of = |j: &Json| -> anyhow::Result<u32> { Ok(j.req_u64("engine")? as u32) };
        match kind {
            "run_start" => {
                let fp_hex = j.req_str("fp")?;
                let config_fp = u64::from_str_radix(fp_hex, 16)
                    .map_err(|e| anyhow::anyhow!("bad run_start fp `{fp_hex}`: {e}"))?;
                let seed_str = j.req_str("seed")?;
                let seed = seed_str
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("bad run_start seed `{seed_str}`: {e}"))?;
                let deadline_s = match j.get("deadline_s") {
                    Some(Json::Null) | None => None,
                    Some(d) => Some(
                        d.as_f64()
                            .ok_or_else(|| anyhow::anyhow!("non-numeric deadline_s"))?,
                    ),
                };
                let slo_mults = j
                    .get("slo_mults")
                    .and_then(|m| m.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("missing slo_mults array"))?
                    .iter()
                    .map(|m| m.as_f64().ok_or_else(|| anyhow::anyhow!("bad slo mult")))
                    .collect::<anyhow::Result<Vec<f64>>>()?;
                let shards = j
                    .get("shards")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("missing shards array"))?
                    .iter()
                    .map(|s| {
                        Ok(ShardEcho {
                            label: s.req_str("label")?.to_string(),
                            lanes: s.req_u64("lanes")? as usize,
                            step_s: s.req_f64("step_s")?,
                            actions_per_step: s.req_f64("actions_per_step")?,
                            j_per_action: s.req_f64("j_per_action")?,
                        })
                    })
                    .collect::<anyhow::Result<Vec<ShardEcho>>>()?;
                Ok(Event::RunStart {
                    t,
                    info: Box::new(RunStartInfo {
                        platform: j.req_str("platform")?.to_string(),
                        scenario: j.req_str("scenario")?.to_string(),
                        mode: RunMode::parse(j.req_str("mode")?)?,
                        config_fp,
                        streams: j.req_u64("streams")? as usize,
                        rate_hz: j.req_f64("rate_hz")?,
                        duration_s: j.req_f64("duration_s")?,
                        seed,
                        deadline_s,
                        admission: j.req_str("admission")?.to_string(),
                        scheduling: j.req_str("scheduling")?.to_string(),
                        slo_mults,
                        autoscaler: j.req_bool("autoscaler")?,
                        failure_rate_hz: j.req_f64("failure_rate_hz")?,
                        engines: j.req_u64("engines")? as usize,
                        shards,
                    }),
                })
            }
            "arrival" => Ok(Event::Arrival {
                t,
                stream: stream_of(j)?,
                step: j.req_u64("step")?,
            }),
            "admit" => Ok(Event::Admit {
                t,
                stream: stream_of(j)?,
            }),
            "reject" => Ok(Event::Reject {
                t,
                stream: stream_of(j)?,
                reason: RejectReason::parse(j.req_str("reason")?)?,
            }),
            "dispatch" => Ok(Event::Dispatch {
                t,
                engine: engine_of(j)?,
                stream: stream_of(j)?,
                delay_s: j.req_f64("delay_s")?,
                service_s: j.req_f64("service_s")?,
                actions_per_step: j.req_f64("actions_per_step")?,
                j_per_action: j.req_f64("j_per_action")?,
            }),
            "completion" => Ok(Event::Completion {
                t,
                engine: engine_of(j)?,
                stream: stream_of(j)?,
                service_s: j.req_f64("service_s")?,
            }),
            "drop" => Ok(Event::Drop {
                t,
                stream: stream_of(j)?,
                reason: DropReason::parse(j.req_str("reason")?)?,
            }),
            "scale" => Ok(Event::Scale {
                t,
                decision: parse_decision(j.req_str("decision")?)?,
                trigger: parse_trigger(j.req_str("trigger")?)?,
                queued: j.req_u64("queued")? as usize,
                alive_before: j.req_u64("alive_before")? as usize,
                alive_after: j.req_u64("alive_after")? as usize,
                applied: j.req_bool("applied")?,
            }),
            "failure" => Ok(Event::Failure {
                t,
                engine: engine_of(j)?,
            }),
            "cache" => Ok(Event::CacheSnapshot {
                t,
                label: j.req_str("label")?.to_string(),
                stats: CacheStats {
                    evals: j.req_u64("evals")?,
                    integrals_requested: j.req_u64("integrals_requested")?,
                    integrals_computed: j.req_u64("integrals_computed")?,
                    decode_cost_hits: j.req_u64("decode_cost_hits")?,
                    baselines_computed: j.req_u64("baselines_computed")?,
                    contexts: j.req_u64("contexts")?,
                },
            }),
            "phase" => Ok(Event::PhaseSpan {
                t,
                phase: parse_phase(j.req_str("phase")?)?,
                dur_s: j.req_f64("dur_s")?,
            }),
            "run_end" => Ok(Event::RunEnd {
                t,
                info: Box::new(RunEndInfo {
                    arrived: j.req_u64("arrived")? as usize,
                    served: j.req_u64("served")? as usize,
                    dropped: j.req_u64("dropped")? as usize,
                    rejected: j.req_u64("rejected")? as usize,
                    throughput: j.req_f64("throughput")?,
                    delay_p50_s: j.req_f64("delay_p50_s")?,
                    delay_p99_s: j.req_f64("delay_p99_s")?,
                    max_burst: j.req_u64("max_burst")? as usize,
                    actions: j.req_f64("actions")?,
                    energy_j: j.req_f64("energy_j")?,
                    j_per_action: j.req_f64("j_per_action")?,
                    peak_engines: j.req_u64("peak_engines")? as usize,
                    failures: j.req_u64("failures")? as usize,
                    scale_ups: j.req_u64("scale_ups")? as usize,
                    scale_downs: j.req_u64("scale_downs")? as usize,
                    makespan_s: j.req_f64("makespan_s")?,
                }),
            }),
            other => Err(anyhow::anyhow!("unknown telemetry event kind `{other}`")),
        }
    }

    /// One NDJSON line (no trailing newline).
    pub fn to_ndjson_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    /// Parse one NDJSON line.
    pub fn parse_line(line: &str) -> anyhow::Result<Event> {
        let j = Json::parse(line)?;
        Event::from_json(&j)
    }
}

fn parse_decision(s: &str) -> anyhow::Result<ScaleDecision> {
    match s {
        "up" => Ok(ScaleDecision::Up),
        "down" => Ok(ScaleDecision::Down),
        "hold" => Ok(ScaleDecision::Hold),
        other => Err(anyhow::anyhow!("unknown scale decision `{other}`")),
    }
}

fn parse_trigger(s: &str) -> anyhow::Result<ScaleTrigger> {
    match s {
        "failover" => Ok(ScaleTrigger::Failover),
        "queue-depth" => Ok(ScaleTrigger::QueueDepth),
        "tail-latency" => Ok(ScaleTrigger::TailLatency),
        "queue-drained" => Ok(ScaleTrigger::QueueDrained),
        "steady" => Ok(ScaleTrigger::Steady),
        other => Err(anyhow::anyhow!("unknown scale trigger `{other}`")),
    }
}

fn parse_phase(s: &str) -> anyhow::Result<Phase> {
    match s {
        "vision" => Ok(Phase::Vision),
        "prefill" => Ok(Phase::Prefill),
        "decode" => Ok(Phase::Decode),
        "action" => Ok(Phase::Action),
        other => Err(anyhow::anyhow!("unknown phase `{other}`")),
    }
}

/// FNV-1a 64-bit hash — the config fingerprint in `run_start`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Where events go. Implementations must be cheap when disabled: the
/// simulators gate every allocation and all sink-only bookkeeping on
/// [`EventSink::enabled`], and the hot-path emit compiles away entirely for
/// the monomorphized [`NullSink`].
pub trait EventSink {
    fn emit(&mut self, event: &Event);

    /// `false` means the producer may skip event construction and any
    /// tracing-only bookkeeping. Default `true` (a method, not an associated
    /// const, so the trait stays object-safe).
    fn enabled(&self) -> bool {
        true
    }
}

/// Discards everything. The default sink on every untraced entry point.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&mut self, _event: &Event) {}

    fn enabled(&self) -> bool {
        false
    }
}

/// Collects events in memory — the test and replay-in-process sink.
#[derive(Debug, Default)]
pub struct VecSink {
    pub events: Vec<Event>,
}

impl VecSink {
    pub fn new() -> VecSink {
        VecSink::default()
    }
}

impl EventSink for VecSink {
    fn emit(&mut self, event: &Event) {
        self.events.push(event.clone());
    }
}

/// Buffered NDJSON writer over any `io::Write` — file or stdout. IO errors
/// latch (the simulator has no error channel mid-run) and surface from
/// [`NdjsonSink::finish`].
pub struct NdjsonSink<W: IoWrite> {
    out: BufWriter<W>,
    written: u64,
    /// Flush after every line (live daemon mode wants line-buffered output).
    line_flush: bool,
    error: Option<std::io::Error>,
}

impl NdjsonSink<File> {
    /// Block-buffered sink writing to a file path.
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<NdjsonSink<File>> {
        Ok(NdjsonSink {
            out: BufWriter::new(File::create(path)?),
            written: 0,
            line_flush: false,
            error: None,
        })
    }
}

impl NdjsonSink<std::io::Stdout> {
    /// Line-flushed sink over stdout — the `--events -` / `--daemon` path.
    pub fn stdout() -> NdjsonSink<std::io::Stdout> {
        NdjsonSink {
            out: BufWriter::new(std::io::stdout()),
            written: 0,
            line_flush: true,
            error: None,
        }
    }
}

impl<W: IoWrite> NdjsonSink<W> {
    /// Block-buffered sink over any writer (`Vec<u8>` for in-memory
    /// streams, `io::sink()` for serialization benchmarks, a socket, ...).
    pub fn new(out: W) -> NdjsonSink<W> {
        NdjsonSink {
            out: BufWriter::new(out),
            written: 0,
            line_flush: false,
            error: None,
        }
    }

    /// Flush and return the number of lines written, or the first IO error.
    pub fn finish(mut self) -> std::io::Result<u64> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        self.out.flush()?;
        Ok(self.written)
    }

    /// Flush and hand back the inner writer plus the line count — the
    /// in-memory (`Vec<u8>`) path reads the stream it just wrote.
    pub fn finish_into(mut self) -> std::io::Result<(W, u64)> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        let out = self.out.into_inner().map_err(|e| e.into_error())?;
        Ok((out, self.written))
    }
}

impl<W: IoWrite> EventSink for NdjsonSink<W> {
    fn emit(&mut self, event: &Event) {
        if self.error.is_some() {
            return;
        }
        let line = event.to_ndjson_line();
        let res = self
            .out
            .write_all(line.as_bytes())
            .and_then(|()| self.out.write_all(b"\n"))
            .and_then(|()| if self.line_flush { self.out.flush() } else { Ok(()) });
        if let Err(e) = res {
            self.error = Some(e);
            return;
        }
        self.written += 1;
    }
}

/// Forwarding impl so `&mut sink` works where a sink is expected (the
/// experiments hand the same sink to the preamble and the run).
impl<T: EventSink + ?Sized> EventSink for &mut T {
    fn emit(&mut self, event: &Event) {
        (**self).emit(event)
    }

    fn enabled(&self) -> bool {
        (**self).enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::CacheSnapshot {
                t: 0.0,
                label: "lowering".to_string(),
                stats: CacheStats {
                    evals: 3,
                    integrals_requested: 12,
                    integrals_computed: 4,
                    decode_cost_hits: 8,
                    baselines_computed: 1,
                    contexts: 2,
                },
            },
            Event::PhaseSpan {
                t: 0.0,
                phase: Phase::Vision,
                dur_s: 0.0125,
            },
            Event::RunStart {
                t: 0.0,
                info: Box::new(RunStartInfo {
                    platform: "jetson_orin_nano".to_string(),
                    scenario: "baseline".to_string(),
                    mode: RunMode::EventLoop,
                    config_fp: 0xdead_beef_0123_4567,
                    streams: 3,
                    rate_hz: 2.0,
                    duration_s: 10.0,
                    seed: u64::MAX - 1,
                    deadline_s: Some(0.4),
                    admission: "token(4/s,b8)".to_string(),
                    scheduling: "edf".to_string(),
                    slo_mults: vec![0.5, 1.0, 2.0],
                    autoscaler: true,
                    failure_rate_hz: 0.05,
                    engines: 2,
                    shards: vec![ShardEcho {
                        label: "baseline/rep2".to_string(),
                        lanes: 2,
                        step_s: 0.04,
                        actions_per_step: 8.0,
                        j_per_action: 0.125,
                    }],
                }),
            },
            Event::Arrival {
                t: 0.1875,
                stream: 2,
                step: 0,
            },
            Event::Admit {
                t: 0.1875,
                stream: 2,
            },
            Event::Reject {
                t: 0.25,
                stream: 1,
                reason: RejectReason::TokenBucket,
            },
            Event::Dispatch {
                t: 0.1875,
                engine: 1,
                stream: 2,
                delay_s: 0.0,
                service_s: 0.04,
                actions_per_step: 8.0,
                j_per_action: 0.125,
            },
            Event::Completion {
                t: 0.2275,
                engine: 1,
                stream: 2,
                service_s: 0.04,
            },
            Event::Drop {
                t: 0.5,
                stream: 0,
                reason: DropReason::Stale,
            },
            Event::Scale {
                t: 0.25,
                decision: ScaleDecision::Up,
                trigger: ScaleTrigger::QueueDepth,
                queued: 9,
                alive_before: 2,
                alive_after: 3,
                applied: true,
            },
            Event::Failure { t: 0.75, engine: 0 },
            Event::RunEnd {
                t: 10.0,
                info: Box::new(RunEndInfo {
                    arrived: 60,
                    served: 50,
                    dropped: 6,
                    rejected: 4,
                    throughput: 5.0,
                    delay_p50_s: 0.01,
                    delay_p99_s: 0.35,
                    max_burst: 4,
                    actions: 400.0,
                    energy_j: 50.0,
                    j_per_action: 0.125,
                    peak_engines: 3,
                    failures: 1,
                    scale_ups: 1,
                    scale_downs: 0,
                    makespan_s: 10.0,
                }),
            },
        ]
    }

    #[test]
    fn every_variant_round_trips_bitwise() {
        for ev in sample_events() {
            let line = ev.to_ndjson_line();
            let back = Event::parse_line(&line)
                .unwrap_or_else(|e| panic!("parse failed for {line}: {e}"));
            assert_eq!(back, ev, "round trip mismatch for {line}");
            // PartialEq on f64 is value equality; re-serialize to prove the
            // bits survived too (fmt_num is shortest-round-trip).
            assert_eq!(back.to_ndjson_line(), line);
        }
    }

    #[test]
    fn u64_fields_survive_beyond_f64_precision() {
        let evs = sample_events();
        let Event::RunStart { info, .. } = &evs[2] else {
            panic!("expected run_start at index 2");
        };
        let line = evs[2].to_ndjson_line();
        let Event::RunStart { info: back, .. } = Event::parse_line(&line).unwrap() else {
            panic!("round trip changed kind");
        };
        assert_eq!(back.seed, u64::MAX - 1, "seed must not pass through f64");
        assert_eq!(back.config_fp, info.config_fp);
    }

    #[test]
    fn schema_version_is_enforced() {
        let good = Event::Failure { t: 1.0, engine: 0 }.to_ndjson_line();
        assert!(Event::parse_line(&good).is_ok());
        let bad = good.replace("\"v\":1", "\"v\":99");
        let err = Event::parse_line(&bad).unwrap_err().to_string();
        assert!(err.contains("schema version"), "got: {err}");
    }

    #[test]
    fn malformed_lines_are_rejected() {
        assert!(Event::parse_line("").is_err());
        assert!(Event::parse_line("not json").is_err());
        assert!(Event::parse_line("{\"v\":1,\"ev\":\"nope\",\"t\":0}").is_err());
        assert!(Event::parse_line("{\"v\":1,\"ev\":\"failure\",\"t\":0}").is_err(), "missing field");
    }

    #[test]
    fn kind_and_t_accessors_cover_every_variant() {
        let kinds: Vec<&str> = sample_events().iter().map(|e| e.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                "cache", "phase", "run_start", "arrival", "admit", "reject", "dispatch",
                "completion", "drop", "scale", "failure", "run_end"
            ]
        );
        for ev in sample_events() {
            assert!(ev.t().is_finite());
        }
    }

    #[test]
    fn fingerprint_tracks_config_bits() {
        let evs = sample_events();
        let Event::RunStart { info, .. } = &evs[2] else {
            panic!();
        };
        let base = info.fingerprint();
        assert_eq!(base, info.fingerprint(), "fingerprint is deterministic");
        let mut bumped = (**info).clone();
        bumped.rate_hz = 2.0 + 1e-12;
        assert_ne!(base, bumped.fingerprint(), "fingerprint sees f64 bits");
        let mut relabeled = (**info).clone();
        relabeled.scheduling = "fifo".to_string();
        assert_ne!(base, relabeled.fingerprint());
    }

    #[test]
    fn null_sink_is_disabled_and_vec_sink_collects() {
        let mut null = NullSink;
        assert!(!null.enabled());
        null.emit(&Event::Failure { t: 0.0, engine: 1 });
        let mut vec = VecSink::new();
        assert!(vec.enabled());
        for ev in sample_events() {
            vec.emit(&ev);
        }
        assert_eq!(vec.events.len(), sample_events().len());
        assert_eq!(vec.events[3], sample_events()[3]);
        // forwarding impl: &mut VecSink is itself a sink
        let mut fwd: &mut VecSink = &mut vec;
        assert!(fwd.enabled());
        fwd.emit(&Event::Failure { t: 9.0, engine: 7 });
        assert_eq!(vec.events.last().unwrap().kind(), "failure");
    }

    #[test]
    fn ndjson_sink_writes_parseable_lines() {
        let dir = std::env::temp_dir().join("vla_char_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.ndjson");
        let mut sink = NdjsonSink::create(&path).unwrap();
        let events = sample_events();
        for ev in &events {
            sink.emit(ev);
        }
        let written = sink.finish().unwrap();
        assert_eq!(written, events.len() as u64);
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed: Vec<Event> = text
            .lines()
            .map(|l| Event::parse_line(l).unwrap())
            .collect();
        assert_eq!(parsed, events);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
