//! Fold a telemetry event stream back into a [`FleetReport`].
//!
//! The replay is the proof that a stream is a *faithful record* of a run:
//! for every mode the folded report is bitwise-equal to the live one —
//! same conservation counts, same throughput bits, same p50/p99 bits. The
//! fold mirrors the live arithmetic exactly:
//!
//! - `dispatch` events carry `(t, service_s, delay_s, actions_per_step,
//!   j_per_action)`; the makespan folds `max(t + service_s)` over them,
//!   which is operand-for-operand the live `free_at` computation.
//! - Mode `event-loop` / `batcher` accumulates actions and energy per
//!   dispatch (the live loop's order); mode `single-lane` recomputes them
//!   from end-of-run totals (`served × actions_per_step`), exactly like
//!   the live mirror. The two are *not* interchangeable at the bit level,
//!   which is why [`RunMode`] is on the wire.
//! - `scale` / `failure` events rebuild the autoscaler counters and the
//!   peak-engine fold.
//!
//! Before returning, the fold cross-checks its counts against the
//! `run_end` summary and fails on any mismatch — a truncated stream or a
//! summary-only stream (the single-lane batcher delegation emits no
//! per-request events) produces an error, never a silently-wrong report.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::{Event, RunMode};
use crate::sim::fleet::{FleetReport, ScaleDecision};
use crate::util::stats::Summary;

/// Replay a parsed event stream into the report it certifies.
pub fn replay(events: &[Event]) -> anyhow::Result<FleetReport> {
    // `cache` / `phase` preamble (lowering stats, per-phase spans) may
    // precede the run frame.
    let mut idx = 0;
    while matches!(
        events.get(idx),
        Some(Event::CacheSnapshot { .. } | Event::PhaseSpan { .. })
    ) {
        idx += 1;
    }
    let Some(Event::RunStart { info, .. }) = events.get(idx) else {
        anyhow::bail!(
            "stream has no run_start (found {})",
            events.get(idx).map_or("end of stream", |e| e.kind())
        );
    };
    idx += 1;

    let streams = info.streams;
    let check_stream = |s: u32| -> anyhow::Result<usize> {
        let s = s as usize;
        anyhow::ensure!(s < streams, "stream index {s} out of bounds (streams={streams})");
        Ok(s)
    };

    let mut per_stream_arrived = vec![0usize; streams];
    let mut per_stream_served = vec![0usize; streams];
    let mut per_stream_dropped = vec![0usize; streams];
    let mut per_stream_rejected = vec![0usize; streams];
    let mut delays: Vec<f64> = Vec::new();
    let mut services: Vec<f64> = Vec::new();
    let mut last_stream = usize::MAX;
    let mut burst = 0usize;
    let mut max_burst = 0usize;
    let mut actions = 0.0f64;
    let mut energy_j = 0.0f64;
    let mut makespan = 0.0f64;
    let mut peak_engines = info.engines;
    let mut failures = 0usize;
    let mut scale_ups = 0usize;
    let mut scale_downs = 0usize;
    let mut end: Option<&super::RunEndInfo> = None;

    for ev in &events[idx..] {
        if end.is_some() {
            anyhow::bail!("event after run_end: {}", ev.kind());
        }
        match ev {
            Event::Arrival { stream, .. } => {
                per_stream_arrived[check_stream(*stream)?] += 1;
            }
            Event::Reject { stream, .. } => {
                per_stream_rejected[check_stream(*stream)?] += 1;
            }
            Event::Drop { stream, .. } => {
                per_stream_dropped[check_stream(*stream)?] += 1;
            }
            Event::Dispatch {
                t,
                stream,
                delay_s,
                service_s,
                actions_per_step,
                j_per_action,
                ..
            } => {
                let s = check_stream(*stream)?;
                if s == last_stream {
                    burst += 1;
                } else {
                    burst = 1;
                    last_stream = s;
                }
                max_burst = max_burst.max(burst);
                actions += actions_per_step;
                energy_j += j_per_action * actions_per_step;
                makespan = makespan.max(t + service_s);
                delays.push(*delay_s);
                services.push(*service_s);
                per_stream_served[s] += 1;
            }
            Event::Scale {
                decision,
                alive_after,
                applied,
                ..
            } => match decision {
                // live: every Up spawns (the autoscaler caps at
                // max_engines before deciding), and the peak fold samples
                // alive engines right after the spawn
                ScaleDecision::Up => {
                    scale_ups += 1;
                    peak_engines = peak_engines.max(*alive_after);
                }
                ScaleDecision::Down => {
                    if *applied {
                        scale_downs += 1;
                    }
                }
                ScaleDecision::Hold => {}
            },
            Event::Failure { .. } => failures += 1,
            Event::RunEnd { info, .. } => end = Some(&**info),
            Event::RunStart { .. } => anyhow::bail!("second run_start mid-stream"),
            // bookkeeping-free kinds
            Event::Admit { .. }
            | Event::Completion { .. }
            | Event::CacheSnapshot { .. }
            | Event::PhaseSpan { .. } => {}
        }
    }
    let Some(end) = end else {
        anyhow::bail!("stream has no run_end (truncated?)");
    };

    let arrived: usize = per_stream_arrived.iter().sum();
    let served = services.len();
    let dropped: usize = per_stream_dropped.iter().sum();
    let rejected: usize = per_stream_rejected.iter().sum();
    anyhow::ensure!(
        (arrived, served, dropped, rejected)
            == (end.arrived, end.served, end.dropped, end.rejected),
        "stream does not self-certify: folded arrived/served/dropped/rejected \
         {arrived}/{served}/{dropped}/{rejected} != run_end {}/{}/{}/{} \
         (summary-only or truncated stream)",
        end.arrived,
        end.served,
        end.dropped,
        end.rejected
    );

    let total_time = makespan.max(1e-12);
    let (actions, energy_j, j_per_action, peak_engines) = match info.mode {
        RunMode::SingleLane => {
            // the live mirror computes these from end-of-run totals
            let shard = info
                .shards
                .first()
                .ok_or_else(|| anyhow::anyhow!("single-lane run_start without a shard echo"))?;
            let actions = served as f64 * shard.actions_per_step;
            (actions, actions * shard.j_per_action, shard.j_per_action, 1)
        }
        RunMode::EventLoop | RunMode::Batcher => {
            let jpa = if actions > 0.0 { energy_j / actions } else { 0.0 };
            (actions, energy_j, jpa, peak_engines)
        }
    };

    Ok(FleetReport {
        arrived,
        served,
        dropped,
        rejected,
        throughput: served as f64 / total_time,
        queue_delay: Summary::of(&delays),
        service: Summary::of(&services),
        per_stream_served,
        per_stream_arrived,
        per_stream_dropped,
        per_stream_rejected,
        max_burst,
        actions,
        agg_actions_s: actions / total_time,
        energy_j,
        j_per_action,
        peak_engines,
        failures,
        scale_ups,
        scale_downs,
        makespan_s: total_time,
    })
}

/// Parse an NDJSON text (one event per line, blank lines ignored) and
/// replay it.
pub fn replay_ndjson(text: &str) -> anyhow::Result<FleetReport> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let ev =
            Event::parse_line(line).map_err(|e| anyhow::anyhow!("events line {}: {e}", i + 1))?;
        events.push(ev);
    }
    replay(&events)
}

/// Bitwise report comparison: `None` when every field of `a` matches `b`
/// bit for bit, otherwise the first mismatching field with both values.
/// This is the yardstick for the replay invariant (tests and the
/// `telemetry` experiment both check through it).
pub fn report_mismatch(a: &FleetReport, b: &FleetReport) -> Option<String> {
    fn num(field: &str, x: f64, y: f64) -> Option<String> {
        (x.to_bits() != y.to_bits()).then(|| format!("{field}: {x:?} != {y:?}"))
    }
    fn summary(field: &str, x: &Summary, y: &Summary) -> Option<String> {
        if x.n != y.n {
            return Some(format!("{field}.n: {} != {}", x.n, y.n));
        }
        num(&format!("{field}.mean"), x.mean, y.mean)
            .or_else(|| num(&format!("{field}.std"), x.std, y.std))
            .or_else(|| num(&format!("{field}.min"), x.min, y.min))
            .or_else(|| num(&format!("{field}.p50"), x.p50, y.p50))
            .or_else(|| num(&format!("{field}.p90"), x.p90, y.p90))
            .or_else(|| num(&format!("{field}.p99"), x.p99, y.p99))
            .or_else(|| num(&format!("{field}.max"), x.max, y.max))
    }
    fn count(field: &str, x: usize, y: usize) -> Option<String> {
        (x != y).then(|| format!("{field}: {x} != {y}"))
    }
    fn counts(field: &str, x: &[usize], y: &[usize]) -> Option<String> {
        (x != y).then(|| format!("{field}: {x:?} != {y:?}"))
    }
    count("arrived", a.arrived, b.arrived)
        .or_else(|| count("served", a.served, b.served))
        .or_else(|| count("dropped", a.dropped, b.dropped))
        .or_else(|| count("rejected", a.rejected, b.rejected))
        .or_else(|| num("throughput", a.throughput, b.throughput))
        .or_else(|| summary("queue_delay", &a.queue_delay, &b.queue_delay))
        .or_else(|| summary("service", &a.service, &b.service))
        .or_else(|| counts("per_stream_served", &a.per_stream_served, &b.per_stream_served))
        .or_else(|| counts("per_stream_arrived", &a.per_stream_arrived, &b.per_stream_arrived))
        .or_else(|| counts("per_stream_dropped", &a.per_stream_dropped, &b.per_stream_dropped))
        .or_else(|| {
            counts("per_stream_rejected", &a.per_stream_rejected, &b.per_stream_rejected)
        })
        .or_else(|| count("max_burst", a.max_burst, b.max_burst))
        .or_else(|| num("actions", a.actions, b.actions))
        .or_else(|| num("agg_actions_s", a.agg_actions_s, b.agg_actions_s))
        .or_else(|| num("energy_j", a.energy_j, b.energy_j))
        .or_else(|| num("j_per_action", a.j_per_action, b.j_per_action))
        .or_else(|| count("peak_engines", a.peak_engines, b.peak_engines))
        .or_else(|| count("failures", a.failures, b.failures))
        .or_else(|| count("scale_ups", a.scale_ups, b.scale_ups))
        .or_else(|| count("scale_downs", a.scale_downs, b.scale_downs))
        .or_else(|| num("makespan_s", a.makespan_s, b.makespan_s))
}

#[cfg(test)]
mod tests {
    use super::super::{RunMeta, VecSink};
    use super::*;
    use crate::sim::fleet::{
        AdmissionPolicy, AutoscalerConfig, FleetConfig, FleetSim, SchedulingPolicy, ShardSpec,
    };

    fn traced(cfg: FleetConfig, shards: Vec<ShardSpec>) -> (FleetReport, Vec<Event>) {
        let sim = FleetSim::new(cfg, shards).unwrap();
        let mut sink = VecSink::new();
        let live = sim.run_traced(&RunMeta::default(), &mut sink);
        (live, sink.events)
    }

    fn busy_cfg() -> FleetConfig {
        FleetConfig {
            streams: 4,
            rate_hz: 3.0,
            duration_s: 8.0,
            seed: 13,
            deadline_s: Some(0.3),
            admission: AdmissionPolicy::TokenBucket { rate_hz: 6.0, burst: 4 },
            scheduling: SchedulingPolicy::Edf,
            slo_deadline_mults: vec![0.5, 1.0, 2.0],
            autoscaler: Some(AutoscalerConfig {
                check_interval_s: 0.25,
                queue_up: 3,
                queue_down: 1,
                p99_up_s: Some(0.2),
                warmup_s: 0.25,
                min_engines: 1,
                max_engines: 4,
            }),
            failure_rate_hz: 0.05,
        }
    }

    #[test]
    fn event_loop_stream_replays_bitwise() {
        let (live, events) = traced(busy_cfg(), vec![ShardSpec::uniform("a", 1, 0.2)]);
        let replayed = replay(&events).unwrap();
        assert_eq!(report_mismatch(&live, &replayed), None);
    }

    #[test]
    fn single_lane_stream_replays_bitwise() {
        let cfg = FleetConfig {
            streams: 3,
            rate_hz: 2.0,
            duration_s: 10.0,
            seed: 11,
            deadline_s: Some(0.3),
            ..Default::default()
        };
        let spec = ShardSpec {
            label: "one".to_string(),
            lanes: 1,
            step_s: 0.4,
            actions_per_step: 8.0,
            j_per_action: 0.5,
        };
        let (live, events) = traced(cfg, vec![spec]);
        // the degenerate path really ran: peak is the hard-coded 1
        assert_eq!(live.peak_engines, 1);
        let replayed = replay(&events).unwrap();
        assert_eq!(report_mismatch(&live, &replayed), None);
    }

    #[test]
    fn collapsed_fleet_flush_replays_bitwise() {
        // mean fail time 20 ms on the only engine: the fleet collapses and
        // the flush emits synthetic arrival+drop pairs for the remainder
        let cfg = FleetConfig {
            streams: 2,
            rate_hz: 2.0,
            duration_s: 10.0,
            seed: 29,
            failure_rate_hz: 50.0,
            ..Default::default()
        };
        let (live, events) = traced(cfg, vec![ShardSpec::uniform("a", 1, 0.1)]);
        assert!(live.failures >= 1 && live.dropped > 0, "{live:?}");
        let replayed = replay(&events).unwrap();
        assert_eq!(report_mismatch(&live, &replayed), None);
    }

    #[test]
    fn ndjson_round_trip_replays_bitwise() {
        let (live, events) = traced(busy_cfg(), vec![ShardSpec::uniform("a", 2, 0.15)]);
        let text: String =
            events.iter().map(|e| e.to_ndjson_line() + "\n").collect();
        let replayed = replay_ndjson(&text).unwrap();
        assert_eq!(report_mismatch(&live, &replayed), None);
    }

    #[test]
    fn timestamps_are_monotone_between_run_frames() {
        let (_, events) = traced(busy_cfg(), vec![ShardSpec::uniform("a", 1, 0.2)]);
        assert_eq!(events.first().unwrap().kind(), "run_start");
        assert_eq!(events.last().unwrap().kind(), "run_end");
        let mut prev = f64::NEG_INFINITY;
        for ev in &events {
            assert!(
                ev.t() >= prev,
                "timestamp regression at {} ({} < {prev})",
                ev.kind(),
                ev.t()
            );
            prev = ev.t();
        }
    }

    #[test]
    fn malformed_streams_are_rejected() {
        let (_, events) = traced(
            FleetConfig { streams: 2, rate_hz: 2.0, duration_s: 5.0, seed: 7, ..Default::default() },
            vec![ShardSpec::uniform("a", 2, 0.05)],
        );
        // no run_start
        assert!(replay(&events[1..]).is_err());
        // truncated: no run_end
        assert!(replay(&events[..events.len() - 1])
            .unwrap_err()
            .to_string()
            .contains("run_end"));
        // counts no longer self-certify with a dispatch removed
        let di = events.iter().position(|e| e.kind() == "dispatch").unwrap();
        let mut cut = events.clone();
        cut.remove(di);
        assert!(cut.len() < events.len());
        let err = replay(&cut).unwrap_err().to_string();
        assert!(err.contains("self-certify"), "got: {err}");
        // second run_start mid-stream
        let mut doubled = events.clone();
        doubled.insert(1, events[0].clone());
        assert!(replay(&doubled).is_err());
        // event after run_end
        let mut trailing = events.clone();
        trailing.push(events[di].clone());
        assert!(replay(&trailing).is_err());
        // empty stream
        assert!(replay(&[]).is_err());
    }

    #[test]
    fn report_mismatch_localizes_the_field() {
        let (live, events) = traced(
            FleetConfig { streams: 2, rate_hz: 2.0, duration_s: 5.0, seed: 7, ..Default::default() },
            vec![ShardSpec::uniform("a", 2, 0.05)],
        );
        let replayed = replay(&events).unwrap();
        assert_eq!(report_mismatch(&live, &replayed), None);
        let mut bumped = replayed.clone();
        bumped.throughput += 1e-9;
        let m = report_mismatch(&live, &bumped).unwrap();
        assert!(m.starts_with("throughput"), "got: {m}");
        let mut counted = replayed;
        counted.max_burst += 1;
        assert!(report_mismatch(&live, &counted).unwrap().starts_with("max_burst"));
    }
}

#[cfg(test)]
mod review_probe {
    use super::super::{RunMeta, VecSink};
    use crate::sim::fleet::{FleetConfig, FleetSim, ShardSpec};

    #[test]
    fn review_probe_monotone_collapsed() {
        let cfg = FleetConfig {
            streams: 2,
            rate_hz: 2.0,
            duration_s: 10.0,
            seed: 29,
            failure_rate_hz: 50.0,
            ..Default::default()
        };
        let sim = FleetSim::new(cfg, vec![ShardSpec::uniform("a", 1, 0.1)]).unwrap();
        let mut sink = VecSink::new();
        let live = sim.run_traced(&RunMeta::default(), &mut sink);
        assert!(live.failures >= 1 && live.dropped > 0);
        let mut prev = f64::NEG_INFINITY;
        for ev in &sink.events {
            assert!(ev.t() >= prev, "regression at {} ({} < {prev})", ev.kind(), ev.t());
            prev = ev.t();
        }
    }
}
