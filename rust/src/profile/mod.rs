//! Profiling: phase-level accumulation for real runs (the Nsight-style
//! decomposition of §3.1) and operator-level trace analysis for simulated
//! runs.

pub mod chrome_trace;
pub mod phases;
pub mod trace;

pub use chrome_trace::{chrome_trace, export_chrome_trace};
pub use phases::PhaseProfiler;
pub use trace::{top_ops, trace_table};
