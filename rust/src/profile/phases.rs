//! Phase-level profiler: accumulates `PhaseTimes` across control steps and
//! renders the Fig 2-style breakdown for real (measured) runs.

use crate::engine::PhaseTimes;
use crate::model::Phase;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Accumulates per-phase samples across steps.
#[derive(Debug, Default, Clone)]
pub struct PhaseProfiler {
    samples: [Vec<f64>; 4],
}

impl PhaseProfiler {
    pub fn new() -> PhaseProfiler {
        PhaseProfiler::default()
    }

    pub fn record(&mut self, t: &PhaseTimes) {
        self.samples[0].push(t.vision.as_secs_f64());
        self.samples[1].push(t.prefill.as_secs_f64());
        self.samples[2].push(t.decode.as_secs_f64());
        self.samples[3].push(t.action.as_secs_f64());
    }

    pub fn n_steps(&self) -> usize {
        self.samples[0].len()
    }

    pub fn summary(&self, phase: Phase) -> Summary {
        let idx = match phase {
            Phase::Vision => 0,
            Phase::Prefill => 1,
            Phase::Decode => 2,
            Phase::Action => 3,
        };
        Summary::of(&self.samples[idx])
    }

    /// Mean total step latency.
    pub fn mean_total(&self) -> f64 {
        Phase::ALL.iter().map(|p| self.summary(*p).mean).sum()
    }

    /// Mean generation (prefill+decode) share.
    pub fn generation_share(&self) -> f64 {
        let total = self.mean_total();
        if total == 0.0 {
            return 0.0;
        }
        (self.summary(Phase::Prefill).mean + self.summary(Phase::Decode).mean) / total
    }

    /// Render the measured phase breakdown.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["phase", "mean (ms)", "p50 (ms)", "p99 (ms)", "share"],
        )
        .left_first();
        let total = self.mean_total().max(1e-12);
        for phase in Phase::ALL {
            let s = self.summary(phase);
            t.row(vec![
                phase.to_string(),
                format!("{:.3}", s.mean * 1e3),
                format!("{:.3}", s.p50 * 1e3),
                format!("{:.3}", s.p99 * 1e3),
                format!("{:.1}%", s.mean / total * 100.0),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn times(v: u64, p: u64, d: u64, a: u64) -> PhaseTimes {
        PhaseTimes {
            vision: Duration::from_millis(v),
            prefill: Duration::from_millis(p),
            decode: Duration::from_millis(d),
            action: Duration::from_millis(a),
        }
    }

    #[test]
    fn accumulates_and_summarizes() {
        let mut prof = PhaseProfiler::new();
        prof.record(&times(10, 20, 60, 10));
        prof.record(&times(10, 20, 80, 10));
        assert_eq!(prof.n_steps(), 2);
        let d = prof.summary(Phase::Decode);
        assert!((d.mean - 0.07).abs() < 1e-9);
        assert!((prof.mean_total() - 0.11).abs() < 1e-9);
        assert!((prof.generation_share() - 90.0 / 110.0).abs() < 1e-9);
    }

    #[test]
    fn table_has_four_phases() {
        let mut prof = PhaseProfiler::new();
        prof.record(&times(1, 2, 3, 4));
        let t = prof.table("measured");
        assert_eq!(t.n_rows(), 4);
    }
}
