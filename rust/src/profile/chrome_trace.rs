//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated VLA
//! control step: every operator becomes a complete event on its engine's
//! track, phases become nested spans — the simulated twin of the Nsight
//! timeline the paper captures on hardware.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::hw::Platform;
use crate::model::VlaConfig;
use crate::sim::{cost_op_scoped, Engine, SimOptions};
use crate::util::json::Json;

/// Build the Chrome-trace JSON document for one simulated control step.
/// Decode positions are sampled with `options.decode_stride` to keep traces
/// viewable; timestamps are the simulator's serial-schedule times (µs).
pub fn chrome_trace(platform: &Platform, options: &SimOptions, cfg: &VlaConfig) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut now_us = 0.0f64;

    let mut emit = |name: &str, cat: &str, ts: f64, dur: f64, tid: u64| {
        events.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("cat", Json::Str(cat.to_string())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::Num(ts)),
            ("dur", Json::Num(dur.max(0.01))),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
        ]));
    };

    type Emit<'a> = &'a mut dyn FnMut(&str, &str, f64, f64, u64);
    let run_stage = |stage: &crate::model::Stage, now_us: &mut f64, emit: Emit| {
        let phase_start = *now_us;
        for op in &stage.ops {
            let c = cost_op_scoped(platform, op, options.effective_pim_scope());
            let dur = c.t_serial().max(options.dispatch_for(c.engine)) * 1e6;
            let tid = match c.engine {
                Engine::Soc => 1,
                Engine::Pim => 2,
            };
            emit(&c.name, stage.phase.name(), *now_us, dur, tid);
            *now_us += dur;
        }
        let phase_dur = *now_us - phase_start;
        emit(&format!("PHASE:{}", stage.name), "phase", phase_start, phase_dur, 0);
    };

    run_stage(&cfg.vision_stage(), &mut now_us, &mut emit);
    run_stage(&cfg.prefill_stage(), &mut now_us, &mut emit);
    let stride = options.decode_stride.max(1);
    let start = cfg.shape.prefill_len();
    let mut pos = 0u64;
    while pos < cfg.shape.decode_tokens {
        run_stage(&cfg.decode_stage_at(start + pos), &mut now_us, &mut emit);
        pos += stride;
    }
    run_stage(&cfg.action_stage(), &mut now_us, &mut emit);

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
        (
            "otherData",
            Json::obj(vec![
                ("platform", Json::Str(platform.name.clone())),
                ("model", Json::Str(cfg.name.clone())),
                ("note", Json::Str("simulated schedule; decode sampled by stride".into())),
            ]),
        ),
    ])
}

/// Write the trace to a file.
pub fn export_chrome_trace(
    platform: &Platform,
    options: &SimOptions,
    cfg: &VlaConfig,
    path: &std::path::Path,
) -> anyhow::Result<()> {
    let doc = chrome_trace(platform, options, cfg);
    std::fs::write(path, doc.to_string_compact())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::vla::tiny_test_config;

    fn opts() -> SimOptions {
        SimOptions {
            decode_stride: 8,
            ..Default::default()
        }
    }

    #[test]
    fn trace_is_valid_json_with_phases() {
        let doc = chrome_trace(&platform::orin(), &opts(), &tiny_test_config());
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.len() > 50);
        let is_phase = |e: &&Json| {
            e.get("name").and_then(|n| n.as_str()).is_some_and(|n| n.starts_with("PHASE:"))
        };
        let phases: Vec<&Json> = events.iter().filter(is_phase).collect();
        // vision + prefill + sampled decode steps + action
        assert!(phases.len() >= 4, "{} phase spans", phases.len());
    }

    #[test]
    fn timestamps_monotone_nonoverlapping_on_track() {
        let doc = chrome_trace(&platform::orin(), &opts(), &tiny_test_config());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let mut last_end = 0.0;
        for e in events.iter().filter(|e| e.get("tid").unwrap().as_f64() == Some(1.0)) {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            assert!(ts + 1e-9 >= last_end, "ops overlap on the SoC track");
            last_end = ts + dur;
        }
        assert!(last_end > 0.0);
    }

    #[test]
    fn pim_platform_uses_pim_track() {
        let cfg = crate::model::molmoact::molmoact_7b();
        let doc = chrome_trace(&platform::orin_pim(), &opts(), &cfg);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get("tid").unwrap().as_f64() == Some(2.0)),
            "PIM track must appear on a PIM platform"
        );
    }

    #[test]
    fn export_writes_file() {
        let path = std::env::temp_dir().join("vla_char_trace_test.json");
        export_chrome_trace(&platform::thor(), &opts(), &tiny_test_config(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
    }
}
