//! Operator-level trace analysis (the simulated analogue of an Nsight
//! kernel trace): cost every op in a stage and report the top-K by time
//! with roofline attribution.

use crate::hw::Platform;
use crate::model::Stage;
use crate::sim::{cost_op, Bound, Engine, OpCost};
use crate::util::table::Table;
use crate::util::units::{fmt_bytes, fmt_time};

/// Cost every operator in `stage` on `platform` (no cross-op effects).
pub fn trace_stage(platform: &Platform, stage: &Stage, allow_pim: bool) -> Vec<OpCost> {
    stage.ops.iter().map(|op| cost_op(platform, op, allow_pim)).collect()
}

/// Top-K ops by serial time.
pub fn top_ops(mut costs: Vec<OpCost>, k: usize) -> Vec<OpCost> {
    costs.sort_by(|a, b| b.t_serial().partial_cmp(&a.t_serial()).unwrap());
    costs.truncate(k);
    costs
}

/// Render an Nsight-like kernel table.
pub fn trace_table(title: &str, costs: &[OpCost]) -> Table {
    let mut t = Table::new(
        title,
        &["op", "kind", "engine", "time", "bytes", "bound", "FLOP/byte"],
    )
    .left_first();
    for c in costs {
        t.row(vec![
            c.name.clone(),
            c.kind.name().to_string(),
            match c.engine {
                Engine::Soc => "SoC".into(),
                Engine::Pim => "PIM".into(),
            },
            fmt_time(c.t_serial()),
            fmt_bytes(c.bytes),
            match c.bound {
                Bound::Compute => "compute".into(),
                Bound::Memory => "memory".into(),
                Bound::Overhead => "overhead".into(),
            },
            format!("{:.2}", c.flops / c.bytes.max(1.0)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;

    #[test]
    fn decode_trace_dominated_by_weight_gemvs() {
        let cfg = molmoact_7b();
        let stage = cfg.decode_stage_at(800);
        let costs = trace_stage(&platform::orin(), &stage, false);
        assert_eq!(costs.len(), stage.ops.len());
        let top = top_ops(costs, 5);
        // the heaviest decode ops must be memory-bound weight matmuls
        for c in &top {
            assert_eq!(c.bound, Bound::Memory, "{}", c.name);
        }
        assert!(top[0].name.contains("lm_head") || top[0].name.contains("w_"));
    }

    #[test]
    fn table_renders() {
        let cfg = molmoact_7b();
        let stage = cfg.decode_stage_at(100);
        let costs = top_ops(trace_stage(&platform::orin_pim(), &stage, true), 10);
        let t = trace_table("top ops", &costs);
        assert_eq!(t.n_rows(), 10);
        assert!(t.to_markdown().contains("PIM"));
    }
}
