//! Unit helpers and human-readable formatting for times, bytes, FLOP counts,
//! bandwidths, and frequencies. All simulator-internal quantities are SI
//! (seconds, bytes, FLOPs); these helpers format for reports.

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
pub const GB: f64 = 1e9;
pub const TERA: f64 = 1e12;
pub const GIGA: f64 = 1e9;
pub const MEGA: f64 = 1e6;
pub const MILLI: f64 = 1e-3;
pub const MICRO: f64 = 1e-6;

/// Format a duration in seconds with an auto-selected unit.
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a >= 1.0 {
        format!("{:.3} s", secs)
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else if a == 0.0 {
        "0 s".to_string()
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    let a = bytes.abs();
    if a >= GIB {
        format!("{:.2} GiB", bytes / GIB)
    } else if a >= MIB {
        format!("{:.2} MiB", bytes / MIB)
    } else if a >= KIB {
        format!("{:.2} KiB", bytes / KIB)
    } else {
        format!("{:.0} B", bytes)
    }
}

/// Format a FLOP count.
pub fn fmt_flops(flops: f64) -> String {
    let a = flops.abs();
    if a >= 1e12 {
        format!("{:.2} TFLOP", flops / 1e12)
    } else if a >= 1e9 {
        format!("{:.2} GFLOP", flops / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} MFLOP", flops / 1e6)
    } else {
        format!("{:.0} FLOP", flops)
    }
}

/// Format a rate in Hz.
pub fn fmt_hz(hz: f64) -> String {
    if hz >= 1.0 {
        format!("{:.2} Hz", hz)
    } else if hz >= 1e-3 {
        format!("{:.2} mHz", hz * 1e3)
    } else {
        format!("{:.4} mHz", hz * 1e3)
    }
}

/// Format a throughput in GB/s.
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    format!("{:.0} GB/s", bytes_per_sec / GB)
}

/// Format a ratio like "1.40x".
pub fn fmt_ratio(r: f64) -> String {
    format!("{:.2}x", r)
}

/// Format a fraction as a percentage.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_units() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0123), "12.300 ms");
        assert_eq!(fmt_time(45e-6), "45.000 us");
        assert_eq!(fmt_time(12e-9), "12.0 ns");
        assert_eq!(fmt_time(0.0), "0 s");
    }

    #[test]
    fn byte_units() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.5 * MIB), "3.50 MiB");
        assert_eq!(fmt_bytes(2.0 * GIB), "2.00 GiB");
    }

    #[test]
    fn flop_units() {
        assert_eq!(fmt_flops(2e12), "2.00 TFLOP");
        assert_eq!(fmt_flops(5e9), "5.00 GFLOP");
    }

    #[test]
    fn rate_units() {
        assert_eq!(fmt_hz(10.0), "10.00 Hz");
        assert_eq!(fmt_hz(0.05), "50.00 mHz");
        assert_eq!(fmt_bw(203e9), "203 GB/s");
        assert_eq!(fmt_ratio(1.4), "1.40x");
        assert_eq!(fmt_pct(0.753), "75.3%");
    }
}
