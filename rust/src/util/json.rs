//! Minimal JSON parser and serializer.
//!
//! `serde`/`serde_json` are unavailable in this offline environment, so the
//! config system, artifact manifests, and report emitters use this
//! self-contained implementation. It supports the full JSON data model
//! (objects, arrays, strings with escapes, numbers, booleans, null) and
//! preserves object key insertion order (important for stable, diffable
//! emitted reports).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: ordered key/value pairs (insertion order preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors (for config loading).
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.get(key)
            .and_then(|v| v.as_bool())
            .ok_or_else(|| anyhow::anyhow!("missing or non-boolean field `{key}`"))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. The entire input must be consumed
    /// (trailing whitespace allowed).
    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        // shortest round-trippable repr rust gives us
        format!("{n}")
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b' ' | b'\t' | b'\n' | b'\r') = self.bytes.get(self.pos) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected `{}` at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => {
                anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)
            }
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        let mut seen = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if seen.insert(key.clone(), ()).is_some() {
                anyhow::bail!("duplicate key `{key}`");
            }
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => {
                    anyhow::bail!("expected `,` or `}}`, found {:?}", other.map(|c| c as char))
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected `,` or `]`, found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // surrogate pairs
                            if (0xD800..0xDC00).contains(&code) {
                                let rest = self
                                    .bytes
                                    .get(self.pos + 5..self.pos + 11)
                                    .ok_or_else(|| anyhow::anyhow!("truncated surrogate pair"))?;
                                if &rest[0..2] != b"\\u" {
                                    anyhow::bail!("unpaired surrogate");
                                }
                                let lo = std::str::from_utf8(&rest[2..6])?;
                                let low = u32::from_str_radix(lo, 16)?;
                                let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                let c = char::from_u32(c)
                                    .ok_or_else(|| anyhow::anyhow!("bad surrogate pair"))?;
                                s.push(c);
                                self.pos += 10;
                            } else {
                                let c = char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                                s.push(c);
                                self.pos += 4;
                            }
                            self.pos += 1;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let text =
                        std::str::from_utf8(rest).map_err(|_| anyhow::anyhow!("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text.parse()?;
        Ok(Json::Num(n))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A 😀");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"orin","bw":203.5,"ok":true,"tags":["edge","soc"],"nested":{"x":1}}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1,}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":1,\"a\":2}").is_err(), "duplicate keys rejected");
    }

    #[test]
    fn key_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(pairs) = &j {
            let keys: Vec<_> = pairs.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn req_accessors() {
        let j = Json::parse(r#"{"bw": 203, "name": "orin", "pim": true}"#).unwrap();
        assert_eq!(j.req_f64("bw").unwrap(), 203.0);
        assert_eq!(j.req_str("name").unwrap(), "orin");
        assert_eq!(j.req_u64("bw").unwrap(), 203);
        assert!(j.req_bool("pim").unwrap());
        assert!(j.req_f64("missing").is_err());
        assert!(j.req_str("bw").is_err());
        assert!(j.req_bool("bw").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld");
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
