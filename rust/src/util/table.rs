//! Table builder with markdown and CSV emitters.
//!
//! All reproduced paper tables/figures are emitted as aligned markdown (for
//! the console and EXPERIMENTS.md) and CSV (for plotting).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table: header row + data rows of strings.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Set alignment per column (defaults to Right; first column commonly Left).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Convenience: left-align the first column only.
    pub fn left_first(mut self) -> Table {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Cell accessor (row, col).
    pub fn cell(&self, r: usize, c: usize) -> &str {
        &self.rows[r][c]
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let pad = |s: &str, w: usize, a: Align| -> String {
            let len = s.chars().count();
            let fill = " ".repeat(w.saturating_sub(len));
            match a {
                Align::Left => format!("{s}{fill}"),
                Align::Right => format!("{fill}{s}"),
            }
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push('|');
        for i in 0..ncols {
            out.push_str(&format!(" {} |", pad(&self.headers[i], widths[i], self.aligns[i])));
        }
        out.push_str("\n|");
        for (i, w) in widths.iter().enumerate() {
            let dashes = "-".repeat(*w);
            match self.aligns[i] {
                Align::Left => out.push_str(&format!(" {dashes} |")),
                Align::Right => out.push_str(&format!(" {dashes}:|")),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for (i, cell) in row.iter().enumerate() {
                out.push_str(&format!(" {} |", pad(cell, widths[i], self.aligns[i])));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| quote(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Parse a CSV produced by [`Table::to_csv`] back into a table (the
    /// title is not stored in the CSV, so the caller supplies it). Used by
    /// the report-sink round-trip tests to prove files are lossless.
    pub fn from_csv(title: &str, text: &str) -> anyhow::Result<Table> {
        let mut records: Vec<Vec<String>> = Vec::new();
        let mut record: Vec<String> = Vec::new();
        let mut field = String::new();
        let mut in_quotes = false;
        let mut chars = text.chars().peekable();
        while let Some(c) = chars.next() {
            if in_quotes {
                if c == '"' {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                } else {
                    field.push(c);
                }
            } else {
                match c {
                    '"' => in_quotes = true,
                    ',' => record.push(std::mem::take(&mut field)),
                    '\n' => {
                        record.push(std::mem::take(&mut field));
                        records.push(std::mem::take(&mut record));
                    }
                    '\r' => {}
                    _ => field.push(c),
                }
            }
        }
        anyhow::ensure!(!in_quotes, "unterminated quoted CSV field");
        if !field.is_empty() || !record.is_empty() {
            record.push(field);
            records.push(record);
        }
        anyhow::ensure!(!records.is_empty(), "empty CSV");
        let headers = records.remove(0);
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(title, &hdr_refs);
        for r in records {
            anyhow::ensure!(
                r.len() == t.headers.len(),
                "CSV row width {} != header width {}",
                r.len(),
                t.headers.len()
            );
            t.row(r);
        }
        Ok(t)
    }

    /// Write markdown + CSV files under `dir` using a slug of the title.
    pub fn save(&self, dir: &std::path::Path, slug: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Render an ASCII horizontal bar chart (for figure reproductions on the
/// console — the paper's Fig 2/3 are bar charts).
pub fn ascii_bars(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-30);
    let label_w = items.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in items {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "  {:<lw$} | {}{} {:.3} {}\n",
            label,
            "#".repeat(n),
            " ".repeat(width - n),
            v,
            unit,
            lw = label_w
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Test", &["name", "bw", "tflops"]).left_first();
        t.row(vec!["orin".into(), "203".into(), "100".into()]);
        t.row(vec!["thor".into(), "273".into(), "500".into()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### Test"));
        assert!(md.contains("| name |"));
        let lines: Vec<&str> = md.lines().collect();
        // title, blank, header, separator, 2 rows
        assert_eq!(lines.len(), 6);
        // all table lines same width
        let w = lines[2].len();
        assert!(lines[3..].iter().all(|l| l.len() == w));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("q", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn csv_round_trips() {
        let mut t = Table::new("rt", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        t.row(vec!["plain".into(), "multi\nline".into()]);
        t.row(vec!["cr\rcell".into(), "3".into()]);
        let back = Table::from_csv("rt", &t.to_csv()).unwrap();
        assert_eq!(back.headers(), t.headers());
        assert_eq!(back.rows(), t.rows());
        assert!(Table::from_csv("bad", "a,b\nonly-one\n").is_err());
        assert!(Table::from_csv("bad", "").is_err());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bars_render() {
        let s = ascii_bars(
            "fig",
            &[("a".into(), 1.0), ("bb".into(), 2.0)],
            "ms",
            10,
        );
        assert!(s.contains("##########")); // max bar is full width
        assert!(s.contains("#####"));
        assert!(s.starts_with("fig\n"));
    }

    #[test]
    fn save_files() {
        let dir = std::env::temp_dir().join("vla_char_table_test");
        sample().save(&dir, "t1").unwrap();
        let md = std::fs::read_to_string(dir.join("t1.md")).unwrap();
        assert!(md.contains("orin"));
        let csv = std::fs::read_to_string(dir.join("t1.csv")).unwrap();
        assert!(csv.starts_with("name,bw,tflops"));
    }
}
