//! Foundation utilities built in-repo because the offline environment has no
//! crates.io access (no serde/clap/criterion/proptest/rand; `anyhow` and
//! `xla` are in-tree shims under `vendor/`): JSON, CLI parsing, statistics,
//! PRNG, tables, a bench harness, a mini property-testing framework, and
//! logging.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod units;
