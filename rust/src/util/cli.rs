//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands with `--flag`, `--key value`, and `--key=value`
//! options, typed accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

/// Declaration of a single option for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value_name: Option<&'static str>, // None => boolean flag
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// A parsed command line: subcommand, options, and positionals.
#[derive(Debug, Clone)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name) against the known option
    /// specs. The first non-option token is the subcommand; later non-option
    /// tokens are positionals.
    pub fn parse(
        program: &str,
        argv: &[String],
        specs: &[OptSpec],
    ) -> anyhow::Result<Args> {
        let is_flag = |name: &str| -> Option<bool> {
            specs
                .iter()
                .find(|s| s.name == name)
                .map(|s| s.value_name.is_none())
        };
        let mut args = Args {
            program: program.to_string(),
            subcommand: None,
            opts: BTreeMap::new(),
            flags: Vec::new(),
            positionals: Vec::new(),
        };
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                match is_flag(&name) {
                    None => anyhow::bail!("unknown option `--{name}` (try --help)"),
                    Some(true) => {
                        if inline_val.is_some() {
                            anyhow::bail!("flag `--{name}` does not take a value");
                        }
                        args.flags.push(name);
                    }
                    Some(false) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                match argv.get(i) {
                                    Some(v) => v.clone(),
                                    None => anyhow::bail!("option `--{name}` expects a value"),
                                }
                            }
                        };
                        args.opts.insert(name, val);
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("option `--{name}` expects a number, got `{v}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("option `--{name}` expects an integer, got `{v}`")),
        }
    }

    /// Parse a comma-separated list of f64.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad number `{x}` in `--{name}`"))
                })
                .collect(),
        }
    }

    /// Parse a comma-separated list of strings.
    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|x| x.trim().to_string()).collect(),
        }
    }
}

/// Render help text from subcommand descriptions and option specs.
pub fn help_text(
    program: &str,
    about: &str,
    subcommands: &[(&str, &str)],
    specs: &[OptSpec],
) -> String {
    let mut out = format!("{program} — {about}\n\nUSAGE:\n  {program} <SUBCOMMAND> [OPTIONS]\n");
    out.push_str("\nSUBCOMMANDS:\n");
    let w = subcommands.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    for (name, desc) in subcommands {
        out.push_str(&format!("  {name:<w$}  {desc}\n"));
    }
    out.push_str("\nOPTIONS:\n");
    let render_name = |s: &OptSpec| match s.value_name {
        Some(v) => format!("--{} <{v}>", s.name),
        None => format!("--{}", s.name),
    };
    let w = specs.iter().map(|s| render_name(s).len()).max().unwrap_or(0);
    for s in specs {
        let mut line = format!("  {:<w$}  {}", render_name(s), s.help);
        if let Some(d) = s.default {
            line.push_str(&format!(" [default: {d}]"));
        }
        out.push_str(&line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[rustfmt::skip]
    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "platform", value_name: Some("NAME"), help: "platform", default: Some("orin") },
            OptSpec { name: "steps", value_name: Some("N"), help: "steps", default: Some("100") },
            OptSpec { name: "verbose", value_name: None, help: "chatty", default: None },
            OptSpec { name: "sizes", value_name: Some("LIST"), help: "sizes", default: None },
        ]
    }

    fn parse(argv: &[&str]) -> anyhow::Result<Args> {
        let v: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        Args::parse("vla-char", &v, &specs())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["characterize", "--platform", "thor", "--verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("characterize"));
        assert_eq!(a.get("platform"), Some("thor"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["run", "--steps=250"]).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 250);
    }

    #[test]
    fn defaults() {
        let a = parse(&["run"]).unwrap();
        assert_eq!(a.get_or("platform", "orin"), "orin");
        assert_eq!(a.get_f64("steps", 100.0).unwrap(), 100.0);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["run", "--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["run", "--platform"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["run", "--verbose=yes"]).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = parse(&["run", "--steps", "abc"]).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["run", "--sizes", "7, 30,100"]).unwrap();
        assert_eq!(a.get_f64_list("sizes", &[]).unwrap(), vec![7.0, 30.0, 100.0]);
        let b = parse(&["run"]).unwrap();
        assert_eq!(b.get_f64_list("sizes", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn positionals() {
        let a = parse(&["run", "alpha", "beta"]).unwrap();
        assert_eq!(a.positionals, vec!["alpha", "beta"]);
    }

    #[test]
    fn help_renders() {
        let h = help_text("vla-char", "VLA characterization", &[("run", "run it")], &specs());
        assert!(h.contains("--platform <NAME>"));
        assert!(h.contains("[default: orin]"));
        assert!(h.contains("run it"));
    }
}
