//! Deterministic PRNG (xoshiro256++) for workload generation and the
//! property-test framework.
//!
//! `rand` is unavailable offline; we need reproducible streams anyway so that
//! every experiment in EXPERIMENTS.md is re-runnable bit-for-bit.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

/// Derive an independent sub-stream seed from a base seed and a stream
/// index, SplitMix64-style: the index is spread by the golden-ratio
/// constant and the mix finalizer decorrelates neighboring indices. Unlike
/// ad-hoc `seed ^ (index << k)` schemes, index 0 does NOT collapse to the
/// base seed — two consumers seeded from the same base (e.g. the batcher's
/// per-stream arrival processes vs the frame source's per-stream noise)
/// cannot silently share a stream.
pub fn stream_seed(seed: u64, stream: u64) -> u64 {
    let mut z = seed ^ stream.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    /// PRNG for sub-stream `stream` of `seed` (see [`stream_seed`]).
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        Prng::new(stream_seed(seed, stream))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn uniform_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "uniform_u64: lo > hi");
        // wrapping: for the full-range span (lo = 0, hi = u64::MAX) the +1
        // wraps to 0, which the guard below maps to a raw draw — plain
        // arithmetic would overflow-panic in debug builds before the guard
        // could ever fire
        let span = hi.wrapping_sub(lo).wrapping_add(1);
        if span == 0 {
            // full range
            return self.next_u64();
        }
        // rejection-free (slightly biased for astronomically large spans; fine
        // for workload gen)
        lo.wrapping_add(self.next_u64() % span)
    }

    pub fn uniform_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.uniform_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn uniform_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.uniform_usize(0, items.len() - 1)]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.uniform_usize(0, i);
            items.swap(i, j);
        }
    }

    /// Exponentially-distributed inter-arrival time with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.next_f64().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Prng::new(4);
        for _ in 0..10_000 {
            let x = r.uniform_u64(10, 20);
            assert!((10..=20).contains(&x));
        }
    }

    #[test]
    fn uniform_full_range_does_not_overflow() {
        // regression: `hi - lo + 1` used to overflow (debug-build panic) for
        // the full-range span before the `span == 0` guard could fire
        let mut r = Prng::new(42);
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..64 {
            distinct.insert(r.uniform_u64(0, u64::MAX));
        }
        assert!(distinct.len() > 60, "full-range draws must actually vary");
        // near-full spans exercise the wrapping arithmetic without hitting
        // the guard
        for _ in 0..1000 {
            let x = r.uniform_u64(5, u64::MAX);
            assert!(x >= 5);
        }
        assert_eq!(r.uniform_u64(7, 7), 7);
    }

    #[test]
    fn stream_seeds_decorrelate_from_the_base_seed() {
        // regression contract for the batcher: sub-stream 0 must NOT be the
        // base seed (the old `seed ^ (0 << 17)` collapsed to it, so stream-0
        // arrivals and the frame source shared a PRNG stream)
        for seed in [0u64, 7, 42, 0xDEADBEEF] {
            assert_ne!(stream_seed(seed, 0), seed);
            let mut base = Prng::new(seed);
            let mut s0 = Prng::for_stream(seed, 0);
            let same = (0..64).filter(|_| base.next_u64() == s0.next_u64()).count();
            assert!(same < 2, "sub-stream 0 of {seed} tracks the base stream");
        }
        // distinct indices give distinct streams; same index is deterministic
        assert_ne!(stream_seed(9, 0), stream_seed(9, 1));
        assert_eq!(stream_seed(9, 3), stream_seed(9, 3));
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(5);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Prng::new(6);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
