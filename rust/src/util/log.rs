//! Leveled stderr logger with wall-clock timestamps relative to process start.
//! Controlled by `VLA_LOG` (error|warn|info|debug|trace; default info).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Parse a `VLA_LOG` value; `None` for an unrecognized name.
pub fn parse_level(v: &str) -> Option<Level> {
    match v.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Initialize from the environment; idempotent. An unrecognized `VLA_LOG`
/// value falls back to Info and says so once on stderr instead of silently
/// swallowing the typo.
pub fn init() {
    start();
    if let Ok(v) = std::env::var("VLA_LOG") {
        match parse_level(&v) {
            Some(lvl) => set_level(lvl),
            None => {
                set_level(Level::Info);
                log(
                    Level::Warn,
                    module_path!(),
                    &format!("unrecognized VLA_LOG={v:?} (want error|warn|info|debug|trace); using info"),
                );
            }
        }
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let t = start().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Trace, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn parses_every_level_and_rejects_typos() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn trace_macro_expands_through_the_logger() {
        // compile-time check that the macro wires to Level::Trace; the
        // level gate keeps it silent here
        set_level(Level::Info);
        crate::log_trace!("unseen {}", 42);
    }
}
