//! Leveled stderr logger with wall-clock timestamps relative to process start.
//! Controlled by `VLA_LOG` (error|warn|info|debug|trace; default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize from the environment; idempotent.
pub fn init() {
    start();
    if let Ok(v) = std::env::var("VLA_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let t = start().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }
}
