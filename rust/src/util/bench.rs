//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + calibrated iteration counts + outlier-robust summary
//! statistics, and a registry so each `[[bench]]` binary (with
//! `harness = false`) reads uniformly:
//!
//! ```ignore
//! let mut b = BenchSet::new("fig2");
//! b.bench("sim_orin", || { simulate(...); });
//! b.finish();
//! ```

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::json::Json;
use super::stats::Summary;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary, in seconds.
    pub summary: Summary,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<40} {:>12}/iter  (p50 {:>12}, p99 {:>12}, n={} x {})",
            self.name,
            super::units::fmt_time(self.summary.mean),
            super::units::fmt_time(self.summary.p50),
            super::units::fmt_time(self.summary.p99),
            self.samples,
            self.iters_per_sample,
        )
    }
}

/// Configuration for the measurement loop.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Number of samples to collect within the measurement budget.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Fast-mode default keeps full `cargo bench` runs tractable; override
        // with VLA_BENCH_SLOW=1 for higher-fidelity runs.
        if std::env::var("VLA_BENCH_SLOW").is_ok() {
            BenchConfig {
                warmup: Duration::from_millis(500),
                measure: Duration::from_secs(2),
                samples: 50,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(100),
                measure: Duration::from_millis(400),
                samples: 20,
            }
        }
    }
}

/// A named collection of benchmarks that prints a uniform report.
pub struct BenchSet {
    pub title: String,
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl BenchSet {
    pub fn new(title: &str) -> BenchSet {
        println!("\n=== bench: {title} ===");
        BenchSet {
            title: title.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    /// Measure `f`, which should perform ONE logical iteration. The harness
    /// calibrates how many iterations fit a sample, then collects samples.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and calibration: find iters such that one sample takes
        // ~measure/samples.
        let warm_end = Instant::now() + self.config.warmup;
        let mut one = Duration::ZERO;
        let mut warm_iters = 0u64;
        while Instant::now() < warm_end || warm_iters == 0 {
            let t0 = Instant::now();
            f();
            one = t0.elapsed();
            warm_iters += 1;
            if warm_iters > 10_000 {
                break;
            }
        }
        let target_sample = self.config.measure.as_secs_f64() / self.config.samples as f64;
        let iters =
            ((target_sample / one.as_secs_f64().max(1e-9)).ceil() as u64).clamp(1, 1_000_000);

        let mut sample_times = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&sample_times),
            iters_per_sample: iters,
            samples: sample_times.len(),
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record an externally-computed scalar metric (e.g. a simulated latency
    /// — the simulator is analytical, its OUTPUT is the benchmark number).
    pub fn record(&mut self, name: &str, value_secs: f64) {
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&[value_secs]),
            iters_per_sample: 1,
            samples: 1,
        };
        println!(
            "{:<40} {:>12}  (modeled)",
            name,
            super::units::fmt_time(value_secs)
        );
        self.results.push(result);
    }

    /// Print a footer; returns results for further inspection.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("=== bench: {} done ({} entries) ===", self.title, self.results.len());
        self.results
    }
}

/// Parse `--json [PATH]` from the bench binary's argv. Every
/// `harness = false` bench supports it: with a bare `--json` the file goes
/// to `default_path` (the tracked `BENCH_*.json` name); `--json PATH`
/// overrides it. Other argv entries (e.g. the `--bench` flag cargo passes
/// to bench targets) are ignored.
pub fn json_path_from_args(default_path: &str) -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--json" {
            return match args.get(i + 1) {
                Some(next) if !next.starts_with('-') => Some(PathBuf::from(next)),
                _ => Some(PathBuf::from(default_path)),
            };
        }
        i += 1;
    }
    None
}

/// The machine-readable form of a bench run's measurements: one entry per
/// [`BenchResult`], seconds per iteration.
pub fn results_json(results: &[BenchResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("mean_s", Json::Num(r.summary.mean)),
                    ("p50_s", Json::Num(r.summary.p50)),
                    ("p99_s", Json::Num(r.summary.p99)),
                    ("iters_per_sample", Json::Num(r.iters_per_sample as f64)),
                    ("samples", Json::Num(r.samples as f64)),
                ])
            })
            .collect(),
    )
}

/// Write a `BENCH_*.json` document (pretty-printed, trailing newline) and
/// log the path — the benches' `--json` sink, diffed against the
/// checked-in baseline by `scripts/check_bench.py` in CI.
pub fn write_json(path: &Path, doc: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, format!("{}\n", doc.to_string_pretty()))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Best-effort blackbox to stop the optimizer deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut set = BenchSet {
            title: "t".into(),
            config: BenchConfig {
                warmup: Duration::from_millis(5),
                measure: Duration::from_millis(20),
                samples: 5,
            },
            results: Vec::new(),
        };
        let mut acc = 0u64;
        set.bench("count", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let r = &set.results[0];
        assert!(r.summary.mean > 0.0);
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn results_json_shape() {
        let r = BenchResult {
            name: "x".into(),
            summary: Summary::of(&[0.5]),
            iters_per_sample: 3,
            samples: 1,
        };
        let j = results_json(&[r]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].req_str("name").unwrap(), "x");
        assert_eq!(arr[0].req_f64("mean_s").unwrap(), 0.5);
        assert_eq!(arr[0].req_u64("iters_per_sample").unwrap(), 3);
    }

    #[test]
    fn write_json_roundtrip() {
        let dir = std::env::temp_dir().join("vla_char_bench_json_test");
        let path = dir.join("BENCH_unit.json");
        let doc = Json::obj(vec![("bench", Json::Str("unit".into())), ("v", Json::Num(1.0))]);
        write_json(&path, &doc).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.req_str("bench").unwrap(), "unit");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_modeled_value() {
        let mut set = BenchSet {
            title: "t".into(),
            config: BenchConfig::default(),
            results: Vec::new(),
        };
        set.record("modeled_latency", 0.123);
        assert_eq!(set.results[0].summary.mean, 0.123);
        let out = set.finish();
        assert_eq!(out.len(), 1);
    }
}
