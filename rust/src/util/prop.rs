//! Tiny property-based-testing framework (proptest is unavailable offline).
//!
//! Generates random cases from a deterministic [`Prng`](super::prng::Prng),
//! runs a property over each, and on failure performs greedy shrinking of the
//! failing case via a user-supplied `shrink` hook (default: none).
//!
//! ```ignore
//! prop_check("roofline monotone in bw", 200, |rng| {
//!     let bw = rng.uniform_f64(1e9, 1e12);
//!     ...
//!     Ok(())
//! });
//! ```

use super::prng::Prng;

/// Run `cases` random trials of `prop`. Each trial gets a fresh deterministic
/// PRNG derived from the trial index so failures are reproducible by index.
/// Panics with the failing case index and message on the first failure.
pub fn prop_check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Prng::new(0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case}: {msg}");
        }
    }
}

/// Assert helper: returns Err with a formatted message when `cond` is false.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate-equality helper for property bodies.
pub fn ensure_close(a: f64, b: f64, rel_tol: f64, ctx: &str) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-300);
    if (a - b).abs() / denom <= rel_tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} !~ {b} (rel err {})", (a - b).abs() / denom))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("add commutes", 100, |rng| {
            let a = rng.uniform_f64(-1e6, 1e6);
            let b = rng.uniform_f64(-1e6, 1e6);
            ensure_close(a + b, b + a, 1e-12, "commute")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_name() {
        prop_check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_per_case() {
        let mut seen = Vec::new();
        prop_check("capture", 3, |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        let mut seen2 = Vec::new();
        prop_check("capture", 3, |rng| {
            seen2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen, seen2);
    }

    #[test]
    fn ensure_helpers() {
        assert!(ensure(true, "x").is_ok());
        assert!(ensure(false, "x").is_err());
        assert!(ensure_close(1.0, 1.0 + 1e-13, 1e-9, "c").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "c").is_err());
    }
}
