//! Descriptive statistics over latency samples: mean, std, percentiles,
//! jitter. Used by the phase profiler, the control-loop driver, and the
//! micro-benchmark harness.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute summary stats. Returns a zeroed summary for empty input.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        // total_cmp: a stray NaN sample (e.g. a 0/0 rate from an empty
        // serving window) sorts to one end (sign-dependent: -NaN first,
        // +NaN last) and taints the adjacent order statistics, instead of
        // panicking mid-sort as partial_cmp().unwrap() did
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }

    /// Coefficient of variation (std / mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice. `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice. NaN samples take a total order (sign
/// bit decides the end they sort to) — never a panic.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, p)
}

/// Geometric mean (all samples must be positive).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean requires positive samples");
            x.ln()
        })
        .sum();
    (log_sum / samples.len() as f64).exp()
}

/// Relative error |a - b| / max(|a|, |b|); 0 if both are 0.
pub fn rel_err(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs());
    if denom == 0.0 {
        0.0
    } else {
        (a - b).abs() / denom
    }
}

/// Prediction accuracy in the paper's sense: 1 - |pred - meas| / meas,
/// clamped to [0, 1]. The paper reports "70% to 90%" simulator accuracy.
pub fn accuracy(predicted: f64, measured: f64) -> f64 {
    if measured == 0.0 {
        return if predicted == 0.0 { 1.0 } else { 0.0 };
    }
    (1.0 - (predicted - measured).abs() / measured).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 25.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn percentile_unsorted_input() {
        assert!((percentile(&[5.0, 1.0, 3.0], 50.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // regression: the partial_cmp().unwrap() sort used to panic on NaN.
        // total_cmp gives NaN a defined slot instead — positive NaN sorts
        // last (tainting max), negative NaN first (tainting min)
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.max.is_nan());
        assert!(percentile(&[1.0, f64::NAN, 0.5], 0.0) == 0.5);
        let neg = Summary::of(&[2.0, -f64::NAN, 1.0]);
        assert!(neg.min.is_nan());
        assert_eq!(neg.max, 2.0);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_matches_paper_sense() {
        assert!((accuracy(90.0, 100.0) - 0.9).abs() < 1e-12);
        assert!((accuracy(130.0, 100.0) - 0.7).abs() < 1e-12);
        assert_eq!(accuracy(300.0, 100.0), 0.0); // clamped
        assert_eq!(accuracy(100.0, 100.0), 1.0);
    }

    #[test]
    fn rel_err_symmetric() {
        assert!((rel_err(90.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
        assert!((rel_err(100.0, 90.0) - rel_err(90.0, 100.0)).abs() < 1e-12);
    }

    #[test]
    fn std_sample_variance() {
        let s = Summary::of(&[2.0, 4.0]);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }
}
