//! The fleet simulator: a deterministic discrete-event engine serving
//! Poisson robot streams against a fleet of heterogeneous engine shards.
//!
//! Two execution paths, one public entry point ([`FleetSim::run`]):
//!
//! - The **degenerate single-lane path** (1 shard, 1 lane, no autoscaler,
//!   no failures, drop-on-deadline admission, earliest-free or round-robin
//!   scheduling, one SLO class) mirrors the legacy batcher event loop
//!   arithmetic operation for operation, so a degenerate fleet is bitwise
//!   the pre-fleet serving stack (`engine::batcher::run_batcher`, and
//!   therefore `engine::shard::run_shard_batcher`) — pinned by tests.
//! - The **general event loop** drives a typed [`EventQueue`] over virtual
//!   time: arrivals, service completions, autoscaler checks, and fail-stop
//!   failures, with pluggable [`AdmissionPolicy`] / [`SchedulingPolicy`]
//!   and per-engine energy accounting.
//!
//! Everything is single-threaded, allocation-deterministic, and seeded
//! through [`Prng::for_stream`] sub-streams: identical configs replay bit
//! for bit, which is what lets `sim::sweep` parallelize fleet grids with
//! bitwise-identical results.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::arrivals::{build_poisson_arrivals, Request};
use super::autoscale::{Autoscaler, AutoscalerConfig, ScaleDecision};
use super::event::{EventQueue, FleetEvent};
use super::policy::{AdmissionPolicy, SchedulingPolicy, TokenBucket};
use crate::telemetry::{
    DropReason, Event, EventSink, NullSink, RejectReason, RunMeta, RunMode, RunStartInfo,
    ShardEcho,
};
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Duration;

/// Seed salt for the per-engine failure process (decorrelates failure
/// draws from the arrival sub-streams of the same base seed).
const FAIL_SALT: u64 = 0xFA11_57A7_0BAD_C0DE;

/// One shard spec: a `ShardService`-lowered scenario reduced to the plain
/// serving numbers the fleet needs. `sim::fleet` deliberately consumes
/// these primitives rather than `engine::shard::ShardService` itself — the
/// layer rule keeps `sim` free of `engine`; the engine layer lowers *into*
/// this struct (`ShardService::fleet_spec`).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    pub label: String,
    /// Parallel engines (serving lanes) of this spec in the static fleet.
    pub lanes: usize,
    /// Per-step service time on one lane (s); quantized to the engine
    /// `Duration` grid at simulation start, exactly like the serving
    /// stack's `SimStepServer` round trip.
    pub step_s: f64,
    /// Actions emitted per served step (lockstep streams × action horizon).
    pub actions_per_step: f64,
    /// Energy per emitted action (J) from the scenario lowering.
    pub j_per_action: f64,
}

impl ShardSpec {
    /// A plain fixed-service shard (tests, synthetic fleets).
    pub fn uniform(label: &str, lanes: usize, step_s: f64) -> ShardSpec {
        ShardSpec {
            label: label.to_string(),
            lanes,
            step_s,
            actions_per_step: 1.0,
            j_per_action: 0.0,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.lanes >= 1, "shard `{}` needs at least one lane", self.label);
        anyhow::ensure!(
            self.step_s.is_finite() && self.step_s > 0.0,
            "shard `{}` step time must be finite and positive (got {})",
            self.label,
            self.step_s
        );
        anyhow::ensure!(
            self.actions_per_step.is_finite() && self.actions_per_step > 0.0,
            "shard `{}` actions/step must be finite and positive (got {})",
            self.label,
            self.actions_per_step
        );
        anyhow::ensure!(
            self.j_per_action.is_finite() && self.j_per_action >= 0.0,
            "shard `{}` J/action must be finite and non-negative (got {})",
            self.label,
            self.j_per_action
        );
        Ok(())
    }
}

/// Fleet workload + policy configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Robot control streams generating requests.
    pub streams: usize,
    /// Per-stream Poisson request rate (Hz).
    pub rate_hz: f64,
    /// Arrival-process duration (virtual s); the simulation runs past it
    /// until the queue drains.
    pub duration_s: f64,
    pub seed: u64,
    /// Base queueing-delay SLO deadline (s); per-request deadlines scale
    /// it by the stream's SLO-class multiplier. `None` serves everything.
    pub deadline_s: Option<f64>,
    pub admission: AdmissionPolicy,
    pub scheduling: SchedulingPolicy,
    /// SLO-class deadline multipliers; stream `s` belongs to class
    /// `s % len`. Empty means one class at 1.0. The *last* class is the
    /// best-effort class for `AdmissionPolicy::SloPriority`.
    pub slo_deadline_mults: Vec<f64>,
    pub autoscaler: Option<AutoscalerConfig>,
    /// Per-engine fail-stop rate (Hz of virtual time); 0 disables failure
    /// injection. Failed engines drain their in-flight step, then die.
    pub failure_rate_hz: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            streams: 2,
            rate_hz: 2.0,
            duration_s: 5.0,
            seed: 7,
            deadline_s: None,
            admission: AdmissionPolicy::DropOnDeadline,
            scheduling: SchedulingPolicy::EarliestFree,
            slo_deadline_mults: vec![1.0],
            autoscaler: None,
            failure_rate_hz: 0.0,
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.streams >= 1, "fleet needs at least one stream");
        anyhow::ensure!(
            self.rate_hz.is_finite() && self.rate_hz > 0.0,
            "fleet rate must be finite and positive (got {})",
            self.rate_hz
        );
        anyhow::ensure!(
            self.duration_s.is_finite() && self.duration_s >= 0.0,
            "fleet duration must be finite and non-negative (got {})",
            self.duration_s
        );
        if let Some(d) = self.deadline_s {
            anyhow::ensure!(
                d.is_finite() && d >= 0.0,
                "fleet deadline must be finite and non-negative (got {d})"
            );
        }
        for m in &self.slo_deadline_mults {
            anyhow::ensure!(
                m.is_finite() && *m > 0.0,
                "SLO deadline multiplier must be finite and positive (got {m})"
            );
        }
        self.admission.validate()?;
        if let Some(a) = &self.autoscaler {
            a.validate()?;
        }
        anyhow::ensure!(
            self.failure_rate_hz.is_finite() && self.failure_rate_hz >= 0.0,
            "failure rate must be finite and non-negative (got {})",
            self.failure_rate_hz
        );
        Ok(())
    }

    /// Effective SLO-class multipliers (empty list = one class at 1.0).
    pub fn slo_mults(&self) -> Vec<f64> {
        if self.slo_deadline_mults.is_empty() {
            vec![1.0]
        } else {
            self.slo_deadline_mults.clone()
        }
    }
}

/// Aggregate + per-stream fleet serving report. Conservation holds by
/// construction: `arrived == served + dropped + rejected` (asserted, and
/// re-checked by the `fleet` experiment on every row).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub arrived: usize,
    pub served: usize,
    /// Deadline-stale at dispatch (plus post-collapse flushes when every
    /// engine failed with no autoscaler to replace them).
    pub dropped: usize,
    /// Refused at admission (token bucket dry, SLO best-effort shed).
    pub rejected: usize,
    /// Served steps per virtual second of makespan.
    pub throughput: f64,
    pub queue_delay: Summary,
    pub service: Summary,
    pub per_stream_served: Vec<usize>,
    pub per_stream_arrived: Vec<usize>,
    pub per_stream_dropped: Vec<usize>,
    pub per_stream_rejected: Vec<usize>,
    /// Max consecutive services given to one stream (fairness indicator).
    pub max_burst: usize,
    /// Total actions emitted and aggregate action throughput.
    pub actions: f64,
    pub agg_actions_s: f64,
    /// Per-engine energy rolled up from the shard lowerings (J), and the
    /// fleet-level J per emitted action.
    pub energy_j: f64,
    pub j_per_action: f64,
    pub peak_engines: usize,
    pub failures: usize,
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Virtual time of the last service completion (s).
    pub makespan_s: f64,
}

impl FleetReport {
    /// Fraction of arrivals dropped as deadline-stale.
    pub fn miss_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.dropped as f64 / self.arrived as f64
        }
    }

    /// Fraction of arrivals not served at all (dropped or rejected).
    pub fn loss_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            (self.dropped + self.rejected) as f64 / self.arrived as f64
        }
    }

    /// The conservation invariant every experiment row is checked against.
    pub fn conserves(&self) -> bool {
        self.arrived == self.served + self.dropped + self.rejected
            && self.served == self.per_stream_served.iter().sum::<usize>()
            && self.dropped == self.per_stream_dropped.iter().sum::<usize>()
            && self.rejected == self.per_stream_rejected.iter().sum::<usize>()
    }
}

/// Service times pass through the engine `Duration` grid exactly like
/// `SimStepServer` (`Duration::from_secs_f64(...).as_secs_f64()`), so a
/// fleet lane and a batcher `StepServer` serve bit-identical times.
fn quantize_step(step_s: f64) -> f64 {
    Duration::from_secs_f64(step_s).as_secs_f64()
}

/// One engine lane of the running fleet.
#[derive(Debug, Clone)]
struct EngineState {
    spec_idx: usize,
    step_s: f64,
    /// Next-free virtual time.
    free: f64,
    /// Accumulated busy (dispatched service) time.
    busy: f64,
    alive: bool,
    /// Fail-stop instant (`INFINITY` = never). Drawn once at spawn from
    /// the `FAIL_SALT` sub-stream of the engine uid.
    fail_at: f64,
    /// Scaled up at runtime (retireable) vs static fleet.
    dynamic: bool,
    served: usize,
}

impl EngineState {
    fn spawn(
        spec_idx: usize,
        step_s: f64,
        at: f64,
        seed: u64,
        uid: u64,
        failure_rate_hz: f64,
        dynamic: bool,
    ) -> EngineState {
        let fail_at = if failure_rate_hz > 0.0 {
            at + Prng::for_stream(seed ^ FAIL_SALT, uid).exponential(failure_rate_hz)
        } else {
            f64::INFINITY
        };
        EngineState {
            spec_idx,
            step_s,
            free: at,
            busy: 0.0,
            alive: true,
            fail_at,
            dynamic,
            served: 0,
        }
    }
}

/// A queued (admitted, not yet dispatched) request.
#[derive(Debug, Clone)]
struct Ready {
    stream: usize,
    arrival: f64,
}

/// Heap entry ordered by `(key, push order)` — key is the arrival time
/// (FIFO) or the absolute SLO deadline (EDF), through the non-negative
/// `to_bits` trick.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct ReadyKey {
    key_bits: u64,
    seq: u64,
    stream: usize,
    arrival_bits: u64,
}

/// Admitted-request store: a priority heap for FIFO/EDF orderings, or
/// per-stream queues with a rotating cursor for round-robin fairness.
#[derive(Debug)]
enum ReadyQueue {
    Heap { heap: BinaryHeap<Reverse<ReadyKey>>, seq: u64 },
    Streams { queues: Vec<VecDeque<Ready>>, rr_next: usize },
}

impl ReadyQueue {
    fn new(policy: SchedulingPolicy, streams: usize) -> ReadyQueue {
        match policy {
            SchedulingPolicy::RoundRobin => {
                ReadyQueue::Streams { queues: vec![VecDeque::new(); streams], rr_next: 0 }
            }
            _ => ReadyQueue::Heap { heap: BinaryHeap::new(), seq: 0 },
        }
    }

    fn push(&mut self, r: Ready, key: f64) {
        match self {
            ReadyQueue::Heap { heap, seq } => {
                heap.push(Reverse(ReadyKey {
                    key_bits: key.to_bits(),
                    seq: *seq,
                    stream: r.stream,
                    arrival_bits: r.arrival.to_bits(),
                }));
                *seq += 1;
            }
            ReadyQueue::Streams { queues, .. } => queues[r.stream].push_back(r),
        }
    }

    fn pop(&mut self) -> Option<Ready> {
        match self {
            ReadyQueue::Heap { heap, .. } => heap.pop().map(|Reverse(k)| Ready {
                stream: k.stream,
                arrival: f64::from_bits(k.arrival_bits),
            }),
            ReadyQueue::Streams { queues, rr_next } => {
                let streams = queues.len();
                let s = (0..streams)
                    .map(|off| (*rr_next + off) % streams)
                    .find(|&s| !queues[s].is_empty())?;
                let r = queues[s].pop_front().unwrap();
                *rr_next = (s + 1) % streams;
                Some(r)
            }
        }
    }

    fn drain(&mut self) -> Vec<Ready> {
        let mut out = Vec::new();
        while let Some(r) = self.pop() {
            out.push(r);
        }
        out
    }
}

/// The fleet simulator: a validated config plus the shard specs that make
/// up the static fleet (the first spec is the *elastic tier* the
/// autoscaler clones when scaling up).
#[derive(Debug, Clone)]
pub struct FleetSim {
    cfg: FleetConfig,
    shards: Vec<ShardSpec>,
}

impl FleetSim {
    pub fn new(cfg: FleetConfig, shards: Vec<ShardSpec>) -> anyhow::Result<FleetSim> {
        cfg.validate()?;
        anyhow::ensure!(!shards.is_empty(), "fleet needs at least one shard spec");
        for s in &shards {
            s.validate()?;
        }
        Ok(FleetSim { cfg, shards })
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    pub fn shards(&self) -> &[ShardSpec] {
        &self.shards
    }

    /// Total static lanes across the shard specs.
    pub fn static_engines(&self) -> usize {
        self.shards.iter().map(|s| s.lanes).sum()
    }

    /// Run the simulation to completion (deterministic; pure function of
    /// the config + specs).
    pub fn run(&self) -> FleetReport {
        self.run_traced(&RunMeta::default(), &mut NullSink)
    }

    /// [`FleetSim::run`] narrating the run into an [`EventSink`]. The
    /// arithmetic is the untraced path verbatim — with [`NullSink`] (the
    /// `run()` delegate) every emission is a no-op and all tracing-only
    /// bookkeeping is skipped, so the report stays bitwise-identical.
    pub fn run_traced<S: EventSink + ?Sized>(&self, meta: &RunMeta, sink: &mut S) -> FleetReport {
        if self.is_degenerate_single_lane() {
            self.run_single_lane(meta, sink)
        } else {
            self.run_event_loop_traced(meta, sink)
        }
    }

    /// The `run_start` config echo for this simulation.
    fn run_start_info(&self, meta: &RunMeta, mode: RunMode) -> RunStartInfo {
        let cfg = &self.cfg;
        let mut info = RunStartInfo {
            platform: meta.platform.clone(),
            scenario: meta.scenario.clone(),
            mode,
            config_fp: 0,
            streams: cfg.streams,
            rate_hz: cfg.rate_hz,
            duration_s: cfg.duration_s,
            seed: cfg.seed,
            deadline_s: cfg.deadline_s,
            admission: cfg.admission.label(),
            scheduling: cfg.scheduling.label().to_string(),
            slo_mults: cfg.slo_mults(),
            autoscaler: cfg.autoscaler.is_some(),
            failure_rate_hz: cfg.failure_rate_hz,
            engines: self.static_engines(),
            shards: self
                .shards
                .iter()
                .map(|s| ShardEcho {
                    label: s.label.clone(),
                    lanes: s.lanes,
                    step_s: s.step_s,
                    actions_per_step: s.actions_per_step,
                    j_per_action: s.j_per_action,
                })
                .collect(),
        };
        info.config_fp = info.fingerprint();
        info
    }

    /// The degenerate configuration whose semantics are exactly the legacy
    /// single-server batcher: one shard, one lane, no autoscaler, no
    /// failures, drop-on-deadline admission, a legacy scheduling order,
    /// and a single unit SLO class.
    fn is_degenerate_single_lane(&self) -> bool {
        self.shards.len() == 1
            && self.shards[0].lanes == 1
            && self.cfg.autoscaler.is_none()
            && self.cfg.failure_rate_hz == 0.0
            && self.cfg.admission == AdmissionPolicy::DropOnDeadline
            && matches!(
                self.cfg.scheduling,
                SchedulingPolicy::EarliestFree | SchedulingPolicy::RoundRobin
            )
            && self.cfg.slo_mults().iter().all(|m| *m == 1.0)
    }

    /// Mirror of `engine::batcher::run_batcher` over a fixed service time:
    /// the same admission loop, the same stream pick, the same
    /// `start = clock.max(arrival)` / `clock = start + service` float
    /// chain, the same `clock.max(1e-12)` makespan floor — operation for
    /// operation, so the report is bitwise the legacy batcher's.
    ///
    /// Event-stream notes (mode `single-lane`): the mirror emits `arrival`
    /// (as requests are pulled into the queues — sorted order), `dispatch`,
    /// `drop` and the run frame. No `admit` events (admission is vacuously
    /// drop-on-deadline here) and no `completion` events — a completion at
    /// `start + service` could precede a later-pulled arrival's smaller
    /// timestamp, and the stream stays monotone without them.
    fn run_single_lane<S: EventSink + ?Sized>(&self, meta: &RunMeta, sink: &mut S) -> FleetReport {
        let on = sink.enabled();
        if on {
            sink.emit(&Event::RunStart {
                t: 0.0,
                info: Box::new(self.run_start_info(meta, RunMode::SingleLane)),
            });
        }
        let cfg = &self.cfg;
        let shard = &self.shards[0];
        let (arrivals, per_stream_arrived) =
            build_poisson_arrivals(cfg.streams, cfg.rate_hz, cfg.duration_s, cfg.seed);
        let arrived = arrivals.len();
        let service_s = quantize_step(shard.step_s);

        let mut queues: Vec<VecDeque<Request>> = vec![VecDeque::new(); cfg.streams];
        let mut pending = arrivals.into_iter().peekable();
        let mut clock = 0.0f64;
        let mut delays = Vec::new();
        let mut services = Vec::new();
        let mut per_stream = vec![0usize; cfg.streams];
        let mut per_stream_dropped = vec![0usize; cfg.streams];
        let mut rr_next = 0usize;
        let mut last_stream = usize::MAX;
        let mut burst = 0usize;
        let mut max_burst = 0usize;

        loop {
            while let Some(r) = pending.peek() {
                if r.arrival <= clock {
                    let r = pending.next().unwrap();
                    if on {
                        sink.emit(&Event::Arrival {
                            t: r.arrival,
                            stream: r.stream as u32,
                            step: r.step,
                        });
                    }
                    queues[r.stream].push_back(r);
                } else {
                    break;
                }
            }
            let Some(s) = pick_stream_single(&queues, self.cfg.scheduling, rr_next) else {
                match pending.next() {
                    Some(r) => {
                        clock = r.arrival;
                        if on {
                            sink.emit(&Event::Arrival {
                                t: r.arrival,
                                stream: r.stream as u32,
                                step: r.step,
                            });
                        }
                        queues[r.stream].push_back(r);
                        continue;
                    }
                    None => break,
                }
            };
            let req = queues[s].pop_front().unwrap();
            rr_next = (s + 1) % cfg.streams;

            let start = clock.max(req.arrival);
            let delay = start - req.arrival;
            if let Some(deadline) = cfg.deadline_s {
                if delay > deadline {
                    per_stream_dropped[s] += 1;
                    if on {
                        sink.emit(&Event::Drop {
                            t: start,
                            stream: s as u32,
                            reason: DropReason::Stale,
                        });
                    }
                    continue;
                }
            }
            if s == last_stream {
                burst += 1;
            } else {
                burst = 1;
                last_stream = s;
            }
            max_burst = max_burst.max(burst);

            if on {
                sink.emit(&Event::Dispatch {
                    t: start,
                    engine: 0,
                    stream: s as u32,
                    delay_s: delay,
                    service_s,
                    actions_per_step: shard.actions_per_step,
                    j_per_action: shard.j_per_action,
                });
            }
            delays.push(delay);
            services.push(service_s);
            per_stream[s] += 1;
            clock = start + service_s;
        }

        let served = services.len();
        let dropped: usize = per_stream_dropped.iter().sum();
        debug_assert_eq!(served + dropped, arrived, "every arrival is served or dropped");
        let total_time = clock.max(1e-12);
        let actions = served as f64 * shard.actions_per_step;
        let energy_j = actions * shard.j_per_action;
        let report = FleetReport {
            arrived,
            served,
            dropped,
            rejected: 0,
            throughput: served as f64 / total_time,
            queue_delay: Summary::of(&delays),
            service: Summary::of(&services),
            per_stream_served: per_stream,
            per_stream_arrived,
            per_stream_dropped,
            per_stream_rejected: vec![0; cfg.streams],
            max_burst,
            actions,
            agg_actions_s: actions / total_time,
            energy_j,
            j_per_action: shard.j_per_action,
            peak_engines: 1,
            failures: 0,
            scale_ups: 0,
            scale_downs: 0,
            makespan_s: total_time,
        };
        if on {
            sink.emit(&Event::run_end(&report, 0.0));
        }
        report
    }

    /// The general typed-event-queue engine (public for cross-validation:
    /// tests pin its degenerate-config output against the single-lane
    /// mirror).
    pub fn run_event_loop(&self) -> FleetReport {
        self.run_event_loop_traced(&RunMeta::default(), &mut NullSink)
    }

    /// [`FleetSim::run_event_loop`] with telemetry.
    pub fn run_event_loop_traced<S: EventSink + ?Sized>(
        &self,
        meta: &RunMeta,
        sink: &mut S,
    ) -> FleetReport {
        EventLoop::new(self, sink).run(meta)
    }
}

/// Single-lane stream pick, mirroring `engine::batcher::pick_stream` for
/// the two legacy orders (FIFO takes the earliest queued arrival,
/// round-robin scans from the cursor).
fn pick_stream_single(
    queues: &[VecDeque<Request>],
    policy: SchedulingPolicy,
    rr_next: usize,
) -> Option<usize> {
    match policy {
        SchedulingPolicy::RoundRobin => {
            let streams = queues.len();
            (0..streams).map(|off| (rr_next + off) % streams).find(|&s| !queues[s].is_empty())
        }
        _ => queues
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .min_by(|a, b| a.1.front().unwrap().arrival.total_cmp(&b.1.front().unwrap().arrival))
            .map(|(i, _)| i),
    }
}

/// One in-flight step on an engine, remembered only when tracing so the
/// telemetry `completion` event can name the stream. `completes_bits` is
/// the engine-free time of the dispatch: the dynamic-engine warm-up wake is
/// also a `Completion` event with no work behind it, and matching the
/// popped event time bitwise against the deque front filters those out.
#[derive(Debug, Clone, Copy)]
struct Inflight {
    stream: u32,
    service_s: f64,
    completes_bits: u64,
}

/// All mutable state of one general-engine run.
struct EventLoop<'a, S: EventSink + ?Sized> {
    sim: &'a FleetSim,
    sink: &'a mut S,
    /// `sink.enabled()` memoized: gates event construction and all
    /// tracing-only bookkeeping (`inflight`, the alive_after scan).
    on: bool,
    /// Per-engine FIFO of in-flight steps; empty when `on` is false.
    inflight: Vec<VecDeque<Inflight>>,
    /// Timestamp of the last popped event (the `run_end` floor — a trailing
    /// admission reject can land after the last service completes).
    last_now: f64,
    mults: Vec<f64>,
    engines: Vec<EngineState>,
    ready: ReadyQueue,
    evq: EventQueue,
    bucket: Option<TokenBucket>,
    scaler: Option<Autoscaler>,
    arrivals: Vec<Request>,
    cursor: usize,
    queued: usize,
    completed: usize,
    delays: Vec<f64>,
    services: Vec<f64>,
    per_stream_served: Vec<usize>,
    per_stream_arrived: Vec<usize>,
    per_stream_dropped: Vec<usize>,
    per_stream_rejected: Vec<usize>,
    last_stream: usize,
    burst: usize,
    max_burst: usize,
    actions: f64,
    energy_j: f64,
    makespan: f64,
    peak_engines: usize,
    failures: usize,
    scale_ups: usize,
    scale_downs: usize,
    next_uid: u64,
}

impl<'a, S: EventSink + ?Sized> EventLoop<'a, S> {
    fn new(sim: &'a FleetSim, sink: &'a mut S) -> EventLoop<'a, S> {
        let cfg = &sim.cfg;
        let (arrivals, per_stream_arrived) =
            build_poisson_arrivals(cfg.streams, cfg.rate_hz, cfg.duration_s, cfg.seed);
        let on = sink.enabled();
        let mut el = EventLoop {
            sim,
            sink,
            on,
            inflight: Vec::new(),
            last_now: 0.0,
            mults: cfg.slo_mults(),
            engines: Vec::new(),
            ready: ReadyQueue::new(cfg.scheduling, cfg.streams),
            evq: EventQueue::new(),
            bucket: match cfg.admission {
                AdmissionPolicy::TokenBucket { rate_hz, burst } => {
                    Some(TokenBucket::new(rate_hz, burst))
                }
                _ => None,
            },
            scaler: cfg.autoscaler.clone().map(Autoscaler::new),
            arrivals,
            cursor: 0,
            queued: 0,
            completed: 0,
            delays: Vec::new(),
            services: Vec::new(),
            per_stream_served: vec![0; cfg.streams],
            per_stream_arrived,
            per_stream_dropped: vec![0; cfg.streams],
            per_stream_rejected: vec![0; cfg.streams],
            last_stream: usize::MAX,
            burst: 0,
            max_burst: 0,
            actions: 0.0,
            energy_j: 0.0,
            makespan: 0.0,
            peak_engines: 0,
            failures: 0,
            scale_ups: 0,
            scale_downs: 0,
            next_uid: 0,
        };
        // static fleet
        for (i, spec) in sim.shards.iter().enumerate() {
            for _ in 0..spec.lanes {
                el.spawn_engine(i, 0.0, false);
            }
        }
        el.peak_engines = el.alive_engines();
        if let Some(sc) = &el.scaler {
            el.evq.push(sc.cfg.check_interval_s, FleetEvent::ScaleCheck);
        }
        el.push_next_arrival();
        el
    }

    fn spawn_engine(&mut self, spec_idx: usize, at: f64, dynamic: bool) {
        let cfg = &self.sim.cfg;
        let spec = &self.sim.shards[spec_idx];
        let eng = EngineState::spawn(
            spec_idx,
            quantize_step(spec.step_s),
            at,
            cfg.seed,
            self.next_uid,
            cfg.failure_rate_hz,
            dynamic,
        );
        self.next_uid += 1;
        let id = self.engines.len() as u32;
        if eng.fail_at.is_finite() {
            self.evq.push(eng.fail_at, FleetEvent::Failure { engine: id });
        }
        if dynamic {
            // wake the dispatcher exactly when the warm-up ends
            self.evq.push(eng.free, FleetEvent::Completion { engine: id });
        }
        self.engines.push(eng);
        self.inflight.push(VecDeque::new());
    }

    fn alive_engines(&self) -> usize {
        self.engines.iter().filter(|e| e.alive).count()
    }

    fn push_next_arrival(&mut self) {
        if let Some(r) = self.arrivals.get(self.cursor) {
            self.evq
                .push(r.arrival, FleetEvent::Arrival { stream: r.stream as u32, step: r.step });
        }
    }

    fn class_of(&self, stream: usize) -> usize {
        stream % self.mults.len()
    }

    /// Effective queueing deadline of a stream's requests (base deadline
    /// scaled by the stream's SLO class).
    fn deadline_of(&self, stream: usize) -> Option<f64> {
        self.sim.cfg.deadline_s.map(|d| d * self.mults[self.class_of(stream)])
    }

    /// Request-ordering key: arrival (FIFO orders) or the absolute SLO
    /// deadline (EDF).
    fn ready_key(&self, stream: usize, arrival: f64) -> f64 {
        match self.sim.cfg.scheduling {
            SchedulingPolicy::Edf => arrival + self.deadline_of(stream).unwrap_or(0.0),
            _ => arrival,
        }
    }

    fn run(mut self, meta: &RunMeta) -> FleetReport {
        if self.on {
            let info = self.sim.run_start_info(meta, RunMode::EventLoop);
            self.sink.emit(&Event::RunStart { t: 0.0, info: Box::new(info) });
        }
        let arrived = self.arrivals.len();
        while self.completed < arrived {
            let Some((now, ev)) = self.evq.pop() else {
                // no events left but work remains: every serving path is
                // gone (all engines failed, no autoscaler) — flush
                let t = self.last_now;
                self.flush_unservable(t);
                break;
            };
            self.last_now = now;
            match ev {
                FleetEvent::Arrival { stream, step } => {
                    if self.on {
                        self.sink.emit(&Event::Arrival { t: now, stream, step });
                    }
                    self.cursor += 1;
                    self.push_next_arrival();
                    self.handle_arrival(stream as usize, now);
                }
                FleetEvent::Completion { engine } => {
                    self.note_completion(engine as usize, now);
                    self.dispatch_all(now);
                }
                FleetEvent::ScaleCheck => self.handle_scale_check(now),
                FleetEvent::Failure { engine } => self.handle_failure(engine as usize, now),
            }
        }
        self.into_report(arrived)
    }

    /// Emit the telemetry `completion` for a popped `Completion` event iff
    /// it corresponds to a real dispatched step (warm-up wakes don't).
    /// Per-engine free times strictly increase through dispatches, so only
    /// the deque front can match the popped event time.
    fn note_completion(&mut self, engine: usize, now: f64) {
        if !self.on {
            return;
        }
        if let Some(front) = self.inflight[engine].front() {
            if front.completes_bits == now.to_bits() {
                let f = self.inflight[engine].pop_front().unwrap();
                self.sink.emit(&Event::Completion {
                    t: now,
                    engine: engine as u32,
                    stream: f.stream,
                    service_s: f.service_s,
                });
            }
        }
    }

    fn handle_arrival(&mut self, stream: usize, now: f64) {
        let admit = match &self.sim.cfg.admission {
            AdmissionPolicy::DropOnDeadline => true,
            AdmissionPolicy::TokenBucket { .. } => self.bucket.as_mut().unwrap().admit(now),
            AdmissionPolicy::SloPriority { depth_limit } => {
                let n = self.mults.len();
                !(n > 1 && self.class_of(stream) == n - 1 && self.queued >= *depth_limit)
            }
        };
        if !admit {
            self.per_stream_rejected[stream] += 1;
            self.completed += 1;
            if self.on {
                let reason = match self.sim.cfg.admission {
                    AdmissionPolicy::TokenBucket { .. } => RejectReason::TokenBucket,
                    _ => RejectReason::SloShed,
                };
                self.sink.emit(&Event::Reject { t: now, stream: stream as u32, reason });
            }
            return;
        }
        if self.on {
            self.sink.emit(&Event::Admit { t: now, stream: stream as u32 });
        }
        let key = self.ready_key(stream, now);
        self.ready.push(Ready { stream, arrival: now }, key);
        self.queued += 1;
        self.dispatch_all(now);
    }

    /// Earliest-free (ties to the lowest engine id) or least-loaded idle
    /// alive engine.
    fn pick_engine(&self, now: f64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.engines.iter().enumerate() {
            if !e.alive || e.free > now {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let eb = &self.engines[b];
                    match self.sim.cfg.scheduling {
                        SchedulingPolicy::LeastLoaded => e.busy < eb.busy,
                        _ => e.free < eb.free,
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    /// Pair idle engines with queued requests until one side runs out.
    /// Deadline-stale requests drop without consuming service.
    fn dispatch_all(&mut self, now: f64) {
        loop {
            let Some(e) = self.pick_engine(now) else { break };
            let Some(r) = self.ready.pop() else { break };
            self.queued -= 1;
            let delay = now - r.arrival;
            if let Some(sc) = self.scaler.as_mut() {
                sc.observe(delay);
            }
            if let Some(d) = self.deadline_of(r.stream) {
                if delay > d {
                    self.per_stream_dropped[r.stream] += 1;
                    self.completed += 1;
                    if self.on {
                        self.sink.emit(&Event::Drop {
                            t: now,
                            stream: r.stream as u32,
                            reason: DropReason::Stale,
                        });
                    }
                    continue; // the engine stays idle; try the next request
                }
            }
            if r.stream == self.last_stream {
                self.burst += 1;
            } else {
                self.burst = 1;
                self.last_stream = r.stream;
            }
            self.max_burst = self.max_burst.max(self.burst);

            let (service, free_at, spec_idx) = {
                let eng = &mut self.engines[e];
                let service = eng.step_s;
                eng.free = now + service;
                eng.busy += service;
                eng.served += 1;
                (service, eng.free, eng.spec_idx)
            };
            let spec = &self.sim.shards[spec_idx];
            self.actions += spec.actions_per_step;
            self.energy_j += spec.j_per_action * spec.actions_per_step;
            self.makespan = self.makespan.max(free_at);
            if self.on {
                self.sink.emit(&Event::Dispatch {
                    t: now,
                    engine: e as u32,
                    stream: r.stream as u32,
                    delay_s: delay,
                    service_s: service,
                    actions_per_step: spec.actions_per_step,
                    j_per_action: spec.j_per_action,
                });
                self.inflight[e].push_back(Inflight {
                    stream: r.stream as u32,
                    service_s: service,
                    completes_bits: free_at.to_bits(),
                });
            }
            self.delays.push(delay);
            self.services.push(service);
            self.per_stream_served[r.stream] += 1;
            self.completed += 1;
            self.evq.push(free_at, FleetEvent::Completion { engine: e as u32 });
        }
    }

    fn handle_scale_check(&mut self, now: f64) {
        let alive = self.alive_engines();
        let queued = self.queued;
        let (decision, trigger, warmup, interval) = match self.scaler.as_mut() {
            Some(sc) => {
                let (decision, trigger) = sc.decide_traced(queued, alive);
                (decision, trigger, sc.cfg.warmup_s, sc.cfg.check_interval_s)
            }
            None => return,
        };
        let mut applied = false;
        match decision {
            ScaleDecision::Up => {
                self.spawn_engine(0, now + warmup, true);
                self.scale_ups += 1;
                self.peak_engines = self.peak_engines.max(self.alive_engines());
                applied = true;
            }
            ScaleDecision::Down => {
                // retire the newest idle dynamic engine; never kill
                // in-flight work
                if let Some(i) = self
                    .engines
                    .iter()
                    .enumerate()
                    .rev()
                    .find(|(_, e)| e.alive && e.dynamic && e.free <= now)
                    .map(|(i, _)| i)
                {
                    self.engines[i].alive = false;
                    self.scale_downs += 1;
                    applied = true;
                }
            }
            ScaleDecision::Hold => {}
        }
        if self.on {
            self.sink.emit(&Event::Scale {
                t: now,
                decision,
                trigger,
                queued,
                alive_before: alive,
                alive_after: self.alive_engines(),
                applied,
            });
        }
        if self.completed < self.arrivals.len() {
            self.evq.push(now + interval, FleetEvent::ScaleCheck);
        }
    }

    fn handle_failure(&mut self, engine: usize, now: f64) {
        if self.engines[engine].alive {
            self.engines[engine].alive = false;
            self.failures += 1;
            if self.on {
                self.sink.emit(&Event::Failure { t: now, engine: engine as u32 });
            }
        }
        if self.scaler.is_none() && self.engines.iter().all(|e| !e.alive) {
            self.flush_unservable(now);
        }
    }

    /// Every serving path is gone: the queue and the untraced remainder of
    /// the arrival process count as dropped (conservation holds).
    ///
    /// Telemetry: drained-queue drops stamp `now`; the never-pulled
    /// remainder emits a synthetic `arrival` + `drop(flush)` pair at each
    /// request's arrival time so the stream conserves on its own. Those
    /// arrival times are `>= now` — the cursor's arrival event is still in
    /// the queue (unpopped) whenever this runs, so the stream stays
    /// monotone.
    fn flush_unservable(&mut self, now: f64) {
        for r in self.ready.drain() {
            self.per_stream_dropped[r.stream] += 1;
            self.completed += 1;
            if self.on {
                self.sink.emit(&Event::Drop {
                    t: now,
                    stream: r.stream as u32,
                    reason: DropReason::Flush,
                });
            }
        }
        self.queued = 0;
        while self.cursor < self.arrivals.len() {
            let r = &self.arrivals[self.cursor];
            if self.on {
                let stream = r.stream as u32;
                let (t, step) = (r.arrival, r.step);
                self.sink.emit(&Event::Arrival { t, stream, step });
                self.sink.emit(&Event::Drop { t, stream, reason: DropReason::Flush });
            }
            let stream = r.stream;
            self.per_stream_dropped[stream] += 1;
            self.completed += 1;
            self.cursor += 1;
        }
    }

    fn into_report(mut self, arrived: usize) -> FleetReport {
        let served = self.services.len();
        let dropped: usize = self.per_stream_dropped.iter().sum();
        let rejected: usize = self.per_stream_rejected.iter().sum();
        debug_assert_eq!(
            served + dropped + rejected,
            arrived,
            "every arrival is served, dropped, or rejected"
        );
        let total_time = self.makespan.max(1e-12);
        let actions = self.actions;
        let report = FleetReport {
            arrived,
            served,
            dropped,
            rejected,
            throughput: served as f64 / total_time,
            queue_delay: Summary::of(&self.delays),
            service: Summary::of(&self.services),
            per_stream_served: self.per_stream_served,
            per_stream_arrived: self.per_stream_arrived,
            per_stream_dropped: self.per_stream_dropped,
            per_stream_rejected: self.per_stream_rejected,
            max_burst: self.max_burst,
            actions,
            agg_actions_s: actions / total_time,
            energy_j: self.energy_j,
            j_per_action: if actions > 0.0 { self.energy_j / actions } else { 0.0 },
            peak_engines: self.peak_engines,
            failures: self.failures,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            makespan_s: total_time,
        };
        if self.on {
            self.sink.emit(&Event::run_end(&report, self.last_now));
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(label: &str, step_ms: f64, lanes: usize) -> ShardSpec {
        ShardSpec::uniform(label, lanes, step_ms / 1000.0)
    }

    fn base_cfg() -> FleetConfig {
        FleetConfig { streams: 3, rate_hz: 2.0, duration_s: 10.0, seed: 11, ..Default::default() }
    }

    #[test]
    fn config_validation_rejects_bad_inputs() {
        let ok = FleetSim::new(base_cfg(), vec![shard("a", 100.0, 1)]);
        assert!(ok.is_ok());
        for bad in [
            FleetConfig { streams: 0, ..base_cfg() },
            FleetConfig { rate_hz: f64::NAN, ..base_cfg() },
            FleetConfig { rate_hz: -2.0, ..base_cfg() },
            FleetConfig { rate_hz: 0.0, ..base_cfg() },
            FleetConfig { duration_s: f64::INFINITY, ..base_cfg() },
            FleetConfig { duration_s: -1.0, ..base_cfg() },
            FleetConfig { deadline_s: Some(f64::NAN), ..base_cfg() },
            FleetConfig { deadline_s: Some(-0.1), ..base_cfg() },
            FleetConfig { slo_deadline_mults: vec![1.0, 0.0], ..base_cfg() },
            FleetConfig { slo_deadline_mults: vec![f64::INFINITY], ..base_cfg() },
            FleetConfig { failure_rate_hz: -1.0, ..base_cfg() },
            FleetConfig { failure_rate_hz: f64::NAN, ..base_cfg() },
        ] {
            assert!(FleetSim::new(bad.clone(), vec![shard("a", 100.0, 1)]).is_err(), "{bad:?}");
        }
        assert!(FleetSim::new(base_cfg(), vec![]).is_err(), "empty fleet");
        assert!(FleetSim::new(base_cfg(), vec![shard("z", 0.0, 1)]).is_err(), "zero step");
        assert!(FleetSim::new(base_cfg(), vec![shard("z", 100.0, 0)]).is_err(), "zero lanes");
        let neg_j = ShardSpec { j_per_action: -1.0, ..shard("j", 100.0, 1) };
        assert!(FleetSim::new(base_cfg(), vec![neg_j]).is_err(), "negative J/action");
    }

    #[test]
    fn fleet_runs_are_deterministic() {
        let cfg = FleetConfig { deadline_s: Some(0.25), ..base_cfg() };
        let sim = FleetSim::new(cfg, vec![shard("a", 150.0, 2), shard("b", 300.0, 1)]).unwrap();
        let a = sim.run();
        let b = sim.run();
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.served, b.served);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(a.queue_delay.p99.to_bits(), b.queue_delay.p99.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.per_stream_served, b.per_stream_served);
    }

    #[test]
    fn degenerate_event_loop_matches_the_single_lane_mirror() {
        for sched in [SchedulingPolicy::EarliestFree, SchedulingPolicy::RoundRobin] {
            let cfg = FleetConfig { deadline_s: Some(0.3), scheduling: sched, ..base_cfg() };
            let sim = FleetSim::new(cfg, vec![shard("one", 400.0, 1)]).unwrap();
            let a = sim.run(); // degenerate -> single-lane mirror
            let b = sim.run_event_loop(); // the general typed-event engine
            assert_eq!(a.arrived, b.arrived, "{sched:?}");
            assert_eq!(a.served, b.served, "{sched:?}");
            assert_eq!(a.dropped, b.dropped, "{sched:?}");
            assert_eq!(a.rejected, b.rejected, "{sched:?}");
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{sched:?}");
            assert_eq!(a.queue_delay.p50.to_bits(), b.queue_delay.p50.to_bits(), "{sched:?}");
            assert_eq!(a.queue_delay.p99.to_bits(), b.queue_delay.p99.to_bits(), "{sched:?}");
            assert_eq!(a.per_stream_served, b.per_stream_served, "{sched:?}");
            assert_eq!(a.per_stream_dropped, b.per_stream_dropped, "{sched:?}");
            assert_eq!(a.max_burst, b.max_burst, "{sched:?}");
        }
    }

    #[test]
    fn conservation_holds_under_every_admission_policy() {
        for admission in [
            AdmissionPolicy::DropOnDeadline,
            AdmissionPolicy::TokenBucket { rate_hz: 2.0, burst: 2 },
            AdmissionPolicy::SloPriority { depth_limit: 2 },
        ] {
            let cfg = FleetConfig {
                streams: 4,
                deadline_s: Some(0.2),
                admission,
                slo_deadline_mults: vec![1.0, 2.0],
                ..base_cfg()
            };
            let sim = FleetSim::new(cfg, vec![shard("a", 250.0, 2)]).unwrap();
            let r = sim.run();
            assert!(r.conserves(), "{admission:?}: {r:?}");
            assert!(r.arrived > 0 && r.served > 0, "{admission:?}");
        }
    }

    #[test]
    fn token_bucket_rejects_beyond_its_rate() {
        let cfg = FleetConfig {
            streams: 4,
            admission: AdmissionPolicy::TokenBucket { rate_hz: 1.0, burst: 2 },
            ..base_cfg()
        };
        let sim = FleetSim::new(cfg, vec![shard("a", 50.0, 1)]).unwrap();
        let r = sim.run();
        // ~80 arrivals metered at ~1/s for 10 s + burst 2
        assert!(r.rejected > 0, "bucket must shed load: {r:?}");
        assert!(r.served <= 2 + 11, "served {} must respect the meter", r.served);
        assert!(r.conserves());
        assert!(r.loss_rate() > r.miss_rate(), "rejections count in loss, not miss");
    }

    #[test]
    fn slo_priority_sheds_only_the_best_effort_class() {
        // depth_limit 0: every best-effort (last class, odd streams) arrival
        // is rejected at the door; guaranteed streams are untouched
        let cfg = FleetConfig {
            streams: 4,
            admission: AdmissionPolicy::SloPriority { depth_limit: 0 },
            slo_deadline_mults: vec![1.0, 1.0],
            ..base_cfg()
        };
        let sim = FleetSim::new(cfg, vec![shard("a", 50.0, 1)]).unwrap();
        let r = sim.run();
        for s in 0..4 {
            if s % 2 == 1 {
                assert_eq!(r.per_stream_rejected[s], r.per_stream_arrived[s], "stream {s}");
                assert_eq!(r.per_stream_served[s], 0, "stream {s}");
            } else {
                assert_eq!(r.per_stream_rejected[s], 0, "stream {s}");
            }
        }
        assert!(r.conserves());
    }

    #[test]
    fn more_lanes_drain_the_queue() {
        let cfg = FleetConfig { streams: 4, ..base_cfg() };
        let one = FleetSim::new(cfg.clone(), vec![shard("a", 500.0, 1)]).unwrap().run();
        let four = FleetSim::new(cfg, vec![shard("a", 500.0, 4)]).unwrap().run();
        assert_eq!(one.arrived, four.arrived, "same trace");
        assert!(four.queue_delay.p99 < one.queue_delay.p99, "lanes must drain the queue");
        assert!(four.throughput > one.throughput);
        assert!(one.conserves() && four.conserves());
    }

    #[test]
    fn heterogeneous_fleet_balances_with_least_loaded() {
        let cfg =
            FleetConfig { streams: 6, scheduling: SchedulingPolicy::LeastLoaded, ..base_cfg() };
        let sim =
            FleetSim::new(cfg, vec![shard("fast", 100.0, 1), shard("slow", 400.0, 1)]).unwrap();
        let r = sim.run();
        assert!(r.conserves());
        assert_eq!(r.served, r.arrived, "no deadline: everything serves");
        assert!(r.peak_engines == 2 && r.failures == 0);
    }

    #[test]
    fn autoscaler_scales_up_under_overload_and_cuts_the_tail() {
        let auto = AutoscalerConfig {
            check_interval_s: 0.25,
            queue_up: 4,
            queue_down: 1,
            p99_up_s: None,
            warmup_s: 0.25,
            min_engines: 1,
            max_engines: 6,
        };
        let cfg = FleetConfig { streams: 6, seed: 17, ..base_cfg() };
        let fixed = FleetSim::new(cfg.clone(), vec![shard("a", 500.0, 1)]).unwrap().run();
        let scaled_cfg = FleetConfig { autoscaler: Some(auto), ..cfg };
        let scaled = FleetSim::new(scaled_cfg, vec![shard("a", 500.0, 1)]).unwrap().run();
        // 12 req/s x 0.5 s = 6 erlangs on one engine: hopeless fixed, the
        // autoscaler must react
        assert!(scaled.scale_ups > 0, "{scaled:?}");
        assert!(scaled.peak_engines > 1);
        assert!(scaled.peak_engines <= 6);
        assert!(scaled.queue_delay.p99 < fixed.queue_delay.p99, "scaling must cut the tail");
        assert!(scaled.conserves() && fixed.conserves());
        assert_eq!(scaled.arrived, fixed.arrived, "same arrival trace");
    }

    #[test]
    fn failure_injection_conserves_and_flushes_dead_fleets() {
        // 3 engines, mean fail time 5 s over a 10 s trace: failures happen,
        // survivors (or the flush) account for every arrival
        let cfg = FleetConfig { streams: 2, failure_rate_hz: 0.2, seed: 23, ..base_cfg() };
        let r = FleetSim::new(cfg, vec![shard("a", 100.0, 3)]).unwrap().run();
        assert!(r.conserves(), "{r:?}");
        assert!(r.served > 0);

        // mean fail time 20 ms on the only engine: the fleet collapses and
        // the flush must still conserve every arrival
        let dead_cfg = FleetConfig { streams: 2, failure_rate_hz: 50.0, seed: 29, ..base_cfg() };
        let dead = FleetSim::new(dead_cfg, vec![shard("a", 100.0, 1)]).unwrap().run();
        assert!(dead.conserves(), "{dead:?}");
        assert!(dead.failures >= 1);
        assert!(dead.dropped > 0, "a collapsed fleet drops its queue: {dead:?}");
    }

    #[test]
    fn edf_is_never_worse_than_fifo_on_misses_at_saturation() {
        // 3 SLO classes with 4:1:(1/4) deadline spread under moderate
        // overload: EDF serves the most-urgent queued request first, FIFO
        // lets tight-deadline requests go stale behind slack ones (this
        // seed gives EDF an 8-drop margin, so the inequality is robust)
        let mk = |sched| {
            let cfg = FleetConfig {
                streams: 8,
                rate_hz: 1.5,
                duration_s: 10.0,
                seed: 71,
                deadline_s: Some(0.12),
                scheduling: sched,
                slo_deadline_mults: vec![0.25, 1.0, 4.0],
                ..Default::default()
            };
            FleetSim::new(cfg, vec![shard("a", 100.0, 1)]).unwrap().run()
        };
        let fifo = mk(SchedulingPolicy::EarliestFree);
        let edf = mk(SchedulingPolicy::Edf);
        assert_eq!(fifo.arrived, edf.arrived);
        assert!(fifo.dropped > 0, "the fleet must actually be saturated: {fifo:?}");
        assert!(
            edf.miss_rate() <= fifo.miss_rate() + 1e-12,
            "EDF miss {} must not exceed FIFO miss {}",
            edf.miss_rate(),
            fifo.miss_rate()
        );
        assert!(fifo.conserves() && edf.conserves());
    }

    #[test]
    fn energy_rolls_up_from_the_shard_lowerings() {
        let spec = ShardSpec {
            label: "e".into(),
            lanes: 1,
            step_s: 0.1,
            actions_per_step: 8.0,
            j_per_action: 0.5,
        };
        let cfg = FleetConfig { streams: 2, rate_hz: 1.0, ..base_cfg() };
        let r = FleetSim::new(cfg, vec![spec]).unwrap().run();
        assert!(r.served > 0);
        assert_eq!(r.actions, r.served as f64 * 8.0);
        assert!((r.energy_j - r.actions * 0.5).abs() < 1e-9);
        assert!((r.j_per_action - 0.5).abs() < 1e-12);
        assert!(r.agg_actions_s > 0.0);
    }
}
