//! Fleet-scale serving simulator: a deterministic discrete-event engine
//! over virtual time (paper §5 scaled out — the direct path to "millions
//! of users" in the ROADMAP).
//!
//! The paper's bottleneck analysis says the memory-bound action-generation
//! phase dominates end-to-end VLA latency; at fleet scale that means edge
//! serving economics are *queueing* economics. This subsystem simulates
//! thousands-to-millions of Poisson robot streams against a fleet of
//! engine shards — each shard a `ShardService`-lowered scenario, so
//! heterogeneous fleets (replicated SoC engines next to pipelined decoders
//! next to PIM-resident shards) cost one shared baseline roofline
//! simulation — under pluggable admission and scheduling policies, an
//! autoscaler, and fail-stop failure injection.
//!
//! Module map:
//!
//! - [`arrivals`]: the Poisson arrival-trace builder every serving layer
//!   shares (the batcher re-uses it, which is what makes the degenerate
//!   bitwise pins meaningful).
//! - [`event`]: the typed event queue over virtual time (arrivals, service
//!   completions, scale checks, failures).
//! - [`policy`]: [`AdmissionPolicy`] (drop-on-deadline, token bucket,
//!   SLO-class priority) and [`SchedulingPolicy`] (earliest-free,
//!   round-robin, least-loaded, SLO-aware EDF).
//! - [`autoscale`]: the queue-depth / p99 autoscaler state machine with
//!   warm-up latency.
//! - [`sim`]: [`FleetSim`] itself — the degenerate single-lane mirror of
//!   the legacy batcher plus the general event loop, and the
//!   conservation-checked [`FleetReport`].
//!
//! Layering: `sim::fleet` consumes plain [`ShardSpec`] numbers, never
//! `engine` types — the engine layer lowers scenario evaluations *into*
//! specs (`ShardService::fleet_spec`), keeping the repo's "`sim` never
//! depends on `engine`" rule intact.

pub mod arrivals;
pub mod autoscale;
pub mod event;
pub mod policy;
pub mod sim;

pub use autoscale::{Autoscaler, AutoscalerConfig, ScaleDecision, ScaleTrigger};
pub use event::{EventQueue, FleetEvent};
pub use policy::{AdmissionPolicy, SchedulingPolicy};
pub use sim::{FleetConfig, FleetReport, FleetSim, ShardSpec};
