//! Poisson arrival-trace generation shared by every serving layer.
//!
//! This is the single source of truth for request arrival processes: the
//! legacy batcher (`engine::batcher`), the shard batcher, and the fleet
//! simulator all build their traces here, so the degenerate-fleet bitwise
//! pins compare loops fed by *identical* request streams.
//!
//! Seeding: each stream's arrival PRNG comes from
//! [`Prng::for_stream`](crate::util::prng::Prng::for_stream) over the base
//! seed, a SplitMix-style sub-stream derivation — stream 0 does NOT
//! collapse to the raw seed, so arrival noise never aliases other
//! consumers of the same base seed (e.g. the engine frame source).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::util::prng::Prng;

/// One step request in virtual time.
#[derive(Debug, Clone)]
pub struct Request {
    pub stream: usize,
    pub step: u64,
    /// Arrival time (virtual seconds).
    pub arrival: f64,
}

/// Build the per-stream Poisson arrival trace, sorted by arrival time.
/// Returns `(arrivals, per_stream_arrived)`.
///
/// The caller is responsible for validating `rate_hz` and `duration_s`
/// (finite, positive rate; finite, non-negative duration) — see
/// `BatcherConfig::validate` / `FleetConfig::validate`.
pub fn build_poisson_arrivals(
    streams: usize,
    rate_hz: f64,
    duration_s: f64,
    seed: u64,
) -> (Vec<Request>, Vec<usize>) {
    let mut arrivals: Vec<Request> = Vec::new();
    for s in 0..streams {
        let mut rng = Prng::for_stream(seed, s as u64);
        let mut t = 0.0;
        let mut step = 0u64;
        loop {
            t += rng.exponential(rate_hz);
            if t > duration_s {
                break;
            }
            arrivals.push(Request { stream: s, step, arrival: t });
            step += 1;
        }
    }
    let mut per_stream_arrived = vec![0usize; streams];
    for r in &arrivals {
        per_stream_arrived[r.stream] += 1;
    }
    arrivals.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    (arrivals, per_stream_arrived)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_conserved() {
        let (arrivals, per_stream) = build_poisson_arrivals(4, 2.0, 10.0, 11);
        assert!(!arrivals.is_empty());
        for w in arrivals.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "trace must be time-sorted");
        }
        assert_eq!(per_stream.iter().sum::<usize>(), arrivals.len());
        for r in &arrivals {
            assert!(r.arrival > 0.0 && r.arrival <= 10.0);
        }
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let (a, _) = build_poisson_arrivals(3, 1.5, 8.0, 42);
        let (b, _) = build_poisson_arrivals(3, 1.5, 8.0, 42);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!((x.stream, x.step), (y.stream, y.step));
        }
        let (c, _) = build_poisson_arrivals(3, 1.5, 8.0, 43);
        assert_ne!(
            a.iter().map(|r| r.arrival.to_bits()).collect::<Vec<_>>(),
            c.iter().map(|r| r.arrival.to_bits()).collect::<Vec<_>>(),
            "different seeds must give different traces"
        );
    }

    #[test]
    fn zero_duration_is_an_empty_trace() {
        let (arrivals, per_stream) = build_poisson_arrivals(5, 2.0, 0.0, 7);
        assert!(arrivals.is_empty());
        assert_eq!(per_stream, vec![0; 5]);
    }
}
