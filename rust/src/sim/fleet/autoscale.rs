//! Queue-depth / tail-latency autoscaler for the fleet simulator.
//!
//! A three-state decision machine evaluated at a fixed virtual-time
//! cadence (`ScaleCheck` events):
//!
//! ```text
//!             queued > queue_up  OR  window p99 > p99_up_s
//!        Hold ────────────────────────────────────────────▶ Up
//!          ▲                                                │ spawn engine,
//!          │  queued < queue_down AND an idle               │ free at
//!          │  dynamic engine exists                         │ now + warmup_s
//!        Down ◀─────────────────────────────────────────────┘
//! ```
//!
//! `Up` additionally fires whenever the alive engine count has fallen
//! below `min_engines` (fail-stop replacement: the autoscaler is also the
//! failover path). Scaled-up engines come online after `warmup_s` of
//! virtual time; scale-down only retires *idle* dynamically-added engines
//! (never the static fleet), so in-flight work is never killed.

use crate::util::stats::Summary;

/// Autoscaler thresholds. `p99_up_s` is the tail-latency trigger over the
/// delays observed since the previous check; `None` scales on queue depth
/// alone.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerConfig {
    /// Virtual seconds between scale checks.
    pub check_interval_s: f64,
    /// Scale up when the total queue depth exceeds this.
    pub queue_up: usize,
    /// Scale down when the total queue depth is below this.
    pub queue_down: usize,
    /// Also scale up when the observed window p99 queueing delay (s)
    /// exceeds this.
    pub p99_up_s: Option<f64>,
    /// Warm-up latency before a scaled-up engine takes work (s).
    pub warmup_s: f64,
    /// Never retire below this many alive engines; falling under it (e.g.
    /// through failures) forces a scale-up.
    pub min_engines: usize,
    /// Never scale above this many alive engines.
    pub max_engines: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            check_interval_s: 0.25,
            queue_up: 8,
            queue_down: 1,
            p99_up_s: None,
            warmup_s: 0.5,
            min_engines: 1,
            max_engines: 8,
        }
    }
}

impl AutoscalerConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.check_interval_s.is_finite() && self.check_interval_s > 0.0,
            "autoscaler check interval must be finite and positive (got {})",
            self.check_interval_s
        );
        anyhow::ensure!(
            self.warmup_s.is_finite() && self.warmup_s >= 0.0,
            "autoscaler warmup must be finite and non-negative (got {})",
            self.warmup_s
        );
        if let Some(p) = self.p99_up_s {
            anyhow::ensure!(
                p.is_finite() && p >= 0.0,
                "autoscaler p99 threshold must be finite and non-negative (got {p})"
            );
        }
        anyhow::ensure!(self.min_engines >= 1, "autoscaler needs at least one engine");
        anyhow::ensure!(
            self.max_engines >= self.min_engines,
            "autoscaler max_engines {} < min_engines {}",
            self.max_engines,
            self.min_engines
        );
        anyhow::ensure!(
            self.queue_down <= self.queue_up,
            "autoscaler queue_down {} > queue_up {} would oscillate",
            self.queue_down,
            self.queue_up
        );
        Ok(())
    }
}

/// One scale decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

impl ScaleDecision {
    pub fn label(self) -> &'static str {
        match self {
            ScaleDecision::Up => "up",
            ScaleDecision::Down => "down",
            ScaleDecision::Hold => "hold",
        }
    }
}

/// Which rule of the state machine produced a decision. Telemetry-facing:
/// the decision alone says *what* happened, the trigger says *why*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleTrigger {
    /// Alive engines fell below `min_engines` (fail-stop replacement).
    Failover,
    /// Queue depth crossed `queue_up`.
    QueueDepth,
    /// Window p99 delay crossed `p99_up_s`.
    TailLatency,
    /// Queue depth fell below `queue_down` with spare engines.
    QueueDrained,
    /// No rule fired (includes up/down rules blocked by min/max caps).
    Steady,
}

impl ScaleTrigger {
    pub fn label(self) -> &'static str {
        match self {
            ScaleTrigger::Failover => "failover",
            ScaleTrigger::QueueDepth => "queue-depth",
            ScaleTrigger::TailLatency => "tail-latency",
            ScaleTrigger::QueueDrained => "queue-drained",
            ScaleTrigger::Steady => "steady",
        }
    }
}

/// Live autoscaler state: the config plus the delay window accumulated
/// since the last check.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscalerConfig,
    window: Vec<f64>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler { cfg, window: Vec::new() }
    }

    /// Record one observed queueing delay (served or dropped dispatch).
    pub fn observe(&mut self, delay_s: f64) {
        self.window.push(delay_s);
    }

    /// Evaluate the state machine at a check point. Consumes the window.
    pub fn decide(&mut self, queued: usize, alive: usize) -> ScaleDecision {
        self.decide_traced(queued, alive).0
    }

    /// [`Autoscaler::decide`] plus the rule that fired. The decision path is
    /// the untraced one verbatim — the trigger is derived alongside, never
    /// by re-running the rules.
    pub fn decide_traced(&mut self, queued: usize, alive: usize) -> (ScaleDecision, ScaleTrigger) {
        let p99 = Summary::of(&self.window).p99;
        self.window.clear();
        if alive < self.cfg.min_engines {
            // failover replacement beats every other rule
            return (ScaleDecision::Up, ScaleTrigger::Failover);
        }
        let tail_hot = self.cfg.p99_up_s.is_some_and(|thr| p99 > thr);
        if (queued > self.cfg.queue_up || tail_hot) && alive < self.cfg.max_engines {
            let trigger = if queued > self.cfg.queue_up {
                ScaleTrigger::QueueDepth
            } else {
                ScaleTrigger::TailLatency
            };
            return (ScaleDecision::Up, trigger);
        }
        if queued < self.cfg.queue_down && alive > self.cfg.min_engines {
            return (ScaleDecision::Down, ScaleTrigger::QueueDrained);
        }
        (ScaleDecision::Hold, ScaleTrigger::Steady)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            check_interval_s: 0.5,
            queue_up: 4,
            queue_down: 1,
            p99_up_s: Some(0.2),
            warmup_s: 0.25,
            min_engines: 1,
            max_engines: 3,
        }
    }

    #[test]
    fn validates_thresholds() {
        assert!(cfg().validate().is_ok());
        assert!(AutoscalerConfig { check_interval_s: 0.0, ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { check_interval_s: f64::NAN, ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { warmup_s: -1.0, ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { p99_up_s: Some(f64::INFINITY), ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { min_engines: 0, ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { max_engines: 0, min_engines: 2, ..cfg() }.validate().is_err());
        assert!(AutoscalerConfig { queue_down: 9, ..cfg() }.validate().is_err());
    }

    #[test]
    fn queue_depth_drives_the_state_machine() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(10, 1), ScaleDecision::Up, "deep queue scales up");
        assert_eq!(a.decide(10, 3), ScaleDecision::Hold, "capped at max_engines");
        assert_eq!(a.decide(2, 2), ScaleDecision::Hold, "hysteresis band holds");
        assert_eq!(a.decide(0, 2), ScaleDecision::Down, "drained queue scales down");
        assert_eq!(a.decide(0, 1), ScaleDecision::Hold, "floored at min_engines");
    }

    #[test]
    fn tail_latency_and_failover_triggers() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..100 {
            a.observe(0.5); // p99 well above the 0.2 s threshold
        }
        assert_eq!(a.decide(0, 2), ScaleDecision::Up, "hot tail scales up at shallow queue");
        // the window was consumed: the same shallow queue now scales down
        assert_eq!(a.decide(0, 2), ScaleDecision::Down);
        // alive below min_engines is an unconditional replacement
        assert_eq!(a.decide(0, 0), ScaleDecision::Up);
    }

    #[test]
    fn traced_decisions_name_the_rule_that_fired() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(
            a.decide_traced(0, 0),
            (ScaleDecision::Up, ScaleTrigger::Failover)
        );
        assert_eq!(
            a.decide_traced(10, 1),
            (ScaleDecision::Up, ScaleTrigger::QueueDepth)
        );
        for _ in 0..100 {
            a.observe(0.5);
        }
        assert_eq!(
            a.decide_traced(0, 2),
            (ScaleDecision::Up, ScaleTrigger::TailLatency),
            "shallow queue + hot tail is the tail-latency rule"
        );
        assert_eq!(
            a.decide_traced(0, 2),
            (ScaleDecision::Down, ScaleTrigger::QueueDrained)
        );
        assert_eq!(
            a.decide_traced(2, 2),
            (ScaleDecision::Hold, ScaleTrigger::Steady)
        );
        // deep queue at max_engines: the up rule is capped, reported Steady
        assert_eq!(
            a.decide_traced(10, 3),
            (ScaleDecision::Hold, ScaleTrigger::Steady)
        );
        // traced and untraced agree by construction
        let mut b = Autoscaler::new(cfg());
        assert_eq!(b.decide(10, 1), b.decide_traced(10, 1).0);
    }
}
