//! Typed event queue over virtual time for the fleet simulator.
//!
//! Events are ordered by `(time, push order)`: time via the `f64::to_bits`
//! trick (valid because virtual times are finite and non-negative, where
//! the IEEE-754 bit pattern is monotone), ties broken by a monotone
//! sequence number so simultaneous events pop in the order they were
//! scheduled — fully deterministic, no float-comparison ambiguity.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One fleet event. `Completion` doubles as "engine ready": a scaled-up
/// engine schedules a completion at the end of its warm-up so the
/// dispatcher wakes exactly when it comes online.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FleetEvent {
    /// Request `step` of `stream` arrives.
    Arrival { stream: u32, step: u64 },
    /// Engine finished its current service (or its warm-up) and can pull
    /// the next request.
    Completion { engine: u32 },
    /// Periodic autoscaler evaluation.
    ScaleCheck,
    /// Fail-stop: the engine dies (drain-then-die — in-flight work
    /// completes, nothing new is dispatched onto it).
    Failure { engine: u32 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Queued {
    time_bits: u64,
    seq: u64,
    event: FleetEvent,
}

/// Deterministic min-queue of [`FleetEvent`]s in virtual time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Queued>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at virtual time `time` (finite, >= 0).
    pub fn push(&mut self, time: f64, event: FleetEvent) {
        debug_assert!(
            time.is_finite() && time >= 0.0,
            "fleet events live in finite non-negative virtual time (got {time})"
        );
        self.heap.push(Reverse(Queued { time_bits: time.to_bits(), seq: self.seq, event }));
        self.seq += 1;
    }

    /// Pop the earliest event, `None` when the queue is drained.
    pub fn pop(&mut self) -> Option<(f64, FleetEvent)> {
        self.heap.pop().map(|Reverse(q)| (f64::from_bits(q.time_bits), q.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_stable_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, FleetEvent::ScaleCheck);
        q.push(1.0, FleetEvent::Completion { engine: 3 });
        q.push(1.0, FleetEvent::Arrival { stream: 0, step: 0 });
        q.push(0.5, FleetEvent::Failure { engine: 1 });
        assert_eq!(q.len(), 4);
        let (t0, e0) = q.pop().unwrap();
        assert_eq!((t0, e0), (0.5, FleetEvent::Failure { engine: 1 }));
        // tie at t=1.0: push order wins (completion was scheduled first)
        let (_, e1) = q.pop().unwrap();
        assert_eq!(e1, FleetEvent::Completion { engine: 3 });
        let (_, e2) = q.pop().unwrap();
        assert_eq!(e2, FleetEvent::Arrival { stream: 0, step: 0 });
        let (t3, e3) = q.pop().unwrap();
        assert_eq!((t3, e3), (2.0, FleetEvent::ScaleCheck));
        assert!(q.pop().is_none() && q.is_empty());
    }

    #[test]
    fn times_round_trip_bitwise() {
        let mut q = EventQueue::new();
        let t = 0.1 + 0.2; // a value with a non-trivial mantissa
        q.push(t, FleetEvent::ScaleCheck);
        let (got, _) = q.pop().unwrap();
        assert_eq!(got.to_bits(), t.to_bits());
    }
}
