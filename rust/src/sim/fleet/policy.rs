//! Pluggable admission and scheduling policies of the fleet simulator.
//!
//! Admission decides at *arrival* whether a request may even enter the
//! queue (`rejected`); scheduling decides at *dispatch* which queued
//! request the next free engine serves. Deadline staleness (drop at
//! dispatch when the queueing delay exceeds the request's SLO deadline) is
//! orthogonal and always on when a deadline is configured — exactly the
//! legacy batcher rule, so the drop-on-deadline admission policy with
//! earliest-free scheduling IS the legacy serving stack.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

/// Admission control applied when a request arrives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Admit every arrival; stale requests drop at dispatch (legacy).
    DropOnDeadline,
    /// Fleet-wide token bucket: `rate_hz` tokens/s refill, `burst`
    /// capacity; an arrival without a full token is rejected outright
    /// (never queued, never served).
    TokenBucket { rate_hz: f64, burst: u32 },
    /// SLO-class priority: best-effort-class arrivals (the *last* SLO
    /// class) are rejected while the total queue depth is at or above
    /// `depth_limit`; guaranteed classes always enter the queue.
    SloPriority { depth_limit: usize },
}

impl AdmissionPolicy {
    pub fn label(&self) -> String {
        match self {
            AdmissionPolicy::DropOnDeadline => "drop".into(),
            AdmissionPolicy::TokenBucket { rate_hz, burst } => {
                format!("token({rate_hz:.0}/s,b{burst})")
            }
            AdmissionPolicy::SloPriority { depth_limit } => format!("slo(q{depth_limit})"),
        }
    }

    /// Parse a CLI admission name. `token` and `slo` take their defaults
    /// from the serving context (the caller substitutes the tuned
    /// parameters); this only selects the family.
    pub fn parse(
        s: &str,
        token_rate_hz: f64,
        token_burst: u32,
        depth_limit: usize,
    ) -> anyhow::Result<AdmissionPolicy> {
        match s {
            "drop" | "deadline" => Ok(AdmissionPolicy::DropOnDeadline),
            "token" | "bucket" => {
                Ok(AdmissionPolicy::TokenBucket { rate_hz: token_rate_hz, burst: token_burst })
            }
            "slo" | "priority" => Ok(AdmissionPolicy::SloPriority { depth_limit }),
            other => anyhow::bail!(
                "unknown admission policy `{other}` (expected `drop`, `token`, or `slo`)"
            ),
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if let AdmissionPolicy::TokenBucket { rate_hz, burst } = self {
            anyhow::ensure!(
                rate_hz.is_finite() && *rate_hz > 0.0,
                "token bucket rate must be finite and positive (got {rate_hz})"
            );
            anyhow::ensure!(*burst >= 1, "token bucket burst must be >= 1");
        }
        Ok(())
    }
}

/// Fleet-wide token bucket state (continuous refill, deterministic f64
/// arithmetic — part of the bitwise-pinned simulation state).
#[derive(Debug, Clone)]
pub(crate) struct TokenBucket {
    rate_hz: f64,
    burst: f64,
    tokens: f64,
    last_t: f64,
}

impl TokenBucket {
    pub(crate) fn new(rate_hz: f64, burst: u32) -> TokenBucket {
        TokenBucket { rate_hz, burst: burst as f64, tokens: burst as f64, last_t: 0.0 }
    }

    /// Refill to time `now` and try to take one token.
    pub(crate) fn admit(&mut self, now: f64) -> bool {
        self.tokens = (self.tokens + (now - self.last_t) * self.rate_hz).min(self.burst);
        self.last_t = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Which queued request the next free engine serves, and which engine a
/// fresh arrival lands on when several sit idle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingPolicy {
    /// Engine: earliest-free (ties to the lowest id). Request: FIFO by
    /// arrival time — the legacy batcher's `Policy::Fifo`.
    EarliestFree,
    /// Engine: earliest-free. Request: round-robin across streams — the
    /// legacy batcher's `Policy::RoundRobin` (bounds per-stream
    /// starvation; selection is O(streams), meant for modest fleets).
    RoundRobin,
    /// Engine: least accumulated busy time (balances heterogeneous
    /// shards). Request: FIFO by arrival time.
    LeastLoaded,
    /// Engine: earliest-free. Request: SLO-aware earliest-deadline-first
    /// over `arrival + class deadline`; with a single SLO class this
    /// degenerates to FIFO.
    Edf,
}

impl SchedulingPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulingPolicy::EarliestFree => "earliest-free",
            SchedulingPolicy::RoundRobin => "round-robin",
            SchedulingPolicy::LeastLoaded => "least-loaded",
            SchedulingPolicy::Edf => "edf",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<SchedulingPolicy> {
        match s {
            "earliest" | "earliest-free" | "fifo" => Ok(SchedulingPolicy::EarliestFree),
            "rr" | "round-robin" => Ok(SchedulingPolicy::RoundRobin),
            "least" | "least-loaded" => Ok(SchedulingPolicy::LeastLoaded),
            "edf" => Ok(SchedulingPolicy::Edf),
            other => anyhow::bail!(
                "unknown scheduling policy `{other}` (expected `earliest`, `rr`, `least`, or `edf`)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels_round_trip() {
        assert_eq!(
            AdmissionPolicy::parse("drop", 10.0, 4, 8).unwrap(),
            AdmissionPolicy::DropOnDeadline
        );
        assert_eq!(
            AdmissionPolicy::parse("token", 10.0, 4, 8).unwrap(),
            AdmissionPolicy::TokenBucket { rate_hz: 10.0, burst: 4 }
        );
        assert_eq!(
            AdmissionPolicy::parse("slo", 10.0, 4, 8).unwrap(),
            AdmissionPolicy::SloPriority { depth_limit: 8 }
        );
        assert!(AdmissionPolicy::parse("open", 10.0, 4, 8).is_err());
        assert_eq!(SchedulingPolicy::parse("edf").unwrap(), SchedulingPolicy::Edf);
        assert_eq!(SchedulingPolicy::parse("fifo").unwrap(), SchedulingPolicy::EarliestFree);
        assert_eq!(SchedulingPolicy::parse("least").unwrap(), SchedulingPolicy::LeastLoaded);
        assert!(SchedulingPolicy::parse("sjf").is_err());
        assert_eq!(SchedulingPolicy::RoundRobin.label(), "round-robin");
        assert!(AdmissionPolicy::DropOnDeadline.label().contains("drop"));
    }

    #[test]
    fn token_bucket_validates_and_meters() {
        assert!(AdmissionPolicy::TokenBucket { rate_hz: f64::NAN, burst: 2 }.validate().is_err());
        assert!(AdmissionPolicy::TokenBucket { rate_hz: -1.0, burst: 2 }.validate().is_err());
        assert!(AdmissionPolicy::TokenBucket { rate_hz: 1.0, burst: 0 }.validate().is_err());
        assert!(AdmissionPolicy::DropOnDeadline.validate().is_ok());

        let mut tb = TokenBucket::new(1.0, 2);
        // burst capacity: two back-to-back admits, then dry
        assert!(tb.admit(0.0));
        assert!(tb.admit(0.0));
        assert!(!tb.admit(0.0));
        // refills at 1 token/s
        assert!(!tb.admit(0.5));
        assert!(tb.admit(1.6));
        // never exceeds burst
        assert!(tb.admit(100.0));
        assert!(tb.admit(100.0));
        assert!(!tb.admit(100.0));
    }
}
