//! The analytical XPU simulator (paper §3.2): roofline operator costs with
//! tiling/SM fidelity, asymmetric bandwidth, cross-operator prefetch, and
//! PIM offload; plus calibration against real measurements.

pub mod calibrate;
pub mod codesign;
pub mod energy;
pub mod fleet;
pub mod roofline;
pub mod scenario;
pub mod simulator;
pub mod sweep;
pub mod tiling;

pub use roofline::{
    cost_on_pim, cost_on_soc, cost_op, cost_op_scoped, Bound, Engine, OpCost, PimScope,
};
pub use simulator::{SimOptions, Simulator, StageResult, VlaSimResult};
