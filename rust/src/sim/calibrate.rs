//! Simulator calibration & validation against real measurements (E-C6).
//!
//! The paper validates its in-house simulator at "70% to 90%" accuracy
//! against production accelerators. We reproduce the methodology at the
//! scale available: the tiny VLA runs for real on this machine's CPU via
//! PJRT; we fit the two free parameters of the `cpu-host` platform model
//! (effective FLOP/s and effective DRAM bandwidth) on a subset of phases,
//! then report per-phase prediction accuracy on all of them.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::hw::platform::cpu_host_with;
use crate::model::layer::BlockDims;
use crate::model::vla::{ActionConfig, DecoderConfig, VitConfig, VlaConfig, WorkloadShape};
use crate::runtime::artifacts::Manifest;
use crate::sim::{SimOptions, Simulator, VlaSimResult};
use crate::util::stats::accuracy;
use crate::util::table::Table;

/// Real per-phase measurements (seconds) of the tiny VLA on this host.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredPhases {
    pub vision: f64,
    pub prefill: f64,
    pub decode: f64,
    pub action: f64,
}

impl MeasuredPhases {
    pub fn as_array(&self) -> [f64; 4] {
        [self.vision, self.prefill, self.decode, self.action]
    }

    pub fn total(&self) -> f64 {
        self.vision + self.prefill + self.decode + self.action
    }
}

/// Build the workload IR matching the runnable tiny VLA (from its manifest),
/// so the simulator and the real engine describe the identical computation.
pub fn tiny_config_from_manifest(m: &Manifest) -> VlaConfig {
    let dt = crate::hw::DType::F32; // artifacts are f32 on the CPU backend
    VlaConfig {
        name: "tiny-vla".into(),
        towers: vec![VitConfig {
            name: "vit".into(),
            layers: m.vision.layers as u64,
            dims: BlockDims {
                hidden: m.vision.hidden as u64,
                heads: 4,
                kv_heads: 4,
                head_dim: (m.vision.hidden / 4) as u64,
                ffn: 4 * m.vision.hidden as u64,
                dtype: dt,
            },
        }],
        projector_hidden: 2 * m.vision.hidden as u64,
        decoder: DecoderConfig {
            layers: m.decoder.layers as u64,
            dims: BlockDims {
                hidden: m.decoder.hidden as u64,
                heads: m.decoder.heads as u64,
                kv_heads: m.decoder.kv_heads as u64,
                head_dim: m.decoder.head_dim as u64,
                ffn: m.decoder.ffn as u64,
                dtype: dt,
            },
            vocab: m.decoder.vocab as u64,
            weight_scale: 1.0,
        },
        action: ActionConfig {
            layers: 2, // tiny DiT depth (fixed, independent of diffusion steps)
            dims: BlockDims {
                hidden: 128,
                heads: 4,
                kv_heads: 4,
                head_dim: 32,
                ffn: 512,
                dtype: dt,
            },
            horizon: m.action.horizon as u64,
            diffusion_steps: m.action.diffusion_steps as u64,
            action_dim: m.action.action_dim as u64,
        },
        shape: WorkloadShape {
            crops: 1,
            patches_per_crop: m.vision.patches as u64,
            image_tokens: m.workload.image_tokens as u64,
            prompt_tokens: m.workload.prompt_tokens as u64,
            decode_tokens: m.workload.decode_tokens as u64,
        },
    }
}

/// Simulator options for the XLA-CPU runtime: compiled (no eager dispatch),
/// no preprocessing, no PIM.
pub fn cpu_sim_options() -> SimOptions {
    SimOptions {
        prefetch: true,
        pim: false,
        decode_stride: 1,
        host_dispatch: 0.0,
        preprocess_per_crop: 0.0,
        ..Default::default()
    }
}

/// Fit (eff_gflops, eff_bw) by log-space grid search minimizing squared
/// log-error across all four measured phases.
pub fn fit_cpu_host(cfg: &VlaConfig, measured: &MeasuredPhases) -> (f64, f64) {
    let mut best = (10.0, 10e9);
    let mut best_loss = f64::INFINITY;
    let gflops_grid: Vec<f64> = (0..28).map(|i| 0.5 * 1.35f64.powi(i)).collect();
    let bw_grid: Vec<f64> = (0..24).map(|i| 0.5e9 * 1.4f64.powi(i)).collect();
    for &g in &gflops_grid {
        for &bw in &bw_grid {
            let sim = Simulator::with_options(cpu_host_with(g, bw), cpu_sim_options());
            let r = sim.simulate_vla(cfg);
            let pred = [r.vision.time, r.prefill.time, r.decode.time, r.action.time];
            let meas = measured.as_array();
            let loss: f64 = pred
                .iter()
                .zip(meas.iter())
                .map(|(p, m)| (p.max(1e-9) / m.max(1e-9)).ln().powi(2))
                .sum();
            if loss < best_loss {
                best_loss = loss;
                best = (g, bw);
            }
        }
    }
    best
}

/// Validation result: per-phase accuracy of the calibrated simulator.
#[derive(Debug, Clone)]
pub struct Validation {
    pub eff_gflops: f64,
    pub eff_bw: f64,
    pub predicted: VlaSimResult,
    pub measured: MeasuredPhases,
}

impl Validation {
    pub fn per_phase_accuracy(&self) -> [(String, f64, f64, f64); 4] {
        let pred = [
            self.predicted.vision.time,
            self.predicted.prefill.time,
            self.predicted.decode.time,
            self.predicted.action.time,
        ];
        let meas = self.measured.as_array();
        let names = ["vision", "prefill", "decode", "action"];
        let mut out = Vec::new();
        for i in 0..4 {
            out.push((names[i].to_string(), pred[i], meas[i], accuracy(pred[i], meas[i])));
        }
        [
            out[0].clone(),
            out[1].clone(),
            out[2].clone(),
            out[3].clone(),
        ]
    }

    /// Total-latency accuracy (the paper's headline validation metric).
    pub fn total_accuracy(&self) -> f64 {
        accuracy(self.predicted.total(), self.measured.total())
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "E-C6: simulator validation vs real PJRT-CPU measurements",
            &["phase", "predicted (s)", "measured (s)", "accuracy"],
        )
        .left_first();
        for (name, p, m, acc) in self.per_phase_accuracy() {
            t.row(vec![
                name,
                format!("{p:.4}"),
                format!("{m:.4}"),
                format!("{:.1}%", acc * 100.0),
            ]);
        }
        t.row(vec![
            "TOTAL".into(),
            format!("{:.4}", self.predicted.total()),
            format!("{:.4}", self.measured.total()),
            format!("{:.1}%", self.total_accuracy() * 100.0),
        ]);
        t
    }
}

/// Calibrate on the measurements and produce the validation report.
pub fn validate(manifest: &Manifest, measured: &MeasuredPhases) -> Validation {
    let cfg = tiny_config_from_manifest(manifest);
    let (g, bw) = fit_cpu_host(&cfg, measured);
    let sim = Simulator::with_options(cpu_host_with(g, bw), cpu_sim_options());
    Validation {
        eff_gflops: g,
        eff_bw: bw,
        predicted: sim.simulate_vla(&cfg),
        measured: *measured,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "n_params": 5800064, "params_sha256": "x",
          "vision": {"patches": 64, "patch_dim": 147, "layers": 2, "hidden": 128},
          "decoder": {"layers": 4, "hidden": 256, "heads": 8, "kv_heads": 2,
                      "head_dim": 32, "ffn": 1024, "vocab": 2048, "max_seq": 128},
          "action": {"horizon": 8, "action_dim": 7, "diffusion_steps": 4},
          "workload": {"image_tokens": 64, "prompt_tokens": 16,
                       "decode_tokens": 24, "prefill_len": 80},
          "golden": {"patch_seed": 42, "prompt_token_ids": [], "first_tokens": [],
                     "next_token": 0, "embeds_sum": 0, "actions_sum": 0,
                     "actions_first_row": [], "prefill_logits_l2": 0}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn config_matches_manifest_dims() {
        let c = tiny_config_from_manifest(&manifest());
        assert_eq!(c.decoder.layers, 4);
        assert_eq!(c.decoder.dims.hidden, 256);
        assert_eq!(c.shape.decode_tokens, 24);
        assert!(c.params() > 1e6);
    }

    #[test]
    fn fit_recovers_synthetic_truth() {
        // generate "measurements" from a known platform, then fit: the
        // recovered parameters must reproduce the phase times closely.
        let cfg = tiny_config_from_manifest(&manifest());
        let truth = Simulator::with_options(cpu_host_with(25.0, 18e9), cpu_sim_options());
        let r = truth.simulate_vla(&cfg);
        let measured = MeasuredPhases {
            vision: r.vision.time,
            prefill: r.prefill.time,
            decode: r.decode.time,
            action: r.action.time,
        };
        let v = validate(&manifest(), &measured);
        assert!(
            v.total_accuracy() > 0.9,
            "self-calibration should be >90% accurate, got {}",
            v.total_accuracy()
        );
        for (name, _, _, acc) in v.per_phase_accuracy() {
            assert!(acc > 0.7, "{name} accuracy {acc} below the paper's 70% floor");
        }
    }

    #[test]
    fn validation_table_renders() {
        let measured = MeasuredPhases {
            vision: 0.01,
            prefill: 0.02,
            decode: 0.2,
            action: 0.03,
        };
        let v = validate(&manifest(), &measured);
        let t = v.table();
        assert_eq!(t.n_rows(), 5);
        assert!(v.eff_gflops > 0.0 && v.eff_bw > 0.0);
    }
}
