//! Energy model for edge deployments.
//!
//! Control frequency is only half of the edge story — a mobile manipulator
//! runs on a battery. This module extends the roofline cost model with an
//! energy-per-operator estimate (compute pJ/FLOP + data-movement pJ/byte,
//! DRAM vs PIM vs on-chip), yielding J/step and J/action for every platform
//! of Table 1. PIM's energy win (no off-chip movement for offloaded ops) is
//! a first-class result in the HBM/LPDDR-PIM literature the paper cites [3].

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::roofline::{Engine, OpCost, PimScope};
use super::simulator::{SimOptions, Simulator, VlaSimResult};
use crate::hw::Platform;
use crate::model::{Stage, VlaConfig};
use crate::util::table::Table;

/// Energy coefficients for a platform (approximate 2024-era edge silicon).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Matrix-engine compute energy (J/FLOP) — bf16 MAC ≈ 0.4 pJ.
    pub pj_per_flop: f64,
    /// Off-chip DRAM access energy (J/byte). LPDDR5 ≈ 6 pJ/bit ≈ 48 pJ/B;
    /// GDDR7 is higher-power per bit moved.
    pub pj_per_dram_byte: f64,
    /// PIM-internal access energy (J/byte): bank-local, no PHY/link cost.
    pub pj_per_pim_byte: f64,
    /// On-chip (L2/SMEM) access energy (J/byte).
    pub pj_per_onchip_byte: f64,
    /// Static/idle platform power (W) charged over elapsed time.
    pub idle_watts: f64,
}

impl EnergyModel {
    /// Coefficients per memory technology.
    pub fn for_platform(platform: &Platform) -> EnergyModel {
        let pj_per_dram_byte = match platform.mem.name.as_str() {
            "LPDDR5" => 48.0,
            "LPDDR5X" => 44.0,
            "GDDR7" => 64.0, // faster but hungrier per byte
            "HBM3" => 31.0,  // short TSV paths beat off-package PHYs
            "HBM3E" => 28.0, // cloud-tier stacks (offload remote end)
            "HBM4" => 26.0,
            "HBM4 PIM" => 26.0,
            "LPDDR6X PIM" => 40.0,
            _ => 50.0,
        };
        EnergyModel {
            pj_per_flop: 0.4,
            pj_per_dram_byte,
            pj_per_pim_byte: 12.0, // bank-local, ~4x cheaper than off-chip
            pj_per_onchip_byte: 2.0,
            idle_watts: if platform.soc.sms >= 32 { 20.0 } else { 10.0 },
        }
    }

    /// Energy of one costed operator (J).
    pub fn op_energy(&self, c: &OpCost) -> f64 {
        let compute = c.flops * self.pj_per_flop * 1e-12;
        let movement = match c.engine {
            Engine::Soc => {
                let offchip = c.offchip_bytes;
                let onchip = (c.bytes - c.offchip_bytes).max(0.0);
                offchip * self.pj_per_dram_byte * 1e-12 + onchip * self.pj_per_onchip_byte * 1e-12
            }
            Engine::Pim => c.bytes * self.pj_per_pim_byte * 1e-12,
        };
        compute + movement
    }
}

/// Per-step energy decomposition.
#[derive(Debug, Clone)]
pub struct EnergyResult {
    pub platform: String,
    pub model: String,
    /// Dynamic energy per phase (J): vision, prefill, decode, action.
    pub phase_dynamic: [f64; 4],
    /// Idle/static energy over the step (J).
    pub static_j: f64,
    pub step_latency: f64,
    pub action_horizon: u64,
}

impl EnergyResult {
    pub fn dynamic_total(&self) -> f64 {
        self.phase_dynamic.iter().sum()
    }

    pub fn total_j(&self) -> f64 {
        self.dynamic_total() + self.static_j
    }

    /// Average power draw during the step (W).
    pub fn avg_watts(&self) -> f64 {
        self.total_j() / self.step_latency.max(1e-12)
    }

    /// Energy per executed action (J), with chunked execution.
    pub fn j_per_action(&self) -> f64 {
        self.total_j() / self.action_horizon.max(1) as f64
    }
}

/// Dynamic energy of one stage under `scope` (J). Op placement matches what
/// the simulator's latency path chooses for the same scope, forced PIM
/// residency included — the single energy-accounting primitive shared by
/// [`simulate_energy`] and the scenario
/// [`Evaluator`](super::scenario::Evaluator).
pub fn stage_dynamic_energy(platform: &Platform, scope: PimScope, stage: &Stage) -> f64 {
    let em = EnergyModel::for_platform(platform);
    stage
        .ops
        .iter()
        .map(|op| em.op_energy(&super::roofline::cost_op_scoped(platform, op, scope)))
        .sum()
}

/// Dynamic energy of the full decode phase (J): the same strided
/// KV-position integration as the latency path, on patched stages (the
/// KV-dependent ops are rebuilt in place per position — identical operator
/// costs to a fresh build, without the per-position stage allocation).
pub fn decode_dynamic_energy(platform: &Platform, options: &SimOptions, config: &VlaConfig) -> f64 {
    let scope = options.effective_pim_scope();
    let stride = options.decode_stride.max(1);
    let start = config.shape.prefill_len();
    let n = config.shape.decode_tokens;
    let mut stage = config.decode_stage_at(start);
    let mut decode_j = 0.0;
    let mut sampled = 0u64;
    let mut pos = 0u64;
    while pos < n {
        config.patch_decode_stage_kv(&mut stage, start + pos);
        decode_j += stage_dynamic_energy(platform, scope, &stage);
        sampled += 1;
        pos += stride;
    }
    decode_j * n as f64 / sampled as f64
}

/// Simulate latency AND energy for a full VLA step.
pub fn simulate_energy(
    platform: &Platform,
    options: &SimOptions,
    config: &VlaConfig,
) -> (VlaSimResult, EnergyResult) {
    let sim = Simulator::with_options(platform.clone(), options.clone());
    let em = EnergyModel::for_platform(platform);
    let scope = options.effective_pim_scope();

    let latency = sim.simulate_vla(config);
    let vision_j = stage_dynamic_energy(platform, scope, &config.vision_stage());
    let prefill_j = stage_dynamic_energy(platform, scope, &config.prefill_stage());
    let decode_j = decode_dynamic_energy(platform, options, config);
    let action_j = stage_dynamic_energy(platform, scope, &config.action_stage());

    let energy = EnergyResult {
        platform: platform.name.clone(),
        model: config.name.clone(),
        phase_dynamic: [vision_j, prefill_j, decode_j, action_j],
        static_j: em.idle_watts * latency.total(),
        step_latency: latency.total(),
        action_horizon: config.action.horizon,
    };
    (latency, energy)
}

/// The per-platform energy table (one row per platform), evaluated on the
/// parallel sweep runner. The single source of the table that `energy` and
/// `report` both emit.
pub fn energy_table(platforms: &[Platform], options: &SimOptions, cfg: &VlaConfig) -> Table {
    let mut t = Table::new(
        &format!("Energy per control step ({})", cfg.name),
        &["Platform", "dynamic J", "static J", "total J", "avg W", "J/action"],
    )
    .left_first();
    let rows = super::sweep::parallel_map(platforms, |p| {
        let (_, e) = simulate_energy(p, options, cfg);
        vec![
            p.name.clone(),
            format!("{:.2}", e.dynamic_total()),
            format!("{:.2}", e.static_j),
            format!("{:.2}", e.total_j()),
            format!("{:.1}", e.avg_watts()),
            format!("{:.2}", e.j_per_action()),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;

    fn opts() -> SimOptions {
        SimOptions {
            decode_stride: 16,
            ..Default::default()
        }
    }

    #[test]
    fn decode_dominates_dynamic_energy() {
        let (_, e) = simulate_energy(&platform::orin(), &opts(), &molmoact_7b());
        assert!(
            e.phase_dynamic[2] > e.phase_dynamic[0] + e.phase_dynamic[1] + e.phase_dynamic[3],
            "decode moves the most bytes: {:?}",
            e.phase_dynamic
        );
        assert!(e.total_j() > e.dynamic_total());
    }

    #[test]
    fn pim_cuts_energy_per_action() {
        let cfg = molmoact_7b();
        let (_, base) = simulate_energy(&platform::orin(), &opts(), &cfg);
        let (_, pim) = simulate_energy(&platform::orin_pim(), &opts(), &cfg);
        // PIM wins twice: less off-chip movement (dynamic) and a much
        // shorter step (static energy)
        assert!(
            pim.j_per_action() < base.j_per_action(),
            "PIM {} J/action vs base {}",
            pim.j_per_action(),
            base.j_per_action()
        );
    }

    #[test]
    fn power_draw_within_edge_envelope() {
        // Jetson-class boards run 15-60 W sustained (MAXN); the model should
        // land in a physically plausible envelope, not a datacenter one.
        for plat in [platform::orin(), platform::thor()] {
            let (_, e) = simulate_energy(&plat, &opts(), &molmoact_7b());
            let w = e.avg_watts();
            assert!((5.0..120.0).contains(&w), "{}: {w} W", e.platform);
        }
    }

    #[test]
    fn energy_scales_with_decode_tokens() {
        let mut cfg = molmoact_7b();
        let (_, e1) = simulate_energy(&platform::thor(), &opts(), &cfg);
        cfg.shape.decode_tokens *= 2;
        let (_, e2) = simulate_energy(&platform::thor(), &opts(), &cfg);
        let ratio = e2.phase_dynamic[2] / e1.phase_dynamic[2];
        assert!((1.8..2.3).contains(&ratio), "decode energy ratio {ratio}");
    }

    #[test]
    fn coefficients_vary_by_memory() {
        let a = EnergyModel::for_platform(&platform::orin());
        let b = EnergyModel::for_platform(&platform::orin_gddr7());
        assert!(b.pj_per_dram_byte > a.pj_per_dram_byte);
        // stacked HBM moves bytes cheaper than any off-package DRAM here
        let h3 = EnergyModel::for_platform(&platform::orin_hbm3());
        let h4 = EnergyModel::for_platform(&platform::thor_hbm4());
        assert!(h3.pj_per_dram_byte < a.pj_per_dram_byte);
        assert!(h4.pj_per_dram_byte < h3.pj_per_dram_byte);
    }

    #[test]
    fn decode_energy_patch_matches_fresh_build() {
        // the patched-stage integration must be BITWISE the fresh-build
        // integration (patch_decode_stage_kv rebuilds identical op costs)
        use crate::model::vla::tiny_test_config;
        let cfg = tiny_test_config();
        let p = platform::orin_pim();
        let o = SimOptions { decode_stride: 3, ..Default::default() };
        let fast = decode_dynamic_energy(&p, &o, &cfg);
        let scope = o.effective_pim_scope();
        let start = cfg.shape.prefill_len();
        let mut j = 0.0;
        let mut sampled = 0u64;
        let mut pos = 0u64;
        while pos < cfg.shape.decode_tokens {
            j += stage_dynamic_energy(&p, scope, &cfg.decode_stage_at(start + pos));
            sampled += 1;
            pos += 3;
        }
        let want = j * cfg.shape.decode_tokens as f64 / sampled as f64;
        assert_eq!(fast.to_bits(), want.to_bits());
    }

    #[test]
    fn energy_table_covers_sweep_set() {
        let t = energy_table(&platform::sweep_platforms(), &opts(), &molmoact_7b());
        assert_eq!(t.n_rows(), platform::sweep_platforms().len());
        assert!(t.to_markdown().contains("Orin+HBM3"));
        // every row parses: total = dynamic + static (within print rounding)
        for r in 0..t.n_rows() {
            let dynamic: f64 = t.cell(r, 1).parse().unwrap();
            let static_j: f64 = t.cell(r, 2).parse().unwrap();
            let total: f64 = t.cell(r, 3).parse().unwrap();
            assert!((dynamic + static_j - total).abs() < 0.02, "row {r}");
        }
    }
}
