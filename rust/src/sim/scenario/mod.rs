//! Composable PIM co-design scenarios.
//!
//! The paper's conclusion calls for *holistic* hardware/software co-design:
//! neither memory scaling nor any single software technique closes the
//! action-generation latency gap alone. This subsystem makes that search
//! space a value:
//!
//! - a [`Lever`] is one technique — weight quantization, KV quantization,
//!   trace compression, speculative decoding, batching, and the three
//!   PIM-residency levers (weight-streaming on PIM, KV-resident-in-PIM
//!   attention, draft-model-on-PIM speculation);
//! - a [`Scenario`] is a named stack of levers (at most one per
//!   [`LeverGroup`]) that *lowers* to a transformed
//!   [`VlaConfig`](crate::model::VlaConfig) + [`SimOptions`] + a decode-cost
//!   override, evaluated against the existing
//!   [`Simulator`](crate::sim::Simulator) by an [`Evaluator`];
//! - [`scenario_matrix`] enumerates the cartesian product of the lever axes
//!   under the validity rules (PIM levers need a PIM device; a PIM-resident
//!   draft claims the PIM units exclusively), with a closed-form size
//!   ([`matrix_size`]) the tests pin against the enumeration.
//!
//! Placement semantics: within the scenario engine, exploiting PIM is an
//! explicit *software mapping decision* (a lever), not an ambient simulator
//! option — SoC-only scenarios cost the stock off-chip path even on
//! PIM-equipped platforms, so the matrix shows exactly what each residency
//! buys. The legacy `sim::codesign` entry points keep their ambient-PIM
//! behavior (and their numbers, bitwise) by passing their options through
//! unchanged.

mod eval;
mod lever;
mod matrix;

pub use eval::{pim_speculative_decode, speculative_decode, Evaluator, ScenarioResult};
pub use lever::{quantize_weights, Lever, LeverGroup};
pub use matrix::{matrix_size, scenario_matrix, SPEC_ALPHA, SPEC_GAMMA, TRACE_FACTOR};

use crate::hw::Platform;

/// A named stack of co-design levers.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Display name, composed from the lever tags ("W8@PIM + 0.5xCoT + ...").
    pub name: String,
    pub levers: Vec<Lever>,
}

impl Scenario {
    /// The empty scenario: the unmodified workload on the stock SoC path.
    pub fn baseline() -> Scenario {
        Scenario { name: "baseline".into(), levers: Vec::new() }
    }

    /// Build a scenario named after its lever tags.
    pub fn of(levers: Vec<Lever>) -> Scenario {
        let name = if levers.is_empty() {
            "baseline".to_string()
        } else {
            levers.iter().map(|l| l.short()).collect::<Vec<_>>().join(" + ")
        };
        Scenario { name, levers }
    }

    /// The lever of `group`, if the stack holds one.
    pub fn lever(&self, group: LeverGroup) -> Option<&Lever> {
        self.levers.iter().find(|l| l.group() == group)
    }

    /// Does any lever in the stack need PIM hardware?
    pub fn requires_pim(&self) -> bool {
        self.levers.iter().any(|l| l.requires_pim())
    }

    /// Worst-case multiplicative overhead the stack's cost models may add
    /// (product of the per-lever bounds): every evaluated scenario must
    /// satisfy `speedup >= 1 / modeled_overhead()`.
    pub fn modeled_overhead(&self) -> f64 {
        self.levers.iter().map(|l| l.modeled_overhead()).product()
    }

    /// Validity rules for `platform`:
    /// - at most one lever per exclusivity group;
    /// - PIM levers require a PIM-capable memory device;
    /// - a PIM-resident draft claims the PIM units, excluding the other
    ///   PIM-residency levers;
    /// - batching does not compose with speculation (verification already
    ///   batches the target pass).
    pub fn validate(&self, platform: &Platform) -> anyhow::Result<()> {
        for (i, a) in self.levers.iter().enumerate() {
            for b in &self.levers[i + 1..] {
                anyhow::ensure!(
                    a.group() != b.group(),
                    "scenario `{}`: `{}` and `{}` are in the same lever group",
                    self.name,
                    a.short(),
                    b.short()
                );
            }
        }
        for l in &self.levers {
            anyhow::ensure!(
                l.valid_on(platform),
                "scenario `{}`: `{}` requires a PIM device, `{}` has none",
                self.name,
                l.short(),
                platform.name
            );
        }
        let pim_draft = matches!(self.lever(LeverGroup::Speculation), Some(Lever::PimDraft { .. }));
        if pim_draft {
            let other_pim = self
                .levers
                .iter()
                .any(|l| l.requires_pim() && l.group() != LeverGroup::Speculation);
            anyhow::ensure!(
                !other_pim,
                "scenario `{}`: a PIM-resident draft claims the PIM units exclusively",
                self.name
            );
        }
        if self.lever(LeverGroup::Batching).is_some() {
            anyhow::ensure!(
                self.lever(LeverGroup::Speculation).is_none(),
                "scenario `{}`: batching does not compose with speculative decoding",
                self.name
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;

    #[test]
    fn names_compose_from_lever_tags() {
        assert_eq!(Scenario::baseline().name, "baseline");
        assert_eq!(Scenario::of(vec![]).name, "baseline");
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::CompressTrace { factor: 0.5 },
        ]);
        assert_eq!(s.name, "W8 + 0.5xCoT");
    }

    #[test]
    fn duplicate_group_rejected() {
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::PimWeightStream { bits: 4 },
        ]);
        assert!(s.validate(&platform::orin_pim()).is_err());
    }

    #[test]
    fn pim_levers_need_pim_hardware() {
        let s = Scenario::of(vec![Lever::PimKvAttention]);
        assert!(s.validate(&platform::orin_pim()).is_ok());
        assert!(s.validate(&platform::orin()).is_err());
        assert!(s.requires_pim());
    }

    #[test]
    fn pim_draft_claims_the_pim_units() {
        let alone = Scenario::of(vec![Lever::PimDraft { gamma: 4, alpha: 0.7 }]);
        assert!(alone.validate(&platform::thor_pim()).is_ok());
        let contended = Scenario::of(vec![
            Lever::PimWeightStream { bits: 8 },
            Lever::PimDraft { gamma: 4, alpha: 0.7 },
        ]);
        assert!(contended.validate(&platform::thor_pim()).is_err());
    }

    #[test]
    fn batching_excludes_speculation() {
        let s = Scenario::of(vec![
            Lever::Batch { streams: 8 },
            Lever::Speculate { gamma: 4, alpha: 0.7 },
        ]);
        assert!(s.validate(&platform::orin()).is_err());
    }

    #[test]
    fn modeled_overhead_compounds() {
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::Speculate { gamma: 4, alpha: 0.7 },
        ]);
        assert!((s.modeled_overhead() - 1.02 * 2.0).abs() < 1e-12);
        assert_eq!(Scenario::baseline().modeled_overhead(), 1.0);
        // per-stream batching is bounded by streams-x (KV/activations scale)
        assert_eq!(Scenario::of(vec![Lever::Batch { streams: 8 }]).modeled_overhead(), 8.0);
    }
}
