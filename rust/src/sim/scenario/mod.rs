//! Composable PIM co-design scenarios.
//!
//! The paper's conclusion calls for *holistic* hardware/software co-design:
//! neither memory scaling nor any single software technique closes the
//! action-generation latency gap alone. This subsystem makes that search
//! space a value:
//!
//! - a [`Lever`] is one technique — weight quantization, KV quantization,
//!   trace compression, speculative decoding, batching, the three
//!   PIM-residency levers (weight-streaming on PIM, KV-resident-in-PIM
//!   attention, draft-model-on-PIM speculation), and the serving shard
//!   topologies of [`engine::shard`](crate::engine::shard) (replicate the
//!   engine / pipeline the decoder);
//! - a [`Scenario`] is a named stack of levers (at most one per
//!   [`LeverGroup`]) that *lowers* to a transformed
//!   [`VlaConfig`](crate::model::VlaConfig) + [`SimOptions`] + a decode-cost
//!   override, evaluated against the existing
//!   [`Simulator`](crate::sim::Simulator) by an [`Evaluator`];
//! - [`scenario_matrix_grid`] enumerates the cartesian product of the lever
//!   axes at a [`LeverGrid`]'s parameter points (γ×α speculation grids,
//!   trace factors, batch sizes) under the validity rules (PIM levers need
//!   a PIM device; a PIM-resident draft claims the PIM units exclusively),
//!   with a closed-form size ([`matrix_size_grid`]) the tests pin against
//!   the enumeration; [`scenario_matrix`]/[`matrix_size`] are the
//!   degenerate [`LeverGrid::legacy`] fixed point (72 PIM / 24 SoC).
//!
//! Phase 2 adds two more result dimensions per evaluated scenario:
//! **capacity validity** — a scenario is over capacity when the lowered
//! model's weights + KV (+ the draft, when a speculation lever places one)
//! exceed the platform's [`MemDevice`](crate::hw::MemDevice) capacity; such
//! rows are flagged ([`ScenarioResult::fits_capacity`]) and reported, never
//! silently dropped — and **energy** — every evaluation also integrates the
//! [`sim::energy`](crate::sim::energy) model, so scenarios rank on a
//! Hz-vs-J/action [`pareto_front`] instead of a single key.
//!
//! Placement semantics: within the scenario engine, exploiting PIM is an
//! explicit *software mapping decision* (a lever), not an ambient simulator
//! option — SoC-only scenarios cost the stock off-chip path even on
//! PIM-equipped platforms, so the matrix shows exactly what each residency
//! buys. The legacy `sim::codesign` entry points keep their ambient-PIM
//! behavior (and their numbers, bitwise) by passing their options through
//! unchanged.
//!
//! Evaluation is *incremental* since the perf-trajectory PR: an
//! [`EvalCache`] memoizes whole roofline integrations and whole
//! decode-phase costs across the grid (see the `cache` module docs for
//! the two levels and the bitwise-identity discipline), collapsing the
//! 690 fresh integrations of the sharded default matrix to 90 distinct
//! ones. [`Evaluator::eval_fresh`] keeps the uncached path alive as the
//! reference the tests pin `eval` against, bit for bit.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

mod cache;
mod eval;
mod lever;
mod matrix;

pub use cache::{CacheStats, EvalCache};
pub use eval::{
    pareto_front, pareto_front3, pim_speculative_decode, speculative_decode, Evaluator,
    ScenarioResult,
};
pub use lever::{quantize_weights, Lever, LeverGroup, NetLink, OffloadMode};
pub use matrix::{
    matrix_size, matrix_size_grid, scenario_matrix, scenario_matrix_grid, LeverGrid, BATCH_STREAMS,
    SPEC_ALPHA, SPEC_GAMMA, TRACE_FACTOR,
};

use crate::hw::Platform;
use crate::model::vla::VlaConfig;

/// A named stack of co-design levers.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Display name, composed from the lever tags ("W8@PIM + 0.5xCoT + ...").
    pub name: String,
    pub levers: Vec<Lever>,
}

impl Scenario {
    /// The empty scenario: the unmodified workload on the stock SoC path.
    pub fn baseline() -> Scenario {
        Scenario { name: "baseline".into(), levers: Vec::new() }
    }

    /// Build a scenario named after its lever tags.
    pub fn of(levers: Vec<Lever>) -> Scenario {
        let name = if levers.is_empty() {
            "baseline".to_string()
        } else {
            levers.iter().map(|l| l.short()).collect::<Vec<_>>().join(" + ")
        };
        Scenario { name, levers }
    }

    /// The lever of `group`, if the stack holds one.
    pub fn lever(&self, group: LeverGroup) -> Option<&Lever> {
        self.levers.iter().find(|l| l.group() == group)
    }

    /// Does any lever in the stack need PIM hardware?
    pub fn requires_pim(&self) -> bool {
        self.levers.iter().any(|l| l.requires_pim())
    }

    /// Worst-case multiplicative overhead the stack's cost models may add
    /// (product of the per-lever bounds): every evaluated scenario must
    /// satisfy `speedup >= 1 / modeled_overhead()`.
    pub fn modeled_overhead(&self) -> f64 {
        self.levers.iter().map(|l| l.modeled_overhead()).product()
    }

    /// Peak device-memory footprint (bytes) of the lowered scenario on its
    /// platform's single memory device (PIM banks live in the same DRAM, so
    /// residency moves compute, not capacity): the lowered target's weights
    /// at their quantized widths, the full-trace KV cache (trace compression
    /// shortens it, KV8 halves its width, batching multiplies it per
    /// stream), and — when a speculation lever places one — the draft
    /// model's weights and KV. A replicate shard lever multiplies the whole
    /// footprint by its engine count (each replica holds a full weight copy
    /// and its own KV on the shared memory system); a pipelined decoder
    /// partitions ONE copy across its stages, so the device total is
    /// unchanged (the per-engine 1/R view lives in
    /// [`ShardModel`](crate::engine::shard::ShardModel)).
    pub fn memory_footprint(&self, target: &VlaConfig, draft: &VlaConfig) -> f64 {
        let mut cfg = target.clone();
        for lever in &self.levers {
            lever.apply_config(&mut cfg);
        }
        let kv_scale =
            if matches!(self.lever(LeverGroup::Kv), Some(Lever::QuantizeKv)) { 0.5 } else { 1.0 };
        let streams = match self.lever(LeverGroup::Batching) {
            Some(Lever::Batch { streams }) => (*streams).max(1),
            _ => 1,
        };
        let seq = (cfg.shape.prefill_len() + cfg.shape.decode_tokens) as f64;
        let kv = cfg.decoder.kv_bytes_per_token() * seq * kv_scale * streams as f64;
        let mut total = cfg.weight_footprint_bytes() + kv;
        if self.lever(LeverGroup::Speculation).is_some() {
            let dseq = (draft.shape.prefill_len() + draft.shape.decode_tokens) as f64;
            total += draft.weight_footprint_bytes() + draft.decoder.kv_bytes_per_token() * dseq;
        }
        match self.lever(LeverGroup::Serving) {
            Some(Lever::Shard { mode, engines }) => {
                crate::engine::shard::ShardModel { mode: *mode, engines: *engines }
                    .device_footprint_bytes(total)
            }
            _ => total,
        }
    }

    /// Capacity-validity rule: does the lowered scenario fit `platform`'s
    /// memory device? Over-capacity scenarios stay structurally valid —
    /// the evaluator flags them ([`ScenarioResult::fits_capacity`]) so the
    /// ranked matrix REPORTS them instead of silently dropping rows.
    pub fn fits_capacity(
        &self,
        platform: &Platform,
        target: &VlaConfig,
        draft: &VlaConfig,
    ) -> bool {
        self.memory_footprint(target, draft) <= platform.mem.capacity
    }

    /// Validity rules for `platform`:
    /// - at most one lever per exclusivity group;
    /// - PIM levers require a PIM-capable memory device;
    /// - a PIM-resident draft claims the PIM units, excluding the other
    ///   PIM-residency levers;
    /// - batching does not compose with speculation (verification already
    ///   batches the target pass);
    /// - an offload link must be physically meaningful: finite latency
    ///   ≥ 0, finite bandwidth > 0, finite cost ≥ 0.
    pub fn validate(&self, platform: &Platform) -> anyhow::Result<()> {
        for (i, a) in self.levers.iter().enumerate() {
            for b in &self.levers[i + 1..] {
                anyhow::ensure!(
                    a.group() != b.group(),
                    "scenario `{}`: `{}` and `{}` are in the same lever group",
                    self.name,
                    a.short(),
                    b.short()
                );
            }
        }
        for l in &self.levers {
            anyhow::ensure!(
                l.valid_on(platform),
                "scenario `{}`: `{}` requires a PIM device, `{}` has none",
                self.name,
                l.short(),
                platform.name
            );
        }
        let pim_draft = matches!(self.lever(LeverGroup::Speculation), Some(Lever::PimDraft { .. }));
        if pim_draft {
            let other_pim = self
                .levers
                .iter()
                .any(|l| l.requires_pim() && l.group() != LeverGroup::Speculation);
            anyhow::ensure!(
                !other_pim,
                "scenario `{}`: a PIM-resident draft claims the PIM units exclusively",
                self.name
            );
        }
        if self.lever(LeverGroup::Batching).is_some() {
            anyhow::ensure!(
                self.lever(LeverGroup::Speculation).is_none(),
                "scenario `{}`: batching does not compose with speculative decoding",
                self.name
            );
        }
        if let Some(Lever::Shard { engines, .. }) = self.lever(LeverGroup::Serving) {
            anyhow::ensure!(
                *engines >= 1,
                "scenario `{}`: a shard topology needs at least one engine",
                self.name
            );
        }
        if let Some(Lever::Offload { link, .. }) = self.lever(LeverGroup::Placement) {
            anyhow::ensure!(
                link.latency_s.is_finite() && link.latency_s >= 0.0,
                "scenario `{}`: offload link latency must be finite and >= 0 (got {})",
                self.name,
                link.latency_s
            );
            anyhow::ensure!(
                link.bw_gbps.is_finite() && link.bw_gbps > 0.0,
                "scenario `{}`: offload link bandwidth must be finite and > 0 (got {})",
                self.name,
                link.bw_gbps
            );
            anyhow::ensure!(
                link.usd_per_month.is_finite() && link.usd_per_month >= 0.0,
                "scenario `{}`: offload link cost must be finite and >= 0 (got {})",
                self.name,
                link.usd_per_month
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;

    #[test]
    fn names_compose_from_lever_tags() {
        assert_eq!(Scenario::baseline().name, "baseline");
        assert_eq!(Scenario::of(vec![]).name, "baseline");
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::CompressTrace { factor: 0.5 },
        ]);
        assert_eq!(s.name, "W8 + 0.5xCoT");
    }

    #[test]
    fn duplicate_group_rejected() {
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::PimWeightStream { bits: 4 },
        ]);
        assert!(s.validate(&platform::orin_pim()).is_err());
    }

    #[test]
    fn pim_levers_need_pim_hardware() {
        let s = Scenario::of(vec![Lever::PimKvAttention]);
        assert!(s.validate(&platform::orin_pim()).is_ok());
        assert!(s.validate(&platform::orin()).is_err());
        assert!(s.requires_pim());
    }

    #[test]
    fn pim_draft_claims_the_pim_units() {
        let alone = Scenario::of(vec![Lever::PimDraft { gamma: 4, alpha: 0.7 }]);
        assert!(alone.validate(&platform::thor_pim()).is_ok());
        let contended = Scenario::of(vec![
            Lever::PimWeightStream { bits: 8 },
            Lever::PimDraft { gamma: 4, alpha: 0.7 },
        ]);
        assert!(contended.validate(&platform::thor_pim()).is_err());
    }

    #[test]
    fn batching_excludes_speculation() {
        let s = Scenario::of(vec![
            Lever::Batch { streams: 8 },
            Lever::Speculate { gamma: 4, alpha: 0.7 },
        ]);
        assert!(s.validate(&platform::orin()).is_err());
    }

    #[test]
    fn modeled_overhead_compounds() {
        let s = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 8 },
            Lever::Speculate { gamma: 4, alpha: 0.7 },
        ]);
        // spec bound is parametric since phase 2: (gamma + 2) / E(gamma, alpha)
        let e = (1.0 - 0.7f64.powi(5)) / (1.0 - 0.7f64).max(1e-9);
        assert!((s.modeled_overhead() - 1.02 * (6.0 / e)).abs() < 1e-12);
        assert_eq!(Scenario::baseline().modeled_overhead(), 1.0);
        // per-stream batching is bounded by streams-x (KV/activations scale)
        assert_eq!(Scenario::of(vec![Lever::Batch { streams: 8 }]).modeled_overhead(), 8.0);
    }

    #[test]
    fn footprint_accounts_for_every_lever() {
        use crate::model::molmoact::molmoact_7b;
        use crate::model::scaling::scaled_vla;
        let target = molmoact_7b();
        let draft = scaled_vla(2.0);
        let fp = |levers: Vec<Lever>| Scenario::of(levers).memory_footprint(&target, &draft);
        let base = fp(vec![]);
        // bf16 7B-class model: weights + KV land in the 14-20 GB band
        assert!((12e9..22e9).contains(&base), "baseline footprint {base:.3e}");
        // quantization shrinks, W4 below W8
        assert!(fp(vec![Lever::QuantizeWeights { bits: 8 }]) < base);
        let w4 = fp(vec![Lever::QuantizeWeights { bits: 4 }]);
        assert!(w4 < fp(vec![Lever::QuantizeWeights { bits: 8 }]));
        // PIM residency moves compute, not capacity: same footprint as W8
        assert_eq!(
            fp(vec![Lever::PimWeightStream { bits: 8 }]),
            fp(vec![Lever::QuantizeWeights { bits: 8 }])
        );
        // KV8 and trace compression shrink the cache term only
        assert!(fp(vec![Lever::QuantizeKv]) < base);
        assert!(fp(vec![Lever::CompressTrace { factor: 0.5 }]) < base);
        // a speculation lever adds the draft; batching multiplies the KV
        assert!(fp(vec![Lever::Speculate { gamma: 4, alpha: 0.7 }]) > base);
        let b8 = fp(vec![Lever::Batch { streams: 8 }]);
        let kv_one = target.decoder.kv_bytes_per_token()
            * (target.shape.prefill_len() + target.shape.decode_tokens) as f64;
        assert!((b8 - base - 7.0 * kv_one).abs() < 1.0, "b8 adds exactly 7 extra KV copies");
    }

    #[test]
    fn shard_lever_footprint_and_validity() {
        use crate::engine::shard::ShardMode;
        use crate::model::molmoact::molmoact_7b;
        use crate::model::scaling::scaled_vla;
        let target = molmoact_7b();
        let draft = scaled_vla(2.0);
        let base = Scenario::baseline().memory_footprint(&target, &draft);
        // replicate-R pays for R full copies on the shared memory system
        let rep4 =
            Scenario::of(vec![Lever::Shard { mode: ShardMode::Replicate, engines: 4 }]);
        assert!((rep4.memory_footprint(&target, &draft) / base - 4.0).abs() < 1e-9);
        // a pipelined decoder partitions ONE copy: device total unchanged
        let pipe4 =
            Scenario::of(vec![Lever::Shard { mode: ShardMode::PipelineDecoder, engines: 4 }]);
        assert_eq!(pipe4.memory_footprint(&target, &draft).to_bits(), base.to_bits());
        // sharding needs no PIM hardware and composes with the other axes
        assert!(rep4.validate(&platform::orin()).is_ok());
        let stacked = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 4 },
            Lever::Shard { mode: ShardMode::PipelineDecoder, engines: 2 },
        ]);
        assert!(stacked.validate(&platform::orin()).is_ok());
        assert_eq!(stacked.name, "W4 + pipe2");
        // zero engines is structurally invalid
        let zero = Scenario::of(vec![Lever::Shard { mode: ShardMode::Replicate, engines: 0 }]);
        assert!(zero.validate(&platform::orin()).is_err());
    }

    #[test]
    fn offload_lever_validity_and_footprint() {
        // offload is valid on every platform — the cloud tier and the link
        // are lever parameters, not platform properties
        let s = Scenario::of(vec![Lever::Offload {
            mode: OffloadMode::VisionPrefillRemote,
            link: NetLink::five_g(),
        }]);
        assert!(s.validate(&platform::orin()).is_ok());
        assert!(s.validate(&platform::thor_hbm4_pim()).is_ok());
        assert_eq!(s.name, "vp@cloud(5g)");
        // ...and it composes with every other group
        let stacked = Scenario::of(vec![
            Lever::QuantizeWeights { bits: 4 },
            Lever::Offload { mode: OffloadMode::DecodeRemote, link: NetLink::wired() },
        ]);
        assert!(stacked.validate(&platform::orin()).is_ok());
        // degenerate link parameters are structurally invalid
        for bad in [
            NetLink { latency_s: -0.001, ..NetLink::five_g() },
            NetLink { latency_s: f64::NAN, ..NetLink::five_g() },
            NetLink { bw_gbps: 0.0, ..NetLink::five_g() },
            NetLink { bw_gbps: -1.0, ..NetLink::five_g() },
            NetLink { bw_gbps: f64::INFINITY, ..NetLink::five_g() },
            NetLink { usd_per_month: -5.0, ..NetLink::five_g() },
            NetLink { usd_per_month: f64::NAN, ..NetLink::five_g() },
        ] {
            let s = Scenario::of(vec![Lever::Offload {
                mode: OffloadMode::VisionPrefillRemote,
                link: bad,
            }]);
            assert!(s.validate(&platform::orin()).is_err(), "{bad:?} should be rejected");
        }
        // the edge device keeps the full model resident (fallback-local
        // operation), so placement does not change the local footprint
        use crate::model::molmoact::molmoact_7b;
        use crate::model::scaling::scaled_vla;
        let target = molmoact_7b();
        let draft = scaled_vla(2.0);
        let base = Scenario::baseline().memory_footprint(&target, &draft);
        let off = Scenario::of(vec![Lever::Offload {
            mode: OffloadMode::DecodeRemote,
            link: NetLink::wifi6(),
        }]);
        assert_eq!(off.memory_footprint(&target, &draft).to_bits(), base.to_bits());
    }

    #[test]
    fn capacity_rule_flags_oversized_models() {
        use crate::model::scaling::scaled_vla;
        let target30 = scaled_vla(30.0);
        let draft = scaled_vla(2.0);
        let base = Scenario::baseline();
        // a bf16 30B-class model (~60+ GB) cannot fit one 36 GB HBM4 stack...
        assert!(!base.fits_capacity(&platform::thor_hbm4_pim(), &target30, &draft));
        // ...but W4 packs it back under the stack's capacity
        let w4 = Scenario::of(vec![Lever::PimWeightStream { bits: 4 }]);
        assert!(w4.fits_capacity(&platform::thor_hbm4_pim(), &target30, &draft));
        // Thor's 128 GB LPDDR5X takes it uncompressed
        assert!(base.fits_capacity(&platform::thor(), &target30, &draft));
    }
}
