//! The scenario matrix: cartesian product of the lever axes under the
//! validity rules, parameterized by a [`LeverGrid`] since phase 2.
//!
//! Axes (values at a grid `g`, canonical defaults in parentheses):
//!
//! | axis       | values                                                    |
//! |------------|-----------------------------------------------------------|
//! | weight     | — · W8 · W4 · W8@PIM · W4@PIM                             |
//! | kv         | — · KV8 · KV@PIM                                          |
//! | trace      | — · one per `g.trace_factors` (0.5x)                      |
//! | spec/batch | — · spec(γ,α) per γ×α point (4×0.7) · spec@PIM(γ,α) per   |
//! |            | γ×α point · b`s` per `g.batch_streams` (b8)               |
//! | serving    | — · rep`R` · pipe`R` per `g.shard_engines` (empty)        |
//! | placement  | — · mode(link) per `g.offload_modes` × `g.offload_links`  |
//! |            | (both empty)                                              |
//!
//! Speculation and batching share one axis because they are mutually
//! exclusive (verification already batches the target pass), so the axis is
//! `{none} ∪ spec-grid ∪ pim-spec-grid ∪ batch-sizes`.
//!
//! Validity rules (enforced by [`Scenario::validate`]): the `@PIM` values
//! need a PIM device, and a PIM-resident draft claims the PIM units, so it
//! excludes the weight/KV residency values. The serving axis (shard
//! topologies, from `engine::shard`) is valid everywhere and composes with
//! everything, so it multiplies the count — and so does the placement axis
//! (edge-to-cloud offload, `Lever::Offload`), which has no validity
//! interaction with any other group. Closed form of the valid total, with
//! `T = 1 + |trace|`, `G = |γ|·|α|`, `B = |batch|`, `S = 1 + 2·|shards|`,
//! `O = 1 + |offload modes|·|links|`:
//!
//! - non-PIM platform: `3 (weights) x 2 (kv) x T x (1 + G + B) x S x O`
//! - PIM platform:     `[5 x 3 x T x (1 + G + B)`  (SoC spec/batch branch)
//!                     `+ 3 x 2 x T x G] x S x O`  (PIM-draft branch)
//!
//! At the degenerate [`LeverGrid::legacy`] (γ×α = {4}×{0.7}, trace {0.5},
//! no batch axis) this is the original 72 (PIM) / 24 (SoC) matrix, element
//! for element in the same order. [`matrix_size_grid`] is the closed form;
//! the tests pin it against the enumeration so an axis or rule change
//! cannot silently shrink coverage.

use super::{Lever, NetLink, OffloadMode, Scenario};
use crate::engine::shard::ShardMode;
use crate::hw::Platform;

/// Canonical speculation depth of the matrix (tokens drafted per round).
pub const SPEC_GAMMA: u64 = 4;
/// Canonical draft acceptance rate of the matrix.
pub const SPEC_ALPHA: f64 = 0.7;
/// Canonical trace-compression factor of the matrix.
pub const TRACE_FACTOR: f64 = 0.5;
/// Canonical batched-stream count of the phase-2 default grid.
pub const BATCH_STREAMS: u64 = 8;

/// The parameter points the lever axes expand over. Counts (not unique
/// values) drive the closed form, so duplicate points simply duplicate
/// scenarios — callers own dedup.
#[derive(Debug, Clone, PartialEq)]
pub struct LeverGrid {
    /// Speculation depths (tokens drafted per round); crossed with
    /// `spec_alphas` for both the SoC and the PIM-draft speculation values.
    pub spec_gammas: Vec<u64>,
    /// Draft acceptance rates, in (0, 1).
    pub spec_alphas: Vec<f64>,
    /// Reasoning-trace compression factors (fraction of generated tokens).
    pub trace_factors: Vec<f64>,
    /// Batched-stream counts; empty = no batch axis.
    pub batch_streams: Vec<u64>,
    /// Shard-serving engine counts; each value contributes a replicate AND
    /// a pipeline-decoder point to the serving axis. Empty = no shard axis
    /// (the pre-serving matrix, bit for bit).
    pub shard_engines: Vec<u64>,
    /// Phase-placement modes of the offload axis; crossed with
    /// `offload_links`. Either empty = no placement axis (the pre-offload
    /// matrix, bit for bit — the same discipline as the shard axis).
    pub offload_modes: Vec<OffloadMode>,
    /// Network links the offload modes are evaluated over.
    pub offload_links: Vec<NetLink>,
}

impl LeverGrid {
    /// The degenerate grid of the PR 3 fixed-point matrix: γ×α = {4}×{0.7},
    /// trace {0.5}, no batch axis. `scenario_matrix_grid(p, &legacy())` is
    /// the original 72/24 enumeration, bitwise (pinned by the tests).
    pub fn legacy() -> LeverGrid {
        LeverGrid {
            spec_gammas: vec![SPEC_GAMMA],
            spec_alphas: vec![SPEC_ALPHA],
            trace_factors: vec![TRACE_FACTOR],
            batch_streams: Vec::new(),
            shard_engines: Vec::new(),
            offload_modes: Vec::new(),
            offload_links: Vec::new(),
        }
    }

    /// The sharded default extended with the canonical placement axis:
    /// both offload modes over the three link presets (5G / WiFi-6 /
    /// wired), `O = 7` — what the `offload` experiment and the perf bench
    /// sweep: 3570 scenarios on a PIM platform, 1260 on a SoC.
    pub fn default_phase2_offload() -> LeverGrid {
        LeverGrid {
            offload_modes: OffloadMode::all(),
            offload_links: NetLink::presets(),
            ..LeverGrid::default_phase2_sharded()
        }
    }

    /// The phase-2 default: the legacy points plus a b8 batching value, so
    /// the ranked matrix carries aggregate-vs-per-stream rows by default.
    pub fn default_phase2() -> LeverGrid {
        LeverGrid { batch_streams: vec![BATCH_STREAMS], ..LeverGrid::legacy() }
    }

    /// The phase-2 default extended with the canonical serving axis
    /// (replicate/pipeline at 2 and 4 engines, `S = 5`) — the full PR 5
    /// matrix the perf bench and the incremental-vs-fresh identity tests
    /// sweep: 510 scenarios on a PIM platform, 180 on a SoC.
    pub fn default_phase2_sharded() -> LeverGrid {
        LeverGrid { shard_engines: vec![2, 4], ..LeverGrid::default_phase2() }
    }

    /// The γ×α cartesian product, γ-major (the enumeration order).
    fn spec_points(&self) -> Vec<(u64, f64)> {
        let mut v = Vec::with_capacity(self.spec_gammas.len() * self.spec_alphas.len());
        for &g in &self.spec_gammas {
            for &a in &self.spec_alphas {
                v.push((g, a));
            }
        }
        v
    }
}

fn weight_axis() -> Vec<Option<Lever>> {
    vec![
        None,
        Some(Lever::QuantizeWeights { bits: 8 }),
        Some(Lever::QuantizeWeights { bits: 4 }),
        Some(Lever::PimWeightStream { bits: 8 }),
        Some(Lever::PimWeightStream { bits: 4 }),
    ]
}

fn kv_axis() -> Vec<Option<Lever>> {
    vec![None, Some(Lever::QuantizeKv), Some(Lever::PimKvAttention)]
}

fn trace_axis(grid: &LeverGrid) -> Vec<Option<Lever>> {
    let mut v = vec![None];
    for &factor in &grid.trace_factors {
        v.push(Some(Lever::CompressTrace { factor }));
    }
    v
}

/// The shared speculation/batching axis: none, then the SoC-speculation
/// grid, then the PIM-draft grid, then the batch values — the legacy
/// `[None, Speculate, PimDraft]` order extended in place.
fn spec_batch_axis(grid: &LeverGrid) -> Vec<Option<Lever>> {
    let mut v = vec![None];
    for (gamma, alpha) in grid.spec_points() {
        v.push(Some(Lever::Speculate { gamma, alpha }));
    }
    for (gamma, alpha) in grid.spec_points() {
        v.push(Some(Lever::PimDraft { gamma, alpha }));
    }
    for &streams in &grid.batch_streams {
        v.push(Some(Lever::Batch { streams }));
    }
    v
}

/// The serving axis: none, then replicate-R, then pipeline-R per engine
/// count. Valid on every platform (sharding needs no PIM hardware), so it
/// multiplies the closed form cleanly.
fn shard_axis(grid: &LeverGrid) -> Vec<Option<Lever>> {
    let mut v = vec![None];
    for &engines in &grid.shard_engines {
        v.push(Some(Lever::Shard { mode: ShardMode::Replicate, engines }));
    }
    for &engines in &grid.shard_engines {
        v.push(Some(Lever::Shard { mode: ShardMode::PipelineDecoder, engines }));
    }
    v
}

/// The placement axis: none, then mode-major over the link grid
/// (`vp@cloud` across every link, then `dec@cloud` across every link).
/// Valid on every platform — the cloud tier and the link are lever
/// parameters, not platform properties — so it multiplies the closed form
/// like the serving axis does.
fn offload_axis(grid: &LeverGrid) -> Vec<Option<Lever>> {
    let mut v = vec![None];
    for &mode in &grid.offload_modes {
        for &link in &grid.offload_links {
            v.push(Some(Lever::Offload { mode, link }));
        }
    }
    v
}

/// Every valid scenario for `platform` at the grid's parameter points, in
/// deterministic axis order. The first entry is always the baseline (all
/// axes at `None`).
pub fn scenario_matrix_grid(platform: &Platform, grid: &LeverGrid) -> Vec<Scenario> {
    let mut out = Vec::new();
    for w in &weight_axis() {
        for k in &kv_axis() {
            for t in &trace_axis(grid) {
                for s in &spec_batch_axis(grid) {
                    for sh in &shard_axis(grid) {
                        for of in &offload_axis(grid) {
                            let levers: Vec<Lever> =
                                [w, k, t, s, sh, of].into_iter().cloned().flatten().collect();
                            let scenario = Scenario::of(levers);
                            if scenario.validate(platform).is_ok() {
                                out.push(scenario);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// The legacy fixed-point matrix: the degenerate [`LeverGrid::legacy`]
/// grid (γ=4, α=0.7, 0.5x trace, no batch axis) — the PR 3 enumeration,
/// element for element.
pub fn scenario_matrix(platform: &Platform) -> Vec<Scenario> {
    scenario_matrix_grid(platform, &LeverGrid::legacy())
}

/// Closed-form size of the valid matrix at `grid` (see the module docs for
/// the derivation). The tests assert this equals
/// `scenario_matrix_grid(p, g).len()` exactly.
pub fn matrix_size_grid(platform: &Platform, grid: &LeverGrid) -> usize {
    let t = 1 + grid.trace_factors.len();
    let g = grid.spec_gammas.len() * grid.spec_alphas.len();
    let b = grid.batch_streams.len();
    // the serving axis (none + replicate-R + pipeline-R per engine count)
    // composes with every other lever on every platform, so it multiplies
    // the whole count — and so does the placement axis (none + mode × link)
    let sh = 1 + 2 * grid.shard_engines.len();
    let o = 1 + grid.offload_modes.len() * grid.offload_links.len();
    if platform.mem.pim.is_some() {
        (5 * 3 * t * (1 + g + b) + 3 * 2 * t * g) * sh * o
    } else {
        3 * 2 * t * (1 + g + b) * sh * o
    }
}

/// Closed-form size of the legacy fixed-point matrix: 72 (PIM) / 24 (SoC).
pub fn matrix_size(platform: &Platform) -> usize {
    matrix_size_grid(platform, &LeverGrid::legacy())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;

    #[test]
    fn enumeration_matches_closed_form_everywhere() {
        for p in platform::sweep_platforms() {
            let m = scenario_matrix(&p);
            assert_eq!(m.len(), matrix_size(&p), "{}", p.name);
            let expect = if p.mem.pim.is_some() { 72 } else { 24 };
            assert_eq!(m.len(), expect, "{}", p.name);
        }
    }

    #[test]
    fn default_phase2_grid_adds_the_batch_axis() {
        for p in platform::sweep_platforms() {
            let g = LeverGrid::default_phase2();
            let m = scenario_matrix_grid(&p, &g);
            assert_eq!(m.len(), matrix_size_grid(&p, &g), "{}", p.name);
            // PIM: 5*3*2*(1+1+1) + 3*2*2*1 = 102; SoC: 3*2*2*3 = 36
            let expect = if p.mem.pim.is_some() { 102 } else { 36 };
            assert_eq!(m.len(), expect, "{}", p.name);
            // exactly weights x kv x trace batched rows appear (|batch| = 1)
            let group = super::super::LeverGroup::Batching;
            let batched = m.iter().filter(|s| s.lever(group).is_some()).count();
            let weights_kv = if p.mem.pim.is_some() { 5 * 3 } else { 3 * 2 };
            assert_eq!(batched, weights_kv * 2, "{}", p.name);
        }
    }

    #[test]
    fn grid_axes_scale_the_closed_form() {
        let grid = LeverGrid {
            spec_gammas: vec![2, 4, 8],
            spec_alphas: vec![0.5, 0.7, 0.9],
            trace_factors: vec![0.25, 0.5],
            batch_streams: vec![4, 16],
            shard_engines: Vec::new(),
            offload_modes: Vec::new(),
            offload_links: Vec::new(),
        };
        // T = 3, G = 9, B = 2
        let pim = scenario_matrix_grid(&platform::orin_pim(), &grid);
        assert_eq!(pim.len(), 5 * 3 * 3 * 12 + 3 * 2 * 3 * 9);
        assert_eq!(pim.len(), matrix_size_grid(&platform::orin_pim(), &grid));
        let soc = scenario_matrix_grid(&platform::orin(), &grid);
        assert_eq!(soc.len(), 3 * 2 * 3 * 12);
        assert_eq!(soc.len(), matrix_size_grid(&platform::orin(), &grid));
        // every grid point surfaces in at least one scenario name
        for (g, a) in [(2u64, 0.5), (8, 0.9)] {
            assert!(pim.iter().any(|s| s.name.contains(&format!("spec(g{g},a{a})"))));
            assert!(pim.iter().any(|s| s.name.contains(&format!("spec@PIM(g{g},a{a})"))));
        }
        assert!(soc.iter().any(|s| s.name.contains("b16")));
        assert!(soc.iter().any(|s| s.name.contains("0.25xCoT")));
    }

    #[test]
    fn sharded_default_grid_sizes() {
        // the canonical perf-bench grid: 510 scenarios on PIM, 180 on SoC
        let g = LeverGrid::default_phase2_sharded();
        assert_eq!(matrix_size_grid(&platform::thor_hbm4_pim(), &g), 510);
        assert_eq!(matrix_size_grid(&platform::orin(), &g), 180);
        assert_eq!(scenario_matrix_grid(&platform::thor_hbm4_pim(), &g).len(), 510);
    }

    #[test]
    fn shard_axis_multiplies_the_closed_form() {
        // |shards| = 2 -> S = 5: the serving axis composes with every other
        // lever on every platform (no validity interactions)
        let grid = LeverGrid { shard_engines: vec![2, 4], ..LeverGrid::default_phase2() };
        for p in [platform::orin(), platform::orin_pim()] {
            let m = scenario_matrix_grid(&p, &grid);
            assert_eq!(m.len(), matrix_size_grid(&p, &grid), "{}", p.name);
            let base = matrix_size_grid(&p, &LeverGrid::default_phase2());
            assert_eq!(m.len(), base * 5, "{}", p.name);
            // every shard point surfaces, replicate and pipeline alike
            for tag in ["rep2", "rep4", "pipe2", "pipe4"] {
                assert!(
                    m.iter().any(|s| s.name.split(" + ").any(|part| part == tag)),
                    "{}: `{tag}` missing from the serving axis",
                    p.name
                );
            }
        }
        // and the empty shard axis is the pre-serving matrix, bit for bit
        let legacy = scenario_matrix_grid(&platform::orin_pim(), &LeverGrid::default_phase2());
        assert_eq!(legacy.len(), 102);
    }

    #[test]
    fn offload_axis_multiplies_the_closed_form() {
        // 2 modes x 3 links -> O = 7: the placement axis composes with
        // every other lever on every platform (no validity interactions)
        let grid = LeverGrid::default_phase2_offload();
        for p in [platform::orin(), platform::orin_pim()] {
            let m = scenario_matrix_grid(&p, &grid);
            assert_eq!(m.len(), matrix_size_grid(&p, &grid), "{}", p.name);
            let base = matrix_size_grid(&p, &LeverGrid::default_phase2_sharded());
            assert_eq!(m.len(), base * 7, "{}", p.name);
            // every mode x link point surfaces as its own scenario tag
            for tag in [
                "vp@cloud(5g)",
                "vp@cloud(wifi6)",
                "vp@cloud(wired)",
                "dec@cloud(5g)",
                "dec@cloud(wifi6)",
                "dec@cloud(wired)",
            ] {
                assert!(
                    m.iter().any(|s| s.name.split(" + ").any(|part| part == tag)),
                    "{}: `{tag}` missing from the placement axis",
                    p.name
                );
            }
        }
        // the canonical offload grid sizes the perf bench pins
        assert_eq!(matrix_size_grid(&platform::thor_hbm4_pim(), &grid), 3570);
        assert_eq!(matrix_size_grid(&platform::orin(), &grid), 1260);
    }

    #[test]
    fn empty_offload_axis_is_the_pre_offload_matrix() {
        // either empty vector drops the whole axis: the enumeration must be
        // EQUAL (same scenarios, same order) to the pre-offload matrix
        let base = scenario_matrix_grid(&platform::orin_pim(), &LeverGrid::default_phase2());
        for grid in [
            LeverGrid::default_phase2(),
            LeverGrid {
                offload_modes: OffloadMode::all(),
                offload_links: Vec::new(),
                ..LeverGrid::default_phase2()
            },
            LeverGrid {
                offload_modes: Vec::new(),
                offload_links: NetLink::presets(),
                ..LeverGrid::default_phase2()
            },
        ] {
            assert_eq!(scenario_matrix_grid(&platform::orin_pim(), &grid), base);
            assert_eq!(matrix_size_grid(&platform::orin_pim(), &grid), 102);
        }
    }

    #[test]
    fn degenerate_grid_is_the_legacy_matrix() {
        for p in [platform::orin(), platform::thor_hbm4_pim()] {
            let legacy = scenario_matrix(&p);
            let degen = scenario_matrix_grid(&p, &LeverGrid::legacy());
            assert_eq!(legacy, degen, "{}: degenerate grid must BE the legacy matrix", p.name);
        }
    }

    #[test]
    fn matrix_leads_with_baseline_and_names_are_unique() {
        for grid in [LeverGrid::legacy(), LeverGrid::default_phase2()] {
            let m = scenario_matrix_grid(&platform::orin_pim(), &grid);
            assert_eq!(m[0].name, "baseline");
            let mut names: Vec<&str> = m.iter().map(|s| s.name.as_str()).collect();
            let n = names.len();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "scenario names must be unique");
        }
    }

    #[test]
    fn non_pim_matrix_has_no_pim_levers() {
        for s in scenario_matrix_grid(&platform::orin(), &LeverGrid::default_phase2()) {
            assert!(!s.requires_pim(), "{}", s.name);
        }
    }

    #[test]
    fn every_generated_scenario_validates() {
        let p = platform::thor_hbm4_pim();
        for s in scenario_matrix_grid(&p, &LeverGrid::default_phase2()) {
            assert!(s.validate(&p).is_ok(), "{}", s.name);
        }
    }
}
