//! The scenario matrix: cartesian product of the lever axes under the
//! validity rules.
//!
//! Axes (canonical parameter points):
//!
//! | axis   | values                                          |
//! |--------|-------------------------------------------------|
//! | weight | — · W8 · W4 · W8@PIM · W4@PIM                   |
//! | kv     | — · KV8 · KV@PIM                                |
//! | trace  | — · 0.5x                                        |
//! | spec   | — · spec(4, 0.7) · spec@PIM(4, 0.7)             |
//!
//! Validity rules (enforced by [`Scenario::validate`]): the `@PIM` values
//! need a PIM device, and a PIM-resident draft claims the PIM units, so it
//! excludes the weight/KV residency values. Closed form of the valid count:
//!
//! - non-PIM platform: `3 (weights) x 2 (kv) x 2 (trace) x 2 (spec)` = 24
//! - PIM platform:     `5 x 3 x 2 x 2` (SoC-draft branch)
//!                     `+ 3 x 2 x 2`   (PIM-draft branch)  = 72
//!
//! [`matrix_size`] is that closed form; the tests pin it against the
//! enumeration so an axis or rule change cannot silently shrink coverage.

use super::{Lever, Scenario};
use crate::hw::Platform;

/// Canonical speculation depth of the matrix (tokens drafted per round).
pub const SPEC_GAMMA: u64 = 4;
/// Canonical draft acceptance rate of the matrix.
pub const SPEC_ALPHA: f64 = 0.7;
/// Canonical trace-compression factor of the matrix.
pub const TRACE_FACTOR: f64 = 0.5;

fn weight_axis() -> Vec<Option<Lever>> {
    vec![
        None,
        Some(Lever::QuantizeWeights { bits: 8 }),
        Some(Lever::QuantizeWeights { bits: 4 }),
        Some(Lever::PimWeightStream { bits: 8 }),
        Some(Lever::PimWeightStream { bits: 4 }),
    ]
}

fn kv_axis() -> Vec<Option<Lever>> {
    vec![None, Some(Lever::QuantizeKv), Some(Lever::PimKvAttention)]
}

fn trace_axis() -> Vec<Option<Lever>> {
    vec![None, Some(Lever::CompressTrace { factor: TRACE_FACTOR })]
}

fn spec_axis() -> Vec<Option<Lever>> {
    vec![
        None,
        Some(Lever::Speculate { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA }),
        Some(Lever::PimDraft { gamma: SPEC_GAMMA, alpha: SPEC_ALPHA }),
    ]
}

/// Every valid scenario for `platform`, in deterministic axis order. The
/// first entry is always the baseline (all axes at `None`).
pub fn scenario_matrix(platform: &Platform) -> Vec<Scenario> {
    let mut out = Vec::new();
    for w in &weight_axis() {
        for k in &kv_axis() {
            for t in &trace_axis() {
                for s in &spec_axis() {
                    let levers: Vec<Lever> = [w, k, t, s].into_iter().cloned().flatten().collect();
                    let scenario = Scenario::of(levers);
                    if scenario.validate(platform).is_ok() {
                        out.push(scenario);
                    }
                }
            }
        }
    }
    out
}

/// Closed-form size of the valid matrix (see the module docs for the
/// derivation). The tests assert this equals `scenario_matrix(p).len()`.
pub fn matrix_size(platform: &Platform) -> usize {
    if platform.mem.pim.is_some() { 5 * 3 * 2 * 2 + 3 * 2 * 2 } else { 3 * 2 * 2 * 2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;

    #[test]
    fn enumeration_matches_closed_form_everywhere() {
        for p in platform::sweep_platforms() {
            let m = scenario_matrix(&p);
            assert_eq!(m.len(), matrix_size(&p), "{}", p.name);
            let expect = if p.mem.pim.is_some() { 72 } else { 24 };
            assert_eq!(m.len(), expect, "{}", p.name);
        }
    }

    #[test]
    fn matrix_leads_with_baseline_and_names_are_unique() {
        let m = scenario_matrix(&platform::orin_pim());
        assert_eq!(m[0].name, "baseline");
        let mut names: Vec<&str> = m.iter().map(|s| s.name.as_str()).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "scenario names must be unique");
    }

    #[test]
    fn non_pim_matrix_has_no_pim_levers() {
        for s in scenario_matrix(&platform::orin()) {
            assert!(!s.requires_pim(), "{}", s.name);
        }
    }

    #[test]
    fn every_generated_scenario_validates() {
        let p = platform::thor_hbm4_pim();
        for s in scenario_matrix(&p) {
            assert!(s.validate(&p).is_ok(), "{}", s.name);
        }
    }
}
