//! The shared lowering cache behind incremental scenario evaluation.
//!
//! A scenario grid re-visits the same roofline integrals many times over:
//! the serving (shard) axis multiplies the matrix without touching the
//! decode lowering at all, a `KV8` midpoint's "full" endpoint is bitwise
//! the same integral as the non-`KV8` scenario beside it, and the SoC and
//! PIM-draft speculation branches verify against the same batched target
//! pass. [`EvalCache`] memoizes that sharing at two levels:
//!
//! 1. **Integral cache** — whole `simulate_decode` / batched
//!    `simulate_stage` integrations (latency bounds + dynamic energy),
//!    keyed by [`IntegralKey`]: the stage shape (full decode vs a batched
//!    mid-trace step at `rows`), the lever-reachable config fields, and
//!    the lowered [`SimOptions`] fingerprint. On the full PR 5 matrix
//!    (default grid + shard axis) this collapses 690 fresh integrations
//!    to 90 distinct ones (pinned by the perf bench).
//! 2. **Decode-cost cache** — the assembled decode-phase cost of a lever
//!    stack, keyed by [`DecodeKey`]: a canonical per-group encoding of the
//!    decode-relevant levers (Weights, Kv, Trace, Speculation/Batching).
//!    The serving group is deliberately excluded — a `Shard` lever is a
//!    config/options no-op, so `W8 + rep2` and `W8 + pipe4` share one
//!    decode cost — and the per-group canonicalization makes hits
//!    order-independent across permuted stacks.
//!
//! Bitwise discipline: the caches only ever reuse *whole* computations.
//! No partial sum is ever split or re-associated, so a cache hit returns
//! the exact f64s a fresh evaluation would have produced (pinned by
//! `scenario_tests` over every platform and by a random-stack property
//! test). All maps are `Sync` — one [`EvalCache`] can be shared across
//! [`sim::sweep`](crate::sim::sweep) workers; duplicated computation under
//! races is benign because every value is deterministic.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::hw::{DType, Platform};
use crate::model::vla::{DecoderConfig, VlaConfig, WorkloadShape};
use crate::sim::roofline::PimScope;
use crate::sim::simulator::{SimOptions, VlaSimResult};

/// Fingerprint of the [`SimOptions`] fields the roofline integrals read.
/// f64 fields are keyed by their bit patterns — exact, no epsilon games.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct OptionsFp {
    prefetch: bool,
    pim: bool,
    scope: (u8, bool, bool),
    stream_dispatch: bool,
    stride: u64,
    host_dispatch_bits: u64,
    preprocess_bits: u64,
}

pub(crate) fn options_fp(o: &SimOptions) -> OptionsFp {
    // exhaustive destructuring on purpose: adding a SimOptions field is a
    // compile error here until the fingerprint covers it — the cache must
    // never alias two option sets the simulator distinguishes
    let SimOptions {
        prefetch,
        pim,
        pim_scope,
        pim_stream_dispatch,
        decode_stride,
        host_dispatch,
        preprocess_per_crop,
    } = o.clone();
    let scope = match pim_scope {
        PimScope::None => (0, false, false),
        PimScope::Auto => (1, false, false),
        PimScope::Resident { weights, kv } => (2, weights, kv),
    };
    OptionsFp {
        prefetch,
        pim,
        scope,
        stream_dispatch: pim_stream_dispatch,
        stride: decode_stride,
        host_dispatch_bits: host_dispatch.to_bits(),
        preprocess_bits: preprocess_per_crop.to_bits(),
    }
}

/// Fingerprint of the [`VlaConfig`] fields a lever stack (or the KV8
/// midpoint's halved endpoint) can reach. Within one evaluation context the
/// target is fixed, so these five fields fully determine the lowered config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct ConfigFp {
    dtype: DType,
    weight_scale_bits: u64,
    decode_tokens: u64,
    prompt_tokens: u64,
    image_tokens: u64,
}

pub(crate) fn config_fp(c: &VlaConfig) -> ConfigFp {
    // exhaustive destructuring on purpose, mirroring `options_fp`: adding
    // a field to any fingerprinted struct is a compile error here until
    // the fingerprint covers it or explicitly opts out with `_` — levers
    // must never produce two configs that alias one cache key
    let VlaConfig { name: _, towers: _, projector_hidden: _, decoder, action: _, shape } = c;
    let DecoderConfig { layers: _, dims, vocab: _, weight_scale } = decoder;
    let WorkloadShape {
        crops: _,
        patches_per_crop: _,
        image_tokens,
        prompt_tokens,
        decode_tokens,
    } = *shape;
    ConfigFp {
        dtype: dims.dtype,
        weight_scale_bits: weight_scale.to_bits(),
        decode_tokens,
        prompt_tokens,
        image_tokens,
    }
}

/// Key of one cached roofline integration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct IntegralKey {
    /// `None` = the full strided decode integration (`simulate_decode`);
    /// `Some(rows)` = one batched mid-trace decode step at `rows` rows (a
    /// speculation verify pass at `gamma + 1`, or a lockstep batch at
    /// `streams`) — both build the same stage, so they share a keyspace.
    pub rows: Option<u64>,
    pub cfg: ConfigFp,
    pub opts: OptionsFp,
}

/// One cached integration: the stage/decode latency decomposition plus its
/// dynamic energy. Raw per-integration values — multipliers (trace length,
/// round counts) are applied by the evaluator AFTER retrieval, in the same
/// expressions the fresh path uses, which is what keeps hits bitwise.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CachedIntegral {
    pub time: f64,
    pub t_compute: f64,
    pub t_memory: f64,
    pub t_overhead: f64,
    pub pim_frac: f64,
    pub energy: f64,
}

/// Canonical encoding of the decode-relevant levers of a scenario — one
/// slot per exclusivity group, so permuted stacks collide (order never
/// changes the lowering: groups touch disjoint config fields and residency
/// options union). The Serving group is excluded on purpose: shard levers
/// transform the assembled step, not the decode lowering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct DecodeKey {
    /// Weights lever: `(on_pim, bits)`.
    pub weights: Option<(bool, u32)>,
    /// Kv lever: 0 = none, 1 = KV8, 2 = KV@PIM.
    pub kv: u8,
    /// Trace lever: compression factor bit pattern.
    pub trace: Option<u64>,
    /// Speculation / batching axis.
    pub spec: SpecKey,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) enum SpecKey {
    None,
    Soc { gamma: u64, alpha_bits: u64 },
    Pim { gamma: u64, alpha_bits: u64 },
    Batch { streams: u64 },
}

/// The per-context baseline bundle the evaluator constructor integrates:
/// the four-phase baseline simulation, the shared phase energies, and the
/// ambient draft step. Shared so a second [`Evaluator`] for the same
/// context (e.g. the `pim` experiment's attribution pass) constructs for
/// the cost of a map lookup.
///
/// [`Evaluator`]: super::Evaluator
#[derive(Debug, Clone)]
pub(crate) struct BaselineBundle {
    pub base: VlaSimResult,
    pub base_total: f64,
    pub base_vision_j: f64,
    pub base_prefill_j: f64,
    pub base_action_j: f64,
    pub idle_watts: f64,
    pub draft_step: f64,
    pub draft_step_j: f64,
}

/// Identity of one evaluation context: (platform, target, draft, ambient
/// options). Names carry the identity; the structural fields guard against
/// two same-named-but-different configs ever sharing a context.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct ContextKey {
    platform: String,
    bw_bits: u64,
    capacity_bits: u64,
    target: String,
    target_fp: ConfigFp,
    draft: String,
    draft_fp: ConfigFp,
    opts: OptionsFp,
}

pub(crate) fn context_key(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
) -> ContextKey {
    ContextKey {
        platform: platform.name.clone(),
        bw_bits: platform.mem.effective_bw().to_bits(),
        capacity_bits: platform.mem.capacity.to_bits(),
        target: target.name.clone(),
        target_fp: config_fp(target),
        draft: draft.name.clone(),
        draft_fp: config_fp(draft),
        opts: options_fp(options),
    }
}

/// Per-context store: the baseline bundle, the lazily integrated
/// PIM-resident draft step, and the two memo maps.
#[derive(Debug, Default)]
pub(crate) struct ContextCache {
    pub baseline: OnceLock<BaselineBundle>,
    pub pim_draft: OnceLock<(f64, f64)>,
    pub integrals: RwLock<HashMap<IntegralKey, CachedIntegral>>,
    pub decode_costs: RwLock<HashMap<DecodeKey, CachedIntegral>>,
}

/// Counter snapshot from [`EvalCache::stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// `eval`/`eval_fresh` calls served.
    pub evals: u64,
    /// Roofline integrations the evaluations asked the integral level for.
    /// Decode-cost hits skip the ask entirely, so under incremental
    /// evaluation this undercounts what a fresh run would integrate — the
    /// perf bench measures the true fresh-vs-incremental ledger by running
    /// both strategies on separate caches and comparing their `computed`.
    pub integrals_requested: u64,
    /// Integrations actually run (cache misses + every fresh-path ask).
    pub integrals_computed: u64,
    /// Whole decode-phase costs served straight from the lever-stack cache.
    pub decode_cost_hits: u64,
    /// Baseline bundles integrated (one per distinct evaluation context).
    pub baselines_computed: u64,
    /// Distinct evaluation contexts resolved.
    pub contexts: u64,
}

impl CacheStats {
    /// Integral-level reuse: asks served per integration actually run
    /// (1.0 when nothing was ever reused).
    pub fn sim_reduction(&self) -> f64 {
        self.integrals_requested as f64 / (self.integrals_computed as f64).max(1.0)
    }
}

/// The shared lowering cache: thread-safe, `Arc`-shared across evaluators
/// and sweep workers. See the module docs for the two cache levels and the
/// bitwise-identity discipline.
#[derive(Debug, Default)]
pub struct EvalCache {
    contexts: Mutex<HashMap<ContextKey, Arc<ContextCache>>>,
    evals: AtomicU64,
    integrals_requested: AtomicU64,
    integrals_computed: AtomicU64,
    decode_cost_hits: AtomicU64,
    baselines_computed: AtomicU64,
}

impl EvalCache {
    /// A fresh shared cache.
    pub fn shared() -> Arc<EvalCache> {
        Arc::new(EvalCache::default())
    }

    /// Resolve (or create) the per-context store for `key`.
    pub(crate) fn context(&self, key: ContextKey) -> Arc<ContextCache> {
        let mut map = self.contexts.lock().expect("EvalCache context lock poisoned");
        Arc::clone(map.entry(key).or_default())
    }

    pub(crate) fn count_eval(&self) {
        self.evals.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count_decode_hit(&self) {
        self.decode_cost_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count_baseline(&self) {
        self.baselines_computed.fetch_add(1, Ordering::Relaxed);
    }

    /// Fetch-or-compute one roofline integration. `use_cache = false` (the
    /// fresh path) still counts, so `stats()` reports exactly how many
    /// integrations each strategy ran.
    pub(crate) fn integral<F: FnOnce() -> CachedIntegral>(
        &self,
        ctx: &ContextCache,
        use_cache: bool,
        key: IntegralKey,
        compute: F,
    ) -> CachedIntegral {
        self.integrals_requested.fetch_add(1, Ordering::Relaxed);
        if use_cache {
            let map = ctx.integrals.read().expect("integral cache lock poisoned");
            if let Some(v) = map.get(&key) {
                return *v;
            }
        }
        // compute outside the lock: a concurrent duplicate is benign (the
        // value is deterministic) and the counter reflects the real work
        let v = compute();
        self.integrals_computed.fetch_add(1, Ordering::Relaxed);
        if use_cache {
            ctx.integrals.write().expect("integral cache lock poisoned").insert(key, v);
        }
        v
    }

    /// Current counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            evals: self.evals.load(Ordering::Relaxed),
            integrals_requested: self.integrals_requested.load(Ordering::Relaxed),
            integrals_computed: self.integrals_computed.load(Ordering::Relaxed),
            decode_cost_hits: self.decode_cost_hits.load(Ordering::Relaxed),
            baselines_computed: self.baselines_computed.load(Ordering::Relaxed),
            contexts: self.contexts.lock().expect("EvalCache context lock poisoned").len()
                as u64,
        }
    }

    /// Current counters as a telemetry `cache` event (the preamble record
    /// the fleet experiments stamp before `run_start`).
    pub fn snapshot_event(&self, t: f64, label: &str) -> crate::telemetry::Event {
        crate::telemetry::Event::cache(t, label, self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;
    use crate::model::scaling::scaled_vla;

    #[test]
    fn options_fp_distinguishes_residency_and_stride() {
        let base = SimOptions { decode_stride: 32, pim: false, ..Default::default() };
        let mut resident = base.clone();
        resident.enable_pim_residency(true, false);
        assert_ne!(options_fp(&base), options_fp(&resident));
        let strided = SimOptions { decode_stride: 16, ..base.clone() };
        assert_ne!(options_fp(&base), options_fp(&strided));
        assert_eq!(options_fp(&base), options_fp(&base.clone()));
    }

    #[test]
    fn config_fp_tracks_lever_reachable_fields() {
        use super::super::{quantize_weights, Lever};
        let cfg = molmoact_7b();
        assert_eq!(config_fp(&cfg), config_fp(&cfg.clone()));
        assert_ne!(config_fp(&cfg), config_fp(&quantize_weights(&cfg, 8)));
        assert_ne!(config_fp(&quantize_weights(&cfg, 8)), config_fp(&quantize_weights(&cfg, 4)));
        let mut traced = cfg.clone();
        Lever::CompressTrace { factor: 0.5 }.apply_config(&mut traced);
        assert_ne!(config_fp(&cfg), config_fp(&traced));
        // the KV8 midpoint's halved endpoint is a distinct integral
        let mut short = cfg.clone();
        short.shape.prompt_tokens /= 2;
        short.shape.image_tokens /= 2;
        assert_ne!(config_fp(&cfg), config_fp(&short));
    }

    #[test]
    fn integral_counters_track_hits_and_misses() {
        let cache = EvalCache::shared();
        let opts = SimOptions::default();
        let ctx = cache.context(context_key(
            &platform::orin(),
            &opts,
            &molmoact_7b(),
            &scaled_vla(2.0),
        ));
        let key =
            IntegralKey { rows: None, cfg: config_fp(&molmoact_7b()), opts: options_fp(&opts) };
        let val = CachedIntegral {
            time: 1.0,
            t_compute: 0.2,
            t_memory: 0.8,
            t_overhead: 0.1,
            pim_frac: 0.0,
            energy: 3.0,
        };
        let a = cache.integral(&ctx, true, key, || val);
        let b = cache.integral(&ctx, true, key, || panic!("must hit the cache"));
        assert_eq!(a.time.to_bits(), b.time.to_bits());
        let s = cache.stats();
        assert_eq!((s.integrals_requested, s.integrals_computed), (2, 1));
        assert_eq!(s.sim_reduction(), 2.0);
        // the fresh path recomputes and counts, but never populates
        cache.integral(&ctx, false, key, || val);
        let s2 = cache.stats();
        assert_eq!((s2.integrals_requested, s2.integrals_computed), (3, 2));
    }

    #[test]
    fn contexts_are_shared_by_identity() {
        let cache = EvalCache::shared();
        let opts = SimOptions::default();
        let k =
            || context_key(&platform::orin(), &opts, &molmoact_7b(), &scaled_vla(2.0));
        let a = cache.context(k());
        let b = cache.context(k());
        assert!(Arc::ptr_eq(&a, &b), "same context key must share the store");
        let other = cache.context(context_key(
            &platform::thor(),
            &opts,
            &molmoact_7b(),
            &scaled_vla(2.0),
        ));
        assert!(!Arc::ptr_eq(&a, &other));
        assert_eq!(cache.stats().contexts, 2);
    }
}
