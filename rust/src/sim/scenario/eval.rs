//! Scenario evaluation: lower a lever stack to a transformed config +
//! options + decode-cost override and integrate it on the existing
//! [`Simulator`].
//!
//! Only the decode phase is overridden — vision, prefill, and the action
//! head come from ONE baseline simulation per (platform, model) pair, which
//! is both the original `codesign_study` semantic (levers attack the
//! bottleneck phase) and what keeps the refactored codesign numbers
//! bitwise-identical to the pre-scenario implementation: the baseline
//! phases are pure functions of (platform, options, model), and the total
//! is summed in the same association order.

use super::{Lever, LeverGroup, Scenario};
use crate::hw::Platform;
use crate::model::vla::VlaConfig;
use crate::sim::roofline::Bound;
use crate::sim::simulator::{SimOptions, Simulator, StageResult, VlaSimResult};

/// Decode-phase cost under a scenario, with enough structure to classify it.
#[derive(Debug, Clone, Copy)]
struct DecodeCost {
    time: f64,
    t_compute: f64,
    t_memory: f64,
    t_overhead: f64,
    pim_frac: f64,
}

impl DecodeCost {
    fn from_stage(r: &StageResult) -> DecodeCost {
        DecodeCost {
            time: r.time,
            t_compute: r.t_compute_bound,
            t_memory: r.t_memory_bound,
            t_overhead: r.t_overhead_bound,
            pim_frac: r.pim_time_frac,
        }
    }

    fn bound(&self) -> Bound {
        if self.t_overhead > self.t_compute.max(self.t_memory) {
            Bound::Overhead
        } else if self.t_compute >= self.t_memory {
            Bound::Compute
        } else {
            Bound::Memory
        }
    }
}

/// One evaluated scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: String,
    pub platform: String,
    pub model: String,
    /// Decode-phase time under the scenario (s).
    pub decode_time: f64,
    /// Full control-step latency (baseline phases + overridden decode).
    pub step_latency: f64,
    /// Projected control-loop frequency (one action chunk per step).
    pub control_hz: f64,
    /// Horizon-amortized actions/s.
    pub amortized_hz: f64,
    pub speedup_vs_baseline: f64,
    /// What bounds the (possibly transformed) decode phase.
    pub bound: Bound,
    /// Fraction of decode time spent on the PIM units.
    pub pim_util: f64,
}

/// Speculative decoding on the SoC: the draft proposes `gamma` tokens per
/// round, the target verifies them in one batched pass at mid-trace KV
/// length; expected accepted tokens per round is
/// `E = (1 - alpha^(gamma+1)) / (1 - alpha)`. Returns the projected decode
/// time for the full trace plus the verify-stage result (for
/// classification). This is the canonical formula `sim::codesign` has
/// always used — `codesign::speculative_decode_time` delegates here.
pub fn speculative_decode(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> (f64, StageResult) {
    let rounds = expected_rounds(target.shape.decode_tokens, gamma, alpha);
    let draft_step = draft_step_time(platform, options, draft);
    let verify_r = verify_pass(platform, options, target, gamma);
    let verify = verify_r.time;
    (rounds * (gamma as f64 * draft_step + verify), verify_r)
}

/// Expected verification rounds to emit `n_tokens`:
/// `n / E` with `E = (1 - alpha^(gamma+1)) / (1 - alpha)`.
fn expected_rounds(n_tokens: u64, gamma: u64, alpha: f64) -> f64 {
    let expected_accept = (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha).max(1e-9);
    n_tokens as f64 / expected_accept
}

/// Per-token draft decode time under `options` (the draft runs gamma
/// sequential single-token steps per round).
fn draft_step_time(platform: &Platform, options: &SimOptions, draft: &VlaConfig) -> f64 {
    Simulator::with_options(platform.clone(), options.clone()).simulate_decode(draft).time
        / draft.shape.decode_tokens as f64
}

/// The target's batched verification of gamma+1 tokens at mid-trace KV len.
fn verify_pass(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    gamma: u64,
) -> StageResult {
    let kv_mid = target.shape.prefill_len() + target.shape.decode_tokens / 2;
    Simulator::with_options(platform.clone(), options.clone())
        .simulate_stage(&target.decode_stage_batched(kv_mid, gamma + 1))
}

/// Draft-model-on-PIM speculation: the draft decodes its `gamma` proposals
/// on the PIM units (full residency, controller-issued command streams)
/// while the SoC verifies the PREVIOUS round's proposal — the two engines
/// pipeline, so a steady-state round costs `max(draft, verify)` instead of
/// their sum, plus one un-overlapped fill term. Returns
/// `(time, pim_busy_fraction, verify_stage)`; `None` without PIM hardware.
pub fn pim_speculative_decode(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> Option<(f64, f64, StageResult)> {
    platform.mem.pim.as_ref()?;
    let draft_step = pim_draft_step_time(platform, options, draft);
    let verify_r = verify_pass(platform, options, target, gamma);
    let (time, pim_frac) =
        pim_spec_combine(target.shape.decode_tokens, gamma, alpha, draft_step, verify_r.time);
    Some((time, pim_frac, verify_r))
}

/// Per-token draft decode time with the draft fully PIM-resident.
fn pim_draft_step_time(platform: &Platform, options: &SimOptions, draft: &VlaConfig) -> f64 {
    let mut draft_options = options.clone();
    draft_options.enable_pim_residency(true, true);
    draft_step_time(platform, &draft_options, draft)
}

/// Steady-state pipelining of a PIM draft against SoC verification: a round
/// costs `max(draft, verify)` plus one un-overlapped fill term.
fn pim_spec_combine(
    n_tokens: u64,
    gamma: u64,
    alpha: f64,
    draft_step: f64,
    verify: f64,
) -> (f64, f64) {
    let rounds = expected_rounds(n_tokens, gamma, alpha);
    let d = gamma as f64 * draft_step;
    let round = d.max(verify);
    let time = rounds * round + d.min(verify); // pipeline fill
    let pim_frac = (rounds * d / time.max(1e-30)).min(1.0);
    (time, pim_frac)
}

/// Evaluates scenarios against one (platform, options, target, draft)
/// context; the baseline step is simulated once at construction.
#[derive(Debug, Clone)]
pub struct Evaluator {
    platform: Platform,
    options: SimOptions,
    target: VlaConfig,
    draft: VlaConfig,
    base: VlaSimResult,
    base_total: f64,
    /// Ambient-path draft decode time per token — invariant across levers
    /// (it depends only on platform, ambient options, and the draft), so it
    /// is integrated once here instead of once per speculative scenario.
    draft_step: f64,
    /// PIM-resident draft decode time per token, integrated on first use
    /// (codesign's classic study never needs it, the matrix's PimDraft
    /// scenarios share one integration).
    draft_step_pim: std::sync::OnceLock<f64>,
}

impl Evaluator {
    pub fn new(
        platform: &Platform,
        options: &SimOptions,
        target: &VlaConfig,
        draft: &VlaConfig,
    ) -> Evaluator {
        let sim = Simulator::with_options(platform.clone(), options.clone());
        let base = sim.simulate_vla(target);
        let base_total = base.vision.time + base.prefill.time + base.decode.time + base.action.time;
        let draft_step = draft_step_time(platform, options, draft);
        Evaluator {
            platform: platform.clone(),
            options: options.clone(),
            target: target.clone(),
            draft: draft.clone(),
            base,
            base_total,
            draft_step,
            draft_step_pim: std::sync::OnceLock::new(),
        }
    }

    /// Lazily integrated PIM-resident draft step (see `draft_step_pim`).
    fn pim_draft_step(&self) -> f64 {
        *self
            .draft_step_pim
            .get_or_init(|| pim_draft_step_time(&self.platform, &self.options, &self.draft))
    }

    /// Baseline (empty-scenario) step latency.
    pub fn baseline_total(&self) -> f64 {
        self.base_total
    }

    /// Lower `scenario` and evaluate it: transformed config + options, the
    /// decode-cost override, baseline phases for the rest of the step.
    pub fn eval(&self, scenario: &Scenario) -> anyhow::Result<ScenarioResult> {
        scenario.validate(&self.platform)?;
        let mut cfg = self.target.clone();
        let mut options = self.options.clone();
        for lever in &scenario.levers {
            lever.apply_config(&mut cfg);
        }
        for lever in &scenario.levers {
            lever.apply_options(&mut options);
        }
        let dc = self.decode_cost(scenario, &cfg, &options);
        let total =
            self.base.vision.time + self.base.prefill.time + dc.time + self.base.action.time;
        Ok(ScenarioResult {
            scenario: scenario.name.clone(),
            platform: self.platform.name.clone(),
            model: self.target.name.clone(),
            decode_time: dc.time,
            step_latency: total,
            control_hz: 1.0 / total,
            amortized_hz: self.target.action.horizon as f64 / total,
            speedup_vs_baseline: self.base_total / total,
            bound: dc.bound(),
            pim_util: dc.pim_frac,
        })
    }

    /// Decode-phase cost of the lowered scenario. The speculation lever
    /// replaces the decode integration; the KV8 lever wraps whichever model
    /// is active in the original midpoint approximation (halved prompt and
    /// image tokens as the reduced-traffic endpoint).
    fn decode_cost(
        &self,
        scenario: &Scenario,
        cfg: &VlaConfig,
        options: &SimOptions,
    ) -> DecodeCost {
        let model = |c: &VlaConfig| -> DecodeCost {
            match scenario.lever(LeverGroup::Speculation) {
                Some(Lever::Speculate { gamma, alpha }) => {
                    self.spec_cost(c, options, *gamma, *alpha, false)
                }
                Some(Lever::PimDraft { gamma, alpha }) => {
                    self.spec_cost(c, options, *gamma, *alpha, true)
                }
                _ => match scenario.lever(LeverGroup::Batching) {
                    Some(Lever::Batch { streams }) => self.batched_cost(c, options, *streams),
                    _ => self.direct_cost(c, options),
                },
            }
        };
        if matches!(scenario.lever(LeverGroup::Kv), Some(Lever::QuantizeKv)) {
            let full = model(cfg);
            let mut short = cfg.clone();
            short.shape.prompt_tokens /= 2;
            short.shape.image_tokens /= 2; // halves the kv_len trajectory
            let less_kv = model(&short);
            // kv traffic is the delta driver; midpoint is the KV8 estimate
            DecodeCost { time: (full.time + less_kv.time) / 2.0, ..full }
        } else {
            model(cfg)
        }
    }

    /// The plain decode integration of the transformed config.
    fn direct_cost(&self, cfg: &VlaConfig, options: &SimOptions) -> DecodeCost {
        let sim = Simulator::with_options(self.platform.clone(), options.clone());
        DecodeCost::from_stage(&sim.simulate_decode(cfg))
    }

    /// Speculative decode cost, with the draft on the SoC or on PIM. The
    /// draft steps come from the per-evaluator caches: the SoC draft runs
    /// on the AMBIENT options — a weights/KV-resident target does not lend
    /// the draft its PIM units (PimDraft is the lever that claims them) —
    /// while only the target's verification pass sees the lowered options.
    fn spec_cost(
        &self,
        cfg: &VlaConfig,
        options: &SimOptions,
        gamma: u64,
        alpha: f64,
        draft_on_pim: bool,
    ) -> DecodeCost {
        let verify_r = verify_pass(&self.platform, options, cfg, gamma);
        if draft_on_pim {
            let draft_step = self.pim_draft_step();
            let (time, pim_frac) =
                pim_spec_combine(cfg.shape.decode_tokens, gamma, alpha, draft_step, verify_r.time);
            DecodeCost { time, pim_frac, ..DecodeCost::from_stage(&verify_r) }
        } else {
            let rounds = expected_rounds(cfg.shape.decode_tokens, gamma, alpha);
            let time = rounds * (gamma as f64 * self.draft_step + verify_r.time);
            DecodeCost { time, ..DecodeCost::from_stage(&verify_r) }
        }
    }

    /// Lockstep multi-robot decode: every stream advances one token per
    /// batched step, so per-stream decode time is the mid-trace batched
    /// step cost times the trace length.
    fn batched_cost(&self, cfg: &VlaConfig, options: &SimOptions, streams: u64) -> DecodeCost {
        let kv_mid = cfg.shape.prefill_len() + cfg.shape.decode_tokens / 2;
        let r = Simulator::with_options(self.platform.clone(), options.clone())
            .simulate_stage(&cfg.decode_stage_batched(kv_mid, streams.max(1)));
        DecodeCost {
            time: r.time * cfg.shape.decode_tokens as f64,
            ..DecodeCost::from_stage(&r)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;
    use crate::model::scaling::scaled_vla;

    fn opts() -> SimOptions {
        SimOptions { decode_stride: 32, pim: false, ..Default::default() }
    }

    fn evaluator(p: &Platform) -> Evaluator {
        Evaluator::new(p, &opts(), &molmoact_7b(), &scaled_vla(2.0))
    }

    #[test]
    fn baseline_scenario_is_identity() {
        let ev = evaluator(&platform::orin());
        let r = ev.eval(&Scenario::baseline()).unwrap();
        assert_eq!(r.step_latency.to_bits(), ev.baseline_total().to_bits());
        assert_eq!(r.speedup_vs_baseline, 1.0);
        assert_eq!(r.bound, Bound::Memory);
        assert_eq!(r.pim_util, 0.0);
    }

    #[test]
    fn quantization_speeds_up_decode_proportionally() {
        let ev = evaluator(&platform::orin());
        let w8 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
        let w4 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 4 }])).unwrap();
        assert!(w8.speedup_vs_baseline > 1.3);
        assert!(w4.decode_time < w8.decode_time, "W4 must stream less than W8");
        assert!(w4.speedup_vs_baseline > w8.speedup_vs_baseline);
    }

    #[test]
    fn pim_residency_rejected_without_pim() {
        let ev = evaluator(&platform::thor());
        assert!(ev.eval(&Scenario::of(vec![Lever::PimWeightStream { bits: 8 }])).is_err());
    }

    #[test]
    fn weight_residency_beats_offchip_quantization() {
        for p in [platform::orin_pim(), platform::thor_pim(), platform::thor_hbm4_pim()] {
            let ev = evaluator(&p);
            let soc = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
            let pim = ev.eval(&Scenario::of(vec![Lever::PimWeightStream { bits: 8 }])).unwrap();
            assert!(
                pim.control_hz > soc.control_hz,
                "{}: W8@PIM {} Hz <= W8 {} Hz",
                p.name,
                pim.control_hz,
                soc.control_hz
            );
            assert!(pim.pim_util > 0.1, "{}: PIM should carry the weight stream", p.name);
        }
    }

    #[test]
    fn pim_draft_pipelines_ahead_of_soc_speculation() {
        let ev = evaluator(&platform::orin_pim());
        let soc = ev.eval(&Scenario::of(vec![Lever::Speculate { gamma: 4, alpha: 0.7 }])).unwrap();
        let pim = ev.eval(&Scenario::of(vec![Lever::PimDraft { gamma: 4, alpha: 0.7 }])).unwrap();
        assert!(pim.control_hz > soc.control_hz);
        assert!(pim.pim_util > 0.0);
    }

    #[test]
    fn soc_draft_does_not_inherit_target_residency() {
        // regression: in `W8@PIM + spec` the draft must be costed on the
        // ambient SoC path, not with the target's PIM-residency options
        let p = platform::orin_pim();
        let ambient = opts();
        let mut resident = ambient.clone();
        resident.enable_pim_residency(true, false);
        let target = molmoact_7b();
        let draft = scaled_vla(2.0);
        let ambient_step = draft_step_time(&p, &ambient, &draft);
        let resident_step = draft_step_time(&p, &resident, &draft);
        assert!(ambient_step > resident_step, "residency must matter for this to be a test");
        // the evaluator's combo: ambient draft + resident verify of the
        // quantized target, assembled exactly like speculative_decode
        let ev = Evaluator::new(&p, &ambient, &target, &draft);
        let combo = ev
            .eval(&Scenario::of(vec![
                Lever::PimWeightStream { bits: 8 },
                Lever::Speculate { gamma: 4, alpha: 0.7 },
            ]))
            .unwrap();
        let cfg8 = super::super::quantize_weights(&target, 8);
        let rounds = expected_rounds(cfg8.shape.decode_tokens, 4, 0.7);
        let verify = verify_pass(&p, &resident, &cfg8, 4).time;
        let want = rounds * (4.0 * ambient_step + verify);
        assert_eq!(combo.decode_time.to_bits(), want.to_bits());
    }

    #[test]
    fn batched_scenario_reports_per_stream_latency() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let b8 = ev.eval(&Scenario::of(vec![Lever::Batch { streams: 8 }])).unwrap();
        // batching never improves per-stream control latency at the edge
        assert!(b8.step_latency >= base.step_latency * 0.95);
    }
}
