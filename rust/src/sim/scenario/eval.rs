//! Scenario evaluation: lower a lever stack to a transformed config +
//! options + decode-cost override and integrate it on the existing
//! [`Simulator`].
//!
//! Only the decode phase is overridden — vision, prefill, and the action
//! head come from ONE baseline simulation per (platform, model) pair, which
//! is both the original `codesign_study` semantic (levers attack the
//! bottleneck phase) and what keeps the refactored codesign numbers
//! bitwise-identical to the pre-scenario implementation: the baseline
//! phases are pure functions of (platform, options, model), and the total
//! is summed in the same association order.
//!
//! Phase 2: every evaluation also integrates the [`sim::energy`] model
//! (same operator placement as the latency path) and applies the
//! capacity-validity rule, so a [`ScenarioResult`] carries J/action, avg-W,
//! aggregate-vs-per-stream rates, and a `fits_capacity` flag alongside the
//! latency projection — the inputs of the Hz-vs-J/action [`pareto_front`].
//!
//! [`sim::energy`]: crate::sim::energy

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::sync::Arc;

use super::cache::{
    config_fp, context_key, options_fp, BaselineBundle, CachedIntegral, ContextCache, DecodeKey,
    IntegralKey, SpecKey,
};
use super::lever::expected_accepted;
use super::{EvalCache, Lever, LeverGroup, NetLink, OffloadMode, Scenario};
use crate::engine::shard::{link_demand_bw, ShardMode, ShardModel};
use crate::hw::Platform;
use crate::model::vla::VlaConfig;
use crate::sim::energy;
use crate::sim::roofline::Bound;
use crate::sim::simulator::{SimOptions, Simulator, StageResult, VlaSimResult};
use crate::util::units::GB;

/// Decode-phase cost under a scenario, with enough structure to classify it.
#[derive(Debug, Clone, Copy)]
struct DecodeCost {
    time: f64,
    t_compute: f64,
    t_memory: f64,
    t_overhead: f64,
    pim_frac: f64,
    /// Dynamic energy of the (possibly transformed) decode phase (J).
    energy: f64,
}

impl DecodeCost {
    fn from_stage(r: &StageResult) -> DecodeCost {
        DecodeCost {
            time: r.time,
            t_compute: r.t_compute_bound,
            t_memory: r.t_memory_bound,
            t_overhead: r.t_overhead_bound,
            pim_frac: r.pim_time_frac,
            energy: 0.0,
        }
    }

    fn from_cached(c: CachedIntegral) -> DecodeCost {
        DecodeCost {
            time: c.time,
            t_compute: c.t_compute,
            t_memory: c.t_memory,
            t_overhead: c.t_overhead,
            pim_frac: c.pim_frac,
            energy: c.energy,
        }
    }

    fn to_cached(self) -> CachedIntegral {
        CachedIntegral {
            time: self.time,
            t_compute: self.t_compute,
            t_memory: self.t_memory,
            t_overhead: self.t_overhead,
            pim_frac: self.pim_frac,
            energy: self.energy,
        }
    }

    fn bound(&self) -> Bound {
        if self.t_overhead > self.t_compute.max(self.t_memory) {
            Bound::Overhead
        } else if self.t_compute >= self.t_memory {
            Bound::Compute
        } else {
            Bound::Memory
        }
    }
}

/// One evaluated scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: String,
    pub platform: String,
    pub model: String,
    /// Decode-phase time under the scenario (s).
    pub decode_time: f64,
    /// Full control-step latency: baseline phases + overridden decode.
    /// Batched scenarios replicate the vision/prefill/action phases per
    /// stream (each robot brings its own frame); only decode is shared.
    pub step_latency: f64,
    /// Projected control-loop frequency (one action chunk per step;
    /// per-stream for batched scenarios).
    pub control_hz: f64,
    /// Horizon-amortized actions/s (per-stream for batched scenarios).
    pub amortized_hz: f64,
    pub speedup_vs_baseline: f64,
    /// What bounds the (possibly transformed) decode phase.
    pub bound: Bound,
    /// Fraction of decode time spent on the PIM units.
    pub pim_util: f64,
    /// Lockstep streams served (1 unless a batching lever is stacked).
    pub streams: u64,
    /// Serving engines (1 unless a shard lever is stacked).
    pub engines: u64,
    /// Aggregate actions/s across all streams and engines (==
    /// `amortized_hz` at b1 on one engine).
    pub aggregate_hz: f64,
    /// Energy per control step, dynamic + static, all streams AND engines
    /// (J) — deployment-level, like `aggregate_hz` and the footprint:
    /// replicate shards scale it by their engine count.
    pub total_j: f64,
    /// Energy per emitted action (J): `total_j` over the actions the
    /// deployment emits per step window (replicate multiplies both, so
    /// this is topology-invariant).
    pub j_per_action: f64,
    /// Average power draw of the whole deployment over the step (W).
    pub avg_watts: f64,
    /// Per-frame network time on the offload link (s): two latency
    /// crossings plus the activation/KV transfer at link bandwidth,
    /// per stream. Exactly 0 for all-local (placement-free) scenarios.
    pub link_s: f64,
    /// Amortized link cost per emitted action (USD): the link's monthly
    /// price prorated over each step window, split across the actions the
    /// deployment emits in it. Exactly 0 for all-local scenarios.
    pub usd_per_action: f64,
    /// Lowered weights + KV (+ draft) footprint (GB).
    pub footprint_gb: f64,
    /// The platform's memory capacity (GB).
    pub capacity_gb: f64,
    /// Capacity-validity: does the lowered scenario fit the device? Invalid
    /// rows are REPORTED with this flag false, never dropped.
    pub fits_capacity: bool,
}

/// Indices of the Pareto-optimal points among `points`, where `.0` is
/// maximized (a rate: control Hz, aggregate actions/s) and `.1` is
/// minimized (a cost: J/action). A point is on the front iff no other
/// point is at least as good on both axes and strictly better on one.
/// O(n^2), deterministic, input order preserved; duplicate points are
/// mutually non-dominating, so both stay on the front.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let dominates = |a: (f64, f64), b: (f64, f64)| -> bool {
        a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
    };
    let mut front = Vec::new();
    for (i, &pt) in points.iter().enumerate() {
        if !points.iter().enumerate().any(|(j, &p)| j != i && dominates(p, pt)) {
            front.push(i);
        }
    }
    front
}

/// Indices of the Pareto-optimal points among three-objective points,
/// where `.0` is maximized (a rate: aggregate actions/s) and `.1`, `.2`
/// are minimized (costs: J/action, $/action). Same contract as
/// [`pareto_front`]: O(n^2), deterministic, input order preserved,
/// duplicates mutually non-dominating. When every `.2` is equal (e.g. an
/// all-local matrix, where $/action is identically 0) the front
/// degenerates to the two-objective [`pareto_front`] of `(.0, .1)` —
/// pinned by the property tests.
pub fn pareto_front3(points: &[(f64, f64, f64)]) -> Vec<usize> {
    let dominates = |a: (f64, f64, f64), b: (f64, f64, f64)| -> bool {
        a.0 >= b.0 && a.1 <= b.1 && a.2 <= b.2 && (a.0 > b.0 || a.1 < b.1 || a.2 < b.2)
    };
    let mut front = Vec::new();
    for (i, &pt) in points.iter().enumerate() {
        if !points.iter().enumerate().any(|(j, &p)| j != i && dominates(p, pt)) {
            front.push(i);
        }
    }
    front
}

/// Speculative decoding on the SoC: the draft proposes `gamma` tokens per
/// round, the target verifies them in one batched pass at mid-trace KV
/// length; expected accepted tokens per round is
/// `E = (1 - alpha^(gamma+1)) / (1 - alpha)`. Returns the projected decode
/// time for the full trace plus the verify-stage result (for
/// classification). This is the canonical formula `sim::codesign` has
/// always used — `codesign::speculative_decode_time` delegates here.
pub fn speculative_decode(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> (f64, StageResult) {
    let rounds = expected_rounds(target.shape.decode_tokens, gamma, alpha);
    let draft_step = draft_step_time(platform, options, draft);
    let verify_r = verify_pass(platform, options, target, gamma);
    let verify = verify_r.time;
    (rounds * (gamma as f64 * draft_step + verify), verify_r)
}

/// Expected verification rounds to emit `n_tokens`:
/// `n / E` with `E = (1 - alpha^(gamma+1)) / (1 - alpha)`.
fn expected_rounds(n_tokens: u64, gamma: u64, alpha: f64) -> f64 {
    n_tokens as f64 / expected_accepted(gamma, alpha)
}

/// Per-token draft decode time under `options` (the draft runs gamma
/// sequential single-token steps per round).
fn draft_step_time(platform: &Platform, options: &SimOptions, draft: &VlaConfig) -> f64 {
    Simulator::with_options(platform.clone(), options.clone()).simulate_decode(draft).time
        / draft.shape.decode_tokens as f64
}

/// The target's batched verification of gamma+1 tokens at mid-trace KV len.
fn verify_pass(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    gamma: u64,
) -> StageResult {
    let kv_mid = target.shape.prefill_len() + target.shape.decode_tokens / 2;
    Simulator::with_options(platform.clone(), options.clone())
        .simulate_stage(&target.decode_stage_batched(kv_mid, gamma + 1))
}

/// Draft-model-on-PIM speculation: the draft decodes its `gamma` proposals
/// on the PIM units (full residency, controller-issued command streams)
/// while the SoC verifies the PREVIOUS round's proposal — the two engines
/// pipeline, so a steady-state round costs `max(draft, verify)` instead of
/// their sum, plus one un-overlapped fill term. Returns
/// `(time, pim_busy_fraction, verify_stage)`; `None` without PIM hardware.
pub fn pim_speculative_decode(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> Option<(f64, f64, StageResult)> {
    platform.mem.pim.as_ref()?;
    let draft_step = pim_draft_step_time(platform, options, draft);
    let verify_r = verify_pass(platform, options, target, gamma);
    let (time, pim_frac) =
        pim_spec_combine(target.shape.decode_tokens, gamma, alpha, draft_step, verify_r.time);
    Some((time, pim_frac, verify_r))
}

/// Per-token draft decode time with the draft fully PIM-resident.
fn pim_draft_step_time(platform: &Platform, options: &SimOptions, draft: &VlaConfig) -> f64 {
    let mut draft_options = options.clone();
    draft_options.enable_pim_residency(true, true);
    draft_step_time(platform, &draft_options, draft)
}

/// Steady-state pipelining of a PIM draft against SoC verification: a round
/// costs `max(draft, verify)` plus one un-overlapped fill term.
fn pim_spec_combine(
    n_tokens: u64,
    gamma: u64,
    alpha: f64,
    draft_step: f64,
    verify: f64,
) -> (f64, f64) {
    let rounds = expected_rounds(n_tokens, gamma, alpha);
    let d = gamma as f64 * draft_step;
    let round = d.max(verify);
    let time = rounds * round + d.min(verify); // pipeline fill
    let pim_frac = (rounds * d / time.max(1e-30)).min(1.0);
    (time, pim_frac)
}

/// Evaluates scenarios against one (platform, options, target, draft)
/// context; the baseline step (latency AND phase energies) is simulated
/// once per context. Since the incremental-evaluation PR every evaluator
/// carries a shared [`EvalCache`] — [`Evaluator::new`] owns a private one
/// (so even a lone evaluator reuses integrals across its grid), and
/// [`Evaluator::with_cache`] threads one cache through many evaluators
/// and [`sim::sweep`](crate::sim::sweep) workers. [`Evaluator::eval_fresh`]
/// bypasses the scenario-level caches and is the pre-cache evaluation
/// path, bit for bit — the identity `eval == eval_fresh` is pinned by the
/// test suites over the full matrix.
#[derive(Debug, Clone)]
pub struct Evaluator {
    platform: Platform,
    options: SimOptions,
    target: VlaConfig,
    draft: VlaConfig,
    base: VlaSimResult,
    base_total: f64,
    /// Dynamic energy of the baseline vision / prefill / action phases (J)
    /// — like the latency phases, shared by every scenario of the matrix.
    base_vision_j: f64,
    base_prefill_j: f64,
    base_action_j: f64,
    /// Static platform power charged over each scenario's step latency (W).
    idle_watts: f64,
    /// Ambient-path draft decode time per token — invariant across levers
    /// (it depends only on platform, ambient options, and the draft), so it
    /// is integrated once per context instead of once per speculative
    /// scenario.
    draft_step: f64,
    /// Ambient-path draft decode energy per token (J).
    draft_step_j: f64,
    /// The shared lowering cache and this evaluator's resolved context
    /// store within it (integrals, decode costs, the lazy PIM draft step).
    cache: Arc<EvalCache>,
    ctx: Arc<ContextCache>,
}

impl Evaluator {
    /// Build an evaluator with a private cache — integrals are still
    /// reused across every scenario this evaluator sees.
    pub fn new(
        platform: &Platform,
        options: &SimOptions,
        target: &VlaConfig,
        draft: &VlaConfig,
    ) -> Evaluator {
        Evaluator::with_cache(platform, options, target, draft, &EvalCache::shared())
    }

    /// Build an evaluator on a shared [`EvalCache`]: evaluators of the
    /// same (platform, options, target, draft) context share baseline
    /// integrations and every memoized lowering; distinct contexts coexist
    /// in one cache. Safe to call from parallel sweep workers.
    pub fn with_cache(
        platform: &Platform,
        options: &SimOptions,
        target: &VlaConfig,
        draft: &VlaConfig,
        cache: &Arc<EvalCache>,
    ) -> Evaluator {
        let ctx = cache.context(context_key(platform, options, target, draft));
        let b = ctx
            .baseline
            .get_or_init(|| {
                cache.count_baseline();
                let sim = Simulator::with_options(platform.clone(), options.clone());
                let base = sim.simulate_vla(target);
                let base_total =
                    base.vision.time + base.prefill.time + base.decode.time + base.action.time;
                let draft_step = draft_step_time(platform, options, draft);
                let scope = options.effective_pim_scope();
                BaselineBundle {
                    base,
                    base_total,
                    base_vision_j: energy::stage_dynamic_energy(
                        platform,
                        scope,
                        &target.vision_stage(),
                    ),
                    base_prefill_j: energy::stage_dynamic_energy(
                        platform,
                        scope,
                        &target.prefill_stage(),
                    ),
                    base_action_j: energy::stage_dynamic_energy(
                        platform,
                        scope,
                        &target.action_stage(),
                    ),
                    idle_watts: energy::EnergyModel::for_platform(platform).idle_watts,
                    draft_step,
                    draft_step_j: energy::decode_dynamic_energy(platform, options, draft)
                        / draft.shape.decode_tokens as f64,
                }
            })
            .clone();
        Evaluator {
            platform: platform.clone(),
            options: options.clone(),
            target: target.clone(),
            draft: draft.clone(),
            base: b.base,
            base_total: b.base_total,
            base_vision_j: b.base_vision_j,
            base_prefill_j: b.base_prefill_j,
            base_action_j: b.base_action_j,
            idle_watts: b.idle_watts,
            draft_step: b.draft_step,
            draft_step_j: b.draft_step_j,
            cache: Arc::clone(cache),
            ctx,
        }
    }

    /// The shared cache this evaluator feeds (for its counter snapshot).
    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// The evaluator's cache counters as a telemetry `cache` event —
    /// what the fleet experiments stamp into the NDJSON preamble so a
    /// stream records how much lowering work backed its shard specs.
    pub fn cache_snapshot(&self, t: f64, label: &str) -> crate::telemetry::Event {
        self.cache.snapshot_event(t, label)
    }

    /// Lazily integrated PIM-resident draft step, shared across the
    /// context: per-token (time, dynamic energy).
    fn pim_draft_step(&self) -> (f64, f64) {
        *self.ctx.pim_draft.get_or_init(|| {
            let mut resident = self.options.clone();
            resident.enable_pim_residency(true, true);
            (
                pim_draft_step_time(&self.platform, &self.options, &self.draft),
                energy::decode_dynamic_energy(&self.platform, &resident, &self.draft)
                    / self.draft.shape.decode_tokens as f64,
            )
        })
    }

    /// Baseline (empty-scenario) step latency.
    pub fn baseline_total(&self) -> f64 {
        self.base_total
    }

    /// Lower `scenario` and evaluate it: transformed config + options, the
    /// decode-cost override, baseline phases for the rest of the step, the
    /// energy integration, and the capacity-validity flag. Incremental:
    /// shared lowerings come from the [`EvalCache`] — bitwise-identical to
    /// [`Evaluator::eval_fresh`] (pinned by the test suites).
    pub fn eval(&self, scenario: &Scenario) -> anyhow::Result<ScenarioResult> {
        self.eval_inner(scenario, true)
    }

    /// Evaluate `scenario` without the scenario-level caches: every
    /// roofline integration runs fresh. This is the pre-cache evaluation
    /// path, bit for bit — the reference the incremental path is pinned
    /// against (and what the perf bench times as "fresh").
    pub fn eval_fresh(&self, scenario: &Scenario) -> anyhow::Result<ScenarioResult> {
        self.eval_inner(scenario, false)
    }

    /// Canonical per-group key of the decode-relevant levers (the Serving
    /// group is a decode-lowering no-op, so it is excluded — that is what
    /// lets the whole shard axis share one decode cost).
    fn decode_key(scenario: &Scenario) -> DecodeKey {
        let mut key = DecodeKey { weights: None, kv: 0, trace: None, spec: SpecKey::None };
        for l in &scenario.levers {
            match l {
                Lever::QuantizeWeights { bits } => key.weights = Some((false, *bits)),
                Lever::PimWeightStream { bits } => key.weights = Some((true, *bits)),
                Lever::QuantizeKv => key.kv = 1,
                Lever::PimKvAttention => key.kv = 2,
                Lever::CompressTrace { factor } => key.trace = Some(factor.to_bits()),
                Lever::Speculate { gamma, alpha } => {
                    key.spec = SpecKey::Soc { gamma: *gamma, alpha_bits: alpha.to_bits() };
                }
                Lever::PimDraft { gamma, alpha } => {
                    key.spec = SpecKey::Pim { gamma: *gamma, alpha_bits: alpha.to_bits() };
                }
                Lever::Batch { streams } => key.spec = SpecKey::Batch { streams: *streams },
                Lever::Shard { .. } => {}
                // placement is a step-assembly decision, not a decode
                // lowering: a vp@cloud stack shares its LOCAL decode cost
                // with the placement-free stack beside it, and a dec@cloud
                // stack costs decode on the REMOTE evaluator's own context
                // (a different ContextKey), so neither can alias here
                Lever::Offload { .. } => {}
            }
        }
        key
    }

    /// Lower `scenario`'s config and options (every lever applied, nothing
    /// integrated yet).
    fn lowered_config(&self, scenario: &Scenario) -> (VlaConfig, SimOptions) {
        let mut cfg = self.target.clone();
        let mut options = self.options.clone();
        for lever in &scenario.levers {
            lever.apply_config(&mut cfg);
        }
        for lever in &scenario.levers {
            lever.apply_options(&mut options);
        }
        (cfg, options)
    }

    /// Lower `scenario` and cost its decode phase: apply the levers, build
    /// the canonical [`DecodeKey`], and walk the decode-cost cache level.
    /// Factored out of `eval_inner` so the placement branch can cost a
    /// stripped lever stack on the remote (cloud-tier) evaluator through
    /// the identical machinery.
    fn lowered_decode_cost(&self, scenario: &Scenario, use_cache: bool) -> (VlaConfig, DecodeCost) {
        let (cfg, options) = self.lowered_config(scenario);
        let dkey = Self::decode_key(scenario);
        let cached_dc = if use_cache {
            let map = self.ctx.decode_costs.read().expect("decode cache lock poisoned");
            map.get(&dkey).copied()
        } else {
            None
        };
        let dc = match cached_dc {
            Some(c) => {
                self.cache.count_decode_hit();
                DecodeCost::from_cached(c)
            }
            None => {
                let dc = self.decode_cost(scenario, &cfg, &options, use_cache);
                if use_cache {
                    self.ctx
                        .decode_costs
                        .write()
                        .expect("decode cache lock poisoned")
                        .insert(dkey, dc.to_cached());
                }
                dc
            }
        };
        (cfg, dc)
    }

    /// The cloud-tier evaluator of this context: same target, draft, and
    /// ambient options, [`cloud_h100`](crate::hw::platform::cloud_h100)
    /// roofline coefficients, on the SAME shared [`EvalCache`] — the cloud
    /// platform resolves to its own [`ContextCache`], so remote baselines
    /// and integrals memoize exactly like local ones.
    fn remote(&self) -> Evaluator {
        Evaluator::with_cache(
            &crate::hw::platform::cloud_h100(),
            &self.options,
            &self.target,
            &self.draft,
            &self.cache,
        )
    }

    fn eval_inner(&self, scenario: &Scenario, use_cache: bool) -> anyhow::Result<ScenarioResult> {
        scenario.validate(&self.platform)?;
        self.cache.count_eval();
        // edge-to-cloud placement: placement-free scenarios take the `None`
        // arms of every match below, whose expressions are bitwise the
        // pre-offload evaluator
        let placement = match scenario.lever(LeverGroup::Placement) {
            Some(Lever::Offload { mode, link }) => Some((*mode, *link)),
            _ => None,
        };
        let (cfg, dc) = match placement {
            Some((OffloadMode::DecodeRemote, _)) => {
                // cost the decode phase on the cloud tier. The placement and
                // serving levers never lower decode, and the PIM-residency
                // levers are a property of the LOCAL memory device — bank
                // residency (and the quantization width bundled with it)
                // does not travel, so the stripped stack keeps only the
                // portable algorithmic levers (W8/W4, KV8, trace, SoC
                // speculation, batching)
                let stripped = Scenario::of(
                    scenario
                        .levers
                        .iter()
                        .filter(|l| {
                            !l.requires_pim()
                                && l.group() != LeverGroup::Serving
                                && l.group() != LeverGroup::Placement
                        })
                        .cloned()
                        .collect(),
                );
                let (_, rdc) = self.remote().lowered_decode_cost(&stripped, use_cache);
                // the LOCAL lowering still shapes the assembled step (trace
                // compression shortens the chunk a pipeline would split;
                // the config drives the shard link-demand model)
                let (cfg, _) = self.lowered_config(scenario);
                (cfg, rdc)
            }
            _ => self.lowered_decode_cost(scenario, use_cache),
        };
        // vision + prefill: remote substitution swaps in the cloud tier's
        // phase times and drops their LOCAL dynamic energy (the cloud's
        // joules do not drain the edge battery; $/action carries the
        // deployment-side cost of the remote tier's link instead)
        let (vp_t, vp_j) = match placement {
            Some((OffloadMode::VisionPrefillRemote, _)) => {
                let remote = self.remote();
                (remote.base.vision.time + remote.base.prefill.time, 0.0)
            }
            _ => (
                self.base.vision.time + self.base.prefill.time,
                self.base_vision_j + self.base_prefill_j,
            ),
        };
        // decode energy: a remote decode burns cloud joules, not edge ones
        let decode_j = match placement {
            Some((OffloadMode::DecodeRemote, _)) => 0.0,
            _ => dc.energy,
        };
        let streams = match scenario.lever(LeverGroup::Batching) {
            Some(Lever::Batch { streams }) => (*streams).max(1),
            _ => 1,
        };
        // one device serves all `streams` robots: each has its own camera
        // frame and action chunk, so vision/prefill/action REPLICATE per
        // stream — only decode shares work (the weight stream is read
        // once), which is the batching lever's whole point. At streams == 1
        // the `* 1.0` terms are bitwise no-ops, preserving the legacy path.
        let s = streams as f64;
        // the serving shard lever transforms the decode phase only:
        // pipelining splits the decoder pass across engines (plus hop
        // cost), replication contends R weight streams on the shared
        // off-chip link. Shard-free scenarios take the untouched-dc path —
        // every expression below is bitwise the pre-shard evaluator.
        let shard = match scenario.lever(LeverGroup::Serving) {
            Some(Lever::Shard { mode, engines }) => {
                ShardModel { mode: *mode, engines: (*engines).max(1) }
            }
            _ => ShardModel::single(),
        };
        let mut decode_time = dc.time;
        let mut agg_engines = 1u64;
        let mut idle_engines = 1u64;
        if shard.engines > 1 {
            match shard.mode {
                ShardMode::PipelineDecoder => {
                    decode_time = shard.decode_time(decode_time, cfg.shape.decode_tokens);
                    // every pipeline stage idles over the one logical step
                    idle_engines = shard.engines;
                }
                ShardMode::Replicate => {
                    let step0 = vp_t * s + decode_time + self.base.action.time * s;
                    let demand = link_demand_bw(scenario, &cfg, step0);
                    decode_time *= shard.contention(demand, self.platform.mem.effective_bw());
                    // each replica produces its own streams' actions
                    agg_engines = shard.engines;
                }
            }
        }
        // the link is charged once per control-loop crossing: two latency
        // hops (request out, result back) plus the per-stream activation/KV
        // payload at link bandwidth. `+ link_s` at 0.0 is a bitwise no-op
        // on the strictly positive placement-free total.
        let link_s = match placement {
            Some((mode, link)) => {
                let act_byte = cfg.decoder.dims.hidden as f64 * cfg.decoder.dims.dtype.bytes();
                let (up, down) = match mode {
                    // the camera frame's visual tokens go up; the prefix KV
                    // comes back so local decode can attend over it
                    OffloadMode::VisionPrefillRemote => (
                        cfg.shape.image_tokens as f64 * act_byte,
                        cfg.shape.prefill_len() as f64 * cfg.decoder.kv_bytes_per_token(),
                    ),
                    // the prefix KV moves up so the cloud can decode; the
                    // generated tokens' activations come back (trace
                    // compression shrinks exactly this payload)
                    OffloadMode::DecodeRemote => (
                        cfg.shape.prefill_len() as f64 * cfg.decoder.kv_bytes_per_token(),
                        cfg.shape.decode_tokens as f64 * act_byte,
                    ),
                };
                // payloads are bytes, NetLink bandwidth is Gbit/s: x8
                2.0 * link.latency_s + (up + down) * 8.0 * s / (link.bw_gbps * 1e9)
            }
            None => 0.0,
        };
        let total = vp_t * s + decode_time + self.base.action.time * s + link_s;
        let horizon = self.target.action.horizon.max(1);
        let amortized_hz = horizon as f64 / total;
        let dynamic_j = vp_j * s + decode_j + self.base_action_j * s;
        // one engine's energy over the step: every pipeline stage idles for
        // the one logical step, so its static share is R x
        let engine_j = if idle_engines > 1 {
            dynamic_j + self.idle_watts * idle_engines as f64 * total
        } else {
            dynamic_j + self.idle_watts * total
        };
        // deployment-level energy: replicate rows scale it by the engine
        // count, matching their R x aggregate_hz and footprint (J/action is
        // invariant — R x the energy produces R x the actions). At one
        // engine the `* 1.0` is a bitwise no-op.
        let total_j = agg_engines as f64 * engine_j;
        // link rent prorated over this step window, split across the
        // actions the whole deployment emits in it. Each replica runs
        // its own step and its own link (`link_s` charges one engine's
        // `s` streams), so replicate-R rents R links — the engine count
        // cancels and $/action is topology-invariant the same way
        // J/action is. At one engine the `* 1.0` is a bitwise no-op.
        let usd_per_action = match placement {
            Some((_, link)) => {
                let usd_per_s = link.usd_per_month / (30.0 * 24.0 * 3600.0);
                usd_per_s * agg_engines as f64 * total / (agg_engines * streams * horizon) as f64
            }
            None => 0.0,
        };
        let footprint = scenario.memory_footprint(&self.target, &self.draft);
        Ok(ScenarioResult {
            scenario: scenario.name.clone(),
            platform: self.platform.name.clone(),
            model: self.target.name.clone(),
            decode_time,
            step_latency: total,
            control_hz: 1.0 / total,
            amortized_hz,
            speedup_vs_baseline: self.base_total / total,
            bound: dc.bound(),
            pim_util: dc.pim_frac,
            streams,
            engines: shard.engines,
            aggregate_hz: (streams * agg_engines) as f64 * amortized_hz,
            total_j,
            j_per_action: total_j / (agg_engines * streams * horizon) as f64,
            avg_watts: total_j / total.max(1e-12),
            link_s,
            usd_per_action,
            footprint_gb: footprint / GB,
            capacity_gb: self.platform.mem.capacity_gb(),
            fits_capacity: footprint <= self.platform.mem.capacity,
        })
    }

    /// Decode-phase cost of the lowered scenario. The speculation lever
    /// replaces the decode integration; the KV8 lever wraps whichever model
    /// is active in the original midpoint approximation (halved prompt and
    /// image tokens as the reduced-traffic endpoint).
    fn decode_cost(
        &self,
        scenario: &Scenario,
        cfg: &VlaConfig,
        options: &SimOptions,
        use_cache: bool,
    ) -> DecodeCost {
        let model = |c: &VlaConfig| -> DecodeCost {
            match scenario.lever(LeverGroup::Speculation) {
                Some(Lever::Speculate { gamma, alpha }) => {
                    self.spec_cost(c, options, *gamma, *alpha, false, use_cache)
                }
                Some(Lever::PimDraft { gamma, alpha }) => {
                    self.spec_cost(c, options, *gamma, *alpha, true, use_cache)
                }
                _ => match scenario.lever(LeverGroup::Batching) {
                    Some(Lever::Batch { streams }) => {
                        self.batched_cost(c, options, *streams, use_cache)
                    }
                    _ => self.direct_cost(c, options, use_cache),
                },
            }
        };
        if matches!(scenario.lever(LeverGroup::Kv), Some(Lever::QuantizeKv)) {
            let full = model(cfg);
            let mut short = cfg.clone();
            short.shape.prompt_tokens /= 2;
            short.shape.image_tokens /= 2; // halves the kv_len trajectory
            let less_kv = model(&short);
            // kv traffic is the delta driver; midpoint is the KV8 estimate
            // (for the time AND the energy integral)
            DecodeCost {
                time: (full.time + less_kv.time) / 2.0,
                energy: (full.energy + less_kv.energy) / 2.0,
                ..full
            }
        } else {
            model(cfg)
        }
    }

    /// The plain decode integration of the transformed config, memoized on
    /// (config, options) — the whole integration is cached, never a
    /// partial sum, so hits are bitwise the fresh result.
    fn direct_cost(&self, cfg: &VlaConfig, options: &SimOptions, use_cache: bool) -> DecodeCost {
        let key = IntegralKey { rows: None, cfg: config_fp(cfg), opts: options_fp(options) };
        let cached = self.cache.integral(&self.ctx, use_cache, key, || {
            let sim = Simulator::with_options(self.platform.clone(), options.clone());
            DecodeCost {
                energy: energy::decode_dynamic_energy(&self.platform, options, cfg),
                ..DecodeCost::from_stage(&sim.simulate_decode(cfg))
            }
            .to_cached()
        });
        DecodeCost::from_cached(cached)
    }

    /// Speculative decode cost, with the draft on the SoC or on PIM. The
    /// draft steps come from the per-evaluator caches: the SoC draft runs
    /// on the AMBIENT options — a weights/KV-resident target does not lend
    /// the draft its PIM units (PimDraft is the lever that claims them) —
    /// while only the target's verification pass sees the lowered options.
    /// Energy is additive across the engines: pipelining overlaps TIME, but
    /// both the draft and the verifier burn their full dynamic energy.
    fn spec_cost(
        &self,
        cfg: &VlaConfig,
        options: &SimOptions,
        gamma: u64,
        alpha: f64,
        draft_on_pim: bool,
        use_cache: bool,
    ) -> DecodeCost {
        // the verify pass is the memoized integral: SoC and PIM-draft
        // speculation at the same gamma share it (the draft placement only
        // changes how the cached pass is combined below), and it shares a
        // keyspace with the lockstep batched step at the same row count
        let verify = self.batched_step(cfg, options, gamma + 1, use_cache);
        let rounds = expected_rounds(cfg.shape.decode_tokens, gamma, alpha);
        if draft_on_pim {
            let (draft_step, draft_j) = self.pim_draft_step();
            let (time, pim_frac) =
                pim_spec_combine(cfg.shape.decode_tokens, gamma, alpha, draft_step, verify.time);
            let energy = rounds * (gamma as f64 * draft_j + verify.energy);
            DecodeCost { time, pim_frac, energy, ..DecodeCost::from_cached(verify) }
        } else {
            let time = rounds * (gamma as f64 * self.draft_step + verify.time);
            let energy = rounds * (gamma as f64 * self.draft_step_j + verify.energy);
            DecodeCost { time, energy, ..DecodeCost::from_cached(verify) }
        }
    }

    /// One batched mid-trace decode step at `rows` rows (a verify pass or
    /// a lockstep batch step): raw per-step latency decomposition + dynamic
    /// energy, memoized on (rows, config, options). The ~430-op stage is
    /// built once per miss; latency and energy walk the same operators, so
    /// this is bitwise what two builds would produce.
    fn batched_step(
        &self,
        cfg: &VlaConfig,
        options: &SimOptions,
        rows: u64,
        use_cache: bool,
    ) -> CachedIntegral {
        let key =
            IntegralKey { rows: Some(rows), cfg: config_fp(cfg), opts: options_fp(options) };
        self.cache.integral(&self.ctx, use_cache, key, || {
            let kv_mid = cfg.shape.prefill_len() + cfg.shape.decode_tokens / 2;
            let stage = cfg.decode_stage_batched(kv_mid, rows);
            let r = Simulator::with_options(self.platform.clone(), options.clone())
                .simulate_stage(&stage);
            let step_j = energy::stage_dynamic_energy(
                &self.platform,
                options.effective_pim_scope(),
                &stage,
            );
            DecodeCost { energy: step_j, ..DecodeCost::from_stage(&r) }.to_cached()
        })
    }

    /// Lockstep multi-robot decode: every stream advances one token per
    /// batched step, so per-stream decode time is the mid-trace batched
    /// step cost times the trace length (and the step energy covers all
    /// streams — weights are read, and their movement paid, once). The
    /// per-stream vision/prefill/action replication lives in `eval`.
    fn batched_cost(
        &self,
        cfg: &VlaConfig,
        options: &SimOptions,
        streams: u64,
        use_cache: bool,
    ) -> DecodeCost {
        // the cache stores the RAW per-step integral; the trace-length
        // multiplication happens here, after retrieval, in the same
        // expression the fresh path evaluates — bitwise either way
        let step = self.batched_step(cfg, options, streams.max(1), use_cache);
        DecodeCost {
            time: step.time * cfg.shape.decode_tokens as f64,
            energy: step.energy * cfg.shape.decode_tokens as f64,
            ..DecodeCost::from_cached(step)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;
    use crate::model::scaling::scaled_vla;

    fn opts() -> SimOptions {
        SimOptions { decode_stride: 32, pim: false, ..Default::default() }
    }

    fn evaluator(p: &Platform) -> Evaluator {
        Evaluator::new(p, &opts(), &molmoact_7b(), &scaled_vla(2.0))
    }

    #[test]
    fn baseline_scenario_is_identity() {
        let ev = evaluator(&platform::orin());
        let r = ev.eval(&Scenario::baseline()).unwrap();
        assert_eq!(r.step_latency.to_bits(), ev.baseline_total().to_bits());
        assert_eq!(r.speedup_vs_baseline, 1.0);
        assert_eq!(r.bound, Bound::Memory);
        assert_eq!(r.pim_util, 0.0);
        assert_eq!(r.streams, 1);
        assert_eq!(r.aggregate_hz.to_bits(), r.amortized_hz.to_bits());
        assert!(r.fits_capacity, "7B bf16 fits a 64 GB Orin");
    }

    #[test]
    fn baseline_energy_matches_simulate_energy() {
        // the evaluator's per-scenario energy integration must agree with
        // the standalone sim::energy pipeline on the empty scenario —
        // bitwise, since both share the same helpers and summation order
        let p = platform::orin();
        let ev = evaluator(&p);
        let r = ev.eval(&Scenario::baseline()).unwrap();
        let (_, e) = energy::simulate_energy(&p, &opts(), &molmoact_7b());
        assert_eq!(r.total_j.to_bits(), e.total_j().to_bits());
        assert_eq!(r.j_per_action.to_bits(), e.j_per_action().to_bits());
        assert_eq!(r.avg_watts.to_bits(), e.avg_watts().to_bits());
    }

    #[test]
    fn quantization_speeds_up_decode_proportionally() {
        let ev = evaluator(&platform::orin());
        let w8 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
        let w4 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 4 }])).unwrap();
        assert!(w8.speedup_vs_baseline > 1.3);
        assert!(w4.decode_time < w8.decode_time, "W4 must stream less than W8");
        assert!(w4.speedup_vs_baseline > w8.speedup_vs_baseline);
    }

    #[test]
    fn quantization_cuts_energy_per_action() {
        // fewer streamed bytes and a shorter step (less static burn) both
        // cut J/action on a bandwidth-bound platform
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let w8 = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
        assert!(w8.j_per_action < base.j_per_action);
        assert!(w8.total_j < base.total_j);
        assert!(base.j_per_action > 0.0 && base.avg_watts > 0.0);
    }

    #[test]
    fn batched_energy_amortizes_across_streams() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let b8 = ev.eval(&Scenario::of(vec![Lever::Batch { streams: 8 }])).unwrap();
        assert_eq!(b8.streams, 8);
        // aggregate rate rises even though the per-stream step is slower
        assert!(b8.aggregate_hz > base.aggregate_hz);
        assert!((b8.aggregate_hz / b8.amortized_hz - 8.0).abs() < 1e-9);
        // weights are read once for all 8 streams: J per action drops
        assert!(b8.j_per_action < base.j_per_action, "batching must amortize energy");
        // but the step burns MORE total energy than a single-stream step
        assert!(b8.total_j > base.total_j);
        // vision/prefill/action replicate per stream (each robot brings its
        // own camera frame): the batched step's non-decode share is 8x
        let base_phases = base.step_latency - base.decode_time;
        let b8_phases = b8.step_latency - b8.decode_time;
        assert!((b8_phases / base_phases - 8.0).abs() < 1e-6, "phase share {b8_phases}");
    }

    #[test]
    fn pim_residency_rejected_without_pim() {
        let ev = evaluator(&platform::thor());
        assert!(ev.eval(&Scenario::of(vec![Lever::PimWeightStream { bits: 8 }])).is_err());
    }

    #[test]
    fn weight_residency_beats_offchip_quantization() {
        for p in [platform::orin_pim(), platform::thor_pim(), platform::thor_hbm4_pim()] {
            let ev = evaluator(&p);
            let soc = ev.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
            let pim = ev.eval(&Scenario::of(vec![Lever::PimWeightStream { bits: 8 }])).unwrap();
            assert!(
                pim.control_hz > soc.control_hz,
                "{}: W8@PIM {} Hz <= W8 {} Hz",
                p.name,
                pim.control_hz,
                soc.control_hz
            );
            assert!(pim.pim_util > 0.1, "{}: PIM should carry the weight stream", p.name);
            // bank-local movement is cheaper than the off-chip link
            assert!(pim.j_per_action < soc.j_per_action, "{}: PIM must save energy", p.name);
        }
    }

    #[test]
    fn pim_draft_pipelines_ahead_of_soc_speculation() {
        let ev = evaluator(&platform::orin_pim());
        let soc = ev.eval(&Scenario::of(vec![Lever::Speculate { gamma: 4, alpha: 0.7 }])).unwrap();
        let pim = ev.eval(&Scenario::of(vec![Lever::PimDraft { gamma: 4, alpha: 0.7 }])).unwrap();
        assert!(pim.control_hz > soc.control_hz);
        assert!(pim.pim_util > 0.0);
    }

    #[test]
    fn soc_draft_does_not_inherit_target_residency() {
        // regression: in `W8@PIM + spec` the draft must be costed on the
        // ambient SoC path, not with the target's PIM-residency options
        let p = platform::orin_pim();
        let ambient = opts();
        let mut resident = ambient.clone();
        resident.enable_pim_residency(true, false);
        let target = molmoact_7b();
        let draft = scaled_vla(2.0);
        let ambient_step = draft_step_time(&p, &ambient, &draft);
        let resident_step = draft_step_time(&p, &resident, &draft);
        assert!(ambient_step > resident_step, "residency must matter for this to be a test");
        // the evaluator's combo: ambient draft + resident verify of the
        // quantized target, assembled exactly like speculative_decode
        let ev = Evaluator::new(&p, &ambient, &target, &draft);
        let combo = ev
            .eval(&Scenario::of(vec![
                Lever::PimWeightStream { bits: 8 },
                Lever::Speculate { gamma: 4, alpha: 0.7 },
            ]))
            .unwrap();
        let cfg8 = super::super::quantize_weights(&target, 8);
        let rounds = expected_rounds(cfg8.shape.decode_tokens, 4, 0.7);
        let verify = verify_pass(&p, &resident, &cfg8, 4).time;
        let want = rounds * (4.0 * ambient_step + verify);
        assert_eq!(combo.decode_time.to_bits(), want.to_bits());
    }

    #[test]
    fn batched_scenario_reports_per_stream_latency() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let b8 = ev.eval(&Scenario::of(vec![Lever::Batch { streams: 8 }])).unwrap();
        // batching never improves per-stream control latency at the edge
        assert!(b8.step_latency >= base.step_latency * 0.95);
    }

    #[test]
    fn capacity_flag_reports_oversized_scenarios() {
        // a bf16 30B-class model overflows one 36 GB HBM4-PIM stack; the
        // evaluation still succeeds and the row carries the flag
        let p = platform::thor_hbm4_pim();
        let ev = Evaluator::new(&p, &opts(), &scaled_vla(30.0), &scaled_vla(2.0));
        let base = ev.eval(&Scenario::baseline()).unwrap();
        assert!(!base.fits_capacity, "bf16 30B cannot fit 36 GB");
        assert!(base.footprint_gb > base.capacity_gb);
        assert!((base.capacity_gb - 36.0).abs() < 1e-9);
        assert!(base.step_latency > 0.0, "invalid rows are still projected");
        // W4 residency packs it back in
        let w4 = ev.eval(&Scenario::of(vec![Lever::PimWeightStream { bits: 4 }])).unwrap();
        assert!(w4.fits_capacity, "W4 30B fits 36 GB: {} GB", w4.footprint_gb);
    }

    #[test]
    fn shard_levers_transform_the_step() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        assert_eq!(base.engines, 1);
        // MolmoAct's decode weight stream is ~3/4 of Orin's link, so even
        // two replicas contend: the per-stream step stretches, aggregate
        // gains stay short of 2x, and footprint pays for both copies
        let rep2 = ev
            .eval(&Scenario::of(vec![Lever::Shard { mode: ShardMode::Replicate, engines: 2 }]))
            .unwrap();
        assert_eq!(rep2.engines, 2);
        assert!(rep2.step_latency > base.step_latency, "two 7B streams contend on Orin");
        let gain2 = rep2.aggregate_hz / base.aggregate_hz;
        assert!(gain2 > 1.0 && gain2 < 2.0, "saturated replicate-2 gain {gain2}");
        assert!((rep2.footprint_gb / base.footprint_gb - 2.0).abs() < 1e-9);
        // replicate-4: deeper saturation, monotone aggregate, bounded slow-down
        let rep4 = ev
            .eval(&Scenario::of(vec![Lever::Shard { mode: ShardMode::Replicate, engines: 4 }]))
            .unwrap();
        assert!(rep4.step_latency > rep2.step_latency, "4 weight streams contend harder");
        let gain4 = rep4.aggregate_hz / base.aggregate_hz;
        assert!(gain4 >= gain2 && gain4 < 4.0, "saturated replicate gain {gain4}");
        assert!(rep4.speedup_vs_baseline >= 1.0 / 4.0, "contention bounded by R");
        // a tiny model's stream is a rounding error on the link: replicate
        // is contention-free — per-stream step BITWISE unchanged, aggregate
        // exactly 2x
        let tiny_ev = Evaluator::new(
            &platform::orin(),
            &opts(),
            &crate::model::vla::tiny_test_config(),
            &scaled_vla(2.0),
        );
        let tiny_base = tiny_ev.eval(&Scenario::baseline()).unwrap();
        let tiny_rep2 = tiny_ev
            .eval(&Scenario::of(vec![Lever::Shard { mode: ShardMode::Replicate, engines: 2 }]))
            .unwrap();
        assert_eq!(tiny_rep2.step_latency.to_bits(), tiny_base.step_latency.to_bits());
        assert!((tiny_rep2.aggregate_hz / tiny_base.aggregate_hz - 2.0).abs() < 1e-9);
        // replicate energy is deployment-level, matching the 2x aggregate
        // and footprint: total/avg-W double, J/action is invariant
        assert!((tiny_rep2.total_j / tiny_base.total_j - 2.0).abs() < 1e-9);
        assert!((tiny_rep2.avg_watts / tiny_base.avg_watts - 2.0).abs() < 1e-9);
        assert!((tiny_rep2.j_per_action / tiny_base.j_per_action - 1.0).abs() < 1e-9);
        // pipeline-4 cuts decode ~4x (minus hop cost) on one weight copy
        let pipe4 = ev
            .eval(&Scenario::of(vec![
                Lever::Shard { mode: ShardMode::PipelineDecoder, engines: 4 },
            ]))
            .unwrap();
        assert!(pipe4.decode_time < base.decode_time / 2.0);
        assert!(pipe4.decode_time > base.decode_time / 8.0, "hop cost bounds the win");
        assert!(pipe4.control_hz > base.control_hz);
        assert_eq!(pipe4.footprint_gb.to_bits(), base.footprint_gb.to_bits());
        // four stages idle over one logical step: latency is bought with
        // energy (4 x static power over the step always exceeds 1 x over
        // the longer one, since the non-decode phases don't shrink)
        assert!(pipe4.total_j > base.total_j, "four idling stages cost energy");
        assert!(pipe4.j_per_action > base.j_per_action);
        assert!(pipe4.avg_watts > base.avg_watts);
    }

    #[test]
    fn pareto_front_basics() {
        // (rate up, cost down): b dominates a and c; d trades off against b
        let pts = [(1.0, 5.0), (2.0, 2.0), (1.5, 2.0), (3.0, 4.0)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![1, 3]);
        // duplicates are mutually non-dominating
        assert_eq!(pareto_front(&[(1.0, 1.0), (1.0, 1.0)]), vec![0, 1]);
        assert_eq!(pareto_front(&[]), Vec::<usize>::new());
        assert_eq!(pareto_front(&[(2.0, 3.0)]), vec![0]);
    }

    #[test]
    fn pareto_front3_basics() {
        // b dominates a on all three; c survives by its cheap third axis;
        // d trades rate against b
        let pts =
            [(1.0, 5.0, 3.0), (2.0, 2.0, 2.0), (1.5, 3.0, 0.0), (3.0, 4.0, 4.0)];
        assert_eq!(pareto_front3(&pts), vec![1, 2, 3]);
        // duplicates are mutually non-dominating; degenerate inputs hold
        assert_eq!(pareto_front3(&[(1.0, 1.0, 1.0), (1.0, 1.0, 1.0)]), vec![0, 1]);
        assert_eq!(pareto_front3(&[]), Vec::<usize>::new());
        assert_eq!(pareto_front3(&[(2.0, 3.0, 1.0)]), vec![0]);
        // equal third axis everywhere -> exactly the two-objective front
        let flat = [(1.0, 5.0), (2.0, 2.0), (1.5, 2.0), (3.0, 4.0)];
        let lifted: Vec<(f64, f64, f64)> = flat.iter().map(|&(a, b)| (a, b, 7.0)).collect();
        assert_eq!(pareto_front3(&lifted), pareto_front(&flat));
    }

    #[test]
    fn all_local_rows_carry_zero_link_cost() {
        let ev = evaluator(&platform::orin());
        for s in [
            Scenario::baseline(),
            Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }]),
            Scenario::of(vec![Lever::Batch { streams: 8 }]),
        ] {
            let r = ev.eval(&s).unwrap();
            assert_eq!(r.link_s.to_bits(), 0.0f64.to_bits(), "{}", s.name);
            assert_eq!(r.usd_per_action.to_bits(), 0.0f64.to_bits(), "{}", s.name);
        }
    }

    #[test]
    fn vision_prefill_offload_substitutes_remote_phases_and_charges_the_link() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let remote =
            Evaluator::new(&platform::cloud_h100(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let link = NetLink::wired();
        let vp = ev
            .eval(&Scenario::of(vec![Lever::Offload {
                mode: OffloadMode::VisionPrefillRemote,
                link,
            }]))
            .unwrap();
        // the cloud tier's vision/prefill are strictly faster than Orin's
        let rvp = remote.base.vision.time + remote.base.prefill.time;
        let lvp = ev.base.vision.time + ev.base.prefill.time;
        assert!(rvp < lvp, "H100 must beat Orin on the compute-bound front: {rvp} vs {lvp}");
        // the link charge is exactly 2 latency hops + payload/bandwidth
        let t = molmoact_7b();
        let act_byte = t.decoder.dims.hidden as f64 * t.decoder.dims.dtype.bytes();
        let up = t.shape.image_tokens as f64 * act_byte;
        let down = t.shape.prefill_len() as f64 * t.decoder.kv_bytes_per_token();
        // byte payload over a Gbit/s link: the bytes-to-bits x8 must be
        // in the charge (a 10 Gbit wired link moves 1.25 GB/s, not 10)
        let want_link = 2.0 * link.latency_s + (up + down) * 8.0 * 1.0 / (link.bw_gbps * 1e9);
        assert_eq!(vp.link_s.to_bits(), want_link.to_bits());
        // the step swaps exactly the vision/prefill phases and adds the link
        let want_total = rvp * 1.0 + base.decode_time + ev.base.action.time * 1.0 + vp.link_s;
        assert_eq!(vp.step_latency.to_bits(), want_total.to_bits());
        // decode is untouched (same local integration, cached or not)
        assert_eq!(vp.decode_time.to_bits(), base.decode_time.to_bits());
        // the edge battery stops paying vision/prefill joules...
        assert!(vp.total_j < base.total_j || vp.step_latency > base.step_latency);
        // ...and the row carries a nonzero link rent
        let want_usd = link.usd_per_month / (30.0 * 24.0 * 3600.0) * vp.step_latency
            / ev.target.action.horizon as f64;
        assert_eq!(vp.usd_per_action.to_bits(), want_usd.to_bits());
        assert!(vp.usd_per_action > 0.0);
    }

    #[test]
    fn decode_offload_costs_decode_on_the_cloud_roofline() {
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let remote =
            Evaluator::new(&platform::cloud_h100(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let rbase = remote.eval(&Scenario::baseline()).unwrap();
        let dec = ev
            .eval(&Scenario::of(vec![Lever::Offload {
                mode: OffloadMode::DecodeRemote,
                link: NetLink::wired(),
            }]))
            .unwrap();
        // the decode phase is the remote tier's own baseline integration
        assert_eq!(dec.decode_time.to_bits(), rbase.decode_time.to_bits());
        assert!(dec.decode_time < base.decode_time, "HBM3E must beat LPDDR5 on decode");
        // remote decode burns cloud joules, not edge ones: the edge step's
        // dynamic energy drops by exactly the decode share
        let edge_dynamic = dec.total_j - ev.idle_watts * dec.step_latency;
        let want_dynamic = ev.base_vision_j + ev.base_prefill_j + ev.base_action_j;
        assert!(
            (edge_dynamic - want_dynamic).abs() < 1e-9,
            "edge dynamic {edge_dynamic} vs {want_dynamic}"
        );
        assert!(dec.usd_per_action > 0.0 && dec.link_s > 0.0);
    }

    #[test]
    fn replicate_shards_get_no_link_rent_discount() {
        // each replica runs its own step over its own link (`link_s`
        // charges one engine's streams), so $/action can only grow under
        // replication (contention lengthens the step) — the R-fold
        // discount a shared-rent formula would grant is the bug pinned
        // here: before the fix rep4 paid ~1/4 of solo's rent
        let ev = evaluator(&platform::orin());
        let link = NetLink::wifi6();
        let solo = ev
            .eval(&Scenario::of(vec![Lever::Offload {
                mode: OffloadMode::VisionPrefillRemote,
                link,
            }]))
            .unwrap();
        let rep4 = ev
            .eval(&Scenario::of(vec![
                Lever::Shard { mode: ShardMode::Replicate, engines: 4 },
                Lever::Offload { mode: OffloadMode::VisionPrefillRemote, link },
            ]))
            .unwrap();
        assert!(
            rep4.usd_per_action >= solo.usd_per_action,
            "replication must not discount the link rent: {} vs {}",
            rep4.usd_per_action,
            solo.usd_per_action
        );
        // pinned: R links' rent over the step window, split across the
        // R replicas' streams x horizon actions — the engine count
        // cancels exactly, same topology invariance as J/action
        let usd_per_s = link.usd_per_month / (30.0 * 24.0 * 3600.0);
        let horizon = ev.target.action.horizon.max(1);
        let want = usd_per_s * 4.0 * rep4.step_latency / ((4 * horizon) as f64);
        assert_eq!(rep4.usd_per_action.to_bits(), want.to_bits());
    }

    #[test]
    fn pim_residency_does_not_travel_to_the_cloud() {
        // `W8@PIM + dec@cloud`: bank residency (and the width bundled with
        // it) is a property of the LOCAL memory device, so the remote
        // decode is the cloud tier's UNQUANTIZED baseline integration
        let p = platform::orin_pim();
        let ev = evaluator(&p);
        let remote =
            Evaluator::new(&platform::cloud_h100(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let combo = ev
            .eval(&Scenario::of(vec![
                Lever::PimWeightStream { bits: 8 },
                Lever::Offload { mode: OffloadMode::DecodeRemote, link: NetLink::five_g() },
            ]))
            .unwrap();
        let rbase = remote.eval(&Scenario::baseline()).unwrap();
        assert_eq!(combo.decode_time.to_bits(), rbase.decode_time.to_bits());
        assert_eq!(combo.pim_util.to_bits(), 0.0f64.to_bits(), "no PIM on the cloud tier");
        // the portable W8 quantization DOES travel when it is not a
        // residency lever
        let w8combo = ev
            .eval(&Scenario::of(vec![
                Lever::QuantizeWeights { bits: 8 },
                Lever::Offload { mode: OffloadMode::DecodeRemote, link: NetLink::five_g() },
            ]))
            .unwrap();
        let rw8 = remote.eval(&Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }])).unwrap();
        assert_eq!(w8combo.decode_time.to_bits(), rw8.decode_time.to_bits());
        assert!(w8combo.decode_time < combo.decode_time);
    }

    #[test]
    fn slow_links_lose_to_local_execution() {
        // a link whose round trip exceeds the phase time it hides can never
        // win: the offload experiment's O2 check, pinned here at the unit
        // level with a pathologically slow link
        let ev = evaluator(&platform::orin());
        let base = ev.eval(&Scenario::baseline()).unwrap();
        let slow = NetLink { latency_s: 5.0, bw_gbps: 0.001, usd_per_month: 1.0 };
        for mode in OffloadMode::all() {
            let r = ev.eval(&Scenario::of(vec![Lever::Offload { mode, link: slow }])).unwrap();
            let hidden = match mode {
                OffloadMode::VisionPrefillRemote => base.step_latency - base.decode_time,
                OffloadMode::DecodeRemote => base.decode_time,
            };
            assert!(r.link_s > hidden, "the slow link must dominate the hidden phase");
            assert!(
                r.control_hz < base.control_hz,
                "{}: offload over a dead link cannot beat local",
                mode.tag()
            );
        }
    }
}
