//! The co-design levers: each one transformation of the workload config,
//! the simulation options, or the decode-phase cost model.
//!
//! A lever is deliberately small: `apply_config` rewrites the
//! [`VlaConfig`], `apply_options` rewrites the [`SimOptions`] (that is how
//! the PIM-residency levers reach the roofline's forced-placement scopes),
//! and the speculation levers are interpreted by the evaluator because they
//! replace the decode integration itself. The five software levers are the
//! ones `sim::codesign` has always modeled; the three `Pim*` levers are the
//! paper's forward-looking hardware/software co-design points.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::engine::shard::ShardMode;
use crate::hw::{DType, Platform};
use crate::model::vla::VlaConfig;
use crate::sim::simulator::SimOptions;

/// Expected accepted tokens per speculation round:
/// `E = (1 - alpha^(gamma+1)) / (1 - alpha)`. The single source of the
/// acceptance expectation — the evaluator's round count and the lever's
/// modeled-overhead bound must agree on it, or the S3 sanity invariant
/// (`speedup >= 1/overhead`) drifts when the γ/α grid moves off the
/// canonical point.
pub(crate) fn expected_accepted(gamma: u64, alpha: f64) -> f64 {
    // α → 1 is a 0/0 of the closed form: the numerator hits exactly 0 while
    // the denominator clamp keeps a 1e-9 floor, collapsing E to 0 and blowing
    // `modeled_overhead` up to inf. The analytic limit is E(γ, 1) = γ + 1
    // (every proposed token plus the verify token is accepted). CLI grids are
    // range-checked to α < 1, but programmatic `LeverGrid`s are not.
    if alpha >= 1.0 {
        return gamma as f64 + 1.0;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha).max(1e-9)
}

/// Scale the decoder's weight storage to a narrower width (activations and
/// KV keep their dtype semantics — W8A16-style inference). W8 swaps the
/// decoder dtype to I8; W4 has no native datatype in the cost model, so it
/// is I8 arithmetic with `weight_scale = 0.5` — the packed nibbles stream
/// half the bytes per token. Other widths pass through unchanged.
pub fn quantize_weights(cfg: &VlaConfig, bits: u32) -> VlaConfig {
    let mut c = cfg.clone();
    match bits {
        8 => c.decoder.dims.dtype = DType::I8,
        4 => {
            c.decoder.dims.dtype = DType::I8;
            c.decoder.weight_scale = 0.5;
        }
        _ => {}
    }
    c
}

/// Exclusivity group: a scenario holds at most one lever per group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeverGroup {
    /// Weight storage/placement (quantization, PIM residency).
    Weights,
    /// KV-cache storage/placement.
    Kv,
    /// Reasoning-trace length.
    Trace,
    /// Speculative decoding.
    Speculation,
    /// Multi-robot batching.
    Batching,
    /// Serving topology (multi-engine sharding).
    Serving,
    /// Phase placement across the edge-to-cloud boundary (offload).
    Placement,
}

/// A typed edge-to-cloud network link: one-way latency, usable bandwidth,
/// and the monthly subscription the deployment pays for it. The evaluator
/// charges `payload bits / bw + 2 x latency` per control-loop crossing on
/// it, and the subscription amortizes into the $/action Pareto objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetLink {
    /// One-way latency per crossing (s).
    pub latency_s: f64,
    /// Usable link bandwidth (Gbit/s).
    pub bw_gbps: f64,
    /// Monthly link cost (USD) — amortized per action by the evaluator.
    pub usd_per_month: f64,
}

impl NetLink {
    /// Public 5G slice: tens-of-ms latency, sub-Gbit usable uplink.
    pub fn five_g() -> NetLink {
        NetLink { latency_s: 0.015, bw_gbps: 0.5, usd_per_month: 60.0 }
    }

    /// On-prem WiFi-6: single-digit-ms latency, ~2 Gbit/s effective.
    pub fn wifi6() -> NetLink {
        NetLink { latency_s: 0.005, bw_gbps: 2.0, usd_per_month: 25.0 }
    }

    /// Wired fiber uplink: ~1 ms to the edge PoP, 10 Gbit/s.
    pub fn wired() -> NetLink {
        NetLink { latency_s: 0.001, bw_gbps: 10.0, usd_per_month: 150.0 }
    }

    /// The canonical preset sweep, in ranking order: 5G / WiFi-6 / wired.
    pub fn presets() -> Vec<NetLink> {
        vec![NetLink::five_g(), NetLink::wifi6(), NetLink::wired()]
    }

    /// Parse a preset name (the `--links` CLI grammar).
    pub fn parse(name: &str) -> anyhow::Result<NetLink> {
        match name.trim().to_ascii_lowercase().as_str() {
            "5g" => Ok(NetLink::five_g()),
            "wifi6" | "wifi-6" => Ok(NetLink::wifi6()),
            "wired" | "fiber" => Ok(NetLink::wired()),
            other => anyhow::bail!("unknown link preset `{other}` (known: 5g, wifi6, wired)"),
        }
    }

    /// Compact label: the preset name when the parameters match one
    /// bit-for-bit, otherwise the raw latency/bandwidth pair.
    pub fn label(&self) -> String {
        for (name, preset) in
            [("5g", NetLink::five_g()), ("wifi6", NetLink::wifi6()), ("wired", NetLink::wired())]
        {
            if *self == preset {
                return name.to_string();
            }
        }
        format!("{}ms/{}g", self.latency_s * 1e3, self.bw_gbps)
    }
}

/// Which phases of the control loop run on the remote cloud tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadMode {
    /// Vision encoding + prefill run remote; the memory-bound action
    /// generation stays on the edge device (the paper's bottleneck phase
    /// keeps its local placement; the link hides the compute-bound front).
    VisionPrefillRemote,
    /// Action generation (decode) runs remote on the cloud roofline; the
    /// edge device keeps vision/prefill/action-head local.
    DecodeRemote,
}

impl OffloadMode {
    /// Compact tag used in scenario names.
    pub fn tag(&self) -> &'static str {
        match self {
            OffloadMode::VisionPrefillRemote => "vp@cloud",
            OffloadMode::DecodeRemote => "dec@cloud",
        }
    }

    /// Both placement modes, in matrix axis order (`vp@cloud` before
    /// `dec@cloud`).
    pub fn all() -> Vec<OffloadMode> {
        vec![OffloadMode::VisionPrefillRemote, OffloadMode::DecodeRemote]
    }

    /// Parse an `--offload-modes` entry. `both` is not a mode — the CLI
    /// list parser expands it to [`OffloadMode::all`] before it gets here.
    pub fn parse(name: &str) -> anyhow::Result<OffloadMode> {
        match name.trim().to_ascii_lowercase().as_str() {
            "vp" | "vision-prefill" => Ok(OffloadMode::VisionPrefillRemote),
            "decode" | "dec" => Ok(OffloadMode::DecodeRemote),
            other => anyhow::bail!("unknown offload mode `{other}` (known: vp, decode)"),
        }
    }
}

/// One co-design lever.
#[derive(Debug, Clone, PartialEq)]
pub enum Lever {
    /// W8/W4 weight quantization on the SoC streaming path.
    QuantizeWeights { bits: u32 },
    /// Weight-streaming on PIM: W8/W4 decoder weights resident in the PIM
    /// banks; decoder GEMVs are costed via `cost_on_pim` (forced residency)
    /// and issued by the PIM command queue instead of the eager host.
    PimWeightStream { bits: u32 },
    /// KV-cache 8-bit quantization (midpoint approximation, as in the
    /// original codesign study).
    QuantizeKv,
    /// KV cache resident in PIM: attention byte traffic (qk/softmax/av) is
    /// served at PIM internal bandwidth from the banks that hold it.
    PimKvAttention,
    /// Reasoning-trace compression to `factor` of the generated tokens.
    CompressTrace { factor: f64 },
    /// Speculative decoding: the draft proposes `gamma` tokens per target
    /// verification pass (acceptance rate `alpha`). The draft runs on the
    /// ambient SoC path — any PIM residency in the stack belongs to the
    /// target; a PIM-hosted draft is [`Lever::PimDraft`]'s job.
    Speculate { gamma: u64, alpha: f64 },
    /// Draft-model-on-PIM speculation: the draft decodes on the PIM units
    /// while the SoC verifies the previous proposal — the engines pipeline.
    PimDraft { gamma: u64, alpha: f64 },
    /// Batched multi-robot serving: `streams` robots decode in lockstep;
    /// weights are read once per step, per-stream latency is the metric.
    Batch { streams: u64 },
    /// Multi-engine shard serving (`engine::shard`): replicate the engine
    /// (`R` full weight copies contending for the shared link, aggregate
    /// throughput `R`x until bandwidth saturation) or pipeline the decoder
    /// across `R` engines (weights shard `1/R` per engine, per-token
    /// latency = max stage time + inter-stage hop).
    Shard { mode: ShardMode, engines: u64 },
    /// Edge-to-cloud phase placement: run `mode`'s phases on the cloud
    /// tier (`hw::platform::cloud_h100`), paying `payload bits / bw +
    /// 2 x latency` on `link` per control-loop crossing. The evaluator substitutes the
    /// remote roofline for the offloaded phases and reports the link time
    /// and the amortized link cost as `link_s` / `usd_per_action`.
    Offload { mode: OffloadMode, link: NetLink },
}

impl Lever {
    /// Compact tag used to compose scenario names.
    pub fn short(&self) -> String {
        match self {
            Lever::QuantizeWeights { bits } => format!("W{bits}"),
            Lever::PimWeightStream { bits } => format!("W{bits}@PIM"),
            Lever::QuantizeKv => "KV8".to_string(),
            Lever::PimKvAttention => "KV@PIM".to_string(),
            Lever::CompressTrace { factor } => format!("{factor}xCoT"),
            Lever::Speculate { gamma, alpha } => format!("spec(g{gamma},a{alpha})"),
            Lever::PimDraft { gamma, alpha } => format!("spec@PIM(g{gamma},a{alpha})"),
            Lever::Batch { streams } => format!("b{streams}"),
            Lever::Shard { mode: ShardMode::Replicate, engines } => format!("rep{engines}"),
            Lever::Shard { mode: ShardMode::PipelineDecoder, engines } => format!("pipe{engines}"),
            Lever::Offload { mode, link } => format!("{}({})", mode.tag(), link.label()),
        }
    }

    pub fn group(&self) -> LeverGroup {
        match self {
            Lever::QuantizeWeights { .. } | Lever::PimWeightStream { .. } => LeverGroup::Weights,
            Lever::QuantizeKv | Lever::PimKvAttention => LeverGroup::Kv,
            Lever::CompressTrace { .. } => LeverGroup::Trace,
            Lever::Speculate { .. } | Lever::PimDraft { .. } => LeverGroup::Speculation,
            Lever::Batch { .. } => LeverGroup::Batching,
            Lever::Shard { .. } => LeverGroup::Serving,
            Lever::Offload { .. } => LeverGroup::Placement,
        }
    }

    /// Does this lever need PIM hardware on the platform?
    pub fn requires_pim(&self) -> bool {
        matches!(
            self,
            Lever::PimWeightStream { .. } | Lever::PimKvAttention | Lever::PimDraft { .. }
        )
    }

    /// Multiplicative bound on how much this lever's modeled overhead may
    /// slow a step down in the worst case (the `speedup >= 1/overhead`
    /// sanity invariant). Quantization/compression/residency never add
    /// modeled cost (1.02 covers approximation slack); speculation can lose
    /// up to the mis-speculated draft work — per round at most `gamma`
    /// draft steps (each ≤ one target step: the draft is the smaller model)
    /// plus one batched verify pass (≤ 2 target steps), amortized over the
    /// `E(gamma, alpha)` tokens a round is expected to accept, so the bound
    /// is `(gamma + 2) / E`, floored at 1 — parametric, because the phase-2
    /// γ/α grids leave the canonical `(4, 0.7)` operating point; lockstep
    /// batching multiplies per-stream KV/activation traffic, so per-stream
    /// latency is bounded by `streams`x the single-stream step (weights are
    /// read once, everything else scales at worst linearly).
    pub fn modeled_overhead(&self) -> f64 {
        match self {
            Lever::Speculate { gamma, alpha } | Lever::PimDraft { gamma, alpha } => {
                ((*gamma as f64 + 2.0) / expected_accepted(*gamma, *alpha)).max(1.0)
            }
            Lever::Batch { streams } => (*streams).max(1) as f64,
            // a shard topology never slows a step beyond Rx: replicate
            // contention is clamped to R by construction, and an R-stage
            // pipeline charges (R-1) hops per token, each below the
            // per-token cost floor — so even a hop-dominated deep pipeline
            // stays within Rx of the unsharded step
            Lever::Shard { engines, .. } => (*engines).max(1) as f64,
            // a link can stall the loop arbitrarily relative to the step it
            // feeds (the transfer time is workload-sized, the step is not),
            // so placement carries no finite platform-free slowdown bound;
            // the `offload` experiment checks the exact accounting instead
            // (link time exceeding the hidden phase must never win)
            Lever::Offload { .. } => f64::INFINITY,
            _ => 1.02,
        }
    }

    /// Rewrite the workload config (weight dtype/scale, trace length).
    pub fn apply_config(&self, cfg: &mut VlaConfig) {
        match self {
            Lever::QuantizeWeights { bits } | Lever::PimWeightStream { bits } => {
                *cfg = quantize_weights(cfg, *bits);
            }
            Lever::CompressTrace { factor } => {
                // truncate, not round: factor 0.5 must match the legacy
                // integer halving (`decode_tokens /= 2`) bit for bit, odd
                // token counts included
                cfg.shape.decode_tokens =
                    ((cfg.shape.decode_tokens as f64 * factor) as u64).max(1);
            }
            _ => {}
        }
    }

    /// Rewrite the simulation options (PIM residency scopes).
    pub fn apply_options(&self, options: &mut SimOptions) {
        match self {
            Lever::PimWeightStream { .. } => options.enable_pim_residency(true, false),
            Lever::PimKvAttention => options.enable_pim_residency(false, true),
            _ => {}
        }
    }

    /// Is this lever applicable to `platform`?
    pub fn valid_on(&self, platform: &Platform) -> bool {
        !self.requires_pim() || platform.mem.pim.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::vla::tiny_test_config;
    use crate::sim::roofline::PimScope;

    #[test]
    fn groups_and_pim_requirements() {
        assert_eq!(Lever::QuantizeWeights { bits: 8 }.group(), LeverGroup::Weights);
        assert_eq!(Lever::PimWeightStream { bits: 4 }.group(), LeverGroup::Weights);
        assert_eq!(Lever::QuantizeKv.group(), LeverGroup::Kv);
        assert_eq!(Lever::PimKvAttention.group(), LeverGroup::Kv);
        assert!(Lever::PimDraft { gamma: 4, alpha: 0.7 }.requires_pim());
        assert!(!Lever::Speculate { gamma: 4, alpha: 0.7 }.requires_pim());
        assert!(Lever::PimKvAttention.valid_on(&platform::orin_pim()));
        assert!(!Lever::PimKvAttention.valid_on(&platform::orin()));
    }

    #[test]
    fn config_transforms() {
        let mut c = tiny_test_config();
        Lever::QuantizeWeights { bits: 8 }.apply_config(&mut c);
        assert_eq!(c.decoder.dims.dtype, DType::I8);
        assert_eq!(c.decoder.weight_scale, 1.0);
        let mut c4 = tiny_test_config();
        Lever::PimWeightStream { bits: 4 }.apply_config(&mut c4);
        assert_eq!(c4.decoder.dims.dtype, DType::I8);
        assert_eq!(c4.decoder.weight_scale, 0.5);
        let mut t = tiny_test_config();
        Lever::CompressTrace { factor: 0.5 }.apply_config(&mut t);
        assert_eq!(t.shape.decode_tokens, tiny_test_config().shape.decode_tokens / 2);
    }

    #[test]
    fn spec_overhead_tracks_the_acceptance_expectation() {
        // canonical point: (4 + 2) / E(4, 0.7) ~ 2.17
        let e = expected_accepted(4, 0.7);
        let spec = Lever::Speculate { gamma: 4, alpha: 0.7 };
        assert!((spec.modeled_overhead() - 6.0 / e).abs() < 1e-12);
        assert_eq!(
            spec.modeled_overhead(),
            Lever::PimDraft { gamma: 4, alpha: 0.7 }.modeled_overhead()
        );
        // a hostile grid point (deep draft, low acceptance) loosens the
        // bound instead of silently violating the S3 invariant
        let hostile = Lever::Speculate { gamma: 8, alpha: 0.3 };
        assert!(hostile.modeled_overhead() > 5.0);
        // near-perfect acceptance floors at 1 (speculation can only help)
        let ideal = Lever::Speculate { gamma: 2, alpha: 0.99 };
        assert!((1.0..1.5).contains(&ideal.modeled_overhead()));
    }

    #[test]
    fn acceptance_expectation_clamps_the_alpha_one_singularity() {
        // REGRESSION: at α = 1.0 the closed form is 0/0 — the numerator is
        // exactly 0.0, the clamped denominator 1e-9, so E collapsed to 0 and
        // `modeled_overhead` divided to inf. The analytic limit is γ + 1.
        assert_eq!(expected_accepted(4, 1.0), 5.0);
        assert_eq!(expected_accepted(2, 1.5), 3.0, "α past 1 clamps to the same limit");
        let spec = Lever::Speculate { gamma: 4, alpha: 1.0 };
        assert!(spec.modeled_overhead().is_finite());
        assert_eq!(spec.modeled_overhead(), (4.0 + 2.0) / 5.0);
        // the limit is continuous: α = 1 - ε must land next to γ + 1
        assert!((expected_accepted(4, 1.0 - 1e-7) - 5.0).abs() < 1e-4);
    }

    #[test]
    fn shard_lever_surface() {
        let rep = Lever::Shard { mode: ShardMode::Replicate, engines: 4 };
        let pipe = Lever::Shard { mode: ShardMode::PipelineDecoder, engines: 4 };
        assert_eq!(rep.short(), "rep4");
        assert_eq!(pipe.short(), "pipe4");
        assert_eq!(rep.group(), LeverGroup::Serving);
        assert_eq!(pipe.group(), LeverGroup::Serving);
        assert!(!rep.requires_pim() && !pipe.requires_pim());
        assert!(rep.valid_on(&platform::orin()), "sharding needs no PIM hardware");
        assert_eq!(rep.modeled_overhead(), 4.0, "replicate contention bounded by R");
        assert_eq!(pipe.modeled_overhead(), 4.0, "hop costs bounded by R per-token floors");
        // sharding transforms neither the workload config nor the options
        let mut c = tiny_test_config();
        rep.apply_config(&mut c);
        assert_eq!(c, tiny_test_config());
        let mut o = SimOptions::default();
        pipe.apply_options(&mut o);
        assert_eq!(o.pim_scope, SimOptions::default().pim_scope);
    }

    #[test]
    fn offload_lever_surface() {
        let vp = Lever::Offload { mode: OffloadMode::VisionPrefillRemote, link: NetLink::five_g() };
        let dec = Lever::Offload { mode: OffloadMode::DecodeRemote, link: NetLink::wired() };
        assert_eq!(vp.short(), "vp@cloud(5g)");
        assert_eq!(dec.short(), "dec@cloud(wired)");
        assert_eq!(vp.group(), LeverGroup::Placement);
        assert_eq!(dec.group(), LeverGroup::Placement);
        assert!(!vp.requires_pim() && !dec.requires_pim());
        assert!(vp.valid_on(&platform::orin()), "offload needs no PIM hardware");
        assert!(vp.modeled_overhead().is_infinite(), "no finite platform-free slowdown bound");
        // placement transforms neither the workload config nor the options:
        // the evaluator owns the phase substitution and the link charge
        let mut c = tiny_test_config();
        vp.apply_config(&mut c);
        assert_eq!(c, tiny_test_config());
        let mut o = SimOptions::default();
        dec.apply_options(&mut o);
        assert_eq!(o.pim_scope, SimOptions::default().pim_scope);
        assert_eq!(o.pim_stream_dispatch, SimOptions::default().pim_stream_dispatch);
        // link grammar: presets parse, garbage is rejected, labels roundtrip
        assert_eq!(NetLink::parse("5g").unwrap(), NetLink::five_g());
        assert_eq!(NetLink::parse("WiFi6").unwrap(), NetLink::wifi6());
        assert_eq!(NetLink::parse("fiber").unwrap(), NetLink::wired());
        assert!(NetLink::parse("frobnicate").is_err());
        assert_eq!(NetLink::presets().len(), 3);
        for l in NetLink::presets() {
            assert_eq!(NetLink::parse(&l.label()).unwrap(), l);
        }
        assert_eq!(
            NetLink { latency_s: 0.002, bw_gbps: 4.0, usd_per_month: 1.0 }.label(),
            "2ms/4g"
        );
        assert_eq!(OffloadMode::parse("vp").unwrap(), OffloadMode::VisionPrefillRemote);
        assert_eq!(OffloadMode::parse("decode").unwrap(), OffloadMode::DecodeRemote);
        assert!(OffloadMode::parse("sideways").is_err());
    }

    #[test]
    fn residency_options_union() {
        let mut o = SimOptions { pim: false, ..Default::default() };
        Lever::PimWeightStream { bits: 8 }.apply_options(&mut o);
        assert!(o.pim && o.pim_stream_dispatch);
        assert_eq!(o.pim_scope, PimScope::Resident { weights: true, kv: false });
        Lever::PimKvAttention.apply_options(&mut o);
        assert_eq!(o.pim_scope, PimScope::Resident { weights: true, kv: true });
    }
}
