//! Stage- and model-level simulation: walks operator sequences through the
//! roofline model, applies cross-operator prefetch, integrates the
//! autoregressive decode loop over KV-cache growth, and aggregates per-phase
//! latencies and control frequency.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::roofline::{cost_op_scoped_unnamed, Bound, Engine, OpCost, PimScope};
use crate::hw::Platform;
use crate::model::{Phase, Stage, VlaConfig};

/// Simulation options (ablation switches).
#[derive(Debug, Clone)]
pub struct SimOptions {
    // NOTE: `sim::scenario`'s lowering cache fingerprints EVERY field of
    // this struct (`cache::options_fp`, which destructures it exhaustively
    // so a new field is a compile error there until it is covered) — two
    // option sets the simulator distinguishes must never alias a cache key.
    /// Cross-operator prefetch: stream weights of upcoming operators during
    /// current-op execution (paper §3.2, "cross-operator optimization").
    pub prefetch: bool,
    /// Allow PIM offload of eligible memory-bound ops (PIM platforms only).
    pub pim: bool,
    /// Which operator classes the PIM path may take when `pim` is true.
    /// `Auto` (the default) is the simulator's own profitability heuristic
    /// over every eligible op; the `sim::scenario` levers narrow it to
    /// forced weight/KV residency.
    pub pim_scope: PimScope,
    /// PIM command streams are issued ahead by the in-memory controller
    /// (fused, queued) rather than per-op by the eager host framework, so
    /// PIM-executed ops bypass `host_dispatch`. Off by default — the
    /// measured PyTorch runtime dispatches every op — and enabled by the
    /// PIM-residency levers of `sim::scenario`.
    pub pim_stream_dispatch: bool,
    /// Simulate every `decode_stride`-th decode position and interpolate.
    /// 1 = exact. KV traffic is linear in position so error is negligible.
    pub decode_stride: u64,
    /// Framework (PyTorch-eager) host dispatch per operator (s). The paper
    /// profiles the PyTorch runtime on Jetson; eager dispatch serializes with
    /// GPU work when kernels are short. 0 = ideal compiled runtime.
    pub host_dispatch: f64,
    /// CPU image preprocessing (resize/normalize/tile) per crop (s) — part of
    /// the measured vision-encoding phase.
    pub preprocess_per_crop: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            prefetch: true,
            pim: true,
            pim_scope: PimScope::Auto,
            pim_stream_dispatch: false,
            decode_stride: 1,
            host_dispatch: 25e-6,
            preprocess_per_crop: 0.08,
        }
    }
}

impl SimOptions {
    /// An idealized compiled runtime (no eager-framework overheads) — used
    /// for ablations against the measured PyTorch configuration.
    pub fn compiled() -> SimOptions {
        SimOptions {
            host_dispatch: 0.0,
            preprocess_per_crop: 0.0,
            ..Default::default()
        }
    }

    /// The PIM scope after the master `pim` switch.
    pub fn effective_pim_scope(&self) -> PimScope {
        if self.pim { self.pim_scope } else { PimScope::None }
    }

    /// Host-dispatch floor for an op executed on `engine`: PIM command
    /// streams issued by the in-memory controller bypass the eager host.
    /// The single source of this rule for every cost path (simulate,
    /// Chrome-trace export).
    pub fn dispatch_for(&self, engine: Engine) -> f64 {
        if self.pim_stream_dispatch && engine == Engine::Pim { 0.0 } else { self.host_dispatch }
    }

    /// Turn on forced PIM residency for the given operand classes (the
    /// scenario levers compose through this: residencies union).
    pub fn enable_pim_residency(&mut self, weights: bool, kv: bool) {
        self.pim = true;
        self.pim_stream_dispatch = true;
        self.pim_scope = match self.pim_scope {
            PimScope::Resident { weights: w, kv: k } => {
                PimScope::Resident { weights: w || weights, kv: k || kv }
            }
            _ => PimScope::Resident { weights, kv },
        };
    }
}

/// Aggregate execution statistics for one stage.
#[derive(Debug, Clone)]
pub struct StageResult {
    pub name: String,
    pub phase: Phase,
    pub time: f64,
    /// Time if every op ran serially with no inter-op overlap.
    pub time_serial: f64,
    pub flops: f64,
    pub bytes: f64,
    /// Time attributed to compute-bound / memory-bound / overhead-bound ops.
    pub t_compute_bound: f64,
    pub t_memory_bound: f64,
    pub t_overhead_bound: f64,
    /// Fraction of ops offloaded to PIM (by time).
    pub pim_time_frac: f64,
    pub n_ops: usize,
}

impl StageResult {
    /// Achieved FLOP/s over the stage.
    pub fn achieved_flops(&self) -> f64 {
        self.flops / self.time.max(1e-30)
    }

    /// Achieved bytes/s over the stage.
    pub fn achieved_bw(&self) -> f64 {
        self.bytes / self.time.max(1e-30)
    }

    /// Is this stage predominantly memory-bandwidth bound?
    pub fn memory_bound(&self) -> bool {
        self.t_memory_bound > self.t_compute_bound + self.t_overhead_bound
    }
}

/// Streaming accumulator over operator costs (avoids materializing per-op
/// cost vectors on the sweep hot path).
#[derive(Debug, Default, Clone)]
struct CostAcc {
    chain: f64,
    serial: f64,
    weight_stream: f64,
    offchip_bytes: f64,
    t_cb: f64,
    t_mb: f64,
    t_ob: f64,
    pim_time: f64,
    n_ops: usize,
}

impl CostAcc {
    #[inline]
    fn add(&mut self, c: &OpCost, dispatch: f64) {
        // eager host dispatch: a kernel cannot start faster than the
        // framework can issue it — short ops become dispatch-bound
        self.serial += c.t_serial().max(dispatch);
        self.chain += c.t_prefetched().max(dispatch);
        self.weight_stream += c.t_mem_weights;
        if c.engine == Engine::Soc {
            self.offchip_bytes += c.offchip_bytes;
        } else {
            self.pim_time += c.t_serial();
        }
        match c.bound {
            Bound::Compute => self.t_cb += c.t_serial(),
            Bound::Memory => self.t_mb += c.t_serial(),
            Bound::Overhead => self.t_ob += c.t_serial(),
        }
        self.n_ops += 1;
    }
}

/// The analytical XPU simulator.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub platform: Platform,
    pub options: SimOptions,
}

impl Simulator {
    pub fn new(platform: Platform) -> Simulator {
        Simulator {
            platform,
            options: SimOptions::default(),
        }
    }

    pub fn with_options(platform: Platform, options: SimOptions) -> Simulator {
        Simulator { platform, options }
    }

    /// Cost every op in a stage and combine with the prefetch model.
    ///
    /// Without prefetch: ops serialize; each op's time is
    /// `max(compute, weights+activations+kv) + launch`.
    ///
    /// With prefetch: weight streams are decoupled from the dependence chain
    /// (operands move early through the hierarchy, §3.2), so stage time is
    ///   max( Σ max(compute_i, other_mem_i) + launches,   ← dependence chain
    ///        Σ weight_time_i(SoC ops),                   ← off-chip stream
    ///        total_offchip_bytes / bw )                  ← link capacity
    pub fn simulate_stage(&self, stage: &Stage) -> StageResult {
        // PERF: aggregation does not need per-op names; fold without
        // collecting an intermediate Vec.
        let mut acc = CostAcc::default();
        let scope = self.options.effective_pim_scope();
        for op in &stage.ops {
            let c = cost_op_scoped_unnamed(&self.platform, op, scope);
            acc.add(&c, self.options.dispatch_for(c.engine));
        }
        self.finish_stage(stage, acc)
    }

    fn finish_stage(&self, stage: &Stage, acc: CostAcc) -> StageResult {
        let CostAcc {
            chain,
            serial,
            weight_stream,
            offchip_bytes,
            t_cb,
            t_mb,
            t_ob,
            pim_time,
            n_ops,
        } = acc;
        let link_time = offchip_bytes / self.platform.mem.effective_bw();
        let time = if self.options.prefetch {
            chain.max(weight_stream).max(link_time)
        } else {
            serial
        };
        StageResult {
            name: stage.name.clone(),
            phase: stage.phase,
            time,
            time_serial: serial,
            flops: stage.total_flops(),
            bytes: stage.total_bytes(),
            t_compute_bound: t_cb,
            t_memory_bound: t_mb,
            t_overhead_bound: t_ob,
            pim_time_frac: if serial > 0.0 { pim_time / serial } else { 0.0 },
            n_ops,
        }
    }

    /// Simulate the full decode phase: one stage per generated token with the
    /// KV cache growing from `prefill_len` to `prefill_len + decode_tokens`.
    pub fn simulate_decode(&self, config: &VlaConfig) -> StageResult {
        let start = config.shape.prefill_len();
        let n = config.shape.decode_tokens;
        let stride = self.options.decode_stride.max(1);
        let mut acc: Option<StageResult> = None;
        let mut simulated = 0u64;
        let mut pos = 0u64;
        // PERF: build the operator sequence once and patch the KV-dependent
        // ops per position (see VlaConfig::patch_decode_stage_kv) — stage
        // construction used to dominate the sweep wall time.
        let mut stage = config.decode_stage_at(start);
        while pos < n {
            config.patch_decode_stage_kv(&mut stage, start + pos);
            let r = self.simulate_stage(&stage);
            simulated += 1;
            acc = Some(match acc {
                None => r,
                Some(mut a) => {
                    a.time += r.time;
                    a.time_serial += r.time_serial;
                    a.flops += r.flops;
                    a.bytes += r.bytes;
                    a.t_compute_bound += r.t_compute_bound;
                    a.t_memory_bound += r.t_memory_bound;
                    a.t_overhead_bound += r.t_overhead_bound;
                    a.pim_time_frac += r.pim_time_frac;
                    a.n_ops += r.n_ops;
                    a
                }
            });
            pos += stride;
        }
        let mut total = acc.expect("decode_tokens > 0");
        // scale sampled positions up to the full token count
        let scale = n as f64 / simulated as f64;
        total.time *= scale;
        total.time_serial *= scale;
        total.flops *= scale;
        total.bytes *= scale;
        total.t_compute_bound *= scale;
        total.t_memory_bound *= scale;
        total.t_overhead_bound *= scale;
        total.pim_time_frac /= simulated as f64;
        total.name = format!("decode x{n}");
        total
    }

    /// Simulate a full VLA control step.
    pub fn simulate_vla(&self, config: &VlaConfig) -> VlaSimResult {
        let mut vision = self.simulate_stage(&config.vision_stage());
        // measured vision phase includes CPU-side image preprocessing
        let prep = self.options.preprocess_per_crop * config.shape.crops as f64;
        vision.time += prep;
        vision.time_serial += prep;
        vision.t_overhead_bound += prep;
        let prefill = self.simulate_stage(&config.prefill_stage());
        let decode = self.simulate_decode(config);
        let action = self.simulate_stage(&config.action_stage());
        VlaSimResult {
            model: config.name.clone(),
            platform: self.platform.name.clone(),
            action_horizon: config.action.horizon,
            vision,
            prefill,
            decode,
            action,
        }
    }
}

/// Per-phase latency decomposition of one VLA control step (Fig 2's unit).
#[derive(Debug, Clone)]
pub struct VlaSimResult {
    pub model: String,
    pub platform: String,
    pub action_horizon: u64,
    pub vision: StageResult,
    pub prefill: StageResult,
    pub decode: StageResult,
    pub action: StageResult,
}

impl VlaSimResult {
    pub fn stages(&self) -> [&StageResult; 4] {
        [&self.vision, &self.prefill, &self.decode, &self.action]
    }

    /// End-to-end latency of one control step.
    pub fn total(&self) -> f64 {
        self.stages().iter().map(|s| s.time).sum()
    }

    /// Generation-phase (prefill + decode) share of total latency — the
    /// paper's headline ~75% figure.
    pub fn generation_share(&self) -> f64 {
        (self.prefill.time + self.decode.time) / self.total().max(1e-30)
    }

    /// Control frequency if each step produces one action (Hz).
    pub fn control_frequency(&self) -> f64 {
        1.0 / self.total().max(1e-30)
    }

    /// Amortized control frequency when each step emits an action chunk over
    /// the horizon (actions/s achievable with chunked execution).
    pub fn amortized_frequency(&self) -> f64 {
        self.action_horizon as f64 / self.total().max(1e-30)
    }

    pub fn phase_time(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Vision => self.vision.time,
            Phase::Prefill => self.prefill.time,
            Phase::Decode => self.decode.time,
            Phase::Action => self.action.time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::vla::tiny_test_config;
    use crate::model::{molmoact::molmoact_7b, Phase};

    #[test]
    fn stage_times_positive_and_consistent() {
        let sim = Simulator::new(platform::orin());
        let c = tiny_test_config();
        let stages =
            [c.vision_stage(), c.prefill_stage(), c.decode_stage_at(100), c.action_stage()];
        for stage in stages {
            let r = sim.simulate_stage(&stage);
            assert!(r.time > 0.0, "{}", r.name);
            assert!(r.time <= r.time_serial * 1.0000001, "prefetch can't exceed serial");
        }
    }

    #[test]
    fn molmoact_on_orin_matches_paper_shape() {
        // Fig 2 claims: generation ~75% of step latency; E2E 200-300x the
        // 100 ms (10 Hz) budget.
        let sim = Simulator::new(platform::orin());
        let r = sim.simulate_vla(&molmoact_7b());
        let total = r.total();
        assert!(
            total > 10.0 && total < 40.0,
            "Orin E2E should be tens of seconds (paper: 200-300x over 100ms): {total}"
        );
        let share = r.generation_share();
        assert!(
            (0.6..0.95).contains(&share),
            "generation share should be ~75%: {share}"
        );
        assert!(r.decode.memory_bound(), "decode must be memory-BW bound");
        assert!(!r.vision.memory_bound(), "vision encode is compute-bound");
    }

    #[test]
    fn thor_speedup_tracks_bandwidth_not_compute() {
        // Paper: "Thor provides 5x the compute of Orin, the end-to-end
        // latency only improves by 1.4x".
        let orin = Simulator::new(platform::orin()).simulate_vla(&molmoact_7b());
        let thor = Simulator::new(platform::thor()).simulate_vla(&molmoact_7b());
        let speedup = orin.total() / thor.total();
        assert!(
            (1.15..2.0).contains(&speedup),
            "E2E Thor speedup should be ~1.4x, got {speedup}"
        );
        // decode speedup specifically ~ BW ratio (273/203 = 1.34)
        let dec_speedup = orin.decode.time / thor.decode.time;
        assert!((1.1..1.7).contains(&dec_speedup), "decode speedup {dec_speedup}");
    }

    #[test]
    fn decode_dominated_by_weight_streaming() {
        let sim = Simulator::new(platform::orin());
        let r = sim.simulate_decode(&molmoact_7b());
        // per-token time ~ decoder bytes / effective BW
        let per_token = r.time / molmoact_7b().shape.decode_tokens as f64;
        let ideal = molmoact_7b().decoder_weight_bytes() / platform::orin().mem.effective_bw();
        assert!(
            per_token > 0.9 * ideal && per_token < 2.0 * ideal,
            "per-token {per_token} vs weight-stream ideal {ideal}"
        );
    }

    #[test]
    fn prefetch_reduces_decode_time() {
        let c = molmoact_7b();
        let opts = |prefetch| SimOptions { prefetch, ..Default::default() };
        let on = Simulator::with_options(platform::orin(), opts(true));
        let off = Simulator::with_options(platform::orin(), opts(false));
        let t_on = on.simulate_decode(&c).time;
        let t_off = off.simulate_decode(&c).time;
        assert!(t_on < t_off, "prefetch must help: {t_on} vs {t_off}");
    }

    #[test]
    fn pim_offload_accelerates_decode() {
        let c = molmoact_7b();
        let base = Simulator::new(platform::orin()).simulate_decode(&c);
        let pim = Simulator::new(platform::orin_pim()).simulate_decode(&c);
        let speedup = base.time / pim.time;
        assert!(speedup > 4.0, "PIM decode speedup {speedup}");
        assert!(pim.pim_time_frac > 0.3, "most decode time should be on PIM");
        // disabling pim on the pim platform falls back to off-chip BW only
        let no_off = Simulator::with_options(
            platform::orin_pim(),
            SimOptions { pim: false, ..Default::default() },
        )
        .simulate_decode(&c);
        assert!(no_off.time > pim.time);
    }

    #[test]
    fn decode_stride_interpolation_close_to_exact() {
        let c = molmoact_7b();
        let exact = Simulator::new(platform::orin()).simulate_decode(&c).time;
        let strided = Simulator::with_options(
            platform::orin(),
            SimOptions { decode_stride: 8, ..Default::default() },
        )
        .simulate_decode(&c)
        .time;
        assert!(
            (exact - strided).abs() / exact < 0.02,
            "stride-8 error {}",
            (exact - strided).abs() / exact
        );
    }

    #[test]
    fn control_frequency_is_inverse_total() {
        let sim = Simulator::new(platform::thor());
        let r = sim.simulate_vla(&tiny_test_config());
        assert!((r.control_frequency() * r.total() - 1.0).abs() < 1e-9);
        assert!((r.amortized_frequency() / r.control_frequency() - 8.0).abs() < 1e-9);
        assert_eq!(r.phase_time(Phase::Decode), r.decode.time);
    }
}
