//! Per-operator roofline cost model: `t = max(t_compute, t_memory)` with
//! micro-architectural corrections (tiling efficiency, asymmetric
//! matrix-engine bandwidth, L2 residency, kernel-launch overhead) and
//! optional PIM execution for eligible memory-bound operators.

use super::tiling::matmul_efficiency;
use crate::hw::Platform;
use crate::model::{OpKind, Operator};

/// Which resource bounds the operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Overhead,
}

impl Bound {
    /// Human-readable classification label (scenario/report tables).
    pub fn label(self) -> &'static str {
        match self {
            Bound::Compute => "compute",
            Bound::Memory => "memory",
            Bound::Overhead => "overhead",
        }
    }
}

/// Which operator classes PIM execution may take.
///
/// `Auto` is the simulator's profitability heuristic: offload any
/// PIM-eligible op that is memory-bound on the SoC when the PIM path is
/// faster. `Resident` is the scenario engine's *placement* semantic: the
/// named operand class (decoder weights and/or the KV cache) lives in the
/// PIM banks, so its admitted operators execute there unconditionally —
/// residency is a data-layout decision, not a per-op dispatch choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PimScope {
    /// No PIM execution.
    None,
    /// Heuristic offload of every PIM-eligible op (the ambient default).
    Auto,
    /// Forced residency of decoder weights and/or the KV cache.
    Resident { weights: bool, kv: bool },
}

impl PimScope {
    /// Does this scope send `op` down the PIM path at all?
    pub fn admits(self, op: &Operator) -> bool {
        if !op.pim_eligible() {
            return false;
        }
        match self {
            PimScope::None => false,
            PimScope::Auto => true,
            PimScope::Resident { weights, kv } => {
                (weights && matches!(op.kind, OpKind::MatmulWeight))
                    || (kv && (op.kv_bytes > 0.0 || matches!(op.kind, OpKind::Softmax)))
            }
        }
    }
}

/// Where the operator executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Soc,
    Pim,
}

/// Fully-resolved operator cost.
#[derive(Debug, Clone)]
pub struct OpCost {
    pub name: String,
    pub kind: OpKind,
    pub engine: Engine,
    /// Time on the compute units (s).
    pub t_compute: f64,
    /// Off-chip time for weight streaming (s) — the component cross-operator
    /// prefetch can hide.
    pub t_mem_weights: f64,
    /// Off-chip/L2 time for activations + KV (s) — on the dependence chain.
    pub t_mem_other: f64,
    /// Fixed launch/dispatch overhead (s).
    pub t_overhead: f64,
    pub flops: f64,
    pub bytes: f64,
    /// Bytes that actually traverse the off-chip DRAM link (weights + KV +
    /// L2-missing activations). Zero for PIM-executed ops.
    pub offchip_bytes: f64,
    pub bound: Bound,
}

impl OpCost {
    /// Inline (serial, no cross-op prefetch) duration.
    pub fn t_serial(&self) -> f64 {
        self.t_compute.max(self.t_mem_weights + self.t_mem_other) + self.t_overhead
    }

    /// Inline duration when weight streaming is hidden by the prefetcher
    /// (weights still consume bandwidth — accounted at stage level).
    pub fn t_prefetched(&self) -> f64 {
        self.t_compute.max(self.t_mem_other) + self.t_overhead
    }

    fn classify(t_compute: f64, t_mem: f64, t_overhead: f64) -> Bound {
        if t_overhead > t_compute.max(t_mem) {
            Bound::Overhead
        } else if t_compute >= t_mem {
            Bound::Compute
        } else {
            Bound::Memory
        }
    }
}

/// Cost an operator on the SoC path of `platform`.
pub fn cost_on_soc(platform: &Platform, op: &Operator) -> OpCost {
    cost_on_soc_impl(platform, op, true)
}

fn cost_on_soc_impl(platform: &Platform, op: &Operator, with_name: bool) -> OpCost {
    let soc = &platform.soc;
    let dram_bw = platform.mem.effective_bw();

    // --- compute time ---
    let t_compute = match op.kind {
        OpKind::MatmulWeight | OpKind::MatmulAct => {
            let eff = matmul_efficiency(soc, op.batch, op.m, op.n, op.k, op.dtype);
            op.flops / (soc.flops_bf16 * eff)
        }
        // streaming ops run on the vector units
        _ => op.flops / (soc.flops_f32 * 0.5),
    };

    // --- memory time ---
    // Weights always stream from DRAM; the reduction-dim layout penalty
    // models the matrix engine's asymmetric bandwidth (§3.2).
    let weight_penalty = if matches!(op.kind, OpKind::MatmulWeight) {
        soc.reduction_bw_penalty
    } else {
        1.0
    };
    let t_mem_weights = op.weight_bytes * weight_penalty / dram_bw;
    // Activations may be L2-resident between adjacent ops; KV always misses.
    let act_bytes = op.act_in_bytes + op.act_out_bytes;
    let l2_resident = act_bytes <= soc.l2_bytes;
    let act_bw = if l2_resident {
        soc.l2_bw.min(dram_bw * 4.0) // L2 hit path
    } else {
        dram_bw
    };
    let t_mem_other = act_bytes / act_bw + op.kv_bytes / dram_bw;
    let offchip_bytes =
        op.weight_bytes + op.kv_bytes + if l2_resident { 0.0 } else { act_bytes };

    let t_overhead = soc.kernel_launch_overhead;
    OpCost {
        name: if with_name { op.name.clone() } else { String::new() },
        kind: op.kind,
        engine: Engine::Soc,
        t_compute,
        t_mem_weights,
        t_mem_other,
        t_overhead,
        flops: op.flops,
        bytes: op.total_bytes(),
        offchip_bytes,
        bound: OpCost::classify(t_compute, t_mem_weights + t_mem_other, t_overhead),
    }
}

/// Cost an operator on the PIM units, if the platform has PIM.
pub fn cost_on_pim(platform: &Platform, op: &Operator) -> Option<OpCost> {
    let pim = platform.mem.pim.as_ref()?;
    let t_compute = op.flops / pim.flops_bf16;
    // all operands stream at PIM internal bandwidth; no off-chip traffic
    let t_mem = op.total_bytes() / pim.effective_bw();
    let t_overhead = pim.dispatch_overhead;
    Some(OpCost {
        name: op.name.clone(),
        kind: op.kind,
        engine: Engine::Pim,
        t_compute,
        // PIM weights are NOT prefetchable over the off-chip link — they are
        // already in memory; fold all traffic into the dependence chain.
        t_mem_weights: 0.0,
        t_mem_other: t_mem,
        t_overhead,
        flops: op.flops,
        bytes: op.total_bytes(),
        offchip_bytes: 0.0,
        bound: OpCost::classify(t_compute, t_mem, t_overhead),
    })
}

/// Choose the best engine for `op` under the given options.
pub fn cost_op(platform: &Platform, op: &Operator, allow_pim: bool) -> OpCost {
    cost_op_scoped_impl(platform, op, bool_scope(allow_pim), true)
}

/// Scope-aware engine choice (the scenario engine's entry point).
pub fn cost_op_scoped(platform: &Platform, op: &Operator, scope: PimScope) -> OpCost {
    cost_op_scoped_impl(platform, op, scope, true)
}

/// PERF variant for the aggregation-only simulate hot path: skips the
/// per-op name clone (~430 String allocations per decode step otherwise).
pub fn cost_op_scoped_unnamed(platform: &Platform, op: &Operator, scope: PimScope) -> OpCost {
    cost_op_scoped_impl(platform, op, scope, false)
}

fn bool_scope(allow_pim: bool) -> PimScope {
    if allow_pim { PimScope::Auto } else { PimScope::None }
}

fn cost_op_scoped_impl(
    platform: &Platform,
    op: &Operator,
    scope: PimScope,
    with_name: bool,
) -> OpCost {
    let soc = cost_on_soc_impl(platform, op, with_name);
    if !scope.admits(op) {
        return soc;
    }
    let pim = match cost_on_pim(platform, op) {
        Some(pim) => pim,
        None => return soc,
    };
    match scope {
        // residency: the operands live in the PIM banks — admitted ops run
        // there whether or not the per-op heuristic would have chosen to
        PimScope::Resident { .. } => pim,
        // auto: offload only when the op is memory-bound on the SoC and PIM wins
        _ if soc.bound == Bound::Memory && pim.t_serial() < soc.t_serial() => pim,
        _ => soc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{platform, DType};
    use crate::model::Operator;

    #[test]
    fn gemv_memory_bound_gemm_compute_bound() {
        let p = platform::orin();
        let gemv = cost_on_soc(&p, &Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16));
        assert_eq!(gemv.bound, Bound::Memory);
        let gemm = cost_on_soc(&p, &Operator::matmul_weight("m", 1, 640, 18944, 3584, DType::BF16));
        assert_eq!(gemm.bound, Bound::Compute);
    }

    #[test]
    fn gemv_time_tracks_bandwidth() {
        // 7B-class GEMV: t ~ weight_bytes / bw
        let p = platform::orin();
        let op = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        let c = cost_on_soc(&p, &op);
        let ideal = op.weight_bytes / p.mem.effective_bw();
        assert!(c.t_mem_weights >= ideal && c.t_mem_weights < 1.5 * ideal);
        assert!(c.t_serial() < 2.0 * ideal, "GEMV should be near the BW bound");
    }

    #[test]
    fn more_bandwidth_reduces_gemv_time() {
        let op = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        let t_orin = cost_on_soc(&platform::orin(), &op).t_serial();
        let t_gddr7 = cost_on_soc(&platform::orin_gddr7(), &op).t_serial();
        assert!(t_gddr7 < t_orin / 3.0, "{t_orin} vs {t_gddr7}");
    }

    #[test]
    fn more_compute_barely_helps_gemv() {
        // Thor has 5x compute but only 1.34x bandwidth: GEMV speedup ~ BW ratio
        let op = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        let t_orin = cost_on_soc(&platform::orin(), &op).t_serial();
        let t_thor = cost_on_soc(&platform::thor(), &op).t_serial();
        let speedup = t_orin / t_thor;
        assert!(speedup < 1.8, "memory-bound op speedup {speedup} should track BW not compute");
    }

    #[test]
    fn pim_offload_happens_for_memory_bound_only() {
        let p = platform::orin_pim();
        let gemv = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        assert_eq!(cost_op(&p, &gemv, true).engine, Engine::Pim);
        assert_eq!(cost_op(&p, &gemv, false).engine, Engine::Soc);
        let gemm = Operator::matmul_weight("m", 1, 640, 18944, 3584, DType::BF16);
        assert_eq!(cost_op(&p, &gemm, true).engine, Engine::Soc);
    }

    #[test]
    fn pim_speeds_up_gemv_by_bandwidth_ratio() {
        let pim = platform::orin_pim();
        let base = platform::orin();
        let op = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        let t_base = cost_op(&base, &op, true).t_serial();
        let t_pim = cost_op(&pim, &op, true).t_serial();
        let speedup = t_base / t_pim;
        // BW ratio 2180*0.85 / (203*0.8) ~ 11.4; allow latency overheads
        assert!(speedup > 6.0 && speedup < 13.0, "PIM GEMV speedup {speedup}");
    }

    #[test]
    fn tiny_op_overhead_bound() {
        let p = platform::orin();
        let op = Operator::norm("ln", 1, 64, DType::BF16);
        let c = cost_on_soc(&p, &op);
        assert_eq!(c.bound, Bound::Overhead);
    }

    #[test]
    fn no_pim_on_non_pim_platform() {
        assert!(cost_on_pim(&platform::thor(), &Operator::norm("n", 1, 64, DType::BF16)).is_none());
    }

    #[test]
    fn resident_scope_forces_admitted_ops_onto_pim() {
        let p = platform::orin_pim();
        // a small attention read: launch-overhead-bound on the SoC, so the
        // Auto heuristic keeps it there — residency forces the PIM path
        let qk = Operator::matmul_act("qk", 4, 7, 800, 128, DType::BF16, true);
        assert_eq!(cost_op(&p, &qk, true).engine, Engine::Soc);
        let kv_scope = PimScope::Resident { weights: false, kv: true };
        assert_eq!(cost_op_scoped(&p, &qk, kv_scope).engine, Engine::Pim);
        // ...but a weights-only residency does not admit attention ops
        let w_scope = PimScope::Resident { weights: true, kv: false };
        assert_eq!(cost_op_scoped(&p, &qk, w_scope).engine, Engine::Soc);
        let gemv = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        assert_eq!(cost_op_scoped(&p, &gemv, w_scope).engine, Engine::Pim);
        assert_eq!(cost_op_scoped(&p, &gemv, kv_scope).engine, Engine::Soc);
    }

    #[test]
    fn scoped_none_and_auto_match_bool_api() {
        let p = platform::orin_pim();
        let gemv = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        for (scope, allow) in [(PimScope::None, false), (PimScope::Auto, true)] {
            let a = cost_op_scoped(&p, &gemv, scope);
            let b = cost_op(&p, &gemv, allow);
            assert_eq!(a.engine, b.engine);
            assert_eq!(a.t_serial().to_bits(), b.t_serial().to_bits());
        }
    }

    #[test]
    fn resident_scope_is_noop_without_pim_hardware() {
        let p = platform::thor();
        let gemv = Operator::matmul_weight("v", 1, 1, 18944, 3584, DType::BF16);
        let scope = PimScope::Resident { weights: true, kv: true };
        assert_eq!(cost_op_scoped(&p, &gemv, scope).engine, Engine::Soc);
    }

    #[test]
    fn bound_labels() {
        assert_eq!(Bound::Memory.label(), "memory");
        assert_eq!(Bound::Compute.label(), "compute");
        assert_eq!(Bound::Overhead.label(), "overhead");
    }
}
