//! Algorithm–system co-design projections.
//!
//! The paper's conclusion: "Standard memory scaling is insufficient ...
//! Future research must explore holistic system optimizations — both
//! hardware and software — to bridge the latency gap." This module models
//! the leading software-side levers on top of the hardware matrix, each as
//! a transformation of the workload or of the effective decode cost:
//!
//! - **Weight quantization** (W8/W4): decode streams fewer bytes per token.
//! - **KV-cache quantization**: shrinks cache traffic (matters at long CoT).
//! - **Speculative decoding**: a small draft model proposes `gamma` tokens,
//!   the target verifies them in one batched pass (accept rate `alpha`).
//! - **Reasoning-trace compression**: fewer generated tokens per step.
//! - **Batched multi-robot serving**: aggregate tokens/s vs per-stream Hz.
//!
//! The levers themselves now live in [`sim::scenario`](super::scenario) as
//! [`Lever`]s; the study below is a fixed stack of scenarios evaluated with
//! ambient options passed through unchanged, which keeps every number this
//! module has always produced bitwise-identical to the pre-scenario
//! implementation (pinned by `experiment_tests`).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::scenario::{Evaluator, Lever, Scenario};
use super::simulator::{SimOptions, Simulator};
use crate::hw::Platform;
use crate::model::vla::VlaConfig;
use crate::util::table::Table;

// The canonical weight-quantization transform lives with the levers; this
// module keeps its historical entry point as a re-export.
pub use super::scenario::quantize_weights;

/// One co-design configuration and its projected effect.
#[derive(Debug, Clone)]
pub struct CodesignResult {
    pub technique: String,
    pub step_latency: f64,
    pub control_hz: f64,
    pub amortized_hz: f64,
    pub speedup_vs_baseline: f64,
}

/// Speculative decoding: draft model proposes `gamma` tokens per target
/// pass; expected accepted tokens per verify is
/// E = (1 - alpha^(gamma+1)) / (1 - alpha). Target verification of gamma+1
/// tokens is one batched pass (weights read once). Returns projected decode
/// time for the full trace. (The canonical formula lives in
/// [`scenario::speculative_decode`](super::scenario::speculative_decode).)
pub fn speculative_decode_time(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> f64 {
    super::scenario::speculative_decode(platform, options, target, draft, gamma, alpha).0
}

/// The fixed lever stacks of the classic study, with their legacy labels.
fn study_scenarios() -> [(Scenario, &'static str); 6] {
    [
        (Scenario::baseline(), "baseline (bf16, full trace)"),
        (Scenario::of(vec![Lever::QuantizeWeights { bits: 8 }]), "W8 weight quantization"),
        (Scenario::of(vec![Lever::QuantizeKv]), "KV-cache 8-bit (approx)"),
        (
            Scenario::of(vec![Lever::CompressTrace { factor: 0.5 }]),
            "trace compression (0.5x tokens)",
        ),
        (
            Scenario::of(vec![Lever::Speculate { gamma: 4, alpha: 0.7 }]),
            "speculative decode (g=4, a=0.7)",
        ),
        (
            Scenario::of(vec![
                Lever::QuantizeWeights { bits: 8 },
                Lever::CompressTrace { factor: 0.5 },
                Lever::Speculate { gamma: 4, alpha: 0.7 },
            ]),
            "combined (W8 + 0.5x trace + spec)",
        ),
    ]
}

/// Run the co-design study on one platform: the classic six rows, evaluated
/// through the scenario engine with the ambient options passed through
/// unchanged (so PIM platforms keep their auto-offload baseline).
pub fn codesign_study(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
) -> Vec<CodesignResult> {
    let ev = Evaluator::new(platform, options, target, draft);
    study_scenarios()
        .into_iter()
        .map(|(scenario, technique)| {
            let r = ev.eval(&scenario).expect("study levers are platform-agnostic");
            CodesignResult {
                technique: technique.into(),
                step_latency: r.step_latency,
                control_hz: r.control_hz,
                amortized_hz: r.amortized_hz,
                speedup_vs_baseline: r.speedup_vs_baseline,
            }
        })
        .collect()
}

/// Render the study as a table.
pub fn codesign_table(platform_name: &str, model_name: &str, results: &[CodesignResult]) -> Table {
    let mut t = Table::new(
        &format!("Co-design projections on {platform_name} ({model_name})"),
        &["technique", "step (s)", "Hz", "actions/s", "speedup"],
    )
    .left_first();
    for r in results {
        t.row(vec![
            r.technique.clone(),
            format!("{:.2}", r.step_latency),
            format!("{:.3}", r.control_hz),
            format!("{:.3}", r.amortized_hz),
            format!("{:.2}x", r.speedup_vs_baseline),
        ]);
    }
    t
}

/// Hardware × software matrix: the combined co-design technique evaluated
/// on every platform of `platforms`, in parallel on the sweep runner. The
/// single source of the matrix that `codesign` and `report` both emit.
pub fn combined_matrix(
    platforms: &[Platform],
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
) -> Table {
    let mut t = Table::new(
        "Combined co-design across the platform matrix",
        &["Platform", "baseline actions/s", "combined actions/s", "gain"],
    )
    .left_first();
    let rows = super::sweep::parallel_map(platforms, |p| {
        let r = codesign_study(p, options, target, draft);
        let base = &r[0];
        let combo = r.last().unwrap();
        vec![
            p.name.clone(),
            format!("{:.3}", base.amortized_hz),
            format!("{:.3}", combo.amortized_hz),
            format!("{:.2}x", combo.speedup_vs_baseline),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

/// Batched serving study: per-stream latency vs aggregate throughput
/// (E-A2). Shows batching recovers aggregate tokens/s but NOT per-robot
/// control latency.
pub fn batch_study(
    platform: &Platform,
    options: &SimOptions,
    cfg: &VlaConfig,
    batches: &[u64],
) -> Table {
    let mut t = Table::new(
        &format!("Batched decode on {} ({})", platform.name, cfg.name),
        &["batch", "step time (ms)", "per-stream tok/s", "aggregate tok/s", "intensity (FLOP/B)"],
    );
    let kv = cfg.shape.prefill_len() + cfg.shape.decode_tokens / 2;
    for &b in batches {
        let stage = cfg.decode_stage_batched(kv, b);
        let r = Simulator::with_options(platform.clone(), options.clone()).simulate_stage(&stage);
        t.row(vec![
            format!("{b}"),
            format!("{:.2}", r.time * 1e3),
            format!("{:.2}", 1.0 / r.time),
            format!("{:.2}", b as f64 / r.time),
            format!("{:.2}", stage.intensity()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{platform, DType};
    use crate::model::molmoact::molmoact_7b;
    use crate::model::scaling::scaled_vla;

    fn opts() -> SimOptions {
        SimOptions {
            decode_stride: 16,
            ..Default::default()
        }
    }

    #[test]
    fn every_technique_helps() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        assert_eq!(results.len(), 6);
        for r in &results[1..] {
            // KV quantization is ~neutral at 7B: GQA keeps the cache tiny
            // relative to 14 GB of weights per token — itself a finding.
            let floor = if r.technique.starts_with("KV") { 0.99 } else { 1.0 };
            assert!(
                r.speedup_vs_baseline > floor,
                "{} should not slow decode: {}x",
                r.technique,
                r.speedup_vs_baseline
            );
        }
    }

    #[test]
    fn w8_speedup_tracks_bytes() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let w8 = results.iter().find(|r| r.technique.starts_with("W8")).unwrap();
        // halving weight bytes on a BW-bound decode ~ 1.5-2x end-to-end
        assert!(
            (1.3..2.2).contains(&w8.speedup_vs_baseline),
            "W8 speedup {}",
            w8.speedup_vs_baseline
        );
    }

    #[test]
    fn combined_beats_individuals() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let combined = results.last().unwrap().speedup_vs_baseline;
        for r in &results[1..results.len() - 1] {
            if r.technique.starts_with("KV") {
                continue; // ~neutral at 7B, see every_technique_helps
            }
            assert!(
                combined > r.speedup_vs_baseline,
                "combined {combined} <= {} ({})",
                r.speedup_vs_baseline,
                r.technique
            );
        }
    }

    #[test]
    fn codesign_plus_pim_approaches_target() {
        // the paper's thesis: hardware OR software alone is insufficient;
        // together they close most of the gap at 7B
        let results =
            codesign_study(&platform::thor_pim(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let combined = results.last().unwrap();
        assert!(
            combined.amortized_hz > 2.0,
            "PIM + co-design should approach the 10 Hz band: {} actions/s",
            combined.amortized_hz
        );
        // and co-design still adds a solid margin on top of PIM hardware
        let base = &results[0];
        assert!(combined.amortized_hz > base.amortized_hz * 1.3);
    }

    #[test]
    fn batching_raises_aggregate_not_per_stream() {
        let t = batch_study(&platform::orin(), &opts(), &molmoact_7b(), &[1, 4, 16]);
        let agg = |r: usize| -> f64 { t.cell(r, 3).parse().unwrap() };
        let per = |r: usize| -> f64 { t.cell(r, 2).parse().unwrap() };
        assert!(agg(2) > 3.0 * agg(0), "batching must lift aggregate throughput");
        assert!(per(2) <= per(0) * 1.05, "per-stream rate cannot improve with batching");
    }

    #[test]
    fn combined_matrix_gains_everywhere() {
        let t = combined_matrix(
            &platform::sweep_platforms(),
            &opts(),
            &molmoact_7b(),
            &scaled_vla(2.0),
        );
        assert_eq!(t.n_rows(), platform::sweep_platforms().len());
        for r in 0..t.n_rows() {
            let gain: f64 = t.cell(r, 3).trim_end_matches('x').parse().unwrap();
            assert!(gain > 1.0, "combined co-design must help on every platform: row {r}");
        }
    }

    #[test]
    fn speculative_model_sane() {
        let t_spec = speculative_decode_time(
            &platform::orin(),
            &opts(),
            &molmoact_7b(),
            &scaled_vla(2.0),
            4,
            0.7,
        );
        let t_base = Simulator::with_options(platform::orin(), opts())
            .simulate_decode(&molmoact_7b())
            .time;
        assert!(t_spec < t_base, "speculation should help a BW-bound target");
        assert!(t_spec > t_base / 6.0, "but not unrealistically");
    }

    #[test]
    fn w4_decode_bytes_half_of_w8() {
        // regression: the 4-bit arm used to fall through to the unquantized
        // dtype, so W4 results silently equaled bf16
        let base = molmoact_7b();
        let w8 = quantize_weights(&base, 8);
        let w4 = quantize_weights(&base, 4);
        assert_eq!(w8.decoder.dims.dtype, DType::I8);
        assert_eq!(w4.decoder.dims.dtype, DType::I8);
        let kv = base.shape.prefill_len() + 64;
        let ratio = w4.decode_stage_at(kv).weight_bytes() / w8.decode_stage_at(kv).weight_bytes();
        assert!((ratio - 0.5).abs() < 0.01, "W4 decode weight bytes ratio {ratio}");
        assert!(
            (w4.decoder_weight_bytes() / w8.decoder_weight_bytes() - 0.5).abs() < 1e-9,
            "decoder bytes must halve"
        );
        // and W4 decode is strictly faster than W8 on a BW-bound platform
        let sim = Simulator::with_options(platform::orin(), opts());
        assert!(sim.simulate_decode(&w4).time < sim.simulate_decode(&w8).time);
        // unknown widths still pass through unchanged
        assert_eq!(quantize_weights(&base, 16), base);
    }
}
