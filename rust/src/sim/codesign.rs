//! Algorithm–system co-design projections.
//!
//! The paper's conclusion: "Standard memory scaling is insufficient ...
//! Future research must explore holistic system optimizations — both
//! hardware and software — to bridge the latency gap." This module models
//! the leading software-side levers on top of the hardware matrix, each as
//! a transformation of the workload or of the effective decode cost:
//!
//! - **Weight quantization** (W8/W4): decode streams fewer bytes per token.
//! - **KV-cache quantization**: shrinks cache traffic (matters at long CoT).
//! - **Speculative decoding**: a small draft model proposes `gamma` tokens,
//!   the target verifies them in one batched pass (accept rate `alpha`).
//! - **Reasoning-trace compression**: fewer generated tokens per step.
//! - **Batched multi-robot serving**: aggregate tokens/s vs per-stream Hz.

use super::simulator::{SimOptions, Simulator};
use crate::hw::{DType, Platform};
use crate::model::vla::VlaConfig;
use crate::util::table::Table;

/// Scale all weight bytes of a config's decoder by using a narrower dtype
/// (keeps activations in bf16 — W8A16-style inference).
fn quantize_weights(cfg: &VlaConfig, bits: u32) -> VlaConfig {
    let mut c = cfg.clone();
    // model narrower weights by scaling weight_bytes via dtype substitution:
    // I8 for 8-bit; 4-bit is modeled as I8 with half the layers' bytes, so
    // instead we scale the stage at simulation time. Simplest faithful knob:
    // swap the decoder dtype and let bytes follow.
    c.decoder.dims.dtype = match bits {
        8 => DType::I8,
        _ => c.decoder.dims.dtype,
    };
    c
}

/// One co-design configuration and its projected effect.
#[derive(Debug, Clone)]
pub struct CodesignResult {
    pub technique: String,
    pub step_latency: f64,
    pub control_hz: f64,
    pub amortized_hz: f64,
    pub speedup_vs_baseline: f64,
}

/// Decode-phase latency of `cfg` on `platform` (helper).
fn decode_time(platform: &Platform, options: &SimOptions, cfg: &VlaConfig) -> f64 {
    Simulator::with_options(platform.clone(), options.clone())
        .simulate_decode(cfg)
        .time
}

/// Full-step latency with an overridden decode time.
fn step_with_decode(
    platform: &Platform,
    options: &SimOptions,
    cfg: &VlaConfig,
    decode: f64,
) -> f64 {
    let sim = Simulator::with_options(platform.clone(), options.clone());
    let r = sim.simulate_vla(cfg);
    r.vision.time + r.prefill.time + decode + r.action.time
}

/// Speculative decoding: draft model of `draft_size_b` proposes `gamma`
/// tokens per target pass; expected accepted tokens per verify is
/// E = (1 - alpha^(gamma+1)) / (1 - alpha). Target verification of gamma+1
/// tokens is one batched pass (weights read once). Returns projected decode
/// time for the full trace.
pub fn speculative_decode_time(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
    gamma: u64,
    alpha: f64,
) -> f64 {
    let n = target.shape.decode_tokens as f64;
    let expected_accept = (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha).max(1e-9);
    let rounds = n / expected_accept;
    // draft runs gamma sequential single-token steps per round
    let draft_step = decode_time(platform, options, draft) / draft.shape.decode_tokens as f64;
    // target verifies gamma+1 tokens in one batched pass at mid-trace KV len
    let kv_mid = target.shape.prefill_len() + target.shape.decode_tokens / 2;
    let verify = Simulator::with_options(platform.clone(), options.clone())
        .simulate_stage(&target.decode_stage_batched(kv_mid, gamma + 1))
        .time;
    rounds * (gamma as f64 * draft_step + verify)
}

/// Run the co-design study on one platform.
pub fn codesign_study(
    platform: &Platform,
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
) -> Vec<CodesignResult> {
    let horizon = target.action.horizon as f64;
    let base_decode = decode_time(platform, options, target);
    let base_total = step_with_decode(platform, options, target, base_decode);
    let mut out = Vec::new();
    let mut push = |name: &str, total: f64| {
        out.push(CodesignResult {
            technique: name.into(),
            step_latency: total,
            control_hz: 1.0 / total,
            amortized_hz: horizon / total,
            speedup_vs_baseline: base_total / total,
        });
    };

    push("baseline (bf16, full trace)", base_total);

    // W8 weight quantization
    let w8 = quantize_weights(target, 8);
    let t = decode_time(platform, options, &w8);
    push("W8 weight quantization", step_with_decode(platform, options, target, t));

    // KV quantization: decode KV traffic halved — model by rebuilding with
    // half decode positions' KV (approx: scale kv-heavy ops via shorter len);
    // weights stay bf16, only the cache narrows.
    let kv_t = {
        let full = decode_time(platform, options, target);
        let mut short = target.clone();
        short.shape.prompt_tokens /= 2;
        short.shape.image_tokens /= 2; // halves kv_len trajectory
        let less_kv = decode_time(platform, options, &short);
        // kv traffic is the delta driver; take midpoint as the W16/KV8 estimate
        (full + less_kv) / 2.0
    };
    push("KV-cache 8-bit (approx)", step_with_decode(platform, options, target, kv_t));

    // reasoning-trace compression to half the tokens
    let mut short_cot = target.clone();
    short_cot.shape.decode_tokens /= 2;
    let t = decode_time(platform, options, &short_cot);
    push("trace compression (0.5x tokens)", step_with_decode(platform, options, target, t));

    // speculative decoding, gamma=4, alpha=0.7
    let t = speculative_decode_time(platform, options, target, draft, 4, 0.7);
    push("speculative decode (g=4, a=0.7)", step_with_decode(platform, options, target, t));

    // combined: W8 + trace compression + speculation
    let mut combo = quantize_weights(target, 8);
    combo.shape.decode_tokens /= 2;
    let t = speculative_decode_time(platform, options, &combo, draft, 4, 0.7);
    push("combined (W8 + 0.5x trace + spec)", step_with_decode(platform, options, target, t));

    out
}

/// Render the study as a table.
pub fn codesign_table(platform_name: &str, model_name: &str, results: &[CodesignResult]) -> Table {
    let mut t = Table::new(
        &format!("Co-design projections on {platform_name} ({model_name})"),
        &["technique", "step (s)", "Hz", "actions/s", "speedup"],
    )
    .left_first();
    for r in results {
        t.row(vec![
            r.technique.clone(),
            format!("{:.2}", r.step_latency),
            format!("{:.3}", r.control_hz),
            format!("{:.3}", r.amortized_hz),
            format!("{:.2}x", r.speedup_vs_baseline),
        ]);
    }
    t
}

/// Hardware × software matrix: the combined co-design technique evaluated
/// on every platform of `platforms`, in parallel on the sweep runner. The
/// single source of the matrix that `codesign` and `report` both emit.
pub fn combined_matrix(
    platforms: &[Platform],
    options: &SimOptions,
    target: &VlaConfig,
    draft: &VlaConfig,
) -> Table {
    let mut t = Table::new(
        "Combined co-design across the platform matrix",
        &["Platform", "baseline actions/s", "combined actions/s", "gain"],
    )
    .left_first();
    let rows = super::sweep::parallel_map(platforms, |p| {
        let r = codesign_study(p, options, target, draft);
        let base = &r[0];
        let combo = r.last().unwrap();
        vec![
            p.name.clone(),
            format!("{:.3}", base.amortized_hz),
            format!("{:.3}", combo.amortized_hz),
            format!("{:.2}x", combo.speedup_vs_baseline),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

/// Batched serving study: per-stream latency vs aggregate throughput
/// (E-A2). Shows batching recovers aggregate tokens/s but NOT per-robot
/// control latency.
pub fn batch_study(
    platform: &Platform,
    options: &SimOptions,
    cfg: &VlaConfig,
    batches: &[u64],
) -> Table {
    let mut t = Table::new(
        &format!("Batched decode on {} ({})", platform.name, cfg.name),
        &["batch", "step time (ms)", "per-stream tok/s", "aggregate tok/s", "intensity (FLOP/B)"],
    );
    let kv = cfg.shape.prefill_len() + cfg.shape.decode_tokens / 2;
    for &b in batches {
        let stage = cfg.decode_stage_batched(kv, b);
        let r = Simulator::with_options(platform.clone(), options.clone()).simulate_stage(&stage);
        t.row(vec![
            format!("{b}"),
            format!("{:.2}", r.time * 1e3),
            format!("{:.2}", 1.0 / r.time),
            format!("{:.2}", b as f64 / r.time),
            format!("{:.2}", stage.intensity()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;
    use crate::model::molmoact::molmoact_7b;
    use crate::model::scaling::scaled_vla;

    fn opts() -> SimOptions {
        SimOptions {
            decode_stride: 16,
            ..Default::default()
        }
    }

    #[test]
    fn every_technique_helps() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        assert_eq!(results.len(), 6);
        for r in &results[1..] {
            // KV quantization is ~neutral at 7B: GQA keeps the cache tiny
            // relative to 14 GB of weights per token — itself a finding.
            let floor = if r.technique.starts_with("KV") { 0.99 } else { 1.0 };
            assert!(
                r.speedup_vs_baseline > floor,
                "{} should not slow decode: {}x",
                r.technique,
                r.speedup_vs_baseline
            );
        }
    }

    #[test]
    fn w8_speedup_tracks_bytes() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let w8 = results.iter().find(|r| r.technique.starts_with("W8")).unwrap();
        // halving weight bytes on a BW-bound decode ~ 1.5-2x end-to-end
        assert!(
            (1.3..2.2).contains(&w8.speedup_vs_baseline),
            "W8 speedup {}",
            w8.speedup_vs_baseline
        );
    }

    #[test]
    fn combined_beats_individuals() {
        let results = codesign_study(&platform::orin(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let combined = results.last().unwrap().speedup_vs_baseline;
        for r in &results[1..results.len() - 1] {
            if r.technique.starts_with("KV") {
                continue; // ~neutral at 7B, see every_technique_helps
            }
            assert!(
                combined > r.speedup_vs_baseline,
                "combined {combined} <= {} ({})",
                r.speedup_vs_baseline,
                r.technique
            );
        }
    }

    #[test]
    fn codesign_plus_pim_approaches_target() {
        // the paper's thesis: hardware OR software alone is insufficient;
        // together they close most of the gap at 7B
        let results =
            codesign_study(&platform::thor_pim(), &opts(), &molmoact_7b(), &scaled_vla(2.0));
        let combined = results.last().unwrap();
        assert!(
            combined.amortized_hz > 2.0,
            "PIM + co-design should approach the 10 Hz band: {} actions/s",
            combined.amortized_hz
        );
        // and co-design still adds a solid margin on top of PIM hardware
        let base = &results[0];
        assert!(combined.amortized_hz > base.amortized_hz * 1.3);
    }

    #[test]
    fn batching_raises_aggregate_not_per_stream() {
        let t = batch_study(&platform::orin(), &opts(), &molmoact_7b(), &[1, 4, 16]);
        let agg = |r: usize| -> f64 { t.cell(r, 3).parse().unwrap() };
        let per = |r: usize| -> f64 { t.cell(r, 2).parse().unwrap() };
        assert!(agg(2) > 3.0 * agg(0), "batching must lift aggregate throughput");
        assert!(per(2) <= per(0) * 1.05, "per-stream rate cannot improve with batching");
    }

    #[test]
    fn combined_matrix_gains_everywhere() {
        let t = combined_matrix(
            &platform::sweep_platforms(),
            &opts(),
            &molmoact_7b(),
            &scaled_vla(2.0),
        );
        assert_eq!(t.n_rows(), platform::sweep_platforms().len());
        for r in 0..t.n_rows() {
            let gain: f64 = t.cell(r, 3).trim_end_matches('x').parse().unwrap();
            assert!(gain > 1.0, "combined co-design must help on every platform: row {r}");
        }
    }

    #[test]
    fn speculative_model_sane() {
        let t_spec = speculative_decode_time(
            &platform::orin(),
            &opts(),
            &molmoact_7b(),
            &scaled_vla(2.0),
            4,
            0.7,
        );
        let t_base = decode_time(&platform::orin(), &opts(), &molmoact_7b());
        assert!(t_spec < t_base, "speculation should help a BW-bound target");
        assert!(t_spec > t_base / 6.0, "but not unrealistically");
    }
}
