//! Worker-pool parallel sweep runner for embarrassingly-parallel grids
//! (Fig 3 sizes × platforms, the co-design platform matrix, the report's
//! registry loop).
//!
//! Design constraints:
//! - **no external deps**: a scoped `std::thread` pool, nothing else;
//! - **deterministic**: results come back in input order regardless of
//!   scheduling, and every work item is a pure function of its inputs, so
//!   the parallel sweep is bitwise-identical to the serial path
//!   (`parallel_map_with(items, 1, f)`);
//! - **work stealing by index**: workers pull the next item off a shared
//!   atomic counter, which load-balances the heavy large-model cells
//!   without any channel machinery.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for a sweep of `items` work items: the smaller of the
/// machine's available parallelism and the item count, overridable with
/// `VLA_SWEEP_THREADS` (useful to force the serial path or to bound CI
/// machines). Always at least 1.
pub fn default_workers(items: usize) -> usize {
    if items == 0 {
        return 1;
    }
    let configured = std::env::var("VLA_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0);
    let workers = configured
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    workers.min(items)
}

/// Map `f` over `items` on a scoped worker pool with the default worker
/// count. Results are returned in input order.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_with(items, default_workers(items.len()), f)
}

/// Map `f` over `items` on `workers` scoped threads. `workers <= 1` (or a
/// single item) runs the plain serial path; any worker count produces the
/// same result in the same (input) order.
pub fn parallel_map_with<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers.min(n));
        for _ in 0..workers.min(n) {
            handles.push(scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(&items[i])));
                }
                local
            }));
        }
        let mut indexed: Vec<(usize, R)> = Vec::with_capacity(n);
        for h in handles {
            indexed.extend(h.join().expect("sweep worker panicked"));
        }
        indexed.sort_by_key(|&(i, _)| i);
        indexed.into_iter().map(|(_, r)| r).collect()
    })
}

/// Timing summary of one [`bench_scaling`] run — the machine-readable
/// counterpart of its console line, consumed by the benches' `--json`
/// emitters (`BENCH_*.json`).
#[derive(Debug, Clone, Copy)]
pub struct ScalingStats {
    pub items: usize,
    pub workers: usize,
    /// Serial (1-worker) wall time over all items (s).
    pub t_serial: f64,
    /// Parallel wall time on `workers` workers (s).
    pub t_parallel: f64,
}

impl ScalingStats {
    pub fn speedup(&self) -> f64 {
        self.t_serial / self.t_parallel.max(1e-12)
    }

    /// Items per second on the worker pool.
    pub fn parallel_rate(&self) -> f64 {
        self.items as f64 / self.t_parallel.max(1e-12)
    }

    /// Items per second on one worker.
    pub fn serial_rate(&self) -> f64 {
        self.items as f64 / self.t_serial.max(1e-12)
    }
}

/// Bench harness hook: map `f` over `items` serially and on the default
/// worker pool, timing both, and print the shared per-worker scaling
/// summary line (workers, wall time, speedup). Returns the parallel results
/// (identical to the serial ones — see [`parallel_map`]'s determinism
/// guarantee). The six `harness = false` benches route their grids through
/// this so every bench reports how the sweep pool scales on the host.
/// `f` may close over shared state (e.g. a scenario [`Evaluator`] and its
/// `EvalCache` — both `Sync`) — the workers hit one memo store together.
///
/// [`Evaluator`]: crate::sim::scenario::Evaluator
pub fn bench_scaling<T, R, F>(label: &str, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    bench_scaling_stats(label, items, f).0
}

/// [`bench_scaling`], returning the timing summary alongside the results.
pub fn bench_scaling_stats<T, R, F>(label: &str, items: &[T], f: F) -> (Vec<R>, ScalingStats)
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let t0 = std::time::Instant::now();
    let serial = parallel_map_with(items, 1, &f);
    let t_serial = t0.elapsed().as_secs_f64();
    drop(serial);
    let workers = default_workers(items.len());
    let t1 = std::time::Instant::now();
    let out = parallel_map_with(items, workers, &f);
    let t_parallel = t1.elapsed().as_secs_f64();
    let stats = ScalingStats { items: items.len(), workers, t_serial, t_parallel };
    println!(
        "sweep scaling[{label}]: {} items | 1 worker {:.1} ms | {} workers {:.1} ms \
         | speedup {:.2}x",
        stats.items,
        t_serial * 1e3,
        workers,
        t_parallel * 1e3,
        stats.speedup()
    );
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..97).collect();
        let out = parallel_map_with(&items, 8, |&x| x * x);
        let want: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let items: Vec<f64> = (0..33).map(|i| i as f64 * 0.37 + 0.01).collect();
        let f = |x: &f64| x.sin() / x.sqrt() + x.ln();
        assert_eq!(parallel_map_with(&items, 1, f), parallel_map_with(&items, 7, f));
    }

    #[test]
    fn empty_and_single_item() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, |&x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_workers_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(parallel_map_with(&items, 64, |&x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn bench_scaling_returns_parallel_results() {
        let items: Vec<u64> = (0..40).collect();
        let out = bench_scaling("unit", &items, |&x| x * 3);
        let want: Vec<u64> = items.iter().map(|&x| x * 3).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn bench_scaling_stats_reports_timing() {
        let items: Vec<u64> = (0..16).collect();
        let (out, s) = bench_scaling_stats("unit", &items, |&x| x + 1);
        assert_eq!(out.len(), 16);
        assert_eq!((s.items, s.workers >= 1), (16, true));
        assert!(s.t_serial >= 0.0 && s.t_parallel >= 0.0);
        assert!(s.serial_rate() > 0.0 && s.parallel_rate() > 0.0 && s.speedup() > 0.0);
    }

    #[test]
    fn default_workers_bounded_by_items() {
        assert_eq!(default_workers(0), 1);
        let w = default_workers(4);
        assert!((1..=4).contains(&w));
        assert!(default_workers(100_000) >= 1);
    }
}
