//! Matmul tiling model: pick an (tm, tn, tk) output tile that fits the SM
//! scratchpad, then derive matrix-engine efficiency from tile-shape padding
//! and SM wave quantization — the "number of SMs, tiling strategies"
//! micro-architectural fidelity the paper's simulator incorporates (§3.2).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::hw::{DType, SocSpec};

/// Result of tile selection for a matmul of logical shape batch x (m, n, k).
#[derive(Debug, Clone, PartialEq)]
pub struct TilePlan {
    pub tm: u64,
    pub tn: u64,
    pub tk: u64,
    /// Total output tiles across the grid (batch included).
    pub n_tiles: u64,
    /// Full waves + tail: n_tiles / sms rounded up.
    pub waves: u64,
    /// Fraction of matrix-engine peak achieved: padding x wave occupancy.
    pub efficiency: f64,
}

/// Candidate output-tile shapes, largest first (bigger tiles amortize operand
/// traffic but waste more on small problems).
const CANDIDATES: [(u64, u64); 6] = [(128, 128), (128, 64), (64, 64), (64, 32), (32, 32), (16, 16)];

/// Select a tile plan for `batch x (m,n,k)` einsum on `soc`.
pub fn plan_matmul(soc: &SocSpec, batch: u64, m: u64, n: u64, k: u64, dt: DType) -> TilePlan {
    let eb = dt.bytes();
    let tk: u64 = 64.max(soc.mma_k as u64);
    let mut best: Option<TilePlan> = None;
    for (tm, tn) in CANDIDATES {
        // working set: A tile + B tile + C accumulator (f32), double-buffered
        // operands
        let ws = 2.0 * (tm * tk) as f64 * eb + 2.0 * (tk * tn) as f64 * eb + (tm * tn) as f64 * 4.0;
        if ws > soc.smem_per_sm {
            continue;
        }
        let grid_m = m.div_ceil(tm);
        let grid_n = n.div_ceil(tn);
        let n_tiles = batch * grid_m * grid_n;
        let waves = n_tiles.div_ceil(soc.sms as u64);
        // padding efficiency: useful fraction of each tile
        let pad_m = m as f64 / (grid_m * tm) as f64;
        let pad_n = n as f64 / (grid_n * tn) as f64;
        let pad_k = k as f64 / (k.div_ceil(tk) * tk) as f64;
        // wave occupancy: last wave may be partially filled
        let occupancy = n_tiles as f64 / (waves * soc.sms as u64) as f64;
        // small-k matmuls can't keep the MMA pipeline full
        let pipe = (k as f64 / (4.0 * soc.mma_k as f64)).min(1.0);
        let efficiency = pad_m * pad_n * pad_k * occupancy * pipe;
        let plan = TilePlan {
            tm,
            tn,
            tk,
            n_tiles,
            waves,
            efficiency,
        };
        match &best {
            Some(b) if b.efficiency >= plan.efficiency => {}
            _ => best = Some(plan),
        }
    }
    best.unwrap_or(TilePlan {
        tm: 16,
        tn: 16,
        tk: 16,
        n_tiles: batch * m.div_ceil(16) * n.div_ceil(16),
        waves: 1,
        efficiency: 0.05,
    })
}

/// Achievable fraction of matrix-engine peak for this matmul, with a
/// realistic ceiling (sustained-vs-peak gap: issue, epilogue, DRAM stalls
/// already modeled separately).
pub fn matmul_efficiency(soc: &SocSpec, batch: u64, m: u64, n: u64, k: u64, dt: DType) -> f64 {
    const SUSTAINED_CEILING: f64 = 0.72; // typical dense-GEMM fraction of peak
    let raw = plan_matmul(soc, batch, m, n, k, dt).efficiency * SUSTAINED_CEILING;
    raw.clamp(0.005, SUSTAINED_CEILING)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::SocSpec;

    #[test]
    fn big_square_gemm_is_efficient() {
        let soc = SocSpec::orin();
        let e = matmul_efficiency(&soc, 1, 4096, 4096, 4096, DType::BF16);
        assert!(e > 0.55, "large GEMM efficiency {e}");
    }

    #[test]
    fn gemv_is_inefficient_on_matrix_engine() {
        let soc = SocSpec::orin();
        let e = matmul_efficiency(&soc, 1, 1, 4096, 4096, DType::BF16);
        assert!(e < 0.08, "m=1 GEMV should waste the MMA tile: {e}");
    }

    #[test]
    fn efficiency_monotone_in_m_roughly() {
        let soc = SocSpec::thor();
        let e1 = matmul_efficiency(&soc, 1, 1, 8192, 8192, DType::BF16);
        let e64 = matmul_efficiency(&soc, 1, 64, 8192, 8192, DType::BF16);
        let e1024 = matmul_efficiency(&soc, 1, 1024, 8192, 8192, DType::BF16);
        assert!(e1 < e64 && e64 <= e1024 * 1.05, "{e1} {e64} {e1024}");
    }

    #[test]
    fn wave_quantization_visible() {
        let soc = SocSpec::orin(); // 16 SMs
        // exactly one wave of 128x128 tiles vs one tile spilling to a 2nd wave
        let full = plan_matmul(&soc, 1, 4 * 128, 4 * 128, 1024, DType::BF16);
        assert_eq!(full.n_tiles, 16);
        assert_eq!(full.waves, 1);
        let spill = plan_matmul(&soc, 1, 4 * 128, 4 * 128 + 1, 1024, DType::BF16);
        assert!(spill.waves >= 2 || spill.tn < 128, "{spill:?}");
    }

    #[test]
    fn tiles_fit_smem() {
        let soc = SocSpec::orin();
        let p = plan_matmul(&soc, 1, 2048, 2048, 2048, DType::BF16);
        let ws = 2.0 * (p.tm * p.tk) as f64 * 2.0
            + 2.0 * (p.tk * p.tn) as f64 * 2.0
            + (p.tm * p.tn) as f64 * 4.0;
        assert!(ws <= soc.smem_per_sm);
    }

    #[test]
    fn efficiency_bounded() {
        let soc = SocSpec::cpu_host(10.0);
        for (m, n, k) in [(1, 1, 1), (1, 100000, 128), (7, 13, 17)] {
            let e = matmul_efficiency(&soc, 1, m, n, k, DType::F32);
            assert!((0.005..=0.72).contains(&e), "({m},{n},{k}) -> {e}");
        }
    }
}
