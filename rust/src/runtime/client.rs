//! PJRT runtime wrapper: load HLO-text artifacts, compile once, execute from
//! the rust hot path with wall-clock phase timing.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use std::path::Path;
use std::time::{Duration, Instant};

/// A PJRT client plus compiled executables. One `Runtime` per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client (the only backend in this environment).
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Clone the underlying PJRT client handle (cheap reference clone) so
    /// long-lived components can create device buffers.
    pub fn client_handle(&self) -> xla::PjRtClient {
        self.client.clone()
    }

    /// Upload a host literal to a device buffer (done once for weights —
    /// PERF: keeps the parameter vector resident instead of re-uploading
    /// ~23 MB on every phase invocation).
    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(anyhow::Error::msg)
    }

    /// Load an HLO-text module and compile it. Compilation happens once at
    /// startup; the request path only executes.
    pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<CompiledModule> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(anyhow::Error::msg)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow::Error::msg)?;
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "<module>".into());
        crate::log_debug!("compiled {} in {:?}", name, t0.elapsed());
        Ok(CompiledModule { exe, name })
    }
}

/// One compiled model entry point.
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl CompiledModule {
    /// Execute with literal inputs passed BY REFERENCE (PERF: `xla::Literal`
    /// is deeply cloned by `Clone`; the 23 MB parameter vector must not be
    /// copied on every decode step). Returns the decomposed output tuple and
    /// the device wall time. The AOT pipeline lowers with return_tuple=True,
    /// so the single output buffer is always a tuple.
    pub fn run(&self, args: &[&xla::Literal]) -> anyhow::Result<(Vec<xla::Literal>, Duration)> {
        let t0 = Instant::now();
        let bufs = self.exe.execute::<&xla::Literal>(args).map_err(anyhow::Error::msg)?;
        let lit = bufs[0][0].to_literal_sync().map_err(anyhow::Error::msg)?;
        let parts = lit.to_tuple().map_err(anyhow::Error::msg)?;
        Ok((parts, t0.elapsed()))
    }

    /// Execute with device-buffer inputs (weights stay resident on device).
    ///
    /// CAUTION: with the bundled xla_extension 0.5.1 CPU plugin, repeated
    /// `execute_b` calls on a multi-output executable abort inside XLA
    /// (`shape_util.cc: pointer_size > 0`). The engine therefore uses the
    /// literal-reference [`CompiledModule::run`] path; this entry point is
    /// kept for single-output modules and future plugin versions (it was
    /// stable for the single-output vision module across 400+ calls).
    pub fn run_b(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<(Vec<xla::Literal>, Duration)> {
        let t0 = Instant::now();
        let bufs = self.exe.execute_b::<&xla::PjRtBuffer>(args).map_err(anyhow::Error::msg)?;
        let lit = bufs[0][0].to_literal_sync().map_err(anyhow::Error::msg)?;
        let parts = lit.to_tuple().map_err(anyhow::Error::msg)?;
        Ok((parts, t0.elapsed()))
    }
}

/// Build an f32 literal of the given shape.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/product mismatch");
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(dims).map_err(anyhow::Error::msg)
    }
}

/// Scalar i32 literal (jax int32 inputs).
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// 1-D i32 literal.
pub fn i32_vec(vals: &[i32]) -> xla::Literal {
    xla::Literal::vec1(vals)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(anyhow::Error::msg)
}

/// Index of the maximum element (greedy sampling).
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0, "first max wins");
    }

    #[test]
    fn f32_literal_shapes() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert!(f32_literal(&[1.0], &[2]).is_err());
    }
}
