//! PJRT runtime: HLO-text loading/compilation and artifact management.
//! Python runs only at build time; this module is the entire runtime
//! dependency surface.

pub mod artifacts;
pub mod client;

pub use artifacts::{artifacts_dir, load_manifest, load_params, Manifest};
pub use client::{CompiledModule, Runtime};
