//! Artifact discovery and manifest parsing.
//!
//! `make artifacts` (the build-time python path) writes HLO-text modules,
//! the flat parameter vector, and `manifest.json` into `artifacts/`. This
//! module locates that directory and exposes the manifest to the runtime —
//! python is never imported at run time.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub n_params: usize,
    pub params_sha256: String,
    pub vision: VisionDims,
    pub decoder: DecoderDims,
    pub action: ActionDims,
    pub workload: WorkloadDims,
    pub golden: Golden,
}

#[derive(Debug, Clone)]
pub struct VisionDims {
    pub patches: usize,
    pub patch_dim: usize,
    pub layers: usize,
    pub hidden: usize,
}

#[derive(Debug, Clone)]
pub struct DecoderDims {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

#[derive(Debug, Clone)]
pub struct ActionDims {
    pub horizon: usize,
    pub action_dim: usize,
    pub diffusion_steps: usize,
}

#[derive(Debug, Clone)]
pub struct WorkloadDims {
    pub image_tokens: usize,
    pub prompt_tokens: usize,
    pub decode_tokens: usize,
    pub prefill_len: usize,
}

/// Golden outputs recorded by the AOT pipeline; the rust runtime must
/// reproduce them bit-for-bit-ish (f32 tolerance) through the artifacts.
#[derive(Debug, Clone)]
pub struct Golden {
    pub patch_seed: u64,
    pub prompt_token_ids: Vec<i32>,
    pub first_tokens: Vec<i64>,
    pub next_token: i64,
    pub embeds_sum: f64,
    pub actions_sum: f64,
    pub actions_first_row: Vec<f64>,
    pub prefill_logits_l2: f64,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text)?;
        let v = j.get("vision").ok_or_else(|| anyhow::anyhow!("missing vision"))?;
        let d = j.get("decoder").ok_or_else(|| anyhow::anyhow!("missing decoder"))?;
        let a = j.get("action").ok_or_else(|| anyhow::anyhow!("missing action"))?;
        let w = j.get("workload").ok_or_else(|| anyhow::anyhow!("missing workload"))?;
        let g = j.get("golden").ok_or_else(|| anyhow::anyhow!("missing golden"))?;
        Ok(Manifest {
            n_params: j.req_u64("n_params")? as usize,
            params_sha256: j.req_str("params_sha256")?.to_string(),
            vision: VisionDims {
                patches: v.req_u64("patches")? as usize,
                patch_dim: v.req_u64("patch_dim")? as usize,
                layers: v.req_u64("layers")? as usize,
                hidden: v.req_u64("hidden")? as usize,
            },
            decoder: DecoderDims {
                layers: d.req_u64("layers")? as usize,
                hidden: d.req_u64("hidden")? as usize,
                heads: d.req_u64("heads")? as usize,
                kv_heads: d.req_u64("kv_heads")? as usize,
                head_dim: d.req_u64("head_dim")? as usize,
                ffn: d.req_u64("ffn")? as usize,
                vocab: d.req_u64("vocab")? as usize,
                max_seq: d.req_u64("max_seq")? as usize,
            },
            action: ActionDims {
                horizon: a.req_u64("horizon")? as usize,
                action_dim: a.req_u64("action_dim")? as usize,
                diffusion_steps: a.req_u64("diffusion_steps")? as usize,
            },
            workload: WorkloadDims {
                image_tokens: w.req_u64("image_tokens")? as usize,
                prompt_tokens: w.req_u64("prompt_tokens")? as usize,
                decode_tokens: w.req_u64("decode_tokens")? as usize,
                prefill_len: w.req_u64("prefill_len")? as usize,
            },
            golden: Golden {
                patch_seed: g.req_u64("patch_seed")?,
                prompt_token_ids: g
                    .get("prompt_token_ids")
                    .and_then(|x| x.as_arr())
                    .map(|a| a.iter().filter_map(|t| t.as_u64().map(|u| u as i32)).collect())
                    .unwrap_or_default(),
                first_tokens: g
                    .get("first_tokens")
                    .and_then(|x| x.as_arr())
                    .map(|a| a.iter().filter_map(|t| t.as_u64().map(|u| u as i64)).collect())
                    .unwrap_or_default(),
                next_token: g.req_u64("next_token")? as i64,
                embeds_sum: g.req_f64("embeds_sum")?,
                actions_sum: g.req_f64("actions_sum")?,
                actions_first_row: g
                    .get("actions_first_row")
                    .and_then(|x| x.as_arr())
                    .map(|a| a.iter().filter_map(|t| t.as_f64()).collect())
                    .unwrap_or_default(),
                prefill_logits_l2: g.req_f64("prefill_logits_l2")?,
            },
        })
    }
}

/// Locate the artifacts directory: `$VLA_ARTIFACTS`, else `artifacts/`
/// relative to the workspace (walking up from cwd).
pub fn artifacts_dir() -> anyhow::Result<PathBuf> {
    if let Ok(dir) = std::env::var("VLA_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
        anyhow::bail!("VLA_ARTIFACTS={} has no manifest.json", p.display());
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found (run `make artifacts` or set VLA_ARTIFACTS)"
            );
        }
    }
}

/// Load + parse the manifest in `dir`.
pub fn load_manifest(dir: &Path) -> anyhow::Result<Manifest> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    Manifest::parse(&text)
}

/// Read the little-endian f32 parameter vector.
pub fn load_params(dir: &Path, expect_n: usize) -> anyhow::Result<Vec<f32>> {
    let raw = std::fs::read(dir.join("params.f32.bin"))?;
    anyhow::ensure!(
        raw.len() == 4 * expect_n,
        "params.f32.bin has {} bytes, expected {}",
        raw.len(),
        4 * expect_n
    );
    Ok(raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "n_params": 10, "params_sha256": "ab",
      "vision": {"patches": 64, "patch_dim": 147, "layers": 2, "hidden": 128},
      "decoder": {"layers": 4, "hidden": 256, "heads": 8, "kv_heads": 2,
                  "head_dim": 32, "ffn": 1024, "vocab": 2048, "max_seq": 128},
      "action": {"horizon": 8, "action_dim": 7, "diffusion_steps": 4},
      "workload": {"image_tokens": 64, "prompt_tokens": 16,
                   "decode_tokens": 24, "prefill_len": 80},
      "golden": {"patch_seed": 42, "prompt_token_ids": [9, 8],
                 "first_tokens": [1, 2],
                 "next_token": 3, "embeds_sum": 1.5, "actions_sum": -0.25,
                 "actions_first_row": [0.1, -0.2],
                 "prefill_logits_l2": 12.25}
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n_params, 10);
        assert_eq!(m.decoder.vocab, 2048);
        assert_eq!(m.workload.prefill_len, 80);
        assert_eq!(m.golden.first_tokens, vec![1, 2]);
        assert_eq!(m.golden.actions_first_row.len(), 2);
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        let no_vocab = SAMPLE.replace("\"vocab\": 2048,", "");
        assert!(Manifest::parse(&no_vocab).is_err());
    }

    #[test]
    fn params_loader_checks_size() {
        let dir = std::env::temp_dir().join("vla_char_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let vals: [f32; 3] = [1.0, -2.5, 3.25];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("params.f32.bin"), &bytes).unwrap();
        let loaded = load_params(&dir, 3).unwrap();
        assert_eq!(loaded, vals);
        assert!(load_params(&dir, 4).is_err());
    }
}
