//! Operator-level workload IR.
//!
//! The paper's simulator "decomposes the VLA model into its constituent
//! stages ... each layer is further resolved into a sequence of operators,
//! primarily high-dimensional einsums" (§3.2). An [`Operator`] carries the
//! einsum shape plus explicit FLOP and byte counts so the roofline model
//! needs no further shape reasoning.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::hw::DType;

/// Broad operator class — drives tiling, PIM eligibility and bandwidth
/// asymmetry decisions in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matmul with a weight operand resident in DRAM (GEMM when m is
    /// large, GEMV-like when m == 1).
    MatmulWeight,
    /// Matmul between two activation tensors (attention score/context).
    MatmulAct,
    /// Elementwise / activation / residual (streaming).
    Elementwise,
    /// Softmax (streaming, two passes).
    Softmax,
    /// Layer/RMS norm (streaming).
    Norm,
    /// Embedding gather / logit sampling.
    Gather,
}

impl OpKind {
    pub fn name(self) -> &'static str {
        match self {
            OpKind::MatmulWeight => "matmul_w",
            OpKind::MatmulAct => "matmul_a",
            OpKind::Elementwise => "eltwise",
            OpKind::Softmax => "softmax",
            OpKind::Norm => "norm",
            OpKind::Gather => "gather",
        }
    }
}

/// One operator instance with fully-resolved cost inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct Operator {
    pub name: String,
    pub kind: OpKind,
    pub dtype: DType,
    /// Einsum dims of the dominant contraction: batch x (m, n, k).
    /// Non-matmul ops use (m=elements, n=1, k=1).
    pub batch: u64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes of weights/parameters streamed from DRAM (reused across tokens
    /// but NOT across a single inference step).
    pub weight_bytes: f64,
    /// Bytes of activations read (DRAM or cache-resident; the memory model
    /// decides which level serves them).
    pub act_in_bytes: f64,
    /// Bytes of activations written.
    pub act_out_bytes: f64,
    /// Bytes of KV-cache traffic (reads during decode; grows with position).
    pub kv_bytes: f64,
}

impl Operator {
    /// Total bytes moved (first-order, before cache modeling).
    pub fn total_bytes(&self) -> f64 {
        self.weight_bytes + self.act_in_bytes + self.act_out_bytes + self.kv_bytes
    }

    /// Arithmetic intensity (FLOP per byte moved).
    pub fn intensity(&self) -> f64 {
        self.flops / self.total_bytes().max(1.0)
    }

    /// PIM eligibility: streaming memory-bound shapes — GEMV-like weight
    /// matmuls (m small), elementwise, norms, softmax, and KV-dominated
    /// attention ops. Large GEMMs stay on the SoC matrix engine.
    pub fn pim_eligible(&self) -> bool {
        match self.kind {
            OpKind::MatmulWeight => self.m <= 16,
            OpKind::MatmulAct => self.kv_bytes > 0.0 && self.m <= 16,
            OpKind::Elementwise | OpKind::Softmax | OpKind::Norm => true,
            OpKind::Gather => false,
        }
    }

    /// Dense matmul `[batch, m, k] x [k, n]` against DRAM-resident weights.
    pub fn matmul_weight(name: &str, batch: u64, m: u64, n: u64, k: u64, dt: DType) -> Operator {
        let b = dt.bytes();
        Operator {
            name: name.into(),
            kind: OpKind::MatmulWeight,
            dtype: dt,
            batch,
            m,
            n,
            k,
            flops: 2.0 * batch as f64 * m as f64 * n as f64 * k as f64,
            weight_bytes: k as f64 * n as f64 * b, // weights shared across batch
            act_in_bytes: batch as f64 * m as f64 * k as f64 * b,
            act_out_bytes: batch as f64 * m as f64 * n as f64 * b,
            kv_bytes: 0.0,
        }
    }

    /// Activation-activation matmul `[batch, m, k] x [batch, k, n]`,
    /// optionally with the second operand served from the KV cache.
    pub fn matmul_act(
        name: &str,
        batch: u64,
        m: u64,
        n: u64,
        k: u64,
        dt: DType,
        second_is_kv: bool,
    ) -> Operator {
        let b = dt.bytes();
        let second = batch as f64 * k as f64 * n as f64 * b;
        Operator {
            name: name.into(),
            kind: OpKind::MatmulAct,
            dtype: dt,
            batch,
            m,
            n,
            k,
            flops: 2.0 * batch as f64 * m as f64 * n as f64 * k as f64,
            weight_bytes: 0.0,
            act_in_bytes: batch as f64 * m as f64 * k as f64 * b
                + if second_is_kv { 0.0 } else { second },
            act_out_bytes: batch as f64 * m as f64 * n as f64 * b,
            kv_bytes: if second_is_kv { second } else { 0.0 },
        }
    }

    /// Streaming elementwise op over `elems` elements with `reads` input
    /// streams and one output stream; `per_elem` ALU ops each.
    pub fn elementwise(name: &str, elems: u64, reads: u64, per_elem: f64, dt: DType) -> Operator {
        let b = dt.bytes();
        Operator {
            name: name.into(),
            kind: OpKind::Elementwise,
            dtype: dt,
            batch: 1,
            m: elems,
            n: 1,
            k: 1,
            flops: elems as f64 * per_elem,
            weight_bytes: 0.0,
            act_in_bytes: elems as f64 * reads as f64 * b,
            act_out_bytes: elems as f64 * b,
            kv_bytes: 0.0,
        }
    }

    /// Softmax over `rows` rows of length `cols` (two streaming passes).
    pub fn softmax(name: &str, rows: u64, cols: u64, dt: DType) -> Operator {
        let b = dt.bytes();
        let elems = rows as f64 * cols as f64;
        Operator {
            name: name.into(),
            kind: OpKind::Softmax,
            dtype: dt,
            batch: 1,
            m: rows,
            n: cols,
            k: 1,
            flops: 5.0 * elems, // max, sub, exp, sum, div
            weight_bytes: 0.0,
            act_in_bytes: 2.0 * elems * b,
            act_out_bytes: elems * b,
            kv_bytes: 0.0,
        }
    }

    /// RMS/LayerNorm over `rows` rows of width `width`.
    pub fn norm(name: &str, rows: u64, width: u64, dt: DType) -> Operator {
        let b = dt.bytes();
        let elems = rows as f64 * width as f64;
        Operator {
            name: name.into(),
            kind: OpKind::Norm,
            dtype: dt,
            batch: 1,
            m: rows,
            n: width,
            k: 1,
            flops: 4.0 * elems,
            weight_bytes: width as f64 * b, // scale params
            act_in_bytes: elems * b,
            act_out_bytes: elems * b,
            kv_bytes: 0.0,
        }
    }

    /// Embedding-table gather of `tokens` rows of width `width` from a table
    /// of `vocab` rows (reads only the gathered rows).
    pub fn gather(name: &str, tokens: u64, width: u64, dt: DType) -> Operator {
        let b = dt.bytes();
        Operator {
            name: name.into(),
            kind: OpKind::Gather,
            dtype: dt,
            batch: 1,
            m: tokens,
            n: width,
            k: 1,
            flops: 0.0,
            weight_bytes: tokens as f64 * width as f64 * b,
            act_in_bytes: 0.0,
            act_out_bytes: tokens as f64 * width as f64 * b,
            kv_bytes: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_weight_counts() {
        let op = Operator::matmul_weight("qkv", 1, 128, 512, 256, DType::BF16);
        assert_eq!(op.flops, 2.0 * 128.0 * 512.0 * 256.0);
        assert_eq!(op.weight_bytes, 512.0 * 256.0 * 2.0);
        assert_eq!(op.act_in_bytes, 128.0 * 256.0 * 2.0);
        assert_eq!(op.act_out_bytes, 128.0 * 512.0 * 2.0);
    }

    #[test]
    fn gemv_is_memory_bound_shape() {
        // decode-time projection: m=1 — intensity ~1 FLOP/byte
        let op = Operator::matmul_weight("proj", 1, 1, 4096, 4096, DType::BF16);
        assert!(op.intensity() < 2.0, "intensity {}", op.intensity());
        assert!(op.pim_eligible());
        // prefill projection: m=640 — high intensity, not PIM-eligible
        let op2 = Operator::matmul_weight("proj", 1, 640, 4096, 4096, DType::BF16);
        assert!(op2.intensity() > 100.0);
        assert!(!op2.pim_eligible());
    }

    #[test]
    fn kv_matmul_attribution() {
        let op = Operator::matmul_act("qk", 4, 1, 832, 128, DType::BF16, true);
        assert!(op.kv_bytes > 0.0);
        assert_eq!(op.weight_bytes, 0.0);
        let no_kv = Operator::matmul_act("qk", 4, 1, 832, 128, DType::BF16, false);
        assert_eq!(no_kv.kv_bytes, 0.0);
        assert_eq!(no_kv.total_bytes(), op.total_bytes());
    }

    #[test]
    fn streaming_ops() {
        let sm = Operator::softmax("sm", 8, 1024, DType::F32);
        assert!(sm.intensity() < 1.0);
        let ew = Operator::elementwise("silu", 1 << 20, 2, 4.0, DType::BF16);
        assert_eq!(ew.act_in_bytes, (1 << 20) as f64 * 2.0 * 2.0);
        let n = Operator::norm("rms", 1, 4096, DType::BF16);
        assert!(n.pim_eligible());
        let g = Operator::gather("embed", 4, 4096, DType::BF16);
        assert!(!g.pim_eligible());
        assert_eq!(g.flops, 0.0);
    }
}
