//! MolmoAct-7B workload description — the paper's measured model (§3.1).
//!
//! Architecture (MolmoAct paper, Lee et al. 2025): fused SigLIP/DINOv2-class
//! dual vision towers (~0.4 B), a Qwen2-7B-dims decoder-only reasoning engine
//! (hidden 3584, 28 layers, 28 q-heads / 4 kv-heads GQA, ffn 18944, vocab
//! 152k), and an action expert head. Per control step it emits spatial
//! reasoning traces (depth/trajectory tokens) followed by action tokens —
//! the ~192-token autoregressive decode that Fig 2 shows dominating latency.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::layer::BlockDims;
use super::vla::{ActionConfig, DecoderConfig, VitConfig, VlaConfig, WorkloadShape};
use crate::hw::DType;

/// MolmoAct-7B with the paper's evaluation workload shape.
pub fn molmoact_7b() -> VlaConfig {
    let dt = DType::BF16;
    VlaConfig {
        name: "MolmoAct-7B".into(),
        towers: vec![
            // SigLIP-SO400M-class tower
            VitConfig {
                name: "siglip".into(),
                layers: 27,
                dims: BlockDims {
                    hidden: 1152,
                    heads: 16,
                    kv_heads: 16,
                    head_dim: 72,
                    ffn: 4304,
                    dtype: dt,
                },
            },
            // DINOv2-L-class tower
            VitConfig {
                name: "dinov2".into(),
                layers: 24,
                dims: BlockDims {
                    hidden: 1024,
                    heads: 16,
                    kv_heads: 16,
                    head_dim: 64,
                    ffn: 4096,
                    dtype: dt,
                },
            },
        ],
        projector_hidden: 4096,
        decoder: DecoderConfig {
            layers: 28,
            dims: BlockDims {
                hidden: 3584,
                heads: 28,
                kv_heads: 4,
                head_dim: 128,
                ffn: 18944,
                dtype: dt,
            },
            vocab: 152_064,
            weight_scale: 1.0,
        },
        action: ActionConfig {
            layers: 6,
            dims: BlockDims {
                hidden: 1024,
                heads: 16,
                kv_heads: 16,
                head_dim: 64,
                ffn: 4096,
                dtype: dt,
            },
            horizon: 8,
            diffusion_steps: 10,
            action_dim: 7,
        },
        shape: WorkloadShape {
            // Molmo-family multi-crop tiling: 12 overlapping 336x336 crops
            // + 1 global view, 576 patches each, 2x2-pooled to 144 visual
            // tokens per crop before the decoder.
            crops: 13,
            patches_per_crop: 576,
            image_tokens: 13 * 144,
            prompt_tokens: 64,
            // spatial-reasoning trace (depth tokens + visual waypoints) +
            // discrete action tokens — MolmoAct's "Action Reasoning" output
            decode_tokens: 256,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_is_about_7b() {
        let c = molmoact_7b();
        let p = c.params();
        assert!(
            (7.0e9..9.5e9).contains(&p),
            "MolmoAct-7B params should be ~7-8B (incl. vision + action expert), got {p:.3e}"
        );
        // decoder alone ~7B class
        let d = c.decoder.params();
        assert!((6.5e9..8.5e9).contains(&d), "decoder params {d:.3e}");
    }

    #[test]
    fn decoder_weight_bytes_about_14gb() {
        let c = molmoact_7b();
        let bytes = c.decoder_weight_bytes();
        assert!(
            (12.0e9..16.0e9).contains(&bytes),
            "decoder bf16 bytes {bytes:.3e} — decode must stream ~14 GB/token"
        );
    }

    #[test]
    fn vision_towers_fused() {
        let c = molmoact_7b();
        assert_eq!(c.towers.len(), 2, "SigLIP + DINOv2 fused backbone");
        // BlockDims::params() models a SwiGLU MLP uniformly, slightly
        // overcounting plain-GELU ViTs (~0.7B real -> ~0.95B modeled); the
        // vision phase is a small latency share so this is conservative.
        let vis: f64 = c.towers.iter().map(|t| t.params()).sum();
        assert!((2.0e8..1.1e9).contains(&vis), "vision params {vis:.3e}");
    }

    #[test]
    fn workload_shape_totals() {
        let c = molmoact_7b();
        assert_eq!(c.shape.prefill_len(), 13 * 144 + 64);
        assert_eq!(c.shape.decode_tokens, 256);
        assert_eq!(c.shape.crops, 13);
    }

    #[test]
    fn kv_cache_footprint_modest() {
        // KV at end of decode: (640+192) tokens x 28 layers x 2 x 4 x 128 x 2B
        let c = molmoact_7b();
        let tokens = (c.shape.prefill_len() + c.shape.decode_tokens) as f64;
        let kv = c.decoder.kv_bytes_per_token() * tokens;
        assert!(kv < 250e6, "GQA keeps the KV cache small: {kv:.3e} B");
    }
}
