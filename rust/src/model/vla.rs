//! VLA model configuration and workload construction.
//!
//! A [`VlaConfig`] describes the three subsystems of Fig 1 (vision encoder
//! towers + projector, decoder-only generation engine, action transformer)
//! plus the per-step workload shape (image tokens, prompt tokens, generated
//! reasoning/action tokens, diffusion steps). [`VlaWorkload`] expands it into
//! operator stages for the simulator.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::layer::{decoder_block_decode, decoder_block_prefill, vit_block, BlockDims};
use super::op::Operator;
use super::stage::{Phase, Stage};
use crate::hw::DType;

/// One vision tower (MolmoAct fuses SigLIP + DINOv2-class backbones).
#[derive(Debug, Clone, PartialEq)]
pub struct VitConfig {
    pub name: String,
    pub layers: u64,
    pub dims: BlockDims,
}

impl VitConfig {
    pub fn params(&self) -> f64 {
        self.layers as f64 * self.dims.params()
    }
}

/// The decoder-only reasoning engine.
#[derive(Debug, Clone, PartialEq)]
pub struct DecoderConfig {
    pub layers: u64,
    pub dims: BlockDims,
    pub vocab: u64,
    /// Storage scale of the decoder weights relative to `dims.dtype`.
    /// Sub-byte quantization has no native datatype in the cost model: W4
    /// is I8 arithmetic with `weight_scale = 0.5` — the packed nibbles
    /// stream half the bytes per token. 1.0 everywhere else.
    pub weight_scale: f64,
}

impl DecoderConfig {
    pub fn params(&self) -> f64 {
        self.layers as f64 * self.dims.params()
            + 2.0 * self.vocab as f64 * self.dims.hidden as f64 // embed + lm_head
    }

    /// KV-cache bytes per token across all layers.
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.layers as f64 * self.dims.kv_bytes_per_token()
    }
}

/// The action transformer (DiT-style continuous decoder over the action
/// horizon, run for `diffusion_steps` denoising iterations).
#[derive(Debug, Clone, PartialEq)]
pub struct ActionConfig {
    pub layers: u64,
    pub dims: BlockDims,
    /// Action chunk length (tokens over the horizon).
    pub horizon: u64,
    /// Denoising iterations per control step.
    pub diffusion_steps: u64,
    /// Action dimensionality (e.g. 7-DoF end effector).
    pub action_dim: u64,
}

impl ActionConfig {
    pub fn params(&self) -> f64 {
        self.layers as f64 * self.dims.params()
    }
}

/// Per-control-step workload shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadShape {
    /// Image crops fed through the vision towers (Molmo-style multi-crop
    /// tiling of the camera frame: 12 overlapping crops + 1 global view).
    pub crops: u64,
    /// Patch tokens per crop inside the vision towers (336/14 squared = 576).
    pub patches_per_crop: u64,
    /// Visual tokens entering the generation engine (after 2x2 pooling in
    /// the projector).
    pub image_tokens: u64,
    /// Text instruction tokens.
    pub prompt_tokens: u64,
    /// Autoregressively generated tokens (CoT / spatial reasoning traces /
    /// discrete action tokens) — the paper's bottleneck phase.
    pub decode_tokens: u64,
}

impl WorkloadShape {
    pub fn prefill_len(&self) -> u64 {
        self.image_tokens + self.prompt_tokens
    }
}

/// Complete VLA model + workload description.
#[derive(Debug, Clone, PartialEq)]
pub struct VlaConfig {
    pub name: String,
    pub towers: Vec<VitConfig>,
    /// Projector MLP: vision hidden -> decoder hidden (2-layer).
    pub projector_hidden: u64,
    pub decoder: DecoderConfig,
    pub action: ActionConfig,
    pub shape: WorkloadShape,
}

impl VlaConfig {
    /// Projector MLP parameter count (concatenated tower features →
    /// projector hidden → decoder hidden) — the single source shared by
    /// [`params`](VlaConfig::params) and
    /// [`weight_footprint_bytes`](VlaConfig::weight_footprint_bytes), so
    /// the capacity rule cannot drift from the canonical count.
    pub fn projector_params(&self) -> f64 {
        self.towers.iter().map(|t| t.dims.hidden).sum::<u64>() as f64
            * self.projector_hidden as f64
            + self.projector_hidden as f64 * self.decoder.dims.hidden as f64
    }

    /// Total parameter count (all subsystems).
    pub fn params(&self) -> f64 {
        let vis: f64 = self.towers.iter().map(|t| t.params()).sum();
        vis + self.projector_params() + self.decoder.params() + self.action.params()
    }

    /// Resident weight bytes of the WHOLE model at its configured storage
    /// widths: vision towers and the action expert at their own dtypes, the
    /// projector and decoder (blocks + embeddings + lm head) at the decoder
    /// dtype times `weight_scale` (W4 packs nibbles into I8 storage). This
    /// is the weights term of the scenario engine's capacity-validity rule
    /// — what must FIT in device memory, as opposed to
    /// [`decoder_weight_bytes`](VlaConfig::decoder_weight_bytes), which is
    /// what decode STREAMS per token.
    pub fn weight_footprint_bytes(&self) -> f64 {
        let vis: f64 = self.towers.iter().map(|t| t.params() * t.dims.dtype.bytes()).sum();
        let dec_bytes = self.decoder.dims.dtype.bytes() * self.decoder.weight_scale;
        let act = self.action.params() * self.action.dims.dtype.bytes();
        vis + self.projector_params() * dec_bytes + self.decoder.params() * dec_bytes + act
    }

    /// Model bytes at the decoder dtype (what decode streams per token).
    pub fn decoder_weight_bytes(&self) -> f64 {
        self.decoder.layers as f64
            * self.decoder.dims.params()
            * self.decoder.dims.dtype.bytes()
            * self.decoder.weight_scale
    }

    /// Apply the decoder's sub-byte weight-storage scale to a built stage's
    /// weight streams (KV and activation traffic keep the dtype's width).
    fn scale_decoder_weight_bytes(&self, ops: &mut [Operator]) {
        let s = self.decoder.weight_scale;
        if s != 1.0 {
            for op in ops {
                op.weight_bytes *= s;
            }
        }
    }

    /// Build the vision-encoding stage: all towers over every crop's patch
    /// grid (crops batched), then the projector over the pooled tokens.
    pub fn vision_stage(&self) -> Stage {
        let mut ops = Vec::new();
        let crops = self.shape.crops.max(1);
        let patches = self.shape.patches_per_crop;
        for tower in &self.towers {
            // patch embedding: conv as matmul [crops*patches, 3*14*14] x [.., hidden]
            ops.push(Operator::matmul_weight(
                &format!("{}.patch_embed", tower.name),
                1,
                crops * patches,
                tower.dims.hidden,
                3 * 14 * 14,
                tower.dims.dtype,
            ));
            for l in 0..tower.layers {
                // attention is per-crop (batch = crops, seq = patches)
                let mut blk = vit_block(&format!("{}.b{l}", tower.name), &tower.dims, patches);
                for op in &mut blk {
                    op.batch *= crops;
                    op.flops *= crops as f64;
                    op.act_in_bytes *= crops as f64;
                    op.act_out_bytes *= crops as f64;
                    // weights shared across crops: weight_bytes unchanged
                }
                ops.extend(blk);
            }
        }
        // projector: concat tower features -> MLP -> decoder hidden
        let cat: u64 = self.towers.iter().map(|t| t.dims.hidden).sum();
        let dt = self.decoder.dims.dtype;
        ops.push(Operator::matmul_weight(
            "projector.fc1",
            1,
            self.shape.image_tokens,
            self.projector_hidden,
            cat,
            dt,
        ));
        ops.push(Operator::elementwise(
            "projector.gelu",
            self.shape.image_tokens * self.projector_hidden,
            1,
            8.0,
            dt,
        ));
        ops.push(Operator::matmul_weight(
            "projector.fc2",
            1,
            self.shape.image_tokens,
            self.decoder.dims.hidden,
            self.projector_hidden,
            dt,
        ));
        Stage::new("vision_encode", Phase::Vision, ops)
    }

    /// Build the prefill stage over image + prompt tokens.
    pub fn prefill_stage(&self) -> Stage {
        let seq = self.shape.prefill_len();
        let dt = self.decoder.dims.dtype;
        let mut ops = vec![Operator::gather(
            "embed",
            self.shape.prompt_tokens,
            self.decoder.dims.hidden,
            dt,
        )];
        for l in 0..self.decoder.layers {
            ops.extend(decoder_block_prefill(&format!("d{l}"), &self.decoder.dims, seq, 0));
        }
        ops.push(Operator::norm("final_ln", seq, self.decoder.dims.hidden, dt));
        // lm head on the last position only
        ops.push(Operator::matmul_weight(
            "lm_head",
            1,
            1,
            self.decoder.vocab,
            self.decoder.dims.hidden,
            dt,
        ));
        self.scale_decoder_weight_bytes(&mut ops);
        Stage::new("prefill", Phase::Prefill, ops)
    }

    /// Build ONE decode step at KV length `kv_len` (cache already holding
    /// `kv_len` tokens). The full decode phase runs this for positions
    /// `prefill_len .. prefill_len + decode_tokens`.
    pub fn decode_stage_at(&self, kv_len: u64) -> Stage {
        let dt = self.decoder.dims.dtype;
        let mut ops = vec![Operator::gather("embed", 1, self.decoder.dims.hidden, dt)];
        for l in 0..self.decoder.layers {
            ops.extend(decoder_block_decode(&format!("d{l}"), &self.decoder.dims, kv_len));
        }
        ops.push(Operator::norm("final_ln", 1, self.decoder.dims.hidden, dt));
        ops.push(Operator::matmul_weight(
            "lm_head",
            1,
            1,
            self.decoder.vocab,
            self.decoder.dims.hidden,
            dt,
        ));
        self.scale_decoder_weight_bytes(&mut ops);
        Stage::new("decode_step", Phase::Decode, ops)
    }

    /// PERF: update an existing decode stage (built by [`decode_stage_at`])
    /// in place to a new KV length, touching only the three KV-dependent
    /// operators per layer (qk, softmax, av). Rebuilding the full stage
    /// allocates ~430 operator names per position; the sweep harness calls
    /// this once per decode token instead.
    ///
    /// [`decode_stage_at`]: VlaConfig::decode_stage_at
    pub fn patch_decode_stage_kv(&self, stage: &mut Stage, kv_len: u64) {
        const OPS_PER_BLOCK: usize = 15;
        let d = &self.decoder.dims;
        let dt = d.dtype;
        let kv = kv_len.max(1);
        let g = d.heads / d.kv_heads.max(1);
        for l in 0..self.decoder.layers as usize {
            let base = 1 + l * OPS_PER_BLOCK; // ops[0] is the embed gather
            for (off, rebuilt) in [
                (4usize, Operator::matmul_act("", d.kv_heads, g, kv, d.head_dim, dt, true)),
                (5, Operator::softmax("", d.heads, kv, dt)),
                (6, Operator::matmul_act("", d.kv_heads, g, d.head_dim, kv, dt, true)),
            ] {
                let slot = &mut stage.ops[base + off];
                let name = std::mem::take(&mut slot.name);
                *slot = rebuilt;
                slot.name = name;
            }
        }
    }

    /// Build ONE decode step serving `batch` independent streams at the same
    /// KV length (multi-robot serving): weight streams are shared across the
    /// batch (read once), while per-stream KV traffic and attention scale
    /// with `batch`. This is how serving batchers recover compute-boundness
    /// on datacenter GPUs — and why it does NOT fix per-stream control
    /// latency at the edge.
    pub fn decode_stage_batched(&self, kv_len: u64, batch: u64) -> Stage {
        let dt = self.decoder.dims.dtype;
        let d = &self.decoder.dims;
        let b = batch.max(1);
        let mut ops = vec![Operator::gather("embed", b, d.hidden, dt)];
        for l in 0..self.decoder.layers {
            let pfx = format!("d{l}");
            ops.push(Operator::norm(&format!("{pfx}.ln1"), b, d.hidden, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.wq"), 1, b, d.q_dim(), d.hidden, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.wk"), 1, b, d.kv_dim(), d.hidden, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.wv"), 1, b, d.kv_dim(), d.hidden, dt));
            // attention: each stream has its own cache
            ops.push(Operator::matmul_act(
                &format!("{pfx}.qk"),
                b * d.kv_heads,
                d.heads / d.kv_heads.max(1),
                kv_len.max(1),
                d.head_dim,
                dt,
                true,
            ));
            ops.push(Operator::softmax(&format!("{pfx}.softmax"), b * d.heads, kv_len.max(1), dt));
            ops.push(Operator::matmul_act(
                &format!("{pfx}.av"),
                b * d.kv_heads,
                d.heads / d.kv_heads.max(1),
                d.head_dim,
                kv_len.max(1),
                dt,
                true,
            ));
            ops.push(Operator::matmul_weight(&format!("{pfx}.wo"), 1, b, d.hidden, d.q_dim(), dt));
            ops.push(Operator::elementwise(&format!("{pfx}.res1"), b * d.hidden, 2, 1.0, dt));
            ops.push(Operator::norm(&format!("{pfx}.ln2"), b, d.hidden, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.w_gate"), 1, b, d.ffn, d.hidden, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.w_up"), 1, b, d.ffn, d.hidden, dt));
            ops.push(Operator::elementwise(&format!("{pfx}.silu_mul"), b * d.ffn, 2, 4.0, dt));
            ops.push(Operator::matmul_weight(&format!("{pfx}.w_down"), 1, b, d.hidden, d.ffn, dt));
            ops.push(Operator::elementwise(&format!("{pfx}.res2"), b * d.hidden, 2, 1.0, dt));
        }
        ops.push(Operator::norm("final_ln", b, self.decoder.dims.hidden, dt));
        ops.push(Operator::matmul_weight(
            "lm_head",
            1,
            b,
            self.decoder.vocab,
            self.decoder.dims.hidden,
            dt,
        ));
        self.scale_decoder_weight_bytes(&mut ops);
        Stage::new("decode_step_batched", Phase::Decode, ops)
    }

    /// Build the action-transformer stage: DiT denoiser over the action
    /// horizon, `diffusion_steps` iterations, conditioned on decoder state.
    pub fn action_stage(&self) -> Stage {
        let a = &self.action;
        let dt = a.dims.dtype;
        let mut ops = Vec::new();
        // condition projection from decoder hidden
        ops.push(Operator::matmul_weight(
            "act.cond_proj",
            1,
            1,
            a.dims.hidden,
            self.decoder.dims.hidden,
            dt,
        ));
        for step in 0..a.diffusion_steps {
            for l in 0..a.layers {
                ops.extend(decoder_block_prefill(
                    &format!("act.s{step}.b{l}"),
                    &a.dims,
                    a.horizon,
                    0,
                ));
            }
        }
        ops.push(Operator::matmul_weight(
            "act.out_proj",
            1,
            a.horizon,
            a.action_dim,
            a.dims.hidden,
            dt,
        ));
        Stage::new("action_transformer", Phase::Action, ops)
    }

    /// Expand into the full per-control-step workload.
    pub fn workload(&self) -> VlaWorkload {
        VlaWorkload { config: self.clone() }
    }
}

/// The expanded per-step workload (stage generators over the config).
#[derive(Debug, Clone)]
pub struct VlaWorkload {
    pub config: VlaConfig,
}

impl VlaWorkload {
    /// Iterator over the KV lengths of each decode step.
    pub fn decode_positions(&self) -> impl Iterator<Item = u64> + '_ {
        let start = self.config.shape.prefill_len();
        (0..self.config.shape.decode_tokens).map(move |i| start + i)
    }

    /// All stages in execution order, decode expanded per token. Mostly for
    /// tests/inspection — the simulator walks decode positions lazily.
    pub fn stage_names(&self) -> Vec<String> {
        let mut v = vec!["vision_encode".to_string(), "prefill".to_string()];
        v.push(format!("decode x{}", self.config.shape.decode_tokens));
        v.push("action_transformer".to_string());
        v
    }
}

/// Construct a standard test-scale config (used in unit tests across the
/// crate; roughly 8M decoder params).
pub fn tiny_test_config() -> VlaConfig {
    let dt = DType::BF16;
    VlaConfig {
        name: "tiny-test".into(),
        towers: vec![VitConfig {
            name: "vit".into(),
            layers: 2,
            dims: BlockDims {
                hidden: 128,
                heads: 4,
                kv_heads: 4,
                head_dim: 32,
                ffn: 512,
                dtype: dt,
            },
        }],
        projector_hidden: 256,
        decoder: DecoderConfig {
            layers: 4,
            dims: BlockDims {
                hidden: 256,
                heads: 8,
                kv_heads: 2,
                head_dim: 32,
                ffn: 1024,
                dtype: dt,
            },
            vocab: 2048,
            weight_scale: 1.0,
        },
        action: ActionConfig {
            layers: 2,
            dims: BlockDims {
                hidden: 128,
                heads: 4,
                kv_heads: 4,
                head_dim: 32,
                ffn: 512,
                dtype: dt,
            },
            horizon: 8,
            diffusion_steps: 4,
            action_dim: 7,
        },
        shape: WorkloadShape {
            crops: 1,
            patches_per_crop: 64,
            image_tokens: 64,
            prompt_tokens: 16,
            decode_tokens: 24,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_config_builds_all_stages() {
        let c = tiny_test_config();
        let v = c.vision_stage();
        let p = c.prefill_stage();
        let d = c.decode_stage_at(100);
        let a = c.action_stage();
        assert_eq!(v.phase, Phase::Vision);
        assert_eq!(p.phase, Phase::Prefill);
        assert_eq!(d.phase, Phase::Decode);
        assert_eq!(a.phase, Phase::Action);
        assert!(v.total_flops() > 0.0);
        assert!(p.total_flops() > d.total_flops(), "prefill >> one decode step");
    }

    #[test]
    fn decode_positions_cover_decode_tokens() {
        let c = tiny_test_config();
        let w = c.workload();
        let pos: Vec<u64> = w.decode_positions().collect();
        assert_eq!(pos.len(), c.shape.decode_tokens as usize);
        assert_eq!(pos[0], c.shape.prefill_len());
        assert_eq!(*pos.last().unwrap(), c.shape.prefill_len() + c.shape.decode_tokens - 1);
    }

    #[test]
    fn decode_weight_traffic_matches_decoder_bytes() {
        // Every decode step streams (approximately) all decoder weights:
        // block weights + lm head; embeddings are gathered sparsely.
        let c = tiny_test_config();
        let d = c.decode_stage_at(500);
        let got = d.weight_bytes();
        let blocks = c.decoder.layers as f64 * c.decoder.dims.params() * 2.0;
        let lm_head = c.decoder.vocab as f64 * c.decoder.dims.hidden as f64 * 2.0;
        let expect = blocks + lm_head;
        assert!(
            (got - expect).abs() / expect < 0.05,
            "decode weight bytes {got:.3e} vs expected {expect:.3e}"
        );
    }

    #[test]
    fn weight_scale_halves_weight_streams_only() {
        let base = tiny_test_config();
        let mut packed = tiny_test_config();
        packed.decoder.weight_scale = 0.5;
        for (full, half) in [
            (base.decode_stage_at(100), packed.decode_stage_at(100)),
            (base.prefill_stage(), packed.prefill_stage()),
            (base.decode_stage_batched(100, 4), packed.decode_stage_batched(100, 4)),
        ] {
            assert!(
                (half.weight_bytes() / full.weight_bytes() - 0.5).abs() < 1e-9,
                "{}: weight bytes must halve",
                full.name
            );
            // KV and activation traffic keep the dtype's width
            assert_eq!(half.kv_bytes().to_bits(), full.kv_bytes().to_bits());
            assert_eq!(half.total_flops().to_bits(), full.total_flops().to_bits());
        }
        assert!((packed.decoder_weight_bytes() / base.decoder_weight_bytes() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn weight_footprint_tracks_params_and_quantization() {
        let c = tiny_test_config();
        // everything is bf16 at the default config: footprint == 2 * params
        let full = c.weight_footprint_bytes();
        assert!((full / (2.0 * c.params()) - 1.0).abs() < 1e-9, "bf16 footprint = 2B/param");
        // W8 shrinks only the projector+decoder share; W4 halves it again
        let mut w8 = c.clone();
        w8.decoder.dims.dtype = crate::hw::DType::I8;
        let mut w4 = w8.clone();
        w4.decoder.weight_scale = 0.5;
        assert!(w8.weight_footprint_bytes() < full);
        assert!(w4.weight_footprint_bytes() < w8.weight_footprint_bytes());
        // W8 drops exactly one byte per decoder+projector parameter
        let proj = {
            let cat = c.towers.iter().map(|t| t.dims.hidden).sum::<u64>() as f64;
            cat * c.projector_hidden as f64
                + c.projector_hidden as f64 * c.decoder.dims.hidden as f64
        };
        let expect_drop = c.decoder.params() + proj;
        assert!(
            ((full - w8.weight_footprint_bytes()) / expect_drop - 1.0).abs() < 1e-9,
            "W8 must drop exactly one byte per decoder+projector param"
        );
    }

    #[test]
    fn params_scale_sane() {
        let c = tiny_test_config();
        let p = c.params();
        assert!(p > 1e6 && p < 1e8, "params {p}");
    }

    #[test]
    fn patch_decode_stage_matches_rebuild() {
        let c = tiny_test_config();
        let mut patched = c.decode_stage_at(10);
        for kv in [11u64, 64, 1, 127] {
            c.patch_decode_stage_kv(&mut patched, kv);
            let fresh = c.decode_stage_at(kv);
            assert_eq!(patched.ops.len(), fresh.ops.len());
            for (a, b) in patched.ops.iter().zip(fresh.ops.iter()) {
                assert_eq!(a.name, b.name, "names preserved");
                assert_eq!(a.kind, b.kind);
                let cost_a = (a.flops, a.weight_bytes, a.kv_bytes, a.act_in_bytes, a.act_out_bytes);
                let cost_b = (b.flops, b.weight_bytes, b.kv_bytes, b.act_in_bytes, b.act_out_bytes);
                assert_eq!(cost_a, cost_b, "{}", a.name);
                assert_eq!((a.batch, a.m, a.n, a.k), (b.batch, b.m, b.n, b.k), "{}", a.name);
            }
        }
    }

    #[test]
    fn batched_decode_amortizes_weights() {
        let c = tiny_test_config();
        let b1 = c.decode_stage_batched(100, 1);
        let b8 = c.decode_stage_batched(100, 8);
        // weight traffic identical up to the embed gather; flops and kv
        // scale with batch
        assert!((b8.weight_bytes() - b1.weight_bytes()) / b1.weight_bytes() < 0.01);
        assert!(b8.total_flops() > 7.0 * b1.total_flops());
        assert!(b8.kv_bytes() > 7.0 * b1.kv_bytes());
        // batched stage intensity is higher -> closer to compute-bound
        assert!(b8.intensity() > 4.0 * b1.intensity());
    }

    #[test]
    fn batch_one_matches_unbatched_decode() {
        let c = tiny_test_config();
        let a = c.decode_stage_at(100);
        let b = c.decode_stage_batched(100, 1);
        assert!((a.total_flops() - b.total_flops()).abs() / a.total_flops() < 1e-9);
        assert!((a.weight_bytes() - b.weight_bytes()).abs() < 1.0);
        assert!((a.kv_bytes() - b.kv_bytes()).abs() < 1.0);
    }

    #[test]
    fn action_stage_scales_with_diffusion_steps() {
        let mut c = tiny_test_config();
        let f1 = c.action_stage().total_flops();
        c.action.diffusion_steps *= 2;
        let f2 = c.action_stage().total_flops();
        assert!(f2 > 1.8 * f1);
    }
}
