//! Scaling laws: construct VLA configs from 2 B to 100 B parameters.
//!
//! The paper (§4.2) scales VLA models "upto 100B parameters, following
//! scaling laws in [1, 8]". We anchor decoder shapes on the open-model
//! family the scaling literature tracks (Qwen/LLaMA-shaped: depth and width
//! grow together, GQA with 8 KV heads at scale), scale the vision towers
//! ViT-L → ViT-H → ViT-g, grow the action expert proportionally, and grow
//! the reasoning-trace length with capability (Fig 3 evaluates "long horizon
//! action generation").

use super::layer::BlockDims;
use super::molmoact::molmoact_7b;
use super::vla::{ActionConfig, DecoderConfig, VitConfig, VlaConfig, WorkloadShape};
use crate::hw::DType;

/// The model sizes (billions of parameters) evaluated in Fig 3.
pub const ANCHOR_SIZES_B: [f64; 6] = [2.0, 7.0, 14.0, 30.0, 70.0, 100.0];

struct DecoderAnchor {
    size_b: f64,
    hidden: u64,
    layers: u64,
    heads: u64,
    kv_heads: u64,
    ffn: u64,
    decode_tokens: u64,
    vision_class: VisionClass,
    action_layers: u64,
    action_hidden: u64,
}

#[derive(Clone, Copy)]
enum VisionClass {
    L,
    H,
    G,
}

#[rustfmt::skip]
fn anchors() -> Vec<DecoderAnchor> {
    vec![
        DecoderAnchor { size_b: 2.0, hidden: 2048, layers: 24, heads: 16, kv_heads: 4, ffn: 5504, decode_tokens: 128, vision_class: VisionClass::L, action_layers: 4, action_hidden: 768 },
        DecoderAnchor { size_b: 7.0, hidden: 3584, layers: 28, heads: 28, kv_heads: 4, ffn: 18944, decode_tokens: 256, vision_class: VisionClass::L, action_layers: 6, action_hidden: 1024 },
        DecoderAnchor { size_b: 14.0, hidden: 5120, layers: 40, heads: 40, kv_heads: 8, ffn: 13824, decode_tokens: 256, vision_class: VisionClass::H, action_layers: 6, action_hidden: 1024 },
        DecoderAnchor { size_b: 30.0, hidden: 5120, layers: 64, heads: 40, kv_heads: 8, ffn: 27648, decode_tokens: 288, vision_class: VisionClass::H, action_layers: 8, action_hidden: 1536 },
        DecoderAnchor { size_b: 70.0, hidden: 8192, layers: 80, heads: 64, kv_heads: 8, ffn: 28672, decode_tokens: 320, vision_class: VisionClass::G, action_layers: 10, action_hidden: 1536 },
        DecoderAnchor { size_b: 100.0, hidden: 9216, layers: 84, heads: 72, kv_heads: 8, ffn: 36864, decode_tokens: 384, vision_class: VisionClass::G, action_layers: 12, action_hidden: 2048 },
    ]
}

fn vision_towers(class: VisionClass) -> Vec<VitConfig> {
    let dt = DType::BF16;
    let mk = |name: &str, layers: u64, hidden: u64, heads: u64, ffn: u64| VitConfig {
        name: name.into(),
        layers,
        dims: BlockDims {
            hidden,
            heads,
            kv_heads: heads,
            head_dim: hidden / heads,
            ffn,
            dtype: dt,
        },
    };
    match class {
        VisionClass::L => vec![
            mk("siglip", 27, 1152, 16, 4304),
            mk("dinov2-l", 24, 1024, 16, 4096),
        ],
        VisionClass::H => vec![
            mk("siglip", 27, 1152, 16, 4304),
            mk("dinov2-h", 32, 1280, 16, 5120),
        ],
        VisionClass::G => vec![
            mk("siglip2", 40, 1536, 16, 6144),
            mk("dinov2-g", 40, 1536, 24, 6144),
        ],
    }
}

/// Build the VLA config for a target size in billions of parameters.
/// `size_b` must be one of [`ANCHOR_SIZES_B`] (Fig 3's x-axis); other values
/// snap to the nearest anchor.
pub fn scaled_vla(size_b: f64) -> VlaConfig {
    let anchor = anchors()
        .into_iter()
        .min_by(|a, b| {
            ((a.size_b - size_b).abs())
                .partial_cmp(&(b.size_b - size_b).abs())
                .unwrap()
        })
        .unwrap();
    let dt = DType::BF16;
    if (anchor.size_b - 7.0).abs() < 1e-9 {
        // the 7 B point IS MolmoAct-7B
        return molmoact_7b();
    }
    VlaConfig {
        name: format!("VLA-{:.0}B", anchor.size_b),
        towers: vision_towers(anchor.vision_class),
        projector_hidden: (anchor.hidden).max(4096),
        decoder: DecoderConfig {
            layers: anchor.layers,
            dims: BlockDims {
                hidden: anchor.hidden,
                heads: anchor.heads,
                kv_heads: anchor.kv_heads,
                head_dim: 128,
                ffn: anchor.ffn,
                dtype: dt,
            },
            vocab: 152_064,
            weight_scale: 1.0,
        },
        action: ActionConfig {
            layers: anchor.action_layers,
            dims: BlockDims {
                hidden: anchor.action_hidden,
                heads: anchor.action_hidden / 64,
                kv_heads: anchor.action_hidden / 64,
                head_dim: 64,
                ffn: 4 * anchor.action_hidden,
                dtype: dt,
            },
            horizon: 8,
            diffusion_steps: 10,
            action_dim: 7,
        },
        shape: WorkloadShape {
            crops: 13,
            patches_per_crop: 576,
            image_tokens: 13 * 144,
            prompt_tokens: 64,
            decode_tokens: anchor.decode_tokens,
        },
    }
}

/// Robot task performance under the power-law scaling of Sartor & Thompson
/// [8]: relative task success improves as params^alpha. Used only for
/// narrative context in reports (the paper motivates scaling with it).
pub fn task_performance_powerlaw(params: f64, alpha: f64) -> f64 {
    (params / 1e9).powf(alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_param_counts_near_targets() {
        for size in ANCHOR_SIZES_B {
            let c = scaled_vla(size);
            let decoder_b = c.decoder.params() / 1e9;
            assert!(
                (decoder_b - size).abs() / size < 0.35,
                "{}: decoder {decoder_b:.2}B vs target {size}B",
                c.name
            );
        }
    }

    #[test]
    fn seven_b_is_molmoact() {
        assert_eq!(scaled_vla(7.0).name, "MolmoAct-7B");
    }

    #[test]
    fn snapping_to_nearest() {
        assert_eq!(scaled_vla(8.0).name, "MolmoAct-7B");
        assert_eq!(scaled_vla(90.0).name, "VLA-100B");
        assert_eq!(scaled_vla(1.0).name, "VLA-2B");
    }

    #[test]
    fn monotone_in_size() {
        let mut last = 0.0;
        for size in ANCHOR_SIZES_B {
            let p = scaled_vla(size).params();
            assert!(p > last, "params must grow with size ({size}B)");
            last = p;
        }
    }

    #[test]
    fn decode_tokens_grow_with_capability() {
        let mut last = 0;
        for size in ANCHOR_SIZES_B {
            let d = scaled_vla(size).shape.decode_tokens;
            assert!(d >= last);
            last = d;
        }
    }

    #[test]
    fn powerlaw_monotone() {
        assert!(task_performance_powerlaw(70e9, 0.3) > task_performance_powerlaw(7e9, 0.3));
    }

    #[test]
    fn seven_b_matches_molmoact_params_within_1pct() {
        // the 7 B anchor IS MolmoAct-7B: parameter counts must agree to <1%
        let scaled = scaled_vla(7.0).params();
        let molmo = molmoact_7b().params();
        assert!(
            (scaled - molmo).abs() / molmo < 0.01,
            "scaled_vla(7.0) {scaled:.3e} vs molmoact_7b() {molmo:.3e}"
        );
    }

    #[test]
    fn monotone_in_params_and_decoder_bytes() {
        // Fig 3's x-axis must be strictly ordered in BOTH total parameters
        // and the bytes decode streams per token (the bottleneck driver).
        let mut last_params = 0.0;
        let mut last_bytes = 0.0;
        for size in ANCHOR_SIZES_B {
            let c = scaled_vla(size);
            let p = c.params();
            let b = c.decoder_weight_bytes();
            assert!(p > last_params, "{size}B params {p:.3e} <= {last_params:.3e}");
            assert!(b > last_bytes, "{size}B decoder bytes {b:.3e} <= {last_bytes:.3e}");
            last_params = p;
            last_bytes = b;
        }
    }

    #[test]
    fn decode_stays_memory_bound_at_every_scale() {
        // Paper §3: single-stream decode is a GEMV stream — its arithmetic
        // intensity must sit far below the machine balance of every Table 1
        // platform (Orin: 100 TFLOPS / 162 GB/s ≈ 616 FLOP/byte).
        for size in ANCHOR_SIZES_B {
            let c = scaled_vla(size);
            let mid = c.shape.prefill_len() + c.shape.decode_tokens / 2;
            let stage = c.decode_stage_at(mid);
            let intensity = stage.intensity();
            assert!(
                intensity < 2.0,
                "{}: decode intensity {intensity:.2} FLOP/byte should be memory-bound",
                c.name
            );
            // and prefill over the same config is the compute-bound contrast
            assert!(c.prefill_stage().intensity() > 50.0, "{} prefill", c.name);
        }
    }

    #[test]
    fn gqa_at_scale() {
        for size in [14.0, 30.0, 70.0, 100.0] {
            let c = scaled_vla(size);
            assert_eq!(c.decoder.dims.kv_heads, 8, "{} uses 8 KV heads", c.name);
            assert_eq!(c.decoder.dims.head_dim, 128);
        }
    }
}
