//! VLA workload IR: operators, transformer layers, stages, model configs
//! (MolmoAct-7B and scaled variants), and scaling laws.

pub mod layer;
pub mod molmoact;
pub mod op;
pub mod scaling;
pub mod stage;
pub mod vla;

pub use layer::BlockDims;
pub use op::{OpKind, Operator};
pub use stage::{Phase, Stage};
pub use vla::{VlaConfig, VlaWorkload, WorkloadShape};
