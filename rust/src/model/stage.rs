//! Stages and phases: the paper's three-subsystem decomposition (Fig 1) —
//! Vision Encoder, Generation Engine (prefill + autoregressive decode), and
//! Action Transformer.

use super::op::Operator;

/// The phase taxonomy used throughout Fig 2's latency decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Vision,
    Prefill,
    Decode,
    Action,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Vision => "vision",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::Action => "action",
        }
    }

    pub const ALL: [Phase; 4] = [Phase::Vision, Phase::Prefill, Phase::Decode, Phase::Action];

    /// The paper reports "generation" = prefill + autoregressive decode.
    pub fn in_generation(self) -> bool {
        matches!(self, Phase::Prefill | Phase::Decode)
    }
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A stage: a named operator sequence executed as a unit.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub name: String,
    pub phase: Phase,
    pub ops: Vec<Operator>,
}

impl Stage {
    pub fn new(name: &str, phase: Phase, ops: Vec<Operator>) -> Stage {
        Stage {
            name: name.into(),
            phase,
            ops,
        }
    }

    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.total_bytes()).sum()
    }

    pub fn weight_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    pub fn kv_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.kv_bytes).sum()
    }

    /// Stage-level arithmetic intensity (FLOP/byte).
    pub fn intensity(&self) -> f64 {
        self.total_flops() / self.total_bytes().max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DType;

    #[test]
    fn phase_names_and_generation() {
        assert_eq!(Phase::Decode.name(), "decode");
        assert!(Phase::Decode.in_generation());
        assert!(Phase::Prefill.in_generation());
        assert!(!Phase::Vision.in_generation());
        assert!(!Phase::Action.in_generation());
        assert_eq!(Phase::ALL.len(), 4);
    }

    #[test]
    fn stage_aggregates() {
        let ops = vec![
            Operator::matmul_weight("a", 1, 4, 8, 16, DType::BF16),
            Operator::matmul_weight("b", 1, 4, 8, 16, DType::BF16),
        ];
        let s = Stage::new("s", Phase::Vision, ops.clone());
        assert_eq!(s.total_flops(), 2.0 * ops[0].flops);
        assert_eq!(s.weight_bytes(), 2.0 * ops[0].weight_bytes);
        assert!(s.intensity() > 0.0);
    }
}
