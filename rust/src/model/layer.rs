//! Transformer-layer builders: expand one layer into its operator sequence
//! for prefill (seq-parallel), decode (single token against a KV cache), and
//! ViT (bidirectional, no cache) execution modes.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::op::Operator;
use crate::hw::DType;

/// Dimensions of one decoder-only transformer block (GQA + SwiGLU, the
/// Qwen2/LLaMA family shape used by MolmoAct's reasoning engine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockDims {
    pub hidden: u64,
    pub heads: u64,
    pub kv_heads: u64,
    pub head_dim: u64,
    pub ffn: u64,
    pub dtype: DType,
}

impl BlockDims {
    pub fn q_dim(&self) -> u64 {
        self.heads * self.head_dim
    }

    pub fn kv_dim(&self) -> u64 {
        self.kv_heads * self.head_dim
    }

    /// Parameters in one block (attention + SwiGLU MLP + norms).
    pub fn params(&self) -> f64 {
        let h = self.hidden as f64;
        let attn = h * self.q_dim() as f64      // Wq
            + 2.0 * h * self.kv_dim() as f64    // Wk, Wv
            + self.q_dim() as f64 * h;          // Wo
        let mlp = 3.0 * h * self.ffn as f64; // gate, up, down
        attn + mlp + 2.0 * h
    }

    /// KV-cache bytes per token for this block.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.kv_dim() as f64 * self.dtype.bytes()
    }
}

/// Ops for one decoder block processing `seq` fresh tokens (prefill mode,
/// causal attention over those tokens plus `past` cached tokens).
pub fn decoder_block_prefill(prefix: &str, d: &BlockDims, seq: u64, past: u64) -> Vec<Operator> {
    let dt = d.dtype;
    let ctx = seq + past;
    // GQA repeats kv heads across q heads; no extra traffic modeled.
    vec![
        Operator::norm(&format!("{prefix}.ln1"), seq, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wq"), 1, seq, d.q_dim(), d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wk"), 1, seq, d.kv_dim(), d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wv"), 1, seq, d.kv_dim(), d.hidden, dt),
        // scores: [heads, seq, hd] x [heads, hd, ctx] — causal ~halves the
        // effective context; model with ctx/2 + seq/2 average length
        Operator::matmul_act(
            &format!("{prefix}.qk"),
            d.heads,
            seq,
            (past + seq / 2).max(1),
            d.head_dim,
            dt,
            false,
        ),
        Operator::softmax(&format!("{prefix}.softmax"), d.heads * seq, ctx, dt),
        Operator::matmul_act(
            &format!("{prefix}.av"),
            d.heads,
            seq,
            d.head_dim,
            (past + seq / 2).max(1),
            dt,
            false,
        ),
        Operator::matmul_weight(&format!("{prefix}.wo"), 1, seq, d.hidden, d.q_dim(), dt),
        Operator::elementwise(&format!("{prefix}.res1"), seq * d.hidden, 2, 1.0, dt),
        Operator::norm(&format!("{prefix}.ln2"), seq, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.w_gate"), 1, seq, d.ffn, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.w_up"), 1, seq, d.ffn, d.hidden, dt),
        Operator::elementwise(&format!("{prefix}.silu_mul"), seq * d.ffn, 2, 4.0, dt),
        Operator::matmul_weight(&format!("{prefix}.w_down"), 1, seq, d.hidden, d.ffn, dt),
        Operator::elementwise(&format!("{prefix}.res2"), seq * d.hidden, 2, 1.0, dt),
    ]
}

/// Ops for one decoder block decoding ONE token at cache length `kv_len`
/// (the memory-bound inner loop of the generation phase).
pub fn decoder_block_decode(prefix: &str, d: &BlockDims, kv_len: u64) -> Vec<Operator> {
    let dt = d.dtype;
    vec![
        Operator::norm(&format!("{prefix}.ln1"), 1, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wq"), 1, 1, d.q_dim(), d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wk"), 1, 1, d.kv_dim(), d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wv"), 1, 1, d.kv_dim(), d.hidden, dt),
        // q @ K^T over the whole cache: KV operand streamed from DRAM.
        // GQA: kv_heads distinct K tensors, each shared by heads/kv_heads
        // query heads -> batch = kv_heads, m = heads/kv_heads.
        Operator::matmul_act(
            &format!("{prefix}.qk"),
            d.kv_heads,
            d.heads / d.kv_heads.max(1),
            kv_len.max(1),
            d.head_dim,
            dt,
            true,
        ),
        Operator::softmax(&format!("{prefix}.softmax"), d.heads, kv_len.max(1), dt),
        Operator::matmul_act(
            &format!("{prefix}.av"),
            d.kv_heads,
            d.heads / d.kv_heads.max(1),
            d.head_dim,
            kv_len.max(1),
            dt,
            true,
        ),
        Operator::matmul_weight(&format!("{prefix}.wo"), 1, 1, d.hidden, d.q_dim(), dt),
        Operator::elementwise(&format!("{prefix}.res1"), d.hidden, 2, 1.0, dt),
        Operator::norm(&format!("{prefix}.ln2"), 1, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.w_gate"), 1, 1, d.ffn, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.w_up"), 1, 1, d.ffn, d.hidden, dt),
        Operator::elementwise(&format!("{prefix}.silu_mul"), d.ffn, 2, 4.0, dt),
        Operator::matmul_weight(&format!("{prefix}.w_down"), 1, 1, d.hidden, d.ffn, dt),
        Operator::elementwise(&format!("{prefix}.res2"), d.hidden, 2, 1.0, dt),
    ]
}

/// Ops for one ViT encoder block over `seq` patch tokens (bidirectional,
/// GELU MLP, no KV cache).
pub fn vit_block(prefix: &str, d: &BlockDims, seq: u64) -> Vec<Operator> {
    let dt = d.dtype;
    vec![
        Operator::norm(&format!("{prefix}.ln1"), seq, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.wqkv"), 1, seq, 3 * d.q_dim(), d.hidden, dt),
        Operator::matmul_act(&format!("{prefix}.qk"), d.heads, seq, seq, d.head_dim, dt, false),
        Operator::softmax(&format!("{prefix}.softmax"), d.heads * seq, seq, dt),
        Operator::matmul_act(&format!("{prefix}.av"), d.heads, seq, d.head_dim, seq, dt, false),
        Operator::matmul_weight(&format!("{prefix}.wo"), 1, seq, d.hidden, d.q_dim(), dt),
        Operator::elementwise(&format!("{prefix}.res1"), seq * d.hidden, 2, 1.0, dt),
        Operator::norm(&format!("{prefix}.ln2"), seq, d.hidden, dt),
        Operator::matmul_weight(&format!("{prefix}.fc1"), 1, seq, d.ffn, d.hidden, dt),
        Operator::elementwise(&format!("{prefix}.gelu"), seq * d.ffn, 1, 8.0, dt),
        Operator::matmul_weight(&format!("{prefix}.fc2"), 1, seq, d.hidden, d.ffn, dt),
        Operator::elementwise(&format!("{prefix}.res2"), seq * d.hidden, 2, 1.0, dt),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> BlockDims {
        BlockDims {
            hidden: 3584,
            heads: 28,
            kv_heads: 4,
            head_dim: 128,
            ffn: 18944,
            dtype: DType::BF16,
        }
    }

    #[test]
    fn qwen7b_block_params() {
        // Qwen2-7B: 28 layers x block params + embeddings ~ 7.6B total
        let p = dims().params();
        assert!(p > 2.0e8 && p < 2.7e8, "block params {p}");
        assert!((28.0 * p - 7.0e9).abs() < 1.0e9, "28 blocks ~ 7B params: {}", 28.0 * p);
    }

    #[test]
    fn decode_block_weight_bytes_equals_params() {
        // During decode every weight is read exactly once: sum of
        // weight_bytes over matmul_w ops == ~params * 2 bytes.
        let d = dims();
        let ops = decoder_block_decode("l0", &d, 640);
        let wbytes: f64 = ops.iter().map(|o| o.weight_bytes).sum();
        let expect = d.params() * 2.0;
        assert!(
            (wbytes - expect).abs() / expect < 0.01,
            "wbytes {wbytes} vs params*2 {expect}"
        );
    }

    #[test]
    fn decode_kv_traffic_grows_with_len() {
        let d = dims();
        let kv_at = |len: u64| -> f64 {
            decoder_block_decode("l", &d, len).iter().map(|o| o.kv_bytes).sum()
        };
        assert!(kv_at(1000) > kv_at(100));
        // kv bytes at len L = 2 (K and V) * kv_dim * L * 2 bytes
        let expect = 2.0 * d.kv_dim() as f64 * 1000.0 * 2.0;
        assert!((kv_at(1000) - expect).abs() / expect < 0.01);
    }

    #[test]
    fn prefill_flops_scale_with_seq() {
        let d = dims();
        let f = |seq: u64| -> f64 {
            decoder_block_prefill("l", &d, seq, 0).iter().map(|o| o.flops).sum()
        };
        let r = f(1280) / f(640);
        assert!(r > 1.9 && r < 2.3, "ratio {r}"); // ~linear in seq (attn slightly super)
    }

    #[test]
    fn decode_is_low_intensity_prefill_is_high() {
        let d = dims();
        let intensity = |ops: &[Operator]| -> f64 {
            let f: f64 = ops.iter().map(|o| o.flops).sum();
            let b: f64 = ops.iter().map(|o| o.total_bytes()).sum();
            f / b
        };
        let dec = decoder_block_decode("l", &d, 640);
        let pre = decoder_block_prefill("l", &d, 640, 0);
        assert!(intensity(&dec) < 2.0, "decode intensity {}", intensity(&dec));
        assert!(intensity(&pre) > 100.0, "prefill intensity {}", intensity(&pre));
    }

    #[test]
    fn vit_block_structure() {
        let d = BlockDims {
            hidden: 1024,
            heads: 16,
            kv_heads: 16,
            head_dim: 64,
            ffn: 4096,
            dtype: DType::BF16,
        };
        let ops = vit_block("v0", &d, 576);
        assert_eq!(ops.len(), 12);
        assert!(ops.iter().all(|o| o.kv_bytes == 0.0), "ViT has no KV cache");
    }
}
