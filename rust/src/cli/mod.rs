//! Command-line interface of the `vla-char` binary (logic lives here so the
//! integration suite can drive it without spawning processes).

use crate::engine::{
    run_batcher, run_control_loop, BatcherConfig, ControlLoopConfig, Policy, StepServer, VlaEngine,
    VlaModel,
};
use crate::hw::platform;
use crate::model::molmoact::molmoact_7b;
use crate::model::scaling::ANCHOR_SIZES_B;
use crate::profile::{top_ops, trace_table, PhaseProfiler};
use crate::report::{check_fig2, check_fig3, fig2, fig3, render};
use crate::runtime::Runtime;
use crate::sim::calibrate::{validate, MeasuredPhases};
use crate::sim::SimOptions;
use crate::util::cli::{help_text, Args, OptSpec};
use crate::util::units::{fmt_hz, fmt_time};
use std::path::PathBuf;

const ABOUT: &str =
    "Characterizing VLA models: the action-generation bottleneck on edge AI architectures \
     (reproduction of CS.PF 2026)";

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("table1", "emit Table 1 (platform matrix)"),
    ("characterize", "Fig 2: MolmoAct-7B phase latency on Orin/Thor + claim checks"),
    ("project", "Fig 3: control frequency for 2-100B models across all platforms"),
    ("ablate", "ablations: prefetch, CoT length, action horizon, framework"),
    ("step", "run ONE real control step through the PJRT artifacts (golden-checked)"),
    ("control-loop", "run the real tiny-VLA control loop and report achieved Hz"),
    ("serve", "multi-stream serving through the batcher (real engine)"),
    ("validate", "E-C6: calibrate the simulator against real measurements"),
    ("codesign", "algorithm-system co-design projections (quantization, speculation, ...)"),
    ("energy", "energy per step / per action across the platform matrix"),
    ("batch", "batched multi-robot decode: per-stream vs aggregate throughput"),
    ("trace-export", "write a Chrome-trace JSON of a simulated control step"),
    ("report", "run every experiment and write markdown+CSV under --out"),
];

#[rustfmt::skip]
fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "help", value_name: None, help: "show this help", default: None },
        OptSpec { name: "platform", value_name: Some("NAME"), help: "platform for --trace (orin, thor, orin+pim, ...)", default: Some("orin") },
        OptSpec { name: "sizes", value_name: Some("LIST"), help: "model sizes in B params for `project`", default: Some("2,7,14,30,70,100") },
        OptSpec { name: "steps", value_name: Some("N"), help: "control-loop steps", default: Some("20") },
        OptSpec { name: "decode-tokens", value_name: Some("N"), help: "override generated tokens per step (real engine)", default: None },
        OptSpec { name: "target-hz", value_name: Some("HZ"), help: "control-loop target frequency", default: Some("10") },
        OptSpec { name: "streams", value_name: Some("N"), help: "serving streams", default: Some("2") },
        OptSpec { name: "rate", value_name: Some("HZ"), help: "per-stream request rate", default: Some("2") },
        OptSpec { name: "policy", value_name: Some("P"), help: "serving policy: fifo | rr", default: Some("rr") },
        OptSpec { name: "duration", value_name: Some("S"), help: "serving arrival-trace duration (virtual s)", default: Some("5") },
        OptSpec { name: "stride", value_name: Some("N"), help: "decode-position sampling stride (sim)", default: Some("1") },
        OptSpec { name: "no-prefetch", value_name: None, help: "disable cross-operator prefetch (sim)", default: None },
        OptSpec { name: "no-pim", value_name: None, help: "disable PIM offload (sim)", default: None },
        OptSpec { name: "compiled", value_name: None, help: "idealized compiled runtime (no eager overheads)", default: None },
        OptSpec { name: "amortized", value_name: None, help: "also print the horizon-amortized Fig 3 table", default: None },
        OptSpec { name: "trace", value_name: None, help: "print the top-20 operator trace (characterize)", default: None },
        OptSpec { name: "seed", value_name: Some("N"), help: "workload seed", default: Some("42") },
        OptSpec { name: "out", value_name: Some("DIR"), help: "output directory for `report`", default: Some("reports") },
        OptSpec { name: "platform-file", value_name: Some("PATH"), help: "JSON platform description (overrides --platform)", default: None },
        OptSpec { name: "model-file", value_name: Some("PATH"), help: "JSON VLA model description (overrides MolmoAct-7B)", default: None },
        OptSpec { name: "size", value_name: Some("B"), help: "model size in B params (codesign/energy/batch/trace-export)", default: Some("7") },
        OptSpec { name: "batches", value_name: Some("LIST"), help: "batch sizes for `batch`", default: Some("1,2,4,8,16") },
        OptSpec { name: "trace-out", value_name: Some("PATH"), help: "output path for `trace-export`", default: Some("trace.json") },
    ]
}

/// Build simulator options from parsed flags.
fn sim_options(args: &Args) -> anyhow::Result<SimOptions> {
    let mut o = if args.flag("compiled") {
        SimOptions::compiled()
    } else {
        SimOptions::default()
    };
    o.prefetch = !args.flag("no-prefetch");
    o.pim = !args.flag("no-pim");
    o.decode_stride = args.get_usize("stride", 1)? as u64;
    Ok(o)
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    crate::util::log::init();
    let args = Args::parse("vla-char", argv, &specs())?;
    if args.flag("help") || args.subcommand.is_none() {
        println!("{}", help_text("vla-char", ABOUT, SUBCOMMANDS, &specs()));
        return Ok(0);
    }
    match args.subcommand.as_deref().unwrap() {
        "table1" => cmd_table1(),
        "characterize" => cmd_characterize(&args),
        "project" => cmd_project(&args),
        "ablate" => cmd_ablate(),
        "step" => cmd_step(&args),
        "control-loop" => cmd_control_loop(&args),
        "serve" => cmd_serve(&args),
        "validate" => cmd_validate(&args),
        "codesign" => cmd_codesign(&args),
        "energy" => cmd_energy(&args),
        "batch" => cmd_batch(&args),
        "trace-export" => cmd_trace_export(&args),
        "report" => cmd_report(&args),
        other => {
            eprintln!("unknown subcommand `{other}` (try --help)");
            Ok(2)
        }
    }
}

fn cmd_table1() -> anyhow::Result<i32> {
    println!("{}", platform::table1().to_markdown());
    Ok(0)
}

fn cmd_characterize(args: &Args) -> anyhow::Result<i32> {
    let options = sim_options(args)?;
    let f = fig2::run(&options);
    println!("{}", f.table().to_markdown());
    println!("{}", f.bars());
    println!("{}\n", f.summary());
    if args.flag("trace") {
        let plat = platform::by_name(args.get_or("platform", "orin"))?;
        let cfg = molmoact_7b();
        let stage = cfg.decode_stage_at(cfg.shape.prefill_len() + 64);
        let costs = crate::profile::trace::trace_stage(&plat, &stage, options.pim);
        println!(
            "{}",
            trace_table(
                &format!("Top decode-step operators on {}", plat.name),
                &top_ops(costs, 20)
            )
            .to_markdown()
        );
    }
    let (text, ok) = render(&check_fig2(&f));
    println!("{text}");
    Ok(if ok { 0 } else { 1 })
}

fn cmd_project(args: &Args) -> anyhow::Result<i32> {
    let options = sim_options(args)?;
    let sizes = args.get_f64_list("sizes", &ANCHOR_SIZES_B)?;
    let f = fig3::run(&options, &sizes);
    println!("{}", f.table(false).to_markdown());
    if args.flag("amortized") {
        println!("{}", f.table(true).to_markdown());
    }
    let reaching = f.reaching_target(10.0);
    println!(
        "configs reaching 10 Hz (amortized): {}",
        if reaching.is_empty() {
            "none".to_string()
        } else {
            reaching
                .iter()
                .map(|c| format!("{}@{:.0}B", c.platform, c.size_b))
                .collect::<Vec<_>>()
                .join(", ")
        }
    );
    let (text, ok) = render(&check_fig3(&f));
    println!("{text}");
    Ok(if ok { 0 } else { 1 })
}

fn cmd_ablate() -> anyhow::Result<i32> {
    println!("{}", crate::report::ablations::prefetch_ablation().to_markdown());
    println!(
        "{}",
        crate::report::ablations::cot_length_ablation(&[32, 64, 128, 256, 512]).to_markdown()
    );
    println!(
        "{}",
        crate::report::ablations::horizon_ablation(&[1, 4, 8, 16, 32]).to_markdown()
    );
    println!("{}", crate::report::ablations::framework_ablation().to_markdown());
    Ok(0)
}

/// Load the real engine (PJRT CPU + artifacts).
fn load_engine(args: &Args) -> anyhow::Result<VlaEngine> {
    let rt = Runtime::cpu()?;
    let model = VlaModel::load(&rt)?;
    Ok(match args.get("decode-tokens") {
        Some(_) => {
            VlaEngine::with_decode_tokens(model, args.get_usize("decode-tokens", 24)?)
        }
        None => VlaEngine::new(model),
    })
}

fn cmd_step(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let m = &engine.model.manifest;
    let seed = args.get_usize("seed", 42)? as u64;
    let mut frames = crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, seed);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let frame = frames.next_frame(0, 0);
    let r = engine.step(&frame, &prompt)?;
    println!("tokens: {:?}...", &r.tokens[..r.tokens.len().min(8)]);
    println!(
        "actions[0]: {:?}",
        &r.actions[..m.action.action_dim.min(r.actions.len())]
    );
    println!(
        "phases: vision {} | prefill {} | decode {} ({} tok, {:.1} tok/s) | action {}",
        fmt_time(r.times.vision.as_secs_f64()),
        fmt_time(r.times.prefill.as_secs_f64()),
        fmt_time(r.times.decode.as_secs_f64()),
        r.tokens.len(),
        r.decode_tps,
        fmt_time(r.times.action.as_secs_f64()),
    );
    println!(
        "total {} | generation share {:.1}%",
        fmt_time(r.times.total().as_secs_f64()),
        r.times.generation_share() * 100.0
    );
    Ok(0)
}

fn cmd_control_loop(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let cfg = ControlLoopConfig {
        target_hz: args.get_f64("target-hz", 10.0)?,
        steps: args.get_usize("steps", 20)? as u64,
        seed: args.get_usize("seed", 42)? as u64,
    };
    let r = run_control_loop(&engine, &cfg)?;
    println!(
        "steps {} | achieved {} (target {}) | amortized {} | misses {}/{}",
        r.steps,
        fmt_hz(r.achieved_hz),
        fmt_hz(r.target_hz),
        fmt_hz(r.amortized_hz),
        r.deadline_misses,
        r.steps
    );
    println!(
        "latency mean {} p99 {} | x{:.1} over budget | generation share {:.1}%",
        fmt_time(r.latency.mean),
        fmt_time(r.latency.p99),
        r.latency_vs_budget(),
        r.generation_share * 100.0
    );
    println!(
        "phases mean: vision {} prefill {} decode {} action {} | decode {:.1} tok/s",
        fmt_time(r.mean_phase[0]),
        fmt_time(r.mean_phase[1]),
        fmt_time(r.mean_phase[2]),
        fmt_time(r.mean_phase[3]),
        r.decode_tps.mean,
    );
    Ok(0)
}

struct EngineServer<'a>(&'a VlaEngine);

impl StepServer for EngineServer<'_> {
    fn serve(
        &mut self,
        frame: &crate::engine::Frame,
        prompt: &[i32],
    ) -> anyhow::Result<std::time::Duration> {
        Ok(self.0.step(frame, prompt)?.times.total())
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let m = engine.model.manifest.clone();
    let cfg = BatcherConfig {
        streams: args.get_usize("streams", 2)?,
        rate_hz: args.get_f64("rate", 2.0)?,
        duration_s: args.get_f64("duration", 5.0)?,
        policy: match args.get_or("policy", "rr") {
            "fifo" => Policy::Fifo,
            _ => Policy::RoundRobin,
        },
        seed: args.get_usize("seed", 42)? as u64,
    };
    let frames_prompt =
        crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, cfg.seed);
    let prompt = frames_prompt.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut server = EngineServer(&engine);
    let r = run_batcher(&mut server, m.vision.patches, m.vision.patch_dim, &prompt, &cfg)?;
    println!(
        "served {} (arrived {:?}) | throughput {:.2} req/s | max burst {}",
        r.served, r.per_stream_arrived, r.throughput, r.max_burst
    );
    println!(
        "queue delay p50 {} p99 {} | service p50 {} p99 {}",
        fmt_time(r.queue_delay.p50),
        fmt_time(r.queue_delay.p99),
        fmt_time(r.service.p50),
        fmt_time(r.service.p99),
    );
    Ok(0)
}

/// Measure real per-phase times over `steps` control steps.
fn measure_phases(engine: &VlaEngine, steps: u64, seed: u64) -> anyhow::Result<MeasuredPhases> {
    let m = &engine.model.manifest;
    let mut frames = crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, seed);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut prof = PhaseProfiler::new();
    for step in 0..steps {
        let frame = frames.next_frame(0, step);
        let r = engine.step(&frame, &prompt)?;
        prof.record(&r.times);
    }
    println!("{}", prof.table("Measured tiny-VLA phase breakdown (PJRT CPU)").to_markdown());
    Ok(MeasuredPhases {
        vision: prof.summary(crate::model::Phase::Vision).p50,
        prefill: prof.summary(crate::model::Phase::Prefill).p50,
        decode: prof.summary(crate::model::Phase::Decode).p50,
        action: prof.summary(crate::model::Phase::Action).p50,
    })
}

fn cmd_validate(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let steps = args.get_usize("steps", 10)? as u64;
    let measured = measure_phases(&engine, steps, args.get_usize("seed", 42)? as u64)?;
    let v = validate(&engine.model.manifest, &measured);
    println!(
        "calibrated cpu-host: {:.1} GFLOP/s effective, {:.1} GB/s effective",
        v.eff_gflops,
        v.eff_bw / 1e9
    );
    println!("{}", v.table().to_markdown());
    let total_acc = v.total_accuracy();
    let ok = total_acc >= 0.7;
    println!(
        "total-latency accuracy {:.1}% (paper's simulator: 70-90%) => {}",
        total_acc * 100.0,
        if ok { "PASS" } else { "FAIL" }
    );
    Ok(if ok { 0 } else { 1 })
}

/// Resolve the platform for single-platform commands.
fn resolve_platform(args: &Args) -> anyhow::Result<crate::hw::Platform> {
    match args.get("platform-file") {
        Some(path) => crate::hw::config_file::load_platform(std::path::Path::new(path)),
        None => platform::by_name(args.get_or("platform", "orin")),
    }
}

/// Resolve the model config for single-model commands.
fn resolve_model(args: &Args) -> anyhow::Result<crate::model::VlaConfig> {
    match args.get("model-file") {
        Some(path) => crate::hw::config_file::load_vla(std::path::Path::new(path)),
        None => Ok(crate::model::scaling::scaled_vla(args.get_f64("size", 7.0)?)),
    }
}

fn cmd_codesign(args: &Args) -> anyhow::Result<i32> {
    let mut options = sim_options(args)?;
    options.decode_stride = options.decode_stride.max(8);
    let target = resolve_model(args)?;
    let draft = crate::model::scaling::scaled_vla(2.0);
    let plat = resolve_platform(args)?;
    let results = crate::sim::codesign::codesign_study(&plat, &options, &target, &draft);
    println!("{}", crate::sim::codesign::codesign_table(&plat.name, &results).to_markdown());
    // hardware x software matrix: combined technique on every platform
    let mut t = crate::util::table::Table::new(
        "Combined co-design across the Table 1 matrix",
        &["Platform", "baseline actions/s", "combined actions/s", "gain"],
    )
    .left_first();
    for p in platform::table1_platforms() {
        let r = crate::sim::codesign::codesign_study(&p, &options, &target, &draft);
        let base = &r[0];
        let combo = r.last().unwrap();
        t.row(vec![
            p.name.clone(),
            format!("{:.3}", base.amortized_hz),
            format!("{:.3}", combo.amortized_hz),
            format!("{:.2}x", combo.speedup_vs_baseline),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(0)
}

fn cmd_energy(args: &Args) -> anyhow::Result<i32> {
    let mut options = sim_options(args)?;
    options.decode_stride = options.decode_stride.max(8);
    let cfg = resolve_model(args)?;
    let mut t = crate::util::table::Table::new(
        &format!("Energy per control step ({})", cfg.name),
        &["Platform", "dynamic J", "static J", "total J", "avg W", "J/action"],
    )
    .left_first();
    for p in platform::table1_platforms() {
        let (_, e) = crate::sim::energy::simulate_energy(&p, &options, &cfg);
        t.row(vec![
            p.name.clone(),
            format!("{:.2}", e.dynamic_total()),
            format!("{:.2}", e.static_j),
            format!("{:.2}", e.total_j()),
            format!("{:.1}", e.avg_watts()),
            format!("{:.2}", e.j_per_action()),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(0)
}

fn cmd_batch(args: &Args) -> anyhow::Result<i32> {
    let mut options = sim_options(args)?;
    options.decode_stride = options.decode_stride.max(8);
    let cfg = resolve_model(args)?;
    let plat = resolve_platform(args)?;
    let batches: Vec<u64> = args
        .get_f64_list("batches", &[1.0, 2.0, 4.0, 8.0, 16.0])?
        .into_iter()
        .map(|b| b as u64)
        .collect();
    println!(
        "{}",
        crate::sim::codesign::batch_study(&plat, &options, &cfg, &batches).to_markdown()
    );
    Ok(0)
}

fn cmd_trace_export(args: &Args) -> anyhow::Result<i32> {
    let mut options = sim_options(args)?;
    options.decode_stride = options.decode_stride.max(16);
    let cfg = resolve_model(args)?;
    let plat = resolve_platform(args)?;
    let path = std::path::PathBuf::from(args.get_or("trace-out", "trace.json"));
    crate::profile::export_chrome_trace(&plat, &options, &cfg, &path)?;
    println!(
        "wrote Chrome trace for {} on {} to {} (open in chrome://tracing or ui.perfetto.dev)",
        cfg.name,
        plat.name,
        path.display()
    );
    Ok(0)
}

fn cmd_report(args: &Args) -> anyhow::Result<i32> {
    let out = PathBuf::from(args.get_or("out", "reports"));
    std::fs::create_dir_all(&out)?;
    let options = sim_options(args)?;

    platform::table1().save(&out, "table1")?;
    let f2 = fig2::run(&options);
    f2.table().save(&out, "fig2")?;
    let mut opt3 = options.clone();
    opt3.decode_stride = opt3.decode_stride.max(4);
    let f3 = fig3::run(&opt3, &ANCHOR_SIZES_B);
    f3.table(false).save(&out, "fig3")?;
    f3.table(true).save(&out, "fig3_amortized")?;
    crate::report::ablations::prefetch_ablation().save(&out, "ablation_prefetch")?;
    crate::report::ablations::cot_length_ablation(&[32, 64, 128, 256, 512])
        .save(&out, "ablation_cot")?;
    crate::report::ablations::horizon_ablation(&[1, 4, 8, 16, 32]).save(&out, "ablation_horizon")?;
    crate::report::ablations::framework_ablation().save(&out, "ablation_framework")?;

    // energy + co-design + batching studies
    let cfg = molmoact_7b();
    let draft = crate::model::scaling::scaled_vla(2.0);
    let mut energy_t = crate::util::table::Table::new(
        "Energy per control step (MolmoAct-7B)",
        &["Platform", "dynamic J", "static J", "total J", "avg W", "J/action"],
    )
    .left_first();
    for p in platform::table1_platforms() {
        let (_, e) = crate::sim::energy::simulate_energy(&p, &opt3, &cfg);
        energy_t.row(vec![
            p.name.clone(),
            format!("{:.2}", e.dynamic_total()),
            format!("{:.2}", e.static_j),
            format!("{:.2}", e.total_j()),
            format!("{:.1}", e.avg_watts()),
            format!("{:.2}", e.j_per_action()),
        ]);
    }
    energy_t.save(&out, "energy")?;
    let cd = crate::sim::codesign::codesign_study(&platform::orin(), &opt3, &cfg, &draft);
    crate::sim::codesign::codesign_table("Orin", &cd).save(&out, "codesign_orin")?;
    crate::sim::codesign::batch_study(&platform::orin(), &opt3, &cfg, &[1, 2, 4, 8, 16])
        .save(&out, "batch_study")?;

    let mut checks = check_fig2(&f2);
    checks.extend(check_fig3(&f3));
    let (text, ok) = render(&checks);
    std::fs::write(out.join("checks.txt"), &text)?;
    println!("{text}");
    println!("wrote reports to {}", out.display());
    Ok(if ok { 0 } else { 1 })
}
