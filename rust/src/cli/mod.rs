//! Command-line interface of the `vla-char` binary (logic lives here so the
//! integration suite can drive it without spawning processes).
//!
//! Subcommands are NOT implemented here: every simulator- AND engine-backed
//! flow is an [`Experiment`](crate::experiment::Experiment) resolved from
//! the static registry and rendered through a [`ReportSink`] (engine-backed
//! experiments report "skipped: no PJRT runtime" where no real runtime
//! exists). This module only parses argv, dispatches, and keeps
//! `trace-export` plus the registry-looping `report`.

use crate::experiment::{self, DirSink, ExpContext, ReportSink, StdoutSink};
use crate::sim::sweep;
use crate::util::cli::{help_text, Args, OptSpec};
use std::path::PathBuf;

const ABOUT: &str =
    "Characterizing VLA models: the action-generation bottleneck on edge AI architectures \
     (reproduction of CS.PF 2026)";

/// Subcommands that are NOT registry experiments: the trace exporter and
/// the registry loop itself.
const EXTRA_SUBCOMMANDS: &[(&str, &str)] = &[
    ("trace-export", "write a Chrome-trace JSON of a simulated control step"),
    ("report", "run every registered experiment and write markdown+CSV under --out"),
];

/// Help-text subcommand table: the experiment registry first, then the
/// non-registry commands.
fn subcommand_help() -> Vec<(&'static str, &'static str)> {
    let mut v: Vec<(&'static str, &'static str)> =
        experiment::registry().iter().map(|e| (e.name(), e.description())).collect();
    v.extend_from_slice(EXTRA_SUBCOMMANDS);
    v
}

#[rustfmt::skip]
fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "help", value_name: None, help: "show this help", default: None },
        OptSpec { name: "platform", value_name: Some("NAME"), help: "focus platform (orin, thor, orin+pim, thor+hbm4-pim, ...)", default: Some("orin") },
        OptSpec { name: "sizes", value_name: Some("LIST"), help: "model sizes in B params for `project`", default: Some("2,7,14,30,70,100") },
        OptSpec { name: "pim-sizes", value_name: Some("LIST"), help: "model sizes in B params swept by `pim`", default: Some("7,30") },
        OptSpec { name: "spec-grid", value_name: Some("GxA"), help: "speculation lever grid for `pim`: gammas x alphas (e.g. 2,4,8x0.5,0.7,0.9)", default: Some("4x0.7") },
        OptSpec { name: "trace-factors", value_name: Some("LIST"), help: "trace-compression factors in the `pim` lever grid", default: Some("0.5") },
        OptSpec { name: "pim-batches", value_name: Some("LIST"), help: "batched-stream values in the `pim` lever grid (`none` drops the axis)", default: Some("8") },
        OptSpec { name: "pareto", value_name: None, help: "rank `pim` Pareto-front-first (Hz vs J/action) and emit the front table", default: None },
        OptSpec { name: "top", value_name: Some("N"), help: "rows printed from the ranked scenario matrix (`pim`; 0 = all)", default: Some("10") },
        OptSpec { name: "steps", value_name: Some("N"), help: "control-loop / validate steps", default: Some("20") },
        OptSpec { name: "decode-tokens", value_name: Some("N"), help: "override generated tokens per step (real engine)", default: None },
        OptSpec { name: "target-hz", value_name: Some("HZ"), help: "control-loop target frequency", default: Some("10") },
        OptSpec { name: "streams", value_name: Some("N"), help: "serving streams", default: Some("2") },
        OptSpec { name: "rate", value_name: Some("HZ"), help: "per-stream request rate", default: Some("2") },
        OptSpec { name: "policy", value_name: Some("P"), help: "serving policy: fifo | rr", default: Some("rr") },
        OptSpec { name: "duration", value_name: Some("S"), help: "serving arrival-trace duration (virtual s)", default: Some("5") },
        OptSpec { name: "shards", value_name: Some("LIST"), help: "shard engine counts swept by `serve`", default: Some("1,2,4") },
        OptSpec { name: "shard-mode", value_name: Some("M"), help: "shard topologies for `serve`: replicate (rep) | pipeline (pipe) | both", default: Some("both") },
        OptSpec { name: "deadline-ms", value_name: Some("MS"), help: "queueing-delay deadline for `serve` (0 = serve everything)", default: Some("0") },
        OptSpec { name: "pim-shards", value_name: Some("LIST"), help: "shard-serving engine counts in the `pim` lever grid (`none` drops the axis)", default: Some("none") },
        OptSpec { name: "links", value_name: Some("LIST"), help: "network links of the placement axis: 5g | wifi6 | wired (`none` drops the axis; `offload` defaults to all presets)", default: Some("none") },
        OptSpec { name: "offload-modes", value_name: Some("LIST"), help: "placement modes of the offload axis: vp | decode | both | none", default: Some("both") },
        OptSpec { name: "fleet-streams", value_name: Some("N"), help: "robot streams served by `fleet`", default: Some("64") },
        OptSpec { name: "admission", value_name: Some("P"), help: "fleet admission policy: drop | token | slo | all (sweep the grid)", default: Some("all") },
        OptSpec { name: "scheduling", value_name: Some("P"), help: "fleet scheduling policy: earliest | rr | least | edf | all (sweep the grid)", default: Some("all") },
        OptSpec { name: "slo-mults", value_name: Some("LIST"), help: "SLO-class deadline multipliers for `fleet` (stream s -> class s % len)", default: Some("0.5,1,2") },
        OptSpec { name: "token-rate", value_name: Some("HZ"), help: "token-bucket admission refill rate (0 = half the offered load)", default: Some("0") },
        OptSpec { name: "token-burst", value_name: Some("N"), help: "token-bucket admission burst capacity", default: Some("8") },
        OptSpec { name: "slo-depth", value_name: Some("N"), help: "queue-depth limit of the SLO-priority admission policy", default: Some("8") },
        OptSpec { name: "scale-up", value_name: Some("N"), help: "autoscaler scale-up queue-depth threshold", default: Some("8") },
        OptSpec { name: "scale-down", value_name: Some("N"), help: "autoscaler scale-down queue-depth threshold", default: Some("1") },
        OptSpec { name: "warmup-ms", value_name: Some("MS"), help: "autoscaler warm-up latency before a new engine takes work", default: Some("500") },
        OptSpec { name: "max-engines", value_name: Some("N"), help: "autoscaler alive-engine ceiling per shard group", default: Some("8") },
        OptSpec { name: "fail-rate", value_name: Some("HZ"), help: "per-engine fail-stop rate for `fleet` (0 disables failures)", default: Some("0") },
        OptSpec { name: "events", value_name: Some("PATH"), help: "write fleet NDJSON telemetry events to PATH (`-` = stdout)", default: None },
        OptSpec { name: "daemon", value_name: None, help: "stream fleet telemetry as line-buffered NDJSON on stdout (implies --events -)", default: None },
        OptSpec { name: "stride", value_name: Some("N"), help: "decode-position sampling stride (sim)", default: Some("1") },
        OptSpec { name: "no-prefetch", value_name: None, help: "disable cross-operator prefetch (sim)", default: None },
        OptSpec { name: "no-pim", value_name: None, help: "disable PIM offload (sim)", default: None },
        OptSpec { name: "compiled", value_name: None, help: "idealized compiled runtime (no eager overheads)", default: None },
        OptSpec { name: "amortized", value_name: None, help: "also print the horizon-amortized Fig 3 table", default: None },
        OptSpec { name: "trace", value_name: None, help: "print the top-20 operator trace (characterize)", default: None },
        OptSpec { name: "seed", value_name: Some("N"), help: "workload seed", default: Some("42") },
        OptSpec { name: "out", value_name: Some("DIR"), help: "output directory for `report`", default: Some("reports") },
        OptSpec { name: "platform-file", value_name: Some("PATH"), help: "JSON platform file, or a directory of them (swept by `project`)", default: None },
        OptSpec { name: "model-file", value_name: Some("PATH"), help: "JSON VLA model description (overrides --size)", default: None },
        OptSpec { name: "size", value_name: Some("B"), help: "model size in B params (codesign/energy/batch/trace-export)", default: Some("7") },
        OptSpec { name: "batches", value_name: Some("LIST"), help: "batch sizes for `batch`", default: Some("1,2,4,8,16") },
        OptSpec { name: "trace-out", value_name: Some("PATH"), help: "output path for `trace-export`", default: Some("trace.json") },
    ]
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    crate::util::log::init();
    let args = Args::parse("vla-char", argv, &specs())?;
    if args.flag("help") || args.subcommand.is_none() {
        println!("{}", help_text("vla-char", ABOUT, &subcommand_help(), &specs()));
        return Ok(0);
    }
    let sub = args.subcommand.as_deref().unwrap();
    // Registry experiments: build the shared context once, run, render.
    if let Some(exp) = experiment::by_name(sub) {
        let ctx = ExpContext::from_args(&args)?;
        let rep = exp.run(&ctx)?;
        StdoutSink.emit(&rep)?;
        return Ok(rep.exit_code());
    }
    match sub {
        "trace-export" => cmd_trace_export(&args),
        "report" => cmd_report(&args),
        other => {
            eprintln!("unknown subcommand `{other}` (try --help)");
            Ok(2)
        }
    }
}

/// `report` IS the registry: every experiment runs (in parallel on the
/// sweep pool — each cell inside an experiment is itself swept), lands in
/// the directory sink, and the aggregated check block decides the exit
/// code. No per-experiment table code lives here.
fn cmd_report(args: &Args) -> anyhow::Result<i32> {
    let out = PathBuf::from(args.get_or("out", "reports"));
    let mut ctx = ExpContext::from_args(args)?;
    // the report always includes the amortized Fig 3 table, and caps the
    // decode integration cost across the whole registry loop
    ctx.amortized = true;
    ctx.options.decode_stride = ctx.options.decode_stride.max(4);
    // two outer workers only: the heavy experiments already saturate the
    // machine through their inner sweeps, so wider nesting would just
    // oversubscribe; two overlaps the cheap experiments with the big grids
    let results = sweep::parallel_map_with(experiment::registry(), 2, |e| e.run(&ctx));
    let mut sink = DirSink::new(&out)?;
    for result in results {
        sink.emit(&result?)?;
    }
    let (text, ok) = sink.finish()?;
    println!("{text}");
    println!("wrote reports to {}", out.display());
    Ok(if ok { 0 } else { 1 })
}

fn cmd_trace_export(args: &Args) -> anyhow::Result<i32> {
    let ctx = ExpContext::from_args(args)?;
    let mut options = ctx.options.clone();
    options.decode_stride = options.decode_stride.max(16);
    let path = PathBuf::from(args.get_or("trace-out", "trace.json"));
    crate::profile::export_chrome_trace(&ctx.platform, &options, &ctx.model, &path)?;
    println!(
        "wrote Chrome trace for {} on {} to {} (open in chrome://tracing or ui.perfetto.dev)",
        ctx.model.name,
        ctx.platform.name,
        path.display()
    );
    Ok(0)
}

