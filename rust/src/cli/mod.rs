//! Command-line interface of the `vla-char` binary (logic lives here so the
//! integration suite can drive it without spawning processes).
//!
//! Simulator-backed subcommands are NOT implemented here: they are
//! [`Experiment`](crate::experiment::Experiment)s resolved from the static
//! registry and rendered through a [`ReportSink`]. This module only parses
//! argv, dispatches, and keeps the PJRT/engine-backed commands (`step`,
//! `control-loop`, `serve`, `validate`) plus `trace-export` and the
//! registry-looping `report`.

use crate::engine::{
    run_batcher, run_control_loop, BatcherConfig, ControlLoopConfig, Policy, StepServer, VlaEngine,
    VlaModel,
};
use crate::experiment::{self, DirSink, ExpContext, ReportSink, StdoutSink};
use crate::profile::PhaseProfiler;
use crate::runtime::Runtime;
use crate::sim::calibrate::{validate, MeasuredPhases};
use crate::sim::sweep;
use crate::util::cli::{help_text, Args, OptSpec};
use crate::util::units::{fmt_hz, fmt_time};
use std::path::PathBuf;

const ABOUT: &str =
    "Characterizing VLA models: the action-generation bottleneck on edge AI architectures \
     (reproduction of CS.PF 2026)";

/// Subcommands that are NOT registry experiments: the engine/PJRT-backed
/// flows, the trace exporter, and the registry loop itself.
const EXTRA_SUBCOMMANDS: &[(&str, &str)] = &[
    ("step", "run ONE real control step through the PJRT artifacts (golden-checked)"),
    ("control-loop", "run the real tiny-VLA control loop and report achieved Hz"),
    ("serve", "multi-stream serving through the batcher (real engine)"),
    ("validate", "E-C6: calibrate the simulator against real measurements"),
    ("trace-export", "write a Chrome-trace JSON of a simulated control step"),
    ("report", "run every registered experiment and write markdown+CSV under --out"),
];

/// Help-text subcommand table: the experiment registry first, then the
/// non-registry commands.
fn subcommand_help() -> Vec<(&'static str, &'static str)> {
    let mut v: Vec<(&'static str, &'static str)> =
        experiment::registry().iter().map(|e| (e.name(), e.description())).collect();
    v.extend_from_slice(EXTRA_SUBCOMMANDS);
    v
}

#[rustfmt::skip]
fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "help", value_name: None, help: "show this help", default: None },
        OptSpec { name: "platform", value_name: Some("NAME"), help: "focus platform (orin, thor, orin+pim, thor+hbm4, ...)", default: Some("orin") },
        OptSpec { name: "sizes", value_name: Some("LIST"), help: "model sizes in B params for `project`", default: Some("2,7,14,30,70,100") },
        OptSpec { name: "steps", value_name: Some("N"), help: "control-loop steps", default: Some("20") },
        OptSpec { name: "decode-tokens", value_name: Some("N"), help: "override generated tokens per step (real engine)", default: None },
        OptSpec { name: "target-hz", value_name: Some("HZ"), help: "control-loop target frequency", default: Some("10") },
        OptSpec { name: "streams", value_name: Some("N"), help: "serving streams", default: Some("2") },
        OptSpec { name: "rate", value_name: Some("HZ"), help: "per-stream request rate", default: Some("2") },
        OptSpec { name: "policy", value_name: Some("P"), help: "serving policy: fifo | rr", default: Some("rr") },
        OptSpec { name: "duration", value_name: Some("S"), help: "serving arrival-trace duration (virtual s)", default: Some("5") },
        OptSpec { name: "stride", value_name: Some("N"), help: "decode-position sampling stride (sim)", default: Some("1") },
        OptSpec { name: "no-prefetch", value_name: None, help: "disable cross-operator prefetch (sim)", default: None },
        OptSpec { name: "no-pim", value_name: None, help: "disable PIM offload (sim)", default: None },
        OptSpec { name: "compiled", value_name: None, help: "idealized compiled runtime (no eager overheads)", default: None },
        OptSpec { name: "amortized", value_name: None, help: "also print the horizon-amortized Fig 3 table", default: None },
        OptSpec { name: "trace", value_name: None, help: "print the top-20 operator trace (characterize)", default: None },
        OptSpec { name: "seed", value_name: Some("N"), help: "workload seed", default: Some("42") },
        OptSpec { name: "out", value_name: Some("DIR"), help: "output directory for `report`", default: Some("reports") },
        OptSpec { name: "platform-file", value_name: Some("PATH"), help: "JSON platform file, or a directory of them (swept by `project`)", default: None },
        OptSpec { name: "model-file", value_name: Some("PATH"), help: "JSON VLA model description (overrides --size)", default: None },
        OptSpec { name: "size", value_name: Some("B"), help: "model size in B params (codesign/energy/batch/trace-export)", default: Some("7") },
        OptSpec { name: "batches", value_name: Some("LIST"), help: "batch sizes for `batch`", default: Some("1,2,4,8,16") },
        OptSpec { name: "trace-out", value_name: Some("PATH"), help: "output path for `trace-export`", default: Some("trace.json") },
    ]
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    crate::util::log::init();
    let args = Args::parse("vla-char", argv, &specs())?;
    if args.flag("help") || args.subcommand.is_none() {
        println!("{}", help_text("vla-char", ABOUT, &subcommand_help(), &specs()));
        return Ok(0);
    }
    let sub = args.subcommand.as_deref().unwrap();
    // Registry experiments: build the shared context once, run, render.
    if let Some(exp) = experiment::by_name(sub) {
        let ctx = ExpContext::from_args(&args)?;
        let rep = exp.run(&ctx)?;
        StdoutSink.emit(&rep)?;
        return Ok(rep.exit_code());
    }
    match sub {
        "step" => cmd_step(&args),
        "control-loop" => cmd_control_loop(&args),
        "serve" => cmd_serve(&args),
        "validate" => cmd_validate(&args),
        "trace-export" => cmd_trace_export(&args),
        "report" => cmd_report(&args),
        other => {
            eprintln!("unknown subcommand `{other}` (try --help)");
            Ok(2)
        }
    }
}

/// `report` IS the registry: every experiment runs (in parallel on the
/// sweep pool — each cell inside an experiment is itself swept), lands in
/// the directory sink, and the aggregated check block decides the exit
/// code. No per-experiment table code lives here.
fn cmd_report(args: &Args) -> anyhow::Result<i32> {
    let out = PathBuf::from(args.get_or("out", "reports"));
    let mut ctx = ExpContext::from_args(args)?;
    // the report always includes the amortized Fig 3 table, and caps the
    // decode integration cost across the whole registry loop
    ctx.amortized = true;
    ctx.options.decode_stride = ctx.options.decode_stride.max(4);
    // two outer workers only: the heavy experiments already saturate the
    // machine through their inner sweeps, so wider nesting would just
    // oversubscribe; two overlaps the cheap experiments with the big grids
    let results = sweep::parallel_map_with(experiment::registry(), 2, |e| e.run(&ctx));
    let mut sink = DirSink::new(&out)?;
    for result in results {
        sink.emit(&result?)?;
    }
    let (text, ok) = sink.finish()?;
    println!("{text}");
    println!("wrote reports to {}", out.display());
    Ok(if ok { 0 } else { 1 })
}

fn cmd_trace_export(args: &Args) -> anyhow::Result<i32> {
    let ctx = ExpContext::from_args(args)?;
    let mut options = ctx.options.clone();
    options.decode_stride = options.decode_stride.max(16);
    let path = PathBuf::from(args.get_or("trace-out", "trace.json"));
    crate::profile::export_chrome_trace(&ctx.platform, &options, &ctx.model, &path)?;
    println!(
        "wrote Chrome trace for {} on {} to {} (open in chrome://tracing or ui.perfetto.dev)",
        ctx.model.name,
        ctx.platform.name,
        path.display()
    );
    Ok(0)
}

/// Load the real engine (PJRT CPU + artifacts).
fn load_engine(args: &Args) -> anyhow::Result<VlaEngine> {
    let rt = Runtime::cpu()?;
    let model = VlaModel::load(&rt)?;
    Ok(match args.get("decode-tokens") {
        Some(_) => VlaEngine::with_decode_tokens(model, args.get_usize("decode-tokens", 24)?),
        None => VlaEngine::new(model),
    })
}

fn cmd_step(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let m = &engine.model.manifest;
    let seed = args.get_usize("seed", 42)? as u64;
    let mut frames = crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, seed);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let frame = frames.next_frame(0, 0);
    let r = engine.step(&frame, &prompt)?;
    println!("tokens: {:?}...", &r.tokens[..r.tokens.len().min(8)]);
    println!(
        "actions[0]: {:?}",
        &r.actions[..m.action.action_dim.min(r.actions.len())]
    );
    println!(
        "phases: vision {} | prefill {} | decode {} ({} tok, {:.1} tok/s) | action {}",
        fmt_time(r.times.vision.as_secs_f64()),
        fmt_time(r.times.prefill.as_secs_f64()),
        fmt_time(r.times.decode.as_secs_f64()),
        r.tokens.len(),
        r.decode_tps,
        fmt_time(r.times.action.as_secs_f64()),
    );
    println!(
        "total {} | generation share {:.1}%",
        fmt_time(r.times.total().as_secs_f64()),
        r.times.generation_share() * 100.0
    );
    Ok(0)
}

fn cmd_control_loop(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let cfg = ControlLoopConfig {
        target_hz: args.get_f64("target-hz", 10.0)?,
        steps: args.get_usize("steps", 20)? as u64,
        seed: args.get_usize("seed", 42)? as u64,
    };
    let r = run_control_loop(&engine, &cfg)?;
    println!(
        "steps {} | achieved {} (target {}) | amortized {} | misses {}/{}",
        r.steps,
        fmt_hz(r.achieved_hz),
        fmt_hz(r.target_hz),
        fmt_hz(r.amortized_hz),
        r.deadline_misses,
        r.steps
    );
    println!(
        "latency mean {} p99 {} | x{:.1} over budget | generation share {:.1}%",
        fmt_time(r.latency.mean),
        fmt_time(r.latency.p99),
        r.latency_vs_budget(),
        r.generation_share * 100.0
    );
    println!(
        "phases mean: vision {} prefill {} decode {} action {} | decode {:.1} tok/s",
        fmt_time(r.mean_phase[0]),
        fmt_time(r.mean_phase[1]),
        fmt_time(r.mean_phase[2]),
        fmt_time(r.mean_phase[3]),
        r.decode_tps.mean,
    );
    Ok(0)
}

struct EngineServer<'a>(&'a VlaEngine);

impl StepServer for EngineServer<'_> {
    fn serve(
        &mut self,
        frame: &crate::engine::Frame,
        prompt: &[i32],
    ) -> anyhow::Result<std::time::Duration> {
        Ok(self.0.step(frame, prompt)?.times.total())
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let m = engine.model.manifest.clone();
    let cfg = BatcherConfig {
        streams: args.get_usize("streams", 2)?,
        rate_hz: args.get_f64("rate", 2.0)?,
        duration_s: args.get_f64("duration", 5.0)?,
        policy: match args.get_or("policy", "rr") {
            "fifo" => Policy::Fifo,
            _ => Policy::RoundRobin,
        },
        seed: args.get_usize("seed", 42)? as u64,
    };
    let frames_prompt =
        crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, cfg.seed);
    let prompt = frames_prompt.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut server = EngineServer(&engine);
    let r = run_batcher(&mut server, m.vision.patches, m.vision.patch_dim, &prompt, &cfg)?;
    println!(
        "served {} (arrived {:?}) | throughput {:.2} req/s | max burst {}",
        r.served, r.per_stream_arrived, r.throughput, r.max_burst
    );
    println!(
        "queue delay p50 {} p99 {} | service p50 {} p99 {}",
        fmt_time(r.queue_delay.p50),
        fmt_time(r.queue_delay.p99),
        fmt_time(r.service.p50),
        fmt_time(r.service.p99),
    );
    Ok(0)
}

/// Measure real per-phase times over `steps` control steps.
fn measure_phases(engine: &VlaEngine, steps: u64, seed: u64) -> anyhow::Result<MeasuredPhases> {
    let m = &engine.model.manifest;
    let mut frames = crate::engine::FrameSource::new(1, m.vision.patches, m.vision.patch_dim, seed);
    let prompt = frames.prompt(0, m.workload.prompt_tokens, m.decoder.vocab);
    let mut prof = PhaseProfiler::new();
    for step in 0..steps {
        let frame = frames.next_frame(0, step);
        let r = engine.step(&frame, &prompt)?;
        prof.record(&r.times);
    }
    println!("{}", prof.table("Measured tiny-VLA phase breakdown (PJRT CPU)").to_markdown());
    Ok(MeasuredPhases {
        vision: prof.summary(crate::model::Phase::Vision).p50,
        prefill: prof.summary(crate::model::Phase::Prefill).p50,
        decode: prof.summary(crate::model::Phase::Decode).p50,
        action: prof.summary(crate::model::Phase::Action).p50,
    })
}

fn cmd_validate(args: &Args) -> anyhow::Result<i32> {
    let engine = load_engine(args)?;
    let steps = args.get_usize("steps", 10)? as u64;
    let measured = measure_phases(&engine, steps, args.get_usize("seed", 42)? as u64)?;
    let v = validate(&engine.model.manifest, &measured);
    println!(
        "calibrated cpu-host: {:.1} GFLOP/s effective, {:.1} GB/s effective",
        v.eff_gflops,
        v.eff_bw / 1e9
    );
    println!("{}", v.table().to_markdown());
    let total_acc = v.total_accuracy();
    let ok = total_acc >= 0.7;
    println!(
        "total-latency accuracy {:.1}% (paper's simulator: 70-90%) => {}",
        total_acc * 100.0,
        if ok { "PASS" } else { "FAIL" }
    );
    Ok(if ok { 0 } else { 1 })
}
