//! # vla-char
//!
//! Reproduction of "Characterizing VLA Models: Identifying the Action
//! Generation Bottleneck for Edge AI Architectures" (CS.PF 2026).
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: analytical XPU simulator, platform registry,
//!   VLA workload IR, PJRT runtime, VLA engine + control-loop coordinator,
//!   profiling and report generation.
//! - **L2** (`python/compile/model.py`): tiny VLA model in JAX, AOT-lowered
//!   to HLO text artifacts consumed by `runtime`.
//! - **L1** (`python/compile/kernels/`): Pallas decode-attention and fused
//!   FFN kernels (interpret mode), lowered inside the L2 graph.
pub mod analysis;
pub mod cli;
pub mod engine;
pub mod experiment;
pub mod hw;
pub mod runtime;
pub mod sim;
pub mod model;
pub mod profile;
pub mod report;
pub mod telemetry;
pub mod util;
