//! SoC compute model: streaming multiprocessors (SMs), matrix engines, and
//! on-chip memory — the micro-architectural inputs the paper's simulator
//! incorporates (§3.2: "number of SMs, tiling strategies, and asymmetric
//! bandwidth characteristics across different dimensions of the XPU's matrix
//! engine").

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use crate::util::units::{KIB, MIB, TERA};

/// A GPU-like SoC compute description.
#[derive(Debug, Clone, PartialEq)]
pub struct SocSpec {
    pub name: String,
    /// Number of streaming multiprocessors (or equivalent cores).
    pub sms: u32,
    /// SM clock (Hz).
    pub clock: f64,
    /// Peak dense BF16 matrix-engine throughput (FLOP/s), whole chip.
    pub flops_bf16: f64,
    /// Peak FP32 vector (CUDA-core) throughput (FLOP/s), whole chip.
    pub flops_f32: f64,
    /// Shared memory / scratchpad per SM (bytes) — bounds the tile working set.
    pub smem_per_sm: f64,
    /// L2 cache size (bytes).
    pub l2_bytes: f64,
    /// L2 bandwidth (bytes/s).
    pub l2_bw: f64,
    /// Matrix-engine native tile (e.g. 16x16 for tensor cores, 128x128 MXU).
    pub mma_m: u32,
    pub mma_n: u32,
    pub mma_k: u32,
    /// Asymmetric matrix-engine bandwidth: relative cost of streaming the
    /// stationary/moving dimension. >1 means operand layouts along the
    /// reduction dimension achieve lower effective bandwidth (strided /
    /// transposed access penalties).
    pub reduction_bw_penalty: f64,
    /// Fixed per-kernel launch overhead (s).
    pub kernel_launch_overhead: f64,
}

impl SocSpec {
    /// Peak matrix FLOP/s per SM.
    pub fn flops_bf16_per_sm(&self) -> f64 {
        self.flops_bf16 / self.sms as f64
    }

    /// Jetson AGX Orin: Ampere iGPU, 16 SMs. Paper Table 1: 100 BF16 TFLOPS.
    pub fn orin() -> SocSpec {
        SocSpec {
            name: "Orin SoC".into(),
            sms: 16,
            clock: 1.3e9,
            flops_bf16: 100.0 * TERA,
            flops_f32: 5.3 * TERA,
            smem_per_sm: 164.0 * KIB,
            l2_bytes: 4.0 * MIB,
            l2_bw: 1.5e12,
            mma_m: 16,
            mma_n: 16,
            mma_k: 16,
            reduction_bw_penalty: 1.15,
            kernel_launch_overhead: 6e-6,
        }
    }

    /// Jetson Thor: Blackwell iGPU. Paper Table 1: 500 BF16 TFLOPS (≈5x Orin).
    pub fn thor() -> SocSpec {
        SocSpec {
            name: "Thor SoC".into(),
            sms: 64,
            clock: 1.6e9,
            flops_bf16: 500.0 * TERA,
            flops_f32: 30.0 * TERA,
            smem_per_sm: 228.0 * KIB,
            l2_bytes: 32.0 * MIB,
            l2_bw: 8.0e12,
            mma_m: 16,
            mma_n: 16,
            mma_k: 16,
            reduction_bw_penalty: 1.10,
            kernel_launch_overhead: 5e-6,
        }
    }

    /// Cloud-tier accelerator (H100 SXM class) — the remote end of the
    /// edge-to-cloud offload lever family. Datacenter parts dominate the
    /// edge SoCs on every roofline coefficient (compute, clock, SRAM, L2),
    /// so a phase moved to the cloud is never slower *on-device*; the link
    /// is the only thing that can make offload lose.
    pub fn cloud_h100() -> SocSpec {
        SocSpec {
            name: "H100 SXM".into(),
            sms: 132,
            clock: 1.8e9,
            flops_bf16: 989.0 * TERA,
            flops_f32: 67.0 * TERA,
            smem_per_sm: 228.0 * KIB,
            l2_bytes: 50.0 * MIB,
            l2_bw: 1.2e13,
            mma_m: 16,
            mma_n: 16,
            mma_k: 16,
            reduction_bw_penalty: 1.10,
            kernel_launch_overhead: 3e-6,
        }
    }

    /// The host CPU running our PJRT CPU backend — used for simulator
    /// calibration (E-C6): predicted-vs-measured on the same machine.
    /// `flops_*` here are *effective* single-stream XLA-CPU throughputs,
    /// fitted by `sim::calibrate` from microbenchmarks.
    pub fn cpu_host(eff_gflops: f64) -> SocSpec {
        SocSpec {
            name: "cpu-host".into(),
            sms: 1,
            clock: 3.0e9,
            flops_bf16: eff_gflops * 1e9,
            flops_f32: eff_gflops * 1e9,
            smem_per_sm: 32.0 * KIB,
            l2_bytes: 16.0 * MIB,
            l2_bw: 2.0e11,
            mma_m: 8,
            mma_n: 8,
            mma_k: 8,
            reduction_bw_penalty: 1.0,
            kernel_launch_overhead: 3e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thor_is_5x_orin_compute() {
        let ratio = SocSpec::thor().flops_bf16 / SocSpec::orin().flops_bf16;
        assert!((ratio - 5.0).abs() < 1e-9, "paper: Thor provides 5x the compute of Orin");
    }

    #[test]
    fn per_sm_flops() {
        let s = SocSpec::orin();
        assert!((s.flops_bf16_per_sm() * s.sms as f64 - s.flops_bf16).abs() < 1.0);
    }

    #[test]
    fn smem_bounds_sane() {
        assert!(SocSpec::orin().smem_per_sm >= 64.0 * KIB);
        assert!(SocSpec::thor().smem_per_sm > SocSpec::orin().smem_per_sm);
    }
}
