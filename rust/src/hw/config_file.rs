//! JSON config files for custom platforms and VLA models — lets downstream
//! users evaluate hardware points and model shapes beyond Table 1 without
//! recompiling (`vla-char characterize --platform-file my_soc.json`).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::mem::{MemDevice, PimSpec};
use super::platform::Platform;
use super::soc::SocSpec;
use crate::hw::DType;
use crate::model::layer::BlockDims;
use crate::model::vla::{ActionConfig, DecoderConfig, VitConfig, VlaConfig, WorkloadShape};
use crate::util::json::Json;
use crate::util::units::{GB, KIB, MIB, TERA};

/// Parse a platform description. Schema (all bandwidths GB/s, flops TFLOPS):
/// ```json
/// {
///   "name": "MySoC+HBM", "hypothetical": true,
///   "soc": {"sms": 32, "clock_ghz": 1.5, "tflops_bf16": 250,
///           "tflops_f32": 15, "smem_kib": 192, "l2_mib": 8,
///           "l2_bw_gbs": 4000, "reduction_bw_penalty": 1.1,
///           "launch_overhead_us": 5},
///   "mem": {"name": "HBM3", "bw_gbs": 800, "capacity_gb": 48,
///           "stream_efficiency": 0.85,
///           "pim": {"internal_bw_gbs": 4000, "tflops_bf16": 2000,
///                    "dispatch_us": 2, "efficiency": 0.85}}
/// }
/// ```
pub fn platform_from_json(text: &str) -> anyhow::Result<Platform> {
    let j = Json::parse(text)?;
    let s = j.get("soc").ok_or_else(|| anyhow::anyhow!("missing `soc`"))?;
    let m = j.get("mem").ok_or_else(|| anyhow::anyhow!("missing `mem`"))?;
    let soc = SocSpec {
        name: format!("{} SoC", j.req_str("name")?),
        sms: s.req_u64("sms")? as u32,
        clock: s.req_f64("clock_ghz")? * 1e9,
        flops_bf16: s.req_f64("tflops_bf16")? * TERA,
        flops_f32: s.req_f64("tflops_f32")? * TERA,
        smem_per_sm: s.req_f64("smem_kib")? * KIB,
        l2_bytes: s.req_f64("l2_mib")? * MIB,
        l2_bw: s.req_f64("l2_bw_gbs")? * GB,
        mma_m: 16,
        mma_n: 16,
        mma_k: 16,
        reduction_bw_penalty: s.get("reduction_bw_penalty").and_then(|v| v.as_f64()).unwrap_or(1.1),
        kernel_launch_overhead: s.get("launch_overhead_us").and_then(|v| v.as_f64()).unwrap_or(5.0)
            * 1e-6,
    };
    let pim = match m.get("pim") {
        Some(p) if *p != Json::Null => Some(PimSpec {
            internal_bw: p.req_f64("internal_bw_gbs")? * GB,
            flops_bf16: p.req_f64("tflops_bf16")? * TERA,
            dispatch_overhead: p.get("dispatch_us").and_then(|v| v.as_f64()).unwrap_or(2.0) * 1e-6,
            efficiency: p.get("efficiency").and_then(|v| v.as_f64()).unwrap_or(0.85),
        }),
        _ => None,
    };
    let capacity_gb = m.req_f64("capacity_gb")?;
    anyhow::ensure!(
        capacity_gb > 0.0,
        "`mem.capacity_gb` must be positive (the scenario engine's capacity-validity \
         rules need a real memory budget), got {capacity_gb}"
    );
    let mem = MemDevice {
        name: m.req_str("name")?.to_string(),
        peak_bw: m.req_f64("bw_gbs")? * GB,
        capacity: capacity_gb * GB,
        stream_efficiency: m.get("stream_efficiency").and_then(|v| v.as_f64()).unwrap_or(0.8),
        pim,
    };
    Ok(Platform {
        name: j.req_str("name")?.to_string(),
        soc,
        mem,
        hypothetical: j.get("hypothetical").and_then(|v| v.as_bool()).unwrap_or(true),
    })
}

/// Serialize a platform back to the JSON schema above.
pub fn platform_to_json(p: &Platform) -> Json {
    let soc = Json::obj(vec![
        ("sms", Json::Num(p.soc.sms as f64)),
        ("clock_ghz", Json::Num(p.soc.clock / 1e9)),
        ("tflops_bf16", Json::Num(p.soc.flops_bf16 / TERA)),
        ("tflops_f32", Json::Num(p.soc.flops_f32 / TERA)),
        ("smem_kib", Json::Num(p.soc.smem_per_sm / KIB)),
        ("l2_mib", Json::Num(p.soc.l2_bytes / MIB)),
        ("l2_bw_gbs", Json::Num(p.soc.l2_bw / GB)),
        ("reduction_bw_penalty", Json::Num(p.soc.reduction_bw_penalty)),
        ("launch_overhead_us", Json::Num(p.soc.kernel_launch_overhead * 1e6)),
    ]);
    let pim = match &p.mem.pim {
        Some(x) => Json::obj(vec![
            ("internal_bw_gbs", Json::Num(x.internal_bw / GB)),
            ("tflops_bf16", Json::Num(x.flops_bf16 / TERA)),
            ("dispatch_us", Json::Num(x.dispatch_overhead * 1e6)),
            ("efficiency", Json::Num(x.efficiency)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("name", Json::Str(p.name.clone())),
        ("hypothetical", Json::Bool(p.hypothetical)),
        ("soc", soc),
        (
            "mem",
            Json::obj(vec![
                ("name", Json::Str(p.mem.name.clone())),
                ("bw_gbs", Json::Num(p.mem.peak_bw / GB)),
                ("capacity_gb", Json::Num(p.mem.capacity / GB)),
                ("stream_efficiency", Json::Num(p.mem.stream_efficiency)),
                ("pim", pim),
            ]),
        ),
    ])
}

fn block_dims(j: &Json) -> anyhow::Result<BlockDims> {
    Ok(BlockDims {
        hidden: j.req_u64("hidden")?,
        heads: j.req_u64("heads")?,
        kv_heads: j.get("kv_heads").and_then(|v| v.as_u64()).unwrap_or(j.req_u64("heads")?),
        head_dim: j.req_u64("head_dim")?,
        ffn: j.req_u64("ffn")?,
        dtype: match j.get("dtype").and_then(|v| v.as_str()).unwrap_or("bf16") {
            "f32" => DType::F32,
            "f16" => DType::F16,
            "i8" => DType::I8,
            _ => DType::BF16,
        },
    })
}

/// Parse a VLA model + workload description.
pub fn vla_from_json(text: &str) -> anyhow::Result<VlaConfig> {
    let j = Json::parse(text)?;
    let mut towers = Vec::new();
    for t in j
        .get("towers")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing `towers` array"))?
    {
        towers.push(VitConfig {
            name: t.req_str("name")?.to_string(),
            layers: t.req_u64("layers")?,
            dims: block_dims(t)?,
        });
    }
    let d = j.get("decoder").ok_or_else(|| anyhow::anyhow!("missing `decoder`"))?;
    let a = j.get("action").ok_or_else(|| anyhow::anyhow!("missing `action`"))?;
    let w = j.get("workload").ok_or_else(|| anyhow::anyhow!("missing `workload`"))?;
    Ok(VlaConfig {
        name: j.req_str("name")?.to_string(),
        towers,
        projector_hidden: j.get("projector_hidden").and_then(|v| v.as_u64()).unwrap_or(4096),
        decoder: DecoderConfig {
            layers: d.req_u64("layers")?,
            dims: block_dims(d)?,
            vocab: d.req_u64("vocab")?,
            weight_scale: d.get("weight_scale").and_then(|v| v.as_f64()).unwrap_or(1.0),
        },
        action: ActionConfig {
            layers: a.req_u64("layers")?,
            dims: block_dims(a)?,
            horizon: a.req_u64("horizon")?,
            diffusion_steps: a.req_u64("diffusion_steps")?,
            action_dim: a.req_u64("action_dim")?,
        },
        shape: WorkloadShape {
            crops: w.get("crops").and_then(|v| v.as_u64()).unwrap_or(1),
            patches_per_crop: w.req_u64("patches_per_crop")?,
            image_tokens: w.req_u64("image_tokens")?,
            prompt_tokens: w.req_u64("prompt_tokens")?,
            decode_tokens: w.req_u64("decode_tokens")?,
        },
    })
}

/// Load a platform from a JSON file.
pub fn load_platform(path: &std::path::Path) -> anyhow::Result<Platform> {
    platform_from_json(&std::fs::read_to_string(path)?)
}

/// Load one platform JSON, or — when `path` is a directory — every `*.json`
/// inside it, sorted by file name so sweep order (and therefore report
/// output) is deterministic. `project` sweeps the whole set.
pub fn load_platforms(path: &std::path::Path) -> anyhow::Result<Vec<Platform>> {
    if !path.is_dir() {
        return Ok(vec![load_platform(path)?]);
    }
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(path)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    files.sort();
    anyhow::ensure!(!files.is_empty(), "no *.json platform files in `{}`", path.display());
    let mut platforms = Vec::with_capacity(files.len());
    for f in &files {
        let p = load_platform(f)
            .map_err(|e| anyhow::anyhow!("bad platform file `{}`: {e}", f.display()))?;
        platforms.push(p);
    }
    Ok(platforms)
}

/// Load a VLA config from a JSON file.
pub fn load_vla(path: &std::path::Path) -> anyhow::Result<VlaConfig> {
    vla_from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform;

    #[test]
    fn table1_platforms_roundtrip() {
        for p in platform::table1_platforms() {
            let text = platform_to_json(&p).to_string_pretty();
            let back = platform_from_json(&text).unwrap();
            assert_eq!(back.name, p.name);
            assert!((back.mem.peak_bw - p.mem.peak_bw).abs() < 1e6);
            assert!((back.soc.flops_bf16 - p.soc.flops_bf16).abs() < 1e9);
            assert_eq!(back.mem.pim.is_some(), p.mem.pim.is_some());
        }
    }

    #[test]
    fn custom_platform_parses() {
        let text = r#"{
          "name": "EdgeX", "hypothetical": true,
          "soc": {"sms": 32, "clock_ghz": 1.5, "tflops_bf16": 250,
                  "tflops_f32": 15, "smem_kib": 192, "l2_mib": 8,
                  "l2_bw_gbs": 4000},
          "mem": {"name": "HBM3", "bw_gbs": 800, "capacity_gb": 48}
        }"#;
        let p = platform_from_json(text).unwrap();
        assert_eq!(p.name, "EdgeX");
        assert_eq!(p.soc.sms, 32);
        assert!(p.mem.pim.is_none());
        // defaults applied
        assert!((p.mem.stream_efficiency - 0.8).abs() < 1e-9);
    }

    #[test]
    fn platform_directory_loads_sorted() {
        let dir = std::env::temp_dir().join("vla_char_platform_dir_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (file, name) in [("b.json", "Beta"), ("a.json", "Alpha")] {
            let mut p = platform::orin();
            p.name = name.to_string();
            std::fs::write(dir.join(file), platform_to_json(&p).to_string_pretty()).unwrap();
        }
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let loaded = load_platforms(&dir).unwrap();
        let names: Vec<&str> = loaded.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["Alpha", "Beta"], "sorted by file name, non-JSON ignored");
        // a single file still loads as a one-element set
        let one = load_platforms(&dir.join("a.json")).unwrap();
        assert_eq!(one.len(), 1);
        // an empty directory is an error, not an empty sweep
        let empty = dir.join("empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(load_platforms(&empty).is_err());
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(platform_from_json("{}").is_err());
        assert!(platform_from_json(r#"{"name": "x", "soc": {}, "mem": {}}"#).is_err());
    }

    #[test]
    fn non_positive_capacity_rejected() {
        let text = |gb: f64| {
            format!(
                r#"{{"name": "EdgeX",
                    "soc": {{"sms": 32, "clock_ghz": 1.5, "tflops_bf16": 250,
                            "tflops_f32": 15, "smem_kib": 192, "l2_mib": 8,
                            "l2_bw_gbs": 4000}},
                    "mem": {{"name": "HBM3", "bw_gbs": 800, "capacity_gb": {gb}}}}}"#
            )
        };
        assert!(platform_from_json(&text(0.0)).is_err());
        assert!(platform_from_json(&text(-4.0)).is_err());
        assert!(platform_from_json(&text(48.0)).is_ok());
    }

    #[test]
    fn vla_config_parses_and_simulates() {
        let text = r#"{
          "name": "custom-3B",
          "towers": [{"name": "vit", "layers": 12, "hidden": 768,
                      "heads": 12, "head_dim": 64, "ffn": 3072}],
          "projector_hidden": 2048,
          "decoder": {"layers": 26, "hidden": 2560, "heads": 20,
                      "kv_heads": 4, "head_dim": 128, "ffn": 6912,
                      "vocab": 152064},
          "action": {"layers": 4, "hidden": 768, "heads": 12,
                     "head_dim": 64, "ffn": 3072, "horizon": 8,
                     "diffusion_steps": 10, "action_dim": 7},
          "workload": {"crops": 13, "patches_per_crop": 576,
                       "image_tokens": 1872, "prompt_tokens": 64,
                       "decode_tokens": 160}
        }"#;
        let cfg = vla_from_json(text).unwrap();
        assert_eq!(cfg.name, "custom-3B");
        assert!(cfg.params() > 2e9 && cfg.params() < 5e9);
        let sim = crate::sim::Simulator::with_options(
            platform::orin(),
            crate::sim::SimOptions {
                decode_stride: 16,
                ..Default::default()
            },
        );
        let r = sim.simulate_vla(&cfg);
        assert!(r.total() > 0.0);
        assert!(r.decode.memory_bound());
    }
}
