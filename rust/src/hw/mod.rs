//! Hardware models: datatypes, memory devices (incl. PIM), SoC compute, and
//! the Table 1 platform registry.

pub mod config_file;
pub mod dtype;
pub mod mem;
pub mod platform;
pub mod soc;

pub use dtype::DType;
pub use mem::{MemDevice, PimSpec};
pub use platform::Platform;
pub use soc::SocSpec;
