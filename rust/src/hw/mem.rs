//! Memory-system models: DRAM device families (LPDDR5/5X, GDDR7, LPDDR6X)
//! and an optional processing-in-memory (PIM) capability modeled on
//! bank-level compute in commercial DRAM (Lee et al., ISCA'21 — the paper's
//! reference [3]).

use crate::util::units::GB;

/// Processing-in-memory capability attached to a memory device.
///
/// PIM exposes the aggregate *internal* (bank-level) bandwidth to a set of
/// simple compute units placed in the DRAM dies. It accelerates memory-bound,
/// streaming operators (GEMV, elementwise, attention-decode) by avoiding the
/// off-chip link; it does not help compute-bound GEMMs.
#[derive(Debug, Clone, PartialEq)]
pub struct PimSpec {
    /// Aggregate internal bandwidth visible to PIM units (bytes/s).
    pub internal_bw: f64,
    /// Peak BF16 throughput of the PIM units (FLOP/s).
    pub flops_bf16: f64,
    /// Fixed per-operator dispatch/launch overhead (s): mode switch, command
    /// issue, result collection. Dominates for tiny ops.
    pub dispatch_overhead: f64,
    /// Fraction of internal bandwidth achievable in practice (row-activation
    /// conflicts, refresh).
    pub efficiency: f64,
}

impl PimSpec {
    /// Effective streaming bandwidth for a PIM-executed operator.
    pub fn effective_bw(&self) -> f64 {
        self.internal_bw * self.efficiency
    }
}

/// A memory device (the off-chip DRAM of an edge SoC).
#[derive(Debug, Clone, PartialEq)]
pub struct MemDevice {
    pub name: String,
    /// Peak off-chip bandwidth (bytes/s).
    pub peak_bw: f64,
    /// Capacity in bytes.
    pub capacity: f64,
    /// Fraction of peak achievable for large streaming reads (command/refresh
    /// overheads, bank conflicts). Typical LPDDR: 0.7–0.85.
    pub stream_efficiency: f64,
    /// Optional PIM capability.
    pub pim: Option<PimSpec>,
}

impl MemDevice {
    /// Effective streaming bandwidth from the SoC (bytes/s).
    pub fn effective_bw(&self) -> f64 {
        self.peak_bw * self.stream_efficiency
    }

    /// Capacity in GB — the `capacity_gb` knob of the platform-JSON schema
    /// and the budget the scenario engine's capacity-validity rule checks
    /// lowered model + KV footprints against. Every registry platform
    /// populates it through its constructor (`lpddr5(64.0)` is 64 GB).
    pub fn capacity_gb(&self) -> f64 {
        self.capacity / GB
    }

    pub fn lpddr5(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "LPDDR5".into(),
            peak_bw: 203.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.80,
            pim: None,
        }
    }

    pub fn lpddr5x(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "LPDDR5X".into(),
            peak_bw: 273.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.80,
            pim: None,
        }
    }

    pub fn gddr7(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "GDDR7".into(),
            peak_bw: 1000.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.78,
            pim: None,
        }
    }

    /// HBM3: one 12-high stack on a 1024-bit interface at 6.4 Gbps —
    /// 819 GB/s. The paper names high-bandwidth memory as a scaling pathway;
    /// the capacity-cost note is that a single stack tops out around 24 GB
    /// and costs (die stacking + interposer) several times LPDDR per GB,
    /// which is why Table 1's commercial edge parts stop at LPDDR5X.
    pub fn hbm3(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "HBM3".into(),
            peak_bw: 819.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.85,
            pim: None,
        }
    }

    /// HBM3E: the stacked memory of H100/H200-class cloud accelerators —
    /// five (H100 SXM) to six stacks aggregating ~3.35 TB/s. Exists here as
    /// the memory system of the *remote* tier in edge-to-cloud offload
    /// scenarios; it is deliberately not part of any edge platform registry.
    pub fn hbm3e(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "HBM3E".into(),
            peak_bw: 3350.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.85,
            pim: None,
        }
    }

    /// HBM4: the JEDEC 2048-bit interface at 6.4 Gbps — 1638 GB/s per
    /// stack. Capacity-cost note: 16-high stacks reach ~36-48 GB, but the
    /// wider base die and hybrid bonding push cost and thermals further
    /// from an edge power envelope; modeled here as a hypothetical ceiling
    /// for non-PIM memory scaling.
    pub fn hbm4(capacity_gb: f64) -> MemDevice {
        MemDevice {
            name: "HBM4".into(),
            peak_bw: 1638.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.85,
            pim: None,
        }
    }

    /// LPDDR6X with PIM. Table 1 reports 2180 GB/s — that is the aggregate
    /// *internal* (bank-level) bandwidth visible to the PIM units; the
    /// off-chip link to the SoC runs at LPDDR6X speed (~546 GB/s). PIM
    /// TFLOPS is the *additional* compute placed in-memory (platform total
    /// = SoC + PIM).
    pub fn lpddr6x_pim(capacity_gb: f64, pim_tflops: f64) -> MemDevice {
        MemDevice {
            name: "LPDDR6X PIM".into(),
            peak_bw: 546.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.80,
            pim: Some(PimSpec {
                internal_bw: 2180.0 * GB,
                flops_bf16: pim_tflops * 1e12,
                dispatch_overhead: 2e-6,
                efficiency: 0.85,
            }),
        }
    }

    /// HBM4 with bank-level PIM (Samsung HBM-PIM / Aquabolt-XL lineage
    /// scaled to the HBM4 interface). The 2048-bit stack interface moves
    /// 1638 GB/s to the SoC; the per-bank compute units see ~4x that
    /// internally — the same internal:external ratio LPDDR6X-PIM exhibits.
    /// This is the ceiling of the memory-scaling pathway: stacked bandwidth
    /// AND in-memory execution.
    pub fn hbm4_pim(capacity_gb: f64, pim_tflops: f64) -> MemDevice {
        MemDevice {
            name: "HBM4 PIM".into(),
            peak_bw: 1638.0 * GB,
            capacity: capacity_gb * GB,
            stream_efficiency: 0.85,
            pim: Some(PimSpec {
                internal_bw: 6553.0 * GB,
                flops_bf16: pim_tflops * 1e12,
                dispatch_overhead: 2e-6,
                efficiency: 0.85,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_presets_match_table1() {
        assert_eq!(MemDevice::lpddr5(64.0).peak_bw, 203.0 * GB);
        assert_eq!(MemDevice::lpddr5x(128.0).peak_bw, 273.0 * GB);
        assert_eq!(MemDevice::gddr7(64.0).peak_bw, 1000.0 * GB);
        let pim = MemDevice::lpddr6x_pim(64.0, 974.0);
        assert_eq!(pim.pim.as_ref().unwrap().internal_bw, 2180.0 * GB);
        assert!(pim.peak_bw < pim.pim.as_ref().unwrap().internal_bw);
    }

    #[test]
    fn capacity_round_trips_through_gb() {
        assert_eq!(MemDevice::lpddr5(64.0).capacity_gb(), 64.0);
        assert_eq!(MemDevice::hbm3(24.0).capacity_gb(), 24.0);
        assert_eq!(MemDevice::hbm4_pim(36.0, 4000.0).capacity_gb(), 36.0);
    }

    #[test]
    fn effective_below_peak() {
        let m = MemDevice::lpddr5(64.0);
        assert!(m.effective_bw() < m.peak_bw);
        assert!(m.effective_bw() > 0.5 * m.peak_bw);
    }

    #[test]
    fn hbm_devices_rank_by_generation() {
        let h3 = MemDevice::hbm3(24.0);
        let h4 = MemDevice::hbm4(36.0);
        assert_eq!(h3.peak_bw, 819.0 * GB);
        assert_eq!(h4.peak_bw, 1638.0 * GB);
        assert!(h4.effective_bw() > h3.effective_bw());
        // HBM3 sits between GDDR7's headline 1000 GB/s and LPDDR5X
        assert!(h3.peak_bw > MemDevice::lpddr5x(64.0).peak_bw);
        assert!(h3.peak_bw < MemDevice::gddr7(64.0).peak_bw);
        assert!(h3.pim.is_none() && h4.pim.is_none());
    }

    #[test]
    fn pim_effective_bw() {
        let m = MemDevice::lpddr6x_pim(64.0, 974.0);
        let p = m.pim.as_ref().unwrap();
        assert!(p.effective_bw() > m.effective_bw(), "PIM internal BW should exceed off-chip");
    }

    #[test]
    fn hbm4_pim_tops_the_bandwidth_ladder() {
        let h = MemDevice::hbm4_pim(36.0, 4000.0);
        let p = h.pim.as_ref().unwrap();
        // stack interface matches plain HBM4; internal BW ~4x, like LPDDR6X-PIM
        assert_eq!(h.peak_bw, MemDevice::hbm4(36.0).peak_bw);
        assert!((p.internal_bw / h.peak_bw - 4.0).abs() < 0.01);
        let l = MemDevice::lpddr6x_pim(64.0, 974.0);
        assert!(p.internal_bw > l.pim.as_ref().unwrap().internal_bw);
    }
}
