//! Platform registry: the commercial and hypothetical edge systems of the
//! paper's Table 1, plus the calibration `cpu-host` target.

use super::mem::MemDevice;
use super::soc::SocSpec;
use crate::util::table::Table;
use crate::util::units::{GB, TERA};

/// A complete edge platform: SoC + memory system.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: String,
    pub soc: SocSpec,
    pub mem: MemDevice,
    /// Whether this is a commercial part (upper half of Table 1) or a
    /// hypothetical variant (lower half).
    pub hypothetical: bool,
}

impl Platform {
    /// Total platform BF16 TFLOPS as reported in Table 1 (SoC + PIM).
    pub fn total_flops_bf16(&self) -> f64 {
        self.soc.flops_bf16
            + self
                .mem
                .pim
                .as_ref()
                .map(|p| p.flops_bf16)
                .unwrap_or(0.0)
    }

    /// The "BW (GB/s)" column of Table 1: off-chip bandwidth, or the
    /// aggregate PIM-internal bandwidth on PIM systems (the paper reports
    /// the bandwidth the workload can actually exploit).
    pub fn headline_bw(&self) -> f64 {
        self.mem
            .pim
            .as_ref()
            .map(|p| p.internal_bw)
            .unwrap_or(self.mem.peak_bw)
    }
}

/// Jetson AGX Orin 64 GB (commercial).
pub fn orin() -> Platform {
    Platform {
        name: "Orin".into(),
        soc: SocSpec::orin(),
        mem: MemDevice::lpddr5(64.0),
        hypothetical: false,
    }
}

/// Jetson Thor 128 GB (commercial).
pub fn thor() -> Platform {
    Platform {
        name: "Thor".into(),
        soc: SocSpec::thor(),
        mem: MemDevice::lpddr5x(128.0),
        hypothetical: false,
    }
}

/// Orin SoC re-equipped with LPDDR5X (hypothetical).
pub fn orin_lpddr5x() -> Platform {
    Platform {
        name: "Orin+LPDDR5X".into(),
        soc: SocSpec::orin(),
        mem: MemDevice::lpddr5x(64.0),
        hypothetical: true,
    }
}

/// Orin SoC with GDDR7 (hypothetical).
pub fn orin_gddr7() -> Platform {
    Platform {
        name: "Orin+GDDR7".into(),
        soc: SocSpec::orin(),
        mem: MemDevice::gddr7(64.0),
        hypothetical: true,
    }
}

/// Orin SoC with LPDDR6X-PIM (hypothetical). Table 1 lists 1074 total BF16
/// TFLOPS = 100 (SoC) + 974 (PIM).
pub fn orin_pim() -> Platform {
    Platform {
        name: "Orin+PIM".into(),
        soc: SocSpec::orin(),
        mem: MemDevice::lpddr6x_pim(64.0, 974.0),
        hypothetical: true,
    }
}

/// Thor SoC with GDDR7 (hypothetical).
pub fn thor_gddr7() -> Platform {
    Platform {
        name: "Thor+GDDR7".into(),
        soc: SocSpec::thor(),
        mem: MemDevice::gddr7(128.0),
        hypothetical: true,
    }
}

/// Thor SoC with LPDDR6X-PIM (hypothetical). Table 1: 3993 total = 500 + 3493.
pub fn thor_pim() -> Platform {
    Platform {
        name: "Thor+PIM".into(),
        soc: SocSpec::thor(),
        mem: MemDevice::lpddr6x_pim(128.0, 3493.0),
        hypothetical: true,
    }
}

/// Orin SoC with one stack of HBM3 (hypothetical). A high-bandwidth-memory
/// pathway point below GDDR7's headline bandwidth but with better stream
/// efficiency; capacity-constrained (one stack ≈ 24 GB — a bf16 30B model
/// no longer fits uncompressed).
pub fn orin_hbm3() -> Platform {
    Platform {
        name: "Orin+HBM3".into(),
        soc: SocSpec::orin(),
        mem: MemDevice::hbm3(24.0),
        hypothetical: true,
    }
}

/// Thor SoC with one stack of HBM4 (hypothetical) — the ceiling of the
/// non-PIM memory-scaling pathway the paper names.
pub fn thor_hbm4() -> Platform {
    Platform {
        name: "Thor+HBM4".into(),
        soc: SocSpec::thor(),
        mem: MemDevice::hbm4(36.0),
        hypothetical: true,
    }
}

/// Thor SoC with an HBM4-PIM stack (hypothetical): the combined ceiling —
/// stacked off-chip bandwidth AND in-memory execution. Third PIM-capable
/// point of the sweep set, so PIM co-design scenarios are evaluated across
/// a bandwidth range rather than at a single device class.
pub fn thor_hbm4_pim() -> Platform {
    Platform {
        name: "Thor+HBM4-PIM".into(),
        soc: SocSpec::thor(),
        mem: MemDevice::hbm4_pim(36.0, 4000.0),
        hypothetical: true,
    }
}

/// Cloud tier for edge-to-cloud offload scenarios: an H100 SXM-class
/// accelerator with HBM3E. This is the remote end of the
/// `Lever::Offload` placement family — the Evaluator costs offloaded
/// phases on these roofline coefficients and charges the network link
/// separately. Deliberately NOT part of `table1_platforms`,
/// `sweep_platforms`, or `by_name`: it is not an edge deployment target,
/// and keeping it out preserves every pinned platform count.
pub fn cloud_h100() -> Platform {
    Platform {
        name: "Cloud+H100".into(),
        soc: SocSpec::cloud_h100(),
        mem: MemDevice::hbm3e(80.0),
        hypothetical: false,
    }
}

/// Calibration target: this machine's CPU running XLA-CPU via PJRT.
/// Effective GFLOPS/BW are fitted by `sim::calibrate`; the defaults here are
/// conservative placeholders used before calibration.
pub fn cpu_host() -> Platform {
    cpu_host_with(12.0, 12.0 * GB)
}

/// Calibrated cpu-host with explicit effective compute (GFLOP/s) and
/// bandwidth (bytes/s).
pub fn cpu_host_with(eff_gflops: f64, eff_bw: f64) -> Platform {
    Platform {
        name: "cpu-host".into(),
        soc: SocSpec::cpu_host(eff_gflops),
        mem: MemDevice {
            name: "DDR".into(),
            peak_bw: eff_bw,
            capacity: 32.0 * GB,
            stream_efficiency: 1.0, // eff_bw is already effective
            pim: None,
        },
        hypothetical: false,
    }
}

/// All seven platforms of Table 1, in paper order.
pub fn table1_platforms() -> Vec<Platform> {
    vec![
        orin(),
        thor(),
        orin_lpddr5x(),
        orin_gddr7(),
        orin_pim(),
        thor_gddr7(),
        thor_pim(),
    ]
}

/// The default sweep set: Table 1 plus the HBM pathway variants (HBM3/HBM4
/// and the HBM4-PIM combined ceiling). This is what `project`, `codesign`,
/// `energy`, and `pim` iterate; `table1()` itself stays exactly the paper's
/// seven rows.
pub fn sweep_platforms() -> Vec<Platform> {
    let mut v = table1_platforms();
    v.push(orin_hbm3());
    v.push(thor_hbm4());
    v.push(thor_hbm4_pim());
    v
}

/// The PIM-capable subset of the sweep set (what the `pim` scenario matrix
/// exercises its PIM levers on).
pub fn pim_platforms() -> Vec<Platform> {
    sweep_platforms().into_iter().filter(|p| p.mem.pim.is_some()).collect()
}

/// Look up a platform by (case-insensitive) name.
pub fn by_name(name: &str) -> anyhow::Result<Platform> {
    let canon = |s: &str| s.to_ascii_lowercase().replace(['_', ' ', '+'], "-");
    let want = canon(name);
    for p in sweep_platforms().into_iter().chain([cpu_host()]) {
        if canon(&p.name) == want {
            return Ok(p);
        }
    }
    anyhow::bail!(
        "unknown platform `{name}` (known: orin, thor, orin+lpddr5x, orin+gddr7, orin+pim, thor+gddr7, thor+pim, orin+hbm3, thor+hbm4, thor+hbm4-pim, cpu-host)"
    )
}

/// Emit Table 1 exactly in the paper's layout.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: Commercial edge platforms and hypothetical hardware systems",
        &["System", "Memory", "BW (GB/s)", "BF16 TFLOPS"],
    )
    .left_first();
    for p in table1_platforms() {
        t.row(vec![
            p.name.clone(),
            p.mem.name.clone(),
            format!("{:.0}", p.headline_bw() / GB),
            format!("{:.0}", p.total_flops_bf16() / TERA),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_values() {
        // (name, memory, bw GB/s, total TFLOPS) exactly as printed in Table 1
        let expect = [
            ("Orin", "LPDDR5", 203.0, 100.0),
            ("Thor", "LPDDR5X", 273.0, 500.0),
            ("Orin+LPDDR5X", "LPDDR5X", 273.0, 100.0),
            ("Orin+GDDR7", "GDDR7", 1000.0, 100.0),
            ("Orin+PIM", "LPDDR6X PIM", 2180.0, 1074.0),
            ("Thor+GDDR7", "GDDR7", 1000.0, 500.0),
            ("Thor+PIM", "LPDDR6X PIM", 2180.0, 3993.0),
        ];
        let plats = table1_platforms();
        assert_eq!(plats.len(), expect.len());
        for (p, (name, mem, bw, tflops)) in plats.iter().zip(expect.iter()) {
            assert_eq!(&p.name, name);
            assert_eq!(&p.mem.name, mem);
            assert!((p.headline_bw() / GB - bw).abs() < 0.5, "{name} bw");
            assert!((p.total_flops_bf16() / TERA - tflops).abs() < 0.5, "{name} tflops");
        }
    }

    #[test]
    fn commercial_vs_hypothetical_split() {
        let plats = table1_platforms();
        assert!(!plats[0].hypothetical && !plats[1].hypothetical);
        assert!(plats[2..].iter().all(|p| p.hypothetical));
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("orin").unwrap().name, "Orin");
        assert_eq!(by_name("Thor+PIM").unwrap().name, "Thor+PIM");
        assert_eq!(by_name("thor-gddr7").unwrap().name, "Thor+GDDR7");
        assert_eq!(by_name("orin_hbm3").unwrap().name, "Orin+HBM3");
        assert_eq!(by_name("thor+hbm4").unwrap().name, "Thor+HBM4");
        assert_eq!(by_name("thor+hbm4-pim").unwrap().name, "Thor+HBM4-PIM");
        assert_eq!(by_name("cpu-host").unwrap().name, "cpu-host");
        assert!(by_name("h100").is_err());
    }

    #[test]
    fn sweep_set_extends_table1() {
        let sweep = sweep_platforms();
        assert_eq!(sweep.len(), table1_platforms().len() + 3);
        assert!(sweep.iter().any(|p| p.name == "Orin+HBM3"));
        assert!(sweep.iter().any(|p| p.name == "Thor+HBM4"));
        assert!(sweep.iter().any(|p| p.name == "Thor+HBM4-PIM"));
        // plain HBM variants are hypothetical and PIM-free; the HBM4-PIM
        // ceiling is hypothetical WITH bank-level compute
        for p in sweep.iter().filter(|p| p.name.contains("HBM")) {
            assert!(p.hypothetical);
            assert_eq!(p.mem.pim.is_some(), p.name.contains("HBM4-PIM"));
        }
        // table1() itself must stay exactly the paper's seven rows
        assert_eq!(table1().n_rows(), 7);
    }

    #[test]
    fn every_sweep_platform_has_a_populated_capacity() {
        // the scenario engine's capacity-validity rule needs a real budget
        // on all 10 platforms: the commercial parts at their shipped
        // capacities, HBM stacks at the single-stack ceiling
        let expect = [
            ("Orin", 64.0),
            ("Thor", 128.0),
            ("Orin+LPDDR5X", 64.0),
            ("Orin+GDDR7", 64.0),
            ("Orin+PIM", 64.0),
            ("Thor+GDDR7", 128.0),
            ("Thor+PIM", 128.0),
            ("Orin+HBM3", 24.0),
            ("Thor+HBM4", 36.0),
            ("Thor+HBM4-PIM", 36.0),
        ];
        let sweep = sweep_platforms();
        assert_eq!(sweep.len(), expect.len());
        for (p, (name, gb)) in sweep.iter().zip(expect.iter()) {
            assert_eq!(&p.name, name);
            assert!((p.mem.capacity_gb() - gb).abs() < 1e-9, "{name}: {}", p.mem.capacity_gb());
        }
    }

    #[test]
    fn pim_subset_has_three_capable_platforms() {
        let pims = pim_platforms();
        assert!(pims.len() >= 3, "the scenario matrix needs >= 3 PIM-capable platforms");
        assert!(pims.iter().all(|p| p.mem.pim.is_some()));
        for name in ["Orin+PIM", "Thor+PIM", "Thor+HBM4-PIM"] {
            assert!(pims.iter().any(|p| p.name == name), "missing {name}");
        }
    }

    #[test]
    fn table1_renders_seven_rows() {
        let t = table1();
        assert_eq!(t.n_rows(), 7);
        let md = t.to_markdown();
        assert!(md.contains("2180"));
        assert!(md.contains("3993"));
    }

    #[test]
    fn cloud_tier_dominates_every_edge_soc_and_stays_out_of_the_registry() {
        let cloud = cloud_h100();
        assert_eq!(cloud.name, "Cloud+H100");
        assert_eq!(cloud.mem.name, "HBM3E");
        assert!((cloud.mem.capacity_gb() - 80.0).abs() < 1e-9);
        assert!(cloud.mem.pim.is_none());
        // the offload lever relies on the remote tier being strictly faster
        // per-phase: every roofline coefficient must dominate the edge SoCs
        for edge in sweep_platforms() {
            assert!(cloud.soc.flops_bf16 > edge.soc.flops_bf16, "{}", edge.name);
            assert!(cloud.soc.flops_f32 > edge.soc.flops_f32, "{}", edge.name);
            assert!(cloud.soc.l2_bw > edge.soc.l2_bw, "{}", edge.name);
            assert!(cloud.soc.smem_per_sm >= edge.soc.smem_per_sm, "{}", edge.name);
            assert!(
                cloud.soc.kernel_launch_overhead <= edge.soc.kernel_launch_overhead,
                "{}",
                edge.name
            );
            assert!(cloud.mem.effective_bw() > edge.mem.effective_bw(), "{}", edge.name);
        }
        // the cloud tier is not an edge deployment target: it must not leak
        // into the pinned platform sets or name lookup
        assert!(sweep_platforms().iter().all(|p| p.name != "Cloud+H100"));
        assert!(table1_platforms().iter().all(|p| p.name != "Cloud+H100"));
        assert!(by_name("cloud+h100").is_err());
        assert!(by_name("h100").is_err());
    }

    #[test]
    fn pim_platforms_have_pim() {
        assert!(orin_pim().mem.pim.is_some());
        assert!(thor_pim().mem.pim.is_some());
        assert!(orin_gddr7().mem.pim.is_none());
    }
}
