//! Numeric datatypes used by the workload IR and the cost models.

/// Element datatype of an operator's operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    BF16,
    F16,
    F32,
    I8,
}

impl DType {
    /// Bytes per element.
    pub fn bytes(self) -> f64 {
        match self {
            DType::BF16 | DType::F16 => 2.0,
            DType::F32 => 4.0,
            DType::I8 => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::F32 => "f32",
            DType::I8 => "i8",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::BF16.bytes(), 2.0);
        assert_eq!(DType::F32.bytes(), 4.0);
        assert_eq!(DType::I8.bytes(), 1.0);
        assert_eq!(DType::BF16.to_string(), "bf16");
    }
}
