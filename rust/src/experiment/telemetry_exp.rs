//! The `telemetry` experiment: trace a policy-rich fleet run, replay its
//! own event stream, and prove the stream is a faithful record.
//!
//! Four contracts, each a report check:
//!
//! - **TL1 replay**: folding the event stream back through
//!   `telemetry::replay` reconstructs the live [`FleetReport`] bitwise —
//!   every count, every p99 bit.
//! - **TL2 conservation**: counting raw event kinds alone (no replay
//!   machinery) balances `arrival == dispatch + drop + reject` and matches
//!   the `run_end` summary.
//! - **TL3 wire round-trip**: serializing every event to its NDJSON line
//!   and parsing it back is the identity, and the re-parsed stream still
//!   replays bitwise.
//! - **TL4 events-off**: the untraced `run()` (NullSink) returns a report
//!   bitwise-identical to the traced run — telemetry costs nothing when
//!   it is off.
//!
//! Reported: event counts by kind, the per-phase spans of one control
//! step, the queueing-latency summary recovered *from the stream*, and
//! the events-on wall-clock overhead (`bench_fleet` gates the same number
//! in `BENCH_fleet.json`).

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::experiments::slug;
use super::{ExpContext, Experiment, Report};
use crate::engine::shard::{ShardModel, ShardService};
use crate::report::checks::Check;
use crate::sim::fleet::{
    AdmissionPolicy, AutoscalerConfig, FleetConfig, FleetSim, SchedulingPolicy, ShardSpec,
};
use crate::sim::scenario::{Evaluator, Scenario};
use crate::telemetry::replay::{replay, report_mismatch};
use crate::telemetry::{Event, RunMeta, VecSink};
use crate::util::table::Table;
use crate::util::units::fmt_time;
use std::time::Instant;

/// Typed telemetry event stream: emit, replay, certify.
pub struct Telemetry;

impl Telemetry {
    /// A policy-rich workload: token-bucket admission, EDF scheduling,
    /// three SLO classes, an autoscaler, and fail-stop failures — so the
    /// stream exercises every event kind the fleet can emit.
    fn config(ctx: &ExpContext) -> FleetConfig {
        let streams = ctx.fleet_streams.clamp(1, 64);
        let rate_hz = ctx.rate_hz.max(0.5);
        let offered = streams as f64 * rate_hz;
        FleetConfig {
            streams,
            rate_hz,
            duration_s: ctx.duration_s.clamp(1.0, 10.0),
            seed: ctx.seed,
            deadline_s: Some(0.4),
            admission: AdmissionPolicy::TokenBucket {
                rate_hz: (0.75 * offered).max(1e-6),
                burst: ctx.token_burst.max(1) as u32,
            },
            scheduling: SchedulingPolicy::Edf,
            slo_deadline_mults: vec![0.5, 1.0, 2.0],
            autoscaler: Some(AutoscalerConfig {
                check_interval_s: 0.25,
                queue_up: ctx.scale_up,
                queue_down: ctx.scale_down,
                p99_up_s: None,
                warmup_s: (ctx.warmup_ms / 1e3).min(0.5),
                min_engines: 1,
                max_engines: ctx.max_engines.clamp(1, 8),
            }),
            failure_rate_hz: if ctx.fail_rate_hz > 0.0 { ctx.fail_rate_hz } else { 0.05 },
        }
    }
}

impl Experiment for Telemetry {
    fn name(&self) -> &'static str {
        "telemetry"
    }

    fn description(&self) -> &'static str {
        "typed event stream: trace a fleet run, replay it bitwise, measure the overhead"
    }

    fn run(&self, ctx: &ExpContext) -> anyhow::Result<Report> {
        let mut options = ctx.options.clone();
        options.decode_stride = options.decode_stride.max(8);
        let scenario = Scenario::baseline();

        // a two-tier fleet lowered from one shared roofline evaluation;
        // the separate evaluator feeds the `cache` preamble snapshot
        let topologies =
            vec![ShardModel::single(), ShardModel { mode: crate::engine::shard::ShardMode::Replicate, engines: 2 }];
        let services: Vec<ShardService> = ShardService::lower_all(
            &ctx.platform,
            &options,
            &ctx.model,
            &ctx.draft,
            &scenario,
            &topologies,
        )?;
        let specs: Vec<ShardSpec> = services.iter().map(|s| s.fleet_spec()).collect();
        let ev = Evaluator::new(&ctx.platform, &options, &ctx.model, &ctx.draft);
        ev.eval(&scenario)?;

        let cfg = Self::config(ctx);
        let meta = RunMeta {
            platform: ctx.platform.name.clone(),
            scenario: scenario.name.clone(),
        };
        let sim = FleetSim::new(cfg, specs)?;

        // events-off pass (NullSink), then the traced pass, both timed
        let t0 = Instant::now();
        let off = sim.run();
        let live_s = t0.elapsed().as_secs_f64();
        let mut sink = VecSink::new();
        let preamble = ev.cache_snapshot(0.0, "lowering");
        sink.events.push(preamble);
        let t1 = Instant::now();
        let live = sim.run_traced(&meta, &mut sink);
        let traced_s = t1.elapsed().as_secs_f64();
        let events = sink.events;

        let mut rep = Report::new(self.name());
        rep.note(format!(
            "traced {} events over {} arrivals of `{}` on {} ({} streams, {:.1} s virtual)",
            events.len(),
            live.arrived,
            ctx.model.name,
            ctx.platform.name,
            live.per_stream_arrived.len(),
            sim_duration(&events),
        ));

        // event counts by kind, in wire order
        let mut ct = Table::new("Event stream composition", &["event", "count"]).left_first();
        let kinds = [
            "cache", "run_start", "arrival", "admit", "reject", "dispatch", "completion",
            "drop", "scale", "failure", "run_end",
        ];
        for k in kinds {
            let n = events.iter().filter(|e| e.kind() == k).count();
            ct.row(vec![k.to_string(), format!("{n}")]);
        }
        rep.push_table(&format!("{}_events", slug(self.name())), ct);

        // the queueing-latency summary as recovered FROM THE STREAM
        let replayed = replay(&events)?;
        let mut lt = Table::new(
            "Latency from the replayed stream",
            &["series", "p50", "p90", "p99", "max"],
        )
        .left_first();
        for (label, s) in [("queue delay", &replayed.queue_delay), ("service", &replayed.service)] {
            lt.row(vec![
                label.to_string(),
                fmt_time(s.p50),
                fmt_time(s.p90),
                fmt_time(s.p99),
                fmt_time(s.max),
            ]);
        }
        rep.push_table(&format!("{}_latency", slug(self.name())), lt);

        // TL1: the replay invariant, bit for bit
        let mismatch = report_mismatch(&live, &replayed);
        rep.checks.push(Check {
            id: "TL1-replay-bitwise",
            claim: "replaying the event stream reconstructs the live report bitwise",
            passed: mismatch.is_none(),
            detail: match &mismatch {
                None => format!("{} events -> identical report", events.len()),
                Some(m) => m.clone(),
            },
        });

        // TL2: conservation from raw event counts alone
        let count = |k: &str| events.iter().filter(|e| e.kind() == k).count();
        let (arrivals, dispatches, drops, rejects) =
            (count("arrival"), count("dispatch"), count("drop"), count("reject"));
        let end_counts = events.iter().rev().find_map(|e| match e {
            Event::RunEnd { info, .. } => {
                Some((info.arrived, info.served, info.dropped, info.rejected))
            }
            _ => None,
        });
        let balanced = arrivals == dispatches + drops + rejects
            && end_counts == Some((arrivals, dispatches, drops, rejects));
        rep.checks.push(Check {
            id: "TL2-stream-conservation",
            claim: "raw event counts balance arrivals == dispatch + drop + reject",
            passed: balanced,
            detail: format!(
                "{arrivals} arrivals vs {dispatches} + {drops} + {rejects} (run_end {end_counts:?})"
            ),
        });

        // TL3: NDJSON wire round-trip is the identity and still replays
        let reparsed: anyhow::Result<Vec<Event>> =
            events.iter().map(|e| Event::parse_line(&e.to_ndjson_line())).collect();
        let tl3 = match reparsed {
            Ok(back) => {
                back == events
                    && replay(&back).map(|r| report_mismatch(&live, &r).is_none()).unwrap_or(false)
            }
            Err(_) => false,
        };
        rep.checks.push(Check {
            id: "TL3-wire-round-trip",
            claim: "every event survives serialize -> parse bitwise and the re-parsed stream replays",
            passed: tl3,
            detail: format!("{} NDJSON lines", events.len()),
        });

        // TL4: with the NullSink the traced path IS the untraced path
        let off_mismatch = report_mismatch(&off, &live);
        rep.checks.push(Check {
            id: "TL4-events-off-bitwise",
            claim: "the untraced run() is bitwise the traced run — telemetry off costs nothing",
            passed: off_mismatch.is_none(),
            detail: match &off_mismatch {
                None => "identical reports".to_string(),
                Some(m) => m.clone(),
            },
        });

        rep.metric("events_total", events.len() as f64);
        rep.metric("events_arrived", live.arrived as f64);
        rep.metric("live_ms", live_s * 1e3);
        rep.metric("traced_ms", traced_s * 1e3);
        if live_s > 0.0 {
            rep.metric("overhead_pct", 100.0 * (traced_s - live_s) / live_s);
        }
        if traced_s > 0.0 {
            rep.metric("events_per_s", events.len() as f64 / traced_s);
        }
        Ok(rep)
    }
}

/// Virtual duration covered by the stream (the `run_end` stamp).
fn sim_duration(events: &[Event]) -> f64 {
    events
        .iter()
        .rev()
        .find_map(|e| match e {
            Event::RunEnd { t, .. } => Some(*t),
            _ => None,
        })
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::ExpContext;

    #[test]
    fn telemetry_experiment_passes_its_own_checks() {
        let mut ctx = ExpContext::default();
        ctx.fleet_streams = 8;
        ctx.duration_s = 3.0;
        let rep = Telemetry.run(&ctx).unwrap();
        assert_eq!(rep.checks.len(), 4);
        for c in &rep.checks {
            assert!(c.passed, "{}: {}", c.id, c.detail);
        }
        assert!(rep.metrics.iter().any(|(k, _)| k == "events_total"));
    }
}
