//! `audit` — the static self-analysis pass as a registry experiment.
//!
//! Loads the real repo tree (found by walking up from the current
//! directory, so it works from the repo root in CI and from `rust/` under
//! `cargo run`/`cargo test`) and runs every [`crate::analysis`] rule over
//! it. Each rule becomes one report [`Check`], so `vla-char audit` exits
//! non-zero on any diagnostic and `scripts/ci.sh` can gate on it exactly
//! like the simulation experiments' acceptance checks. Diagnostics are
//! rendered file/line-anchored in their own table; see `docs/ANALYSIS.md`
//! for the rule catalog and the `audit:allow(<RULE>)` suppression syntax.

use crate::analysis::{self, SourceTree};
use crate::report::checks::Check;
use crate::util::table::Table;

use super::{ExpContext, Experiment, Report};

pub struct Audit;

impl Experiment for Audit {
    fn name(&self) -> &'static str {
        "audit"
    }

    fn description(&self) -> &'static str {
        "static self-audit: pin coverage, doc/wire drift, unit and bench-key lints"
    }

    fn run(&self, _ctx: &ExpContext) -> anyhow::Result<Report> {
        let root = analysis::repo_root()?;
        let tree = SourceTree::load(&root)?;
        let mut rep = Report::new("audit");
        rep.note(format!("audited {} files under {}", tree.len(), root.display()));

        let mut summary =
            Table::new("Audit rules", &["rule", "invariant", "diagnostics", "status"]).left_first();
        let mut details = Table::new("Diagnostics", &["rule", "location", "message"]).left_first();
        let mut total = 0usize;
        for def in analysis::RULES {
            let diags = analysis::run_rule(def, &tree);
            total += diags.len();
            summary.row(vec![
                def.id.to_string(),
                def.claim.to_string(),
                diags.len().to_string(),
                if diags.is_empty() { "ok" } else { "FAIL" }.to_string(),
            ]);
            for d in &diags {
                details.row(vec![
                    d.rule.to_string(),
                    format!("{}:{}", d.file, d.line),
                    d.message.clone(),
                ]);
            }
            let detail = if diags.is_empty() {
                "clean".to_string()
            } else {
                diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
            };
            rep.checks.push(Check {
                id: def.name,
                claim: def.claim,
                passed: diags.is_empty(),
                detail,
            });
        }
        rep.metric("files_scanned", tree.len() as f64);
        rep.metric("diagnostics_total", total as f64);
        rep.push_table("audit-rules", summary);
        if details.n_rows() > 0 {
            rep.push_table("audit-diagnostics", details);
        }
        Ok(rep)
    }
}
