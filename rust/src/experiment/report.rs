//! Structured experiment output.
//!
//! An [`Experiment`](super::Experiment) returns a [`Report`] — owned tables,
//! free-form console notes, paper-shape [`Check`]s, and scalar metrics — and
//! never prints or writes files itself. [`ReportSink`]s decide what a report
//! becomes: console output ([`StdoutSink`]) or a directory of markdown + CSV
//! artifacts ([`DirSink`]). This is what lets `report` be a registry loop
//! instead of a re-implementation of every command.

use crate::report::checks::{render, Check};
use crate::util::table::Table;
use std::path::{Path, PathBuf};

/// One renderable item, in emission order (so stdout interleaves tables and
/// notes exactly as the experiment laid them out).
#[derive(Debug, Clone)]
pub enum Item {
    /// A table plus the file slug its markdown/CSV artifacts are saved under.
    Table(String, Table),
    /// A free-form console block (ASCII bar chart, summary lines, ...).
    Note(String),
}

/// The structured result of one experiment run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// The producing experiment's registry key.
    pub name: String,
    /// Tables and notes, in emission order.
    pub items: Vec<Item>,
    /// Paper-shape acceptance checks evaluated by this experiment.
    pub checks: Vec<Check>,
    /// Machine-readable headline numbers (`metrics.csv` in the report dir).
    pub metrics: Vec<(String, f64)>,
}

impl Report {
    pub fn new(name: &str) -> Report {
        Report { name: name.to_string(), ..Default::default() }
    }

    /// Append a table; `slug` names its `.md`/`.csv` files in a [`DirSink`].
    pub fn push_table(&mut self, slug: &str, table: Table) {
        self.items.push(Item::Table(slug.to_string(), table));
    }

    /// Append a console note (printed verbatim by [`StdoutSink`]).
    pub fn note(&mut self, text: String) {
        self.items.push(Item::Note(text));
    }

    /// Record a scalar metric.
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// The tables in emission order, with their slugs.
    pub fn tables(&self) -> impl Iterator<Item = (&str, &Table)> {
        self.items.iter().filter_map(|i| match i {
            Item::Table(slug, t) => Some((slug.as_str(), t)),
            Item::Note(_) => None,
        })
    }

    /// Did every check pass? (Trivially true for check-free experiments.)
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    /// CLI exit code: 0 when all checks pass, 1 otherwise.
    pub fn exit_code(&self) -> i32 {
        if self.passed() { 0 } else { 1 }
    }
}

/// Where finished reports go.
pub trait ReportSink {
    fn emit(&mut self, report: &Report) -> anyhow::Result<()>;
}

/// Console sink: tables as aligned markdown, notes verbatim, then the check
/// block — the same layout the per-command output always had.
pub struct StdoutSink;

impl ReportSink for StdoutSink {
    fn emit(&mut self, report: &Report) -> anyhow::Result<()> {
        for item in &report.items {
            match item {
                Item::Table(_, t) => println!("{}", t.to_markdown()),
                Item::Note(text) => println!("{text}"),
            }
        }
        if !report.checks.is_empty() {
            let (text, _) = render(&report.checks);
            println!("{text}");
        }
        Ok(())
    }
}

/// Directory sink: every table lands as `<slug>.md` + `<slug>.csv`; checks
/// and metrics are aggregated across all emitted reports and written by
/// [`DirSink::finish`] as `checks.txt` and `metrics.csv`.
pub struct DirSink {
    dir: PathBuf,
    checks: Vec<Check>,
    metrics: Vec<(String, String, f64)>,
}

impl DirSink {
    pub fn new(dir: &Path) -> anyhow::Result<DirSink> {
        std::fs::create_dir_all(dir)?;
        Ok(DirSink { dir: dir.to_path_buf(), checks: Vec::new(), metrics: Vec::new() })
    }

    /// Write the aggregated `checks.txt` and `metrics.csv`; returns the
    /// rendered check block and whether every aggregated check passed.
    pub fn finish(self) -> anyhow::Result<(String, bool)> {
        let (text, ok) = render(&self.checks);
        std::fs::write(self.dir.join("checks.txt"), &text)?;
        let mut m = Table::new("", &["experiment", "metric", "value"]);
        for (exp, key, v) in &self.metrics {
            m.row(vec![exp.clone(), key.clone(), format!("{v}")]);
        }
        std::fs::write(self.dir.join("metrics.csv"), m.to_csv())?;
        Ok((text, ok))
    }
}

impl ReportSink for DirSink {
    fn emit(&mut self, report: &Report) -> anyhow::Result<()> {
        for (slug, t) in report.tables() {
            t.save(&self.dir, slug)?;
        }
        self.checks.extend(report.checks.iter().cloned());
        for (k, v) in &report.metrics {
            self.metrics.push((report.name.clone(), k.clone(), *v));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> Report {
        let mut t = Table::new("T", &["k", "v"]).left_first();
        t.row(vec!["a".into(), "1".into()]);
        let mut rep = Report::new("sample");
        rep.push_table("sample_t", t);
        rep.note("a note".to_string());
        rep.metric("answer", 42.0);
        rep
    }

    #[test]
    fn exit_code_follows_checks() {
        let mut rep = sample_report();
        assert!(rep.passed());
        assert_eq!(rep.exit_code(), 0);
        rep.checks.push(Check { id: "x", claim: "c", passed: false, detail: String::new() });
        assert!(!rep.passed());
        assert_eq!(rep.exit_code(), 1);
    }

    #[test]
    fn tables_iterator_skips_notes() {
        let rep = sample_report();
        let slugs: Vec<&str> = rep.tables().map(|(s, _)| s).collect();
        assert_eq!(slugs, vec!["sample_t"]);
    }

    #[test]
    fn dir_sink_writes_tables_checks_metrics() {
        let dir = std::env::temp_dir().join("vla_char_dir_sink_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let mut sink = DirSink::new(&dir).unwrap();
        sink.emit(&sample_report()).unwrap();
        let (text, ok) = sink.finish().unwrap();
        assert!(ok && text.is_empty());
        assert!(dir.join("sample_t.md").exists() && dir.join("sample_t.csv").exists());
        assert!(dir.join("checks.txt").exists());
        let metrics = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
        assert!(metrics.contains("sample,answer,42"));
    }
}
