//! The `fleet` experiment: discrete-event fleet serving at scale.
//!
//! Elevates the `serve` topology sweep into ONE heterogeneous fleet: every
//! `--shard-mode` x `--shards` topology is lowered (from one shared
//! roofline evaluation) into a [`ShardSpec`] lane group, and the whole
//! fleet serves `--fleet-streams` Poisson robot streams through the
//! [`FleetSim`] discrete-event engine — admission x scheduling policy
//! grid, SLO classes, autoscaling, and fail-stop failure injection, all
//! without a PJRT runtime.
//!
//! Reported: the lowered fleet composition, the per-policy serving matrix
//! (p50/p99 queueing delay, miss/loss rates, aggregate actions/s,
//! J/action, peak engines), and an elasticity table (static vs autoscaled
//! vs autoscaled under failures). Checks pin the simulator's contracts:
//! conservation `arrived == served + dropped + rejected` on every row, the
//! degenerate single-shard fleet bitwise equal to `run_shard_batcher`, EDF
//! never worse than FIFO on miss rate at saturation, and the autoscaler
//! reacting to overload within its engine bound.

// Numeric casts in this module predate the workspace-level
// `cast_possible_truncation`/`cast_lossless` denies and are deliberate
// (indices, bit packing, display rounding); new code converts
// explicitly (`u64::from`, `try_into`) instead of widening this allow.
#![allow(clippy::cast_possible_truncation, clippy::cast_lossless)]

use super::experiments::slug;
use super::{ExpContext, Experiment, Report, Serve};
use crate::engine::shard::{run_shard_batcher, ShardModel, ShardService, SimStepServer};
use crate::engine::{BatcherConfig, Policy};
use crate::model::Phase;
use crate::report::checks::Check;
use crate::sim::fleet::{
    AdmissionPolicy, AutoscalerConfig, FleetConfig, FleetReport, FleetSim, SchedulingPolicy,
    ShardSpec,
};
use crate::sim::scenario::{Evaluator, Scenario};
use crate::sim::simulator::{SimOptions, Simulator};
use crate::sim::sweep;
use crate::telemetry::replay::{replay_ndjson, report_mismatch};
use crate::telemetry::{Event, EventSink, NdjsonSink, RunMeta};
use crate::util::table::Table;
use crate::util::units::fmt_time;

/// Fleet-scale discrete-event serving, simulator-backed.
pub struct Fleet;

/// One policy-grid cell.
struct Cell {
    admission: AdmissionPolicy,
    scheduling: SchedulingPolicy,
}

impl Fleet {
    /// Admission policies of the grid: `--admission all` sweeps the three
    /// families; a named family runs alone. The token bucket defaults to
    /// metering half the offered load when `--token-rate` is unset.
    fn admissions(ctx: &ExpContext) -> anyhow::Result<Vec<AdmissionPolicy>> {
        let offered = ctx.fleet_streams as f64 * ctx.rate_hz;
        let token_rate =
            if ctx.token_rate_hz > 0.0 { ctx.token_rate_hz } else { (0.5 * offered).max(1e-6) };
        let burst = ctx.token_burst.max(1) as u32;
        if ctx.admission == "all" {
            Ok(vec![
                AdmissionPolicy::DropOnDeadline,
                AdmissionPolicy::TokenBucket { rate_hz: token_rate, burst },
                AdmissionPolicy::SloPriority { depth_limit: ctx.slo_depth },
            ])
        } else {
            Ok(vec![AdmissionPolicy::parse(&ctx.admission, token_rate, burst, ctx.slo_depth)?])
        }
    }

    /// Scheduling policies of the grid (`--scheduling all` sweeps all four).
    fn schedulings(ctx: &ExpContext) -> anyhow::Result<Vec<SchedulingPolicy>> {
        if ctx.scheduling == "all" {
            Ok(vec![
                SchedulingPolicy::EarliestFree,
                SchedulingPolicy::RoundRobin,
                SchedulingPolicy::LeastLoaded,
                SchedulingPolicy::Edf,
            ])
        } else {
            Ok(vec![SchedulingPolicy::parse(&ctx.scheduling)?])
        }
    }

    /// The shared fleet workload under one (admission, scheduling) choice.
    fn fleet_config(
        ctx: &ExpContext,
        admission: AdmissionPolicy,
        scheduling: SchedulingPolicy,
        autoscaler: Option<AutoscalerConfig>,
        failure_rate_hz: f64,
    ) -> FleetConfig {
        FleetConfig {
            streams: ctx.fleet_streams,
            rate_hz: ctx.rate_hz,
            duration_s: ctx.duration_s,
            seed: ctx.seed,
            deadline_s: if ctx.deadline_ms > 0.0 { Some(ctx.deadline_ms / 1e3) } else { None },
            admission,
            scheduling,
            slo_deadline_mults: ctx.slo_mults.clone(),
            autoscaler,
            failure_rate_hz,
        }
    }

    /// Autoscaler thresholds from the CLI flags.
    fn autoscaler(ctx: &ExpContext) -> AutoscalerConfig {
        AutoscalerConfig {
            check_interval_s: 0.25,
            queue_up: ctx.scale_up,
            queue_down: ctx.scale_down,
            p99_up_s: None,
            warmup_s: ctx.warmup_ms / 1e3,
            min_engines: 1,
            max_engines: ctx.max_engines.max(1),
        }
    }

    /// The NDJSON preamble stamped before `run_start`: the lowering-cache
    /// counter snapshot (label `lowering`) plus the per-phase spans of one
    /// control step on the focus platform. Span timestamps are relative to
    /// the start of the step, not the fleet clock — they precede the run
    /// frame precisely so the in-run monotonicity contract stays intact.
    fn preamble(
        ctx: &ExpContext,
        options: &SimOptions,
        scenario: &Scenario,
    ) -> anyhow::Result<Vec<Event>> {
        let ev = Evaluator::new(&ctx.platform, options, &ctx.model, &ctx.draft);
        ev.eval(scenario)?;
        let mut events = vec![ev.cache_snapshot(0.0, "lowering")];
        let sim = Simulator::with_options(ctx.platform.clone(), options.clone());
        let res = sim.simulate_vla(&ctx.model);
        let mut t = 0.0;
        for (phase, stage) in [
            (Phase::Vision, &res.vision),
            (Phase::Prefill, &res.prefill),
            (Phase::Decode, &res.decode),
            (Phase::Action, &res.action),
        ] {
            events.push(Event::PhaseSpan { t, phase, dur_s: stage.time });
            t += stage.time;
        }
        Ok(events)
    }

    /// `--events PATH` / `--daemon`: ONE traced fleet run (first admission
    /// x first scheduling of the grid, autoscaled, `--fail-rate` failures)
    /// streamed as NDJSON instead of the full policy sweep.
    ///
    /// File mode re-reads the stream and replays it, proving it
    /// reconstructs the live report bitwise. Stdout mode (`--events -` or
    /// `--daemon`, line-buffered) keeps stdout pure NDJSON for downstream
    /// consumers — the returned report is empty, so the CLI prints nothing
    /// after the stream.
    fn run_streaming(
        &self,
        ctx: &ExpContext,
        options: &SimOptions,
        scenario: &Scenario,
        specs: Vec<ShardSpec>,
    ) -> anyhow::Result<Report> {
        let admission = Self::admissions(ctx)?[0];
        let scheduling = Self::schedulings(ctx)?[0];
        let cfg = Self::fleet_config(
            ctx,
            admission,
            scheduling,
            Some(Self::autoscaler(ctx)),
            ctx.fail_rate_hz,
        );
        let meta = RunMeta {
            platform: ctx.platform.name.clone(),
            scenario: scenario.name.clone(),
        };
        let preamble = Self::preamble(ctx, options, scenario)?;
        let sim = FleetSim::new(cfg, specs)?;

        let to_stdout = ctx.daemon || ctx.events.as_deref() == Some("-");
        if to_stdout {
            let mut sink = NdjsonSink::stdout();
            for e in &preamble {
                sink.emit(e);
            }
            sim.run_traced(&meta, &mut sink);
            sink.finish()
                .map_err(|e| anyhow::anyhow!("telemetry stream to stdout failed: {e}"))?;
            // pure-NDJSON stdout: nothing to render after the stream
            return Ok(Report::new(self.name()));
        }

        let path = ctx.events.clone().expect("run_streaming without --events/--daemon");
        let mut sink = NdjsonSink::create(&path)
            .map_err(|e| anyhow::anyhow!("cannot create event stream {path}: {e}"))?;
        for e in &preamble {
            sink.emit(e);
        }
        let live = sim.run_traced(&meta, &mut sink);
        let lines = sink
            .finish()
            .map_err(|e| anyhow::anyhow!("telemetry stream to {path} failed: {e}"))?;

        // the stream certifies itself: read it back and replay it
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot re-read event stream {path}: {e}"))?;
        let replayed = replay_ndjson(&text)?;
        let mismatch = report_mismatch(&live, &replayed);

        let mut rep = Report::new(self.name());
        rep.note(format!(
            "streamed {lines} events ({} {} + autoscaler, fail rate {} Hz) to {path}",
            admission.label(),
            scheduling.label(),
            ctx.fail_rate_hz,
        ));
        rep.metric("events_lines", lines as f64);
        rep.metric("events_served", live.served as f64);
        rep.checks.push(Check {
            id: "FL5-events-replay",
            claim: "replaying the written NDJSON stream reconstructs the live report bitwise",
            passed: mismatch.is_none(),
            detail: match mismatch {
                None => format!("{lines} events -> identical report ({} served)", live.served),
                Some(m) => m,
            },
        });
        Ok(rep)
    }
}

impl Experiment for Fleet {
    fn name(&self) -> &'static str {
        "fleet"
    }

    fn description(&self) -> &'static str {
        "discrete-event fleet serving: admission x scheduling grid, autoscaling, failures"
    }

    fn run(&self, ctx: &ExpContext) -> anyhow::Result<Report> {
        anyhow::ensure!(ctx.rate_hz > 0.0, "`fleet` needs a positive --rate");
        anyhow::ensure!(ctx.fleet_streams >= 1, "`fleet` needs at least one stream");
        let mut options = ctx.options.clone();
        options.decode_stride = options.decode_stride.max(8);
        let scenario = Scenario::baseline();

        // ONE heterogeneous fleet out of the whole serve topology sweep,
        // lowered from one shared roofline evaluation
        let topologies = Serve::topologies(ctx);
        let services: Vec<ShardService> = ShardService::lower_all(
            &ctx.platform,
            &options,
            &ctx.model,
            &ctx.draft,
            &scenario,
            &topologies,
        )?;
        let specs: Vec<ShardSpec> = services.iter().map(|s| s.fleet_spec()).collect();
        let static_engines: usize = specs.iter().map(|s| s.lanes).sum();

        // telemetry streaming mode replaces the policy sweep entirely
        if ctx.daemon || ctx.events.is_some() {
            return self.run_streaming(ctx, &options, &scenario, specs);
        }

        let mut rep = Report::new(self.name());
        rep.note(format!(
            "fleet of {} static engines ({} shard specs) serving {} streams x {:.2} Hz for \
             {:.1} s of `{}` on {}",
            static_engines,
            specs.len(),
            ctx.fleet_streams,
            ctx.rate_hz,
            ctx.duration_s,
            ctx.model.name,
            ctx.platform.name
        ));

        // fleet composition: the lowered shard lane groups
        let mut ft = Table::new(
            &format!("Fleet composition ({} on {})", ctx.model.name, ctx.platform.name),
            &["shard", "lanes", "step (s)", "act/step", "J/action"],
        )
        .left_first();
        for s in &specs {
            ft.row(vec![
                s.label.clone(),
                format!("{}", s.lanes),
                format!("{:.3}", s.step_s),
                format!("{:.0}", s.actions_per_step),
                format!("{:.2}", s.j_per_action),
            ]);
        }
        rep.push_table(&format!("{}_composition", slug(self.name())), ft);

        // the admission x scheduling policy grid, swept on the worker pool
        // (every fleet run is bitwise-deterministic, so the parallel sweep
        // matches the serial one — pinned by the integration tests)
        let admissions = Self::admissions(ctx)?;
        let schedulings = Self::schedulings(ctx)?;
        let mut cells: Vec<Cell> = Vec::new();
        for &admission in &admissions {
            for &scheduling in &schedulings {
                cells.push(Cell { admission, scheduling });
            }
        }
        let reports: Vec<FleetReport> = sweep::parallel_map(&cells, |c| {
            let cfg = Self::fleet_config(ctx, c.admission, c.scheduling, None, ctx.fail_rate_hz);
            FleetSim::new(cfg, specs.clone()).map(|sim| sim.run())
        })
        .into_iter()
        .collect::<anyhow::Result<Vec<_>>>()?;

        let mut pt = Table::new(
            &format!("Fleet policy matrix ({} cells)", cells.len()),
            &[
                "admission", "scheduling", "arrived", "served", "miss", "loss", "delay p50",
                "delay p99", "agg act/s", "J/action", "peak",
            ],
        )
        .left_first();
        for (c, r) in cells.iter().zip(&reports) {
            pt.row(vec![
                c.admission.label(),
                c.scheduling.label().to_string(),
                format!("{}", r.arrived),
                format!("{}", r.served),
                format!("{:.0}%", 100.0 * r.miss_rate()),
                format!("{:.0}%", 100.0 * r.loss_rate()),
                fmt_time(r.queue_delay.p50),
                fmt_time(r.queue_delay.p99),
                format!("{:.3}", r.agg_actions_s),
                format!("{:.2}", r.j_per_action),
                format!("{}", r.peak_engines),
            ]);
        }
        rep.push_table(&format!("{}_policies", slug(self.name())), pt);

        // elasticity: one elastic-tier engine (spec 0), static vs
        // autoscaled vs autoscaled under fail-stop failures
        let auto = Self::autoscaler(ctx);
        let elastic = vec![specs[0].clone()];
        let drop_ef = |autoscaler: Option<AutoscalerConfig>, failure_rate_hz: f64| {
            Self::fleet_config(
                ctx,
                AdmissionPolicy::DropOnDeadline,
                SchedulingPolicy::EarliestFree,
                autoscaler,
                failure_rate_hz,
            )
        };
        let fixed = FleetSim::new(drop_ef(None, 0.0), elastic.clone())?.run();
        let scaled = FleetSim::new(drop_ef(Some(auto.clone()), 0.0), elastic.clone())?.run();
        let fail_rate = if ctx.fail_rate_hz > 0.0 { ctx.fail_rate_hz } else { 0.05 };
        let failed = FleetSim::new(drop_ef(Some(auto.clone()), fail_rate), elastic)?.run();

        let mut et = Table::new(
            &format!("Elasticity on one `{}` tier", specs[0].label),
            &["fleet", "peak", "ups", "downs", "failures", "delay p99", "miss", "act/s"],
        )
        .left_first();
        for (label, r) in
            [("static", &fixed), ("autoscaled", &scaled), ("autoscaled+failures", &failed)]
        {
            et.row(vec![
                label.to_string(),
                format!("{}", r.peak_engines),
                format!("{}", r.scale_ups),
                format!("{}", r.scale_downs),
                format!("{}", r.failures),
                fmt_time(r.queue_delay.p99),
                format!("{:.0}%", 100.0 * r.miss_rate()),
                format!("{:.3}", r.agg_actions_s),
            ]);
        }
        rep.push_table(&format!("{}_elasticity", slug(self.name())), et);

        let all_rows: Vec<&FleetReport> =
            reports.iter().chain([&fixed, &scaled, &failed]).collect();
        let best = reports
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.agg_actions_s.total_cmp(&b.1.agg_actions_s))
            .map(|(i, _)| i)
            .unwrap();
        rep.note(format!(
            "best policy cell: {} + {} -> {:.3} aggregate actions/s (loss {:.0}%)",
            cells[best].admission.label(),
            cells[best].scheduling.label(),
            reports[best].agg_actions_s,
            100.0 * reports[best].loss_rate()
        ));
        rep.metric("cells", cells.len() as f64);
        rep.metric("static_engines", static_engines as f64);
        rep.metric("best_agg_actions_s", reports[best].agg_actions_s);
        rep.metric("loss_rate_max", reports.iter().map(|r| r.loss_rate()).fold(0.0, f64::max));
        let peak_max = all_rows.iter().map(|r| r.peak_engines).max().unwrap_or(0);
        rep.metric("peak_engines_max", peak_max as f64);

        // FL1: conservation on every row (policy grid + elasticity)
        let conserved = all_rows.iter().all(|r| r.conserves());
        rep.checks.push(Check {
            id: "FL1-conservation",
            claim: "every arrival is served, deadline-dropped, or admission-rejected",
            passed: conserved,
            detail: format!(
                "{} arrivals across {} rows",
                all_rows.iter().map(|r| r.arrived).sum::<usize>(),
                all_rows.len()
            ),
        });

        // FL2: the degenerate single-shard fleet (1 lane, no autoscaler, no
        // failures, drop-on-deadline, one SLO class) is bitwise the sharded
        // batcher serving the same lowered scenario
        let single = match services.iter().find(|s| s.model.engines == 1) {
            Some(s) => s.clone(),
            None => ShardService::lower(
                &ctx.platform,
                &options,
                &ctx.model,
                &ctx.draft,
                &scenario,
                ShardModel::single(),
            )?,
        };
        let deadline_s = if ctx.deadline_ms > 0.0 { Some(ctx.deadline_ms / 1e3) } else { None };
        let bcfg = BatcherConfig {
            streams: ctx.fleet_streams,
            rate_hz: ctx.rate_hz,
            duration_s: ctx.duration_s,
            policy: match ctx.policy.as_str() {
                "fifo" => Policy::Fifo,
                _ => Policy::RoundRobin,
            },
            seed: ctx.seed,
            deadline_s,
        };
        let mut server = SimStepServer::for_service(&single);
        let legacy = run_shard_batcher(&mut server, 2, 2, &[1, 2, 3], &bcfg, &single.model)?;
        let dcfg = FleetConfig {
            streams: ctx.fleet_streams,
            rate_hz: ctx.rate_hz,
            duration_s: ctx.duration_s,
            seed: ctx.seed,
            deadline_s,
            admission: AdmissionPolicy::DropOnDeadline,
            scheduling: match bcfg.policy {
                Policy::Fifo => SchedulingPolicy::EarliestFree,
                Policy::RoundRobin => SchedulingPolicy::RoundRobin,
            },
            slo_deadline_mults: vec![1.0],
            autoscaler: None,
            failure_rate_hz: 0.0,
        };
        let degen = FleetSim::new(dcfg, vec![single.fleet_spec()])?.run();
        let bitwise = degen.arrived == legacy.arrived
            && degen.served == legacy.served
            && degen.dropped == legacy.dropped
            && degen.rejected == 0
            && degen.throughput.to_bits() == legacy.throughput.to_bits()
            && degen.queue_delay.p50.to_bits() == legacy.queue_delay.p50.to_bits()
            && degen.queue_delay.p99.to_bits() == legacy.queue_delay.p99.to_bits()
            && degen.per_stream_served == legacy.per_stream_served
            && degen.per_stream_dropped == legacy.per_stream_dropped
            && degen.max_burst == legacy.max_burst;
        rep.checks.push(Check {
            id: "FL2-degenerate-bitwise",
            claim: "a 1-shard fleet with legacy policies is bitwise run_shard_batcher",
            passed: bitwise,
            detail: format!(
                "served {} vs {}, throughput {:.4} vs {:.4} req/s",
                degen.served, legacy.served, degen.throughput, legacy.throughput
            ),
        });

        // FL3: EDF never worse than FIFO on miss rate at saturation. The
        // probe scales the validated saturation shape (8 streams at 1.2
        // erlangs offered, deadline 1.2x the step, a 16:1 SLO deadline
        // spread) to the lowered step time, clamped away from the ns
        // quantization grid and from hour-long virtual traces.
        let probe_step = specs[0].step_s.clamp(1e-3, 10.0);
        let probe = ShardSpec {
            label: "edf-probe".into(),
            lanes: 1,
            step_s: probe_step,
            actions_per_step: specs[0].actions_per_step,
            j_per_action: specs[0].j_per_action,
        };
        let saturated = |scheduling| -> anyhow::Result<FleetReport> {
            let cfg = FleetConfig {
                streams: 8,
                rate_hz: 1.2 / (8.0 * probe_step),
                duration_s: 100.0 * probe_step,
                seed: 71,
                deadline_s: Some(1.2 * probe_step),
                admission: AdmissionPolicy::DropOnDeadline,
                scheduling,
                slo_deadline_mults: vec![0.25, 1.0, 4.0],
                autoscaler: None,
                failure_rate_hz: 0.0,
            };
            Ok(FleetSim::new(cfg, vec![probe.clone()])?.run())
        };
        let fifo = saturated(SchedulingPolicy::EarliestFree)?;
        let edf = saturated(SchedulingPolicy::Edf)?;
        rep.checks.push(Check {
            id: "FL3-edf-at-saturation",
            claim: "EDF never misses more than FIFO on a saturated single-lane probe",
            passed: fifo.dropped > 0
                && edf.miss_rate() <= fifo.miss_rate() + 1e-12
                && fifo.conserves()
                && edf.conserves(),
            detail: format!(
                "miss {:.1}% (edf) vs {:.1}% (fifo), {} arrivals",
                100.0 * edf.miss_rate(),
                100.0 * fifo.miss_rate(),
                fifo.arrived
            ),
        });

        // FL4: the autoscaler reacts to overload on the elastic tier and
        // stays within its engine bound (failures ride the same machinery:
        // the min-engine floor is the failover path)
        let offered = ctx.fleet_streams as f64 * ctx.rate_hz;
        let tier_capacity = 1.0 / specs[0].step_s.max(1e-30);
        let overloaded = offered > 1.5 * tier_capacity;
        let bounded =
            scaled.peak_engines <= auto.max_engines && failed.peak_engines <= auto.max_engines;
        let reacted = scaled.scale_ups > 0
            && scaled.peak_engines > 1
            && scaled.loss_rate() <= fixed.loss_rate() + 1e-12;
        rep.checks.push(Check {
            id: "FL4-autoscaler",
            claim: "the autoscaler reacts to overload within its max-engine bound",
            passed: bounded && (!overloaded || reacted),
            detail: format!(
                "offered {:.1}/s vs tier {:.2}/s; peak {} (max {}), {} ups, {} failures",
                offered,
                tier_capacity,
                scaled.peak_engines,
                auto.max_engines,
                scaled.scale_ups,
                failed.failures
            ),
        });

        Ok(rep)
    }
}
