//! Typed experiment API.
//!
//! The paper's contribution is a *matrix* of experiments — phase
//! characterization (Fig 2), scaling projections (Fig 3), ablations,
//! co-design, energy. This module makes each of them a value:
//!
//! - [`Experiment`]: a named, registry-discoverable unit of work that
//!   consumes an [`ExpContext`] and returns a structured [`Report`];
//! - [`ExpContext`]: `SimOptions` + resolved platform/model/size sets,
//!   built once from the parsed CLI args instead of re-parsed per command;
//! - [`Report`] + [`ReportSink`]: owned tables/checks/metrics with
//!   pluggable markdown/CSV/stdout rendering;
//! - [`REGISTRY`]: the static list the CLI dispatches on and `report`
//!   loops over (in parallel, on the `sim::sweep` worker pool).
//!
//! Adding an experiment = implement the trait on a unit struct and add it
//! to [`REGISTRY`]; it immediately appears in `--help`, gains a CLI
//! subcommand, and is included in `report` output.

mod audit_exp;
mod context;
mod engine_exps;
mod experiments;
mod fleet_exp;
mod offload_exp;
mod report;
mod serve_exp;
mod telemetry_exp;

pub use audit_exp::Audit;
pub use context::ExpContext;
pub use engine_exps::{ControlLoop, StepOnce, Validate};
pub use experiments::{Ablate, Batch, Characterize, Codesign, Energy, PimScenarios, Project, Table1};
pub use fleet_exp::Fleet;
pub use offload_exp::Offload;
pub use report::{DirSink, Item, Report, ReportSink, StdoutSink};
pub use serve_exp::Serve;
pub use telemetry_exp::Telemetry;

/// A named experiment producing a structured report.
pub trait Experiment: Sync {
    /// Registry key; doubles as the CLI subcommand name.
    fn name(&self) -> &'static str;
    /// One-line help text (shown in `--help` and the README table).
    fn description(&self) -> &'static str;
    /// Run against a resolved context.
    fn run(&self, ctx: &ExpContext) -> anyhow::Result<Report>;
}

/// Every registered experiment, in help/report order: the simulator-backed
/// paper artifacts first, then the engine-backed (PJRT) flows, which report
/// "skipped: no PJRT runtime" where no real runtime is available. `serve`
/// and `fleet` are simulator-backed since the shard model landed — they
/// run everywhere.
pub static REGISTRY: &[&dyn Experiment] = &[
    &Table1,
    &Characterize,
    &Project,
    &Ablate,
    &Codesign,
    &PimScenarios,
    &Offload,
    &Energy,
    &Batch,
    &StepOnce,
    &ControlLoop,
    &Serve,
    &Fleet,
    &Telemetry,
    &Validate,
    &Audit,
];

/// The experiment registry.
pub fn registry() -> &'static [&'static dyn Experiment] {
    REGISTRY
}

/// Look up an experiment by its registry key.
pub fn by_name(name: &str) -> Option<&'static dyn Experiment> {
    REGISTRY.iter().copied().find(|e| e.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_keys_unique_and_resolvable() {
        let mut names: Vec<&str> = registry().iter().map(|e| e.name()).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "registry keys must be unique");
        for e in registry() {
            assert_eq!(by_name(e.name()).unwrap().name(), e.name());
            assert!(!e.description().is_empty());
        }
        assert!(by_name("frobnicate").is_none());
    }
}
